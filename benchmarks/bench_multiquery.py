"""Multi-query wave amortization (the serving workload, §3.4/§5).

A1's throughput headline comes from amortizing operator waves across many
concurrent queries.  This suite runs a *heterogeneous* query mix (different
hop counts, directions, filters — so the per-plan fast path can't apply)
through ``run_queries_batched`` at batch sizes 1/8/64 and reports per-query
latency.  The amortization claim is that batch-64 per-query latency lands
well under batch-1; ``tests/test_planner.py::test_amortization_gate``
enforces the <= 0.5x gate on the ref backend, while the ``derived`` field
records the measured speedup so the BENCH_*.json trajectory keeps it
observable across commits.
"""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.query.executor import QueryCaps
from repro.core.query.planner import run_queries_batched
from repro.data.kg import build_film_kg

CAPS = QueryCaps(frontier=128, expand=512, results=16)

BATCHES = (1, 8, 64)


def q_2hop(did):
    return {"type": "director", "id": int(did),
            "_out_edge": {"type": "film.director",
                          "_target": {"type": "film",
                                      "_out_edge": {"type": "film.actor",
                                                    "_target": {
                                                        "type": "actor",
                                                        "select": "count"}}}}}


def q_rev(aid):
    return {"type": "actor", "id": int(aid),
            "_in_edge": {"type": "film.actor",
                         "_target": {"type": "film", "select": "count"}}}


def q_filtered(did, genre):
    return {"type": "director", "id": int(did),
            "_out_edge": {"type": "film.director",
                          "_target": {"type": "film",
                                      "filter": {"attr": "genre", "op": "==",
                                                 "value": int(genre)},
                                      "_out_edge": {"type": "film.actor",
                                                    "_target": {
                                                        "type": "actor",
                                                        "select": "count"}}}}}


def make_batch(kg, rng, b: int) -> list[dict]:
    """Heterogeneous mix: cycle three plan shapes with random keys."""
    out = []
    for i in range(b):
        kind = i % 3
        if kind == 0:
            out.append(q_2hop(rng.choice(kg.director_keys)))
        elif kind == 1:
            out.append(q_rev(rng.choice(kg.actor_keys[:100])))
        else:
            out.append(q_filtered(rng.choice(kg.director_keys),
                                  rng.integers(kg.n_genres)))
    return out


def run(kg=None):
    kg = kg or build_film_kg(n_films=150, n_actors=200, n_directors=30)
    db = kg.db
    rng = np.random.default_rng(0)
    per_q = {}
    for b in BATCHES:
        queries = make_batch(kg, rng, b)
        avg, p99, _ = timeit(lambda: run_queries_batched(db, queries, CAPS),
                             warmup=2, iters=10)
        per_q[b] = avg / b * 1e6
        speedup = per_q[BATCHES[0]] / per_q[b]
        emit(f"multiquery_b{b}", per_q[b],
             f"batch={b};avg_ms={avg*1e3:.2f};p99_ms={p99*1e3:.2f};"
             f"perq_speedup_vs_b1={speedup:.2f}x")
    return db


if __name__ == "__main__":
    run()
