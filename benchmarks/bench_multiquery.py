"""Multi-query wave amortization (the serving workload, §3.4/§5).

A1's throughput headline comes from amortizing operator waves across many
concurrent queries.  This suite runs a *heterogeneous* query mix (different
hop counts, directions, filters — so the per-plan fast path can't apply)
through the fused-wave path (``GraphDB.query(..., fused=True)``) at batch
sizes 1/8/64 and reports per-query latency, plus star-pattern and mixed
chain+star batches (fused into the same waves since A1QL v2), plus the
**shared-frontier** mode (``budget="shared"``) at batch 64/256 — the
serving-cap memory shape, whose rows stamp the measured peak frontier
bytes per mode into the derived metadata (the O(F*sqrt(Q))-vs-O(F*Q)
claim stays observable across commits).  The amortization claim is that
batch-64 per-query latency lands well under batch-1;
``tests/test_planner.py::test_amortization_gate`` (and its ``_with_stars``
twin) enforce the <= 0.5x gate on the ref backend, while the ``derived``
field records the measured speedup so the BENCH_*.json trajectory keeps it
observable across commits.
"""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.query.executor import QueryCaps
from repro.data.kg import build_film_kg

CAPS = QueryCaps(frontier=128, expand=512, results=16)

BATCHES = (1, 8, 64)
STAR_BATCHES = (8,)
MIXED_BATCHES = (8, 32)
SHARED_BATCHES = (64, 256)


def q_2hop(did):
    return {"type": "director", "id": int(did),
            "_out_edge": {"type": "film.director",
                          "_target": {"type": "film",
                                      "_out_edge": {"type": "film.actor",
                                                    "_target": {
                                                        "type": "actor",
                                                        "select": "count"}}}}}


def q_rev(aid):
    return {"type": "actor", "id": int(aid),
            "_in_edge": {"type": "film.actor",
                         "_target": {"type": "film", "select": "count"}}}


def q_filtered(did, genre):
    return {"type": "director", "id": int(did),
            "_out_edge": {"type": "film.director",
                          "_target": {"type": "film",
                                      "filter": {"attr": "genre", "op": "==",
                                                 "value": int(genre)},
                                      "_out_edge": {"type": "film.actor",
                                                    "_target": {
                                                        "type": "actor",
                                                        "select": "count"}}}}}


def q_star(did, aid):
    """Star pattern (paper Q3): films by director X AND starring actor Y."""
    return {"intersect": [
        {"type": "director", "id": int(did),
         "_out_edge": {"type": "film.director", "_target": {"type": "film"}}},
        {"type": "actor", "id": int(aid),
         "_in_edge": {"type": "film.actor", "_target": {"type": "film"}}}],
        "select": "count"}


def make_batch(kg, rng, b: int, mix=("2hop", "rev", "filtered")) -> list:
    """Heterogeneous mix: cycle plan shapes with random keys."""
    out = []
    for i in range(b):
        kind = mix[i % len(mix)]
        if kind == "2hop":
            out.append(q_2hop(rng.choice(kg.director_keys)))
        elif kind == "rev":
            out.append(q_rev(rng.choice(kg.actor_keys[:100])))
        elif kind == "star":
            out.append(q_star(rng.choice(kg.director_keys),
                              rng.choice(kg.actor_keys[:100])))
        else:
            out.append(q_filtered(rng.choice(kg.director_keys),
                                  rng.integers(kg.n_genres)))
    return out


def _frontier_meta():
    """Peak frontier bytes per budget mode, from the planner's counters."""
    from repro.core.query import planner
    fs = planner.FRONTIER_STATS
    cs = planner.CACHE_STATS
    total = cs["hits"] + cs["misses"]
    hit = cs["hits"] / total if total else 0.0
    return (f"peak_frontier_perq_B={fs['per_query_peak_bytes']}"
            f";peak_frontier_shared_B={fs['shared_peak_bytes']}"
            f";planner_cache_hit_rate={hit:.3f}")


def _bench(db, name, queries, b, base_us=None, budget=None):
    avg, p99, _ = timeit(lambda: db.query(queries, caps=CAPS, fused=True,
                                          budget=budget),
                         warmup=2, iters=10)
    us = avg / b * 1e6
    derived = (f"batch={b};avg_ms={avg*1e3:.2f};p99_ms={p99*1e3:.2f}")
    if base_us:
        derived += f";perq_speedup_vs_b1={base_us / us:.2f}x"
    derived += ";" + _frontier_meta()
    emit(name, us, derived)
    return us


def run(kg=None):
    kg = kg or build_film_kg(n_films=150, n_actors=200, n_directors=30)
    db = kg.db
    rng = np.random.default_rng(0)
    base_us = None
    perq_us = {}
    for b in BATCHES:
        us = _bench(db, f"multiquery_b{b}", make_batch(kg, rng, b), b,
                    base_us)
        base_us = base_us or us
        perq_us[b] = us
    # star + mixed chain+star batches: fused into the same waves (A1QL v2)
    for b in STAR_BATCHES:
        _bench(db, f"multiquery_star_b{b}",
               make_batch(kg, rng, b, mix=("star",)), b, base_us)
    for b in MIXED_BATCHES:
        _bench(db, f"multiquery_mixed_b{b}",
               make_batch(kg, rng, b, mix=("2hop", "star", "rev")), b,
               base_us)
    # shared-frontier mode: same mix, one shared (seg, gid) pool per batch
    for b in SHARED_BATCHES:
        us = _bench(db, f"multiquery_shared_b{b}", make_batch(kg, rng, b),
                    b, base_us, budget="shared")
        if b in perq_us:
            emit(f"multiquery_shared_vs_perq_b{b}", 0.0,
                 f"shared_over_perq={us / perq_us[b]:.2f}x")
    return db


if __name__ == "__main__":
    run()
