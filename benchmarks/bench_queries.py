"""Q1/Q2/Q3 latency benchmarks (paper Figures 10, 12, 13).

Q1: 2-hop count   — actors who worked with director X
Q2: 3-hop count   — "actors who played Batman" shape (entity->film->cast)
Q3: star intersect — films by director X AND starring actor Y (AND genre)

Reports avg and P99 end-to-end latency per query batch, the paper's
availability metric ("if a system's 80th percentile latency is 100ms, the
system's effective availability is only 80%").
"""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.query.executor import QueryCaps
from repro.data.kg import build_film_kg

CAPS = QueryCaps(frontier=2048, expand=16384, results=32)


def q1(did):
    return {"type": "director", "id": int(did),
            "_out_edge": {"type": "film.director",
                          "_target": {"type": "film",
                                      "_out_edge": {"type": "film.actor",
                                                    "_target": {
                                                        "type": "actor",
                                                        "select": "count"}}}}}


def q2(aid):
    return {"type": "actor", "id": int(aid),
            "_in_edge": {"type": "film.actor",
                         "_target": {"type": "film",
                                     "_out_edge": {"type": "film.genre",
                                                   "_target": {
                                                       "type": "genre",
                                                       "select": "count"}}}}}


def q3(did, aid):
    return {"intersect": [
        {"type": "director", "id": int(did),
         "_out_edge": {"type": "film.director",
                       "_target": {"type": "film"}}},
        {"type": "actor", "id": int(aid),
         "_in_edge": {"type": "film.actor", "_target": {"type": "film"}}}],
        "select": "count"}


def run(kg=None):
    kg = kg or build_film_kg(n_films=150, n_actors=200, n_directors=30)
    db = kg.db
    rng = np.random.default_rng(0)
    B = 16

    for name, mk in [
        ("Q1_2hop_count", lambda: [q1(d) for d in
                                   rng.choice(kg.director_keys, B)]),
        ("Q2_3hop_count", lambda: [q2(a) for a in
                                   rng.choice(kg.actor_keys[:100], B)]),
        ("Q3_star_intersect", lambda: [q3(d, a) for d, a in zip(
            rng.choice(kg.director_keys, B),
            rng.choice(kg.actor_keys[:100], B))]),
    ]:
        queries = mk()
        avg, p99, _ = timeit(lambda: db.query(queries, caps=CAPS),
                             warmup=1, iters=5)
        emit(name, avg / B * 1e6,
             f"batch={B};avg_ms={avg*1e3:.2f};p99_ms={p99*1e3:.2f}")
    return db


if __name__ == "__main__":
    run()
