"""Read-time vs read-count (paper Figure 11 analogue).

The paper plots total RDMA read time against the number of reads a worker
performs (roughly linear, ~17us average per read).  Our analogue: batched
snapshot vertex reads of increasing count against the storage layer — the
linearity (and the per-read constant) is the property being reproduced;
the absolute constant is CPU-bound here and TPU-gather-bound in production.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.store import gather_data
from repro.data.kg import build_film_kg


def run(kg=None):
    kg = kg or build_film_kg(n_films=150, n_actors=200, n_directors=30)
    db = kg.db
    rng = np.random.default_rng(0)
    rts = jnp.int32(db.snapshot_ts())
    rows = []
    for n_reads in (64, 256, 1024, 4096, 16384):
        gids = jnp.asarray(rng.integers(0, 1024, n_reads).astype(np.int32))

        def read():
            f, i, alive = gather_data(db.store, db.cfg, gids, rts)
            f.block_until_ready()

        avg, p99, _ = timeit(read, warmup=1, iters=5)
        rows.append((n_reads, avg))
        emit(f"batched_reads_{n_reads}", avg * 1e6,
             f"us_per_read={avg/n_reads*1e6:.3f}")
    # linearity check: time(16384)/time(64) should be << 256x (batching wins)
    ratio = rows[-1][1] / rows[0][1]
    emit("read_batching_gain", 0.0,
         f"t16384/t64={ratio:.1f}x;ideal_serial=256x")
    return db


if __name__ == "__main__":
    run()
