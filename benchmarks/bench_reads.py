"""Read-time vs read-count (paper Figure 11 analogue).

The paper plots total RDMA read time against the number of reads a worker
performs (roughly linear, ~17us average per read).  Our analogue: batched
snapshot vertex reads of increasing count against the storage layer — the
linearity (and the per-read constant) is the property being reproduced;
the absolute constant is CPU-bound here and TPU-gather-bound in production.

Also benchmarks the primary-index probe with the delta scan full vs
windowed (``planner.index_window``: a host fill-count-bounded static
slice, pow2-keyed — the before/after of the ROADMAP item is recorded in
the two rows' metadata).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import index as index_mod
from repro.core.query.planner import index_window
from repro.core.store import gather_data
from repro.data.kg import build_film_kg


def run(kg=None):
    kg = kg or build_film_kg(n_films=150, n_actors=200, n_directors=30)
    db = kg.db
    rng = np.random.default_rng(0)
    rts = jnp.int32(db.snapshot_ts())
    rows = []
    for n_reads in (64, 256, 1024, 4096, 16384):
        gids = jnp.asarray(rng.integers(0, 1024, n_reads).astype(np.int32))

        def read():
            f, i, alive = gather_data(db.store, db.cfg, gids, rts)
            f.block_until_ready()

        avg, p99, _ = timeit(read, warmup=1, iters=5)
        rows.append((n_reads, avg))
        emit(f"batched_reads_{n_reads}", avg * 1e6,
             f"us_per_read={avg/n_reads*1e6:.3f}")
    # linearity check: time(16384)/time(64) should be << 256x (batching wins)
    ratio = rows[-1][1] / rows[0][1]
    emit("read_batching_gain", 0.0,
         f"t16384/t64={ratio:.1f}x;ideal_serial=256x")

    # ---- primary-index probe: delta scan full vs windowed -----------------
    # write a few vertices so the index delta is non-empty (the worst case
    # for the full scan and the realistic serving state between compactions)
    for i in range(8):
        db.create_vertex("actor", 90_000 + i)
    probe = jnp.asarray(rng.choice(kg.actor_keys, 1024).astype(np.int32))
    vts = jnp.full((1024,), db.vt("actor").type_id, jnp.int32)
    ones = jnp.ones((1024,), bool)
    rts = jnp.int32(db.snapshot_ts())
    win = index_window(db)

    def probe_fn(xd_win):
        fn = jax.jit(lambda st, k, t: index_mod.lookup(
            st, db.cfg, vts, k, ones, t, xd_win=xd_win)[0])
        return lambda: fn(db.store, probe, rts).block_until_ready()

    t_full, _, _ = timeit(probe_fn(None), warmup=2, iters=10)
    t_win, _, _ = timeit(probe_fn(win), warmup=2, iters=10)
    meta = (f"win={win};cap_idx_delta={db.cfg.cap_idx_delta};"
            f"fullscan_us={t_full*1e6:.1f};windowed_us={t_win*1e6:.1f};"
            f"speedup={t_full/t_win:.2f}x")
    emit("index_lookup_fullscan_1024", t_full * 1e6, meta)
    emit("index_lookup_windowed_1024", t_win * 1e6, meta)
    return db


if __name__ == "__main__":
    run()
