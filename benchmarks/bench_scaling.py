"""Latency vs load vs cluster size (paper Figure 14).

The paper sweeps clusters of 10/15/35/55 machines under increasing query
load: below saturation latency is flat, and usable throughput grows with
cluster size.  We reproduce the *protocol* on logical shard counts
(1/2/4/8 shards on the CPU substrate): per-batch latency at increasing
offered batch sizes per shard count.
"""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.query.executor import QueryCaps
from repro.data.kg import build_film_kg
from repro.core.addressing import StoreConfig


def q1(did):
    return {"type": "director", "id": int(did),
            "_out_edge": {"type": "film.director",
                          "_target": {"type": "film",
                                      "_out_edge": {"type": "film.actor",
                                                    "_target": {
                                                        "type": "actor",
                                                        "select": "count"}}}}}


def run():
    rng = np.random.default_rng(0)
    for shards in (1, 2, 4, 8):
        cfg = StoreConfig(n_shards=shards, cap_v=max(2048 // shards, 512),
                          cap_e=max(16384 // shards, 2048),
                          cap_delta=512, cap_idx=max(4096 // shards, 512),
                          cap_idx_delta=256, d_f32=2, d_i32=2)
        kg = build_film_kg(n_films=100, n_actors=150, n_directors=24,
                           cfg=cfg)
        db = kg.db
        caps = QueryCaps(frontier=1024, expand=8192, results=16)
        for load in (4, 16):
            queries = [q1(d) for d in rng.choice(kg.director_keys, load)]
            avg, p99, _ = timeit(lambda: db.query(queries, caps=caps),
                                 warmup=1, iters=3)
            emit(f"scaling_s{shards}_load{load}", avg / load * 1e6,
                 f"batch_ms={avg*1e3:.2f};qps={load/avg:.0f}")


if __name__ == "__main__":
    run()
