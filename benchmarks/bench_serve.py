"""Serving-tier benchmark: open-loop overload + §4/§5.3 recovery.

Two claims, each with rows and an asserted gate:

* **overload safety** — a closed calibration loop measures the sustainable
  wave throughput, then open-loop arrivals are replayed at 1x and 2x that
  rate.  2x is typically *absorbed*: bigger admission waves amortize the
  fixed per-wave cost, so capacity grows with load (that IS the overload
  story's first line of defense).  A third run escalates the rate until
  the shed watermark trips — configured *below* the wave size there,
  because the synchronous wave close bounds the queue at ``read_batch``
  (a production watermark sheds what the next wave cannot drain, instead
  of queueing it).  Gates (asserted, not just reported): goodput at 2x
  and at saturation >= 0.8x the 1x goodput, shed responses are
  sub-millisecond at the median, and **every** submitted request id
  terminates in a stored result;

* **cluster scale-out** — process-worker fleets (1 and 4 coordinators
  over one shared-memory store) replay open-loop arrivals at 1x and 2x
  the single worker's sustainable rate.  Goodput counts only
  *within-budget* answers, so the overloaded single worker degrades
  (queued requests burn their SLO budgets in line) while the 4-worker
  fleet absorbs the same rate at 0.5x per worker — the asserted gate is
  >= 2.5x goodput at 2x overload (armed only with >= 4 cores), plus a
  sub-millisecond median for frontend-local budget-exhausted answers;

* **recovery** — §4 consistent recovery (replay the versioned tables
  through the transactional write path) vs §5.3 fast restart (re-attach
  process-external regions): the wall-time gap is the paper's
  order-of-magnitude restart story (``recovery_consistent`` vs
  ``recovery_fast_restart``).
"""
import time

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.query.executor import QueryCaps
from repro.launch.serve import A1Server

N_HUB, DEG = 8, 12
CAPS = QueryCaps(frontier=64, expand=256, results=8)


def _db():
    cfg = StoreConfig(n_shards=4, cap_v=2048, cap_e=16384, cap_delta=256,
                      cap_idx=4096, cap_idx_delta=2048, d_f32=2, d_i32=2)
    db = GraphDB(cfg)
    db.vertex_type("hub")
    db.vertex_type("spoke")
    db.edge_type("link")
    hubs = [db.create_vertex("hub", i) for i in range(N_HUB)]
    spokes = [db.create_vertex("spoke", 1000 + k)
              for k in range(N_HUB * DEG)]
    k = 0
    for h in hubs:                       # one wave per hub: modest txn sizes
        t = db.create_transaction()
        for _ in range(DEG):
            db.create_edge(h, spokes[k], "link", txn=t)
            k += 1
        assert db.commit(t) == "COMMITTED"
    db.run_compaction()
    return db


def _doc(i):
    return {"type": "hub", "id": i % N_HUB,
            "_out_edge": {"type": "link",
                          "_target": {"type": "spoke", "select": "count"}}}


def _server(db, read_batch=8, watermark=None):
    return A1Server(db, caps=CAPS, read_batch=read_batch,
                    read_deadline_ms=2.0,
                    shed_watermark=watermark or 2 * read_batch)


def _warmup(db, read_batch):
    """Trace every wave size the admission tier can close (1..read_batch)
    so the timed loops measure dispatch, not jit tracing."""
    srv = _server(db, read_batch)
    for q in range(1, read_batch + 1):
        srv.execute([_doc(i) for i in range(q)], qclass="warmup")


def _calibrate(db, read_batch, waves=12):
    """Closed loop: full waves back to back -> sustainable QPS."""
    srv = _server(db, read_batch)
    t0 = time.perf_counter()
    for w in range(waves):
        for i in range(read_batch):
            srv.submit_query(_doc(w * read_batch + i))
        srv.flush_queries()
    wall = time.perf_counter() - t0
    return waves * read_batch / wall


def _open_loop(db, read_batch, rate_qps, n_req, watermark=None):
    """Open-loop arrivals at ``rate_qps``; the server sheds what it must.

    Returns per-run metrics; asserts the no-silent-termination gate."""
    srv = _server(db, read_batch, watermark)
    submit_dt = {}
    t0 = time.perf_counter()
    next_t = t0
    i = 0
    while i < n_req:
        now = time.perf_counter()
        if now >= next_t:
            s0 = time.perf_counter()
            qid = srv.submit_query(_doc(i))
            submit_dt[qid] = time.perf_counter() - s0
            next_t += 1.0 / rate_qps
            i += 1
        # pump every iteration, not just when idle: the deadline clock must
        # advance even while a burst of overdue arrivals is being admitted
        srv.pump()
    srv.flush_queries()
    wall = time.perf_counter() - t0
    rows = {q: srv.query_result(q) for q in submit_dt}
    # the overload contract: no admitted request terminates silently
    assert all(r is not None for r in rows.values())
    assert srv.stats["admitted"] == srv.stats["served"]
    ok = sum(r["status"] == "OK" for r in rows.values())
    shed = [q for q, r in rows.items() if r["status"] == "SHED"]
    lat = np.asarray(srv.latencies.get("q", [0.0])) * 1e3
    shed_ms = (float(np.median([submit_dt[q] for q in shed])) * 1e3
               if shed else 0.0)
    return {"goodput": ok / wall, "ok": ok, "shed": len(shed),
            "shed_rate": len(shed) / n_req, "shed_p50_ms": shed_ms,
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99))}


def _bench_overload(smoke):
    db = _db()
    B = 8
    _warmup(db, B)
    qps = _calibrate(db, B)
    n = 300 if smoke else 1200
    r1 = _open_loop(db, B, qps, n)
    r2 = _open_loop(db, B, 2 * qps, n)
    emit("serve_open_1x", 1e6 / r1["goodput"],
         f"rate={qps:.0f}qps;p50_ms={r1['p50_ms']:.2f};"
         f"p99_ms={r1['p99_ms']:.2f};shed_rate={r1['shed_rate']:.3f}")
    emit("serve_open_2x", 1e6 / r2["goodput"],
         f"rate={2 * qps:.0f}qps;p50_ms={r2['p50_ms']:.2f};"
         f"p99_ms={r2['p99_ms']:.2f};shed_rate={r2['shed_rate']:.3f};"
         f"shed_p50_ms={r2['shed_p50_ms']:.3f};"
         f"goodput_ratio={r2['goodput'] / r1['goodput']:.2f}")
    # the overload gate: shedding preserves goodput instead of collapsing
    # the wave pipeline under queue growth
    assert r2["goodput"] >= 0.8 * r1["goodput"], (r1, r2)
    if r2["shed"]:
        assert r2["shed_p50_ms"] < 1.0, r2   # sheds are immediate, not queued
    # 2x is often still absorbed — bigger admission waves amortize the fixed
    # per-wave cost, so capacity grows with load.  The synchronous wave
    # close bounds the queue at read_batch, so for the saturation run the
    # watermark sits BELOW the wave size (shed what the next wave cannot
    # drain).  Escalate until it actually trips, then gate THAT regime:
    # goodput holds and shed responses are immediate.
    mult, rs = 4, r2
    while rs["shed"] == 0 and mult <= 32:
        rs = _open_loop(db, B, mult * qps, n, watermark=B - 1)
        mult *= 2
    emit("serve_open_sat", 1e6 / rs["goodput"],
         f"rate={mult // 2 * qps:.0f}qps;p50_ms={rs['p50_ms']:.2f};"
         f"p99_ms={rs['p99_ms']:.2f};shed_rate={rs['shed_rate']:.3f};"
         f"shed_p50_ms={rs['shed_p50_ms']:.3f};"
         f"goodput_ratio={rs['goodput'] / r1['goodput']:.2f}")
    assert rs["shed"] > 0, rs                # saturation was actually reached
    assert rs["shed_p50_ms"] < 1.0, rs       # sheds are immediate, not queued
    assert rs["goodput"] >= 0.8 * r1["goodput"], (r1, rs)


# ---------------------------------------------------------------------------
# cluster front: process-worker scale-out under open-loop overload
# ---------------------------------------------------------------------------

CLUSTER_B = 4          # small wave cap: each spawned worker jit-traces
                       # every closable wave size (1..B) during warmup


def _cluster_poll(fe, pub, timeout_s=60.0):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout_s:
        r = fe.query_result(pub)
        if r is not None:
            return r
        time.sleep(0.001)
    raise TimeoutError(f"no result for {pub}")


def _cluster_warm(fe):
    """Warm EVERY worker for every closable wave size: process workers
    compile in their own process, and least-loaded routing would leave
    cold shapes to blow SLO budgets mid-measurement."""
    for cid in list(fe.workers):
        for q in range(1, CLUSTER_B + 1):
            qids = []
            for i in range(q):
                resp = fe._rpc(cid, {"op": "query", "doc": _doc(i),
                                     "budget_ms": 1e9})
                assert resp["status"] == "OK", resp
                qids.append(resp["qid"])
            fe._rpc(cid, {"op": "flush"})
            for qid in qids:
                r = fe._rpc(cid, {"op": "result", "qid": qid})
                assert r["result"]["status"] == "OK", r


def _cluster_calibrate(fe, waves=10):
    """Closed loop of full waves through one worker -> sustainable QPS."""
    t0 = time.perf_counter()
    for w in range(waves):
        pubs = [fe.submit_query(_doc(w * CLUSTER_B + i), budget_ms=1e9)
                for i in range(CLUSTER_B)]
        fe.flush()
        for p in pubs:
            _cluster_poll(fe, p)
    return waves * CLUSTER_B / (time.perf_counter() - t0)


def _cluster_open(fe, rate_qps, n_req, budget_ms):
    """Open-loop arrivals through the SLB.

    Each request carries the time it was *scheduled* to arrive: when the
    pacing loop falls behind (a saturated worker blocks the submit RPC),
    the lateness is docked from the request's SLO budget — exactly the
    front-door queueing a real load balancer would charge.  Goodput
    counts only within-budget answers: a ``budget_exhausted`` row is an
    SLO miss, the overload collapse the fleet is supposed to prevent."""
    pubs = []
    t0 = time.perf_counter()
    for i in range(n_req):
        sched = t0 + i / rate_qps
        dt = sched - time.perf_counter()
        if dt > 0:
            time.sleep(dt)
        late_ms = max(0.0, (time.perf_counter() - sched) * 1e3)
        pubs.append(fe.submit_query(
            _doc(i), budget_ms=max(0.0, budget_ms - late_ms)))
    fe.flush()
    rows = [_cluster_poll(fe, p) for p in pubs]
    wall = time.perf_counter() - t0
    assert all(r is not None for r in rows)      # no silent terminations
    ok = sum(r["status"] == "OK" and not r.get("budget_exhausted")
             for r in rows)
    exhausted = sum(bool(r.get("budget_exhausted")) for r in rows)
    return {"goodput": max(ok, 1) / wall, "ok": ok,
            "exhausted": exhausted, "n": n_req}


def _bench_cluster(smoke):
    """ISSUE 9 rows: ``cluster_open_{1x,2x}_w{1,4}`` + the front-door
    budget-exhaustion latency.

    Process-mode fleets (real worker processes over ONE shared-memory
    segment) so the scale-out is physical.  ``queue_frac=0.5`` lets the
    sparse per-worker streams of the 4-way fleet accumulate multi-member
    waves on the workers' own pump clocks (concurrently across
    processes) instead of dribbling size-1 waves.  The 4-worker goodput
    gate needs >= 4 cores to mean anything — on smaller machines the
    rows are still emitted but the ratio is reported, not asserted."""
    import os

    from repro.core import backend as backend_mod
    from repro.launch.cluster import A1Frontend

    if backend_mod.resolve(None).kind != "ref":
        return                    # cluster rows are a ref-backend claim
    db = _db()
    kw = dict(caps=CAPS, read_batch=CLUSTER_B, queue_frac=0.5)
    # long enough that the overloaded single worker's backlog (and with
    # it the docked-budget misses) dominates the warm head of the stream
    n = 320 if smoke else 800
    res, qps = {}, None
    for nw in (1, 4):
        fe = A1Frontend(db, nw, mode="process", name=f"bench_w{nw}", **kw)
        try:
            _cluster_warm(fe)
            if nw == 1:
                qps = _cluster_calibrate(fe)
                # generous enough that steady-state waves never exhaust,
                # tight enough that a growing overload backlog does
                budget = max(25.0, 3e3 * CLUSTER_B / qps)
            for mult in (1, 2):
                res[(nw, mult)] = _cluster_open(fe, mult * qps, n, budget)
        finally:
            fe.close()
    ratio = res[(4, 2)]["goodput"] / res[(1, 2)]["goodput"]
    for (nw, mult), r in sorted(res.items()):
        extra = f";goodput_ratio_2x={ratio:.2f}" if (nw, mult) == (4, 2) \
            else ""
        emit(f"cluster_open_{mult}x_w{nw}", 1e6 / r["goodput"],
             f"rate={mult * qps:.0f}qps;budget={budget:.0f}ms;"
             f"ok={r['ok']}/{r['n']};exhausted={r['exhausted']}{extra}")
    if (os.cpu_count() or 1) >= 4:
        # the scale-out gate: 4 workers hold >= 2.5x the single worker's
        # within-budget goodput at 2x overload (the single worker's own
        # goodput degrades — late requests arrive with burnt budgets)
        assert ratio >= 2.5, (res[(1, 2)], res[(4, 2)])

    # the front door answers an exhausted budget without a worker frame:
    # sub-millisecond at the median, any machine, any mode
    fe = A1Frontend(db, 2, name="bench_exh", **kw)
    try:
        dts = []
        for i in range(60):
            t0 = time.perf_counter()
            pub = fe.submit_query(_doc(i), budget_ms=0.0)
            r = fe.query_result(pub)
            dts.append(time.perf_counter() - t0)
            assert r["budget_exhausted"]
    finally:
        fe.close()
    p50_ms = float(np.median(dts)) * 1e3
    emit("cluster_budget_exhausted", p50_ms * 1e3, f"p50_ms={p50_ms:.4f}")
    assert p50_ms < 1.0, p50_ms


# ---------------------------------------------------------------------------
# §4 consistent recovery vs §5.3 fast restart
# ---------------------------------------------------------------------------

def _bench_recovery(n=48):
    from repro.core.recovery import FastRestartCache, consistent_recover
    from repro.core.replication import ObjectStore, ReplicationLog
    cfg = StoreConfig(n_shards=4, cap_v=512, cap_e=4096, cap_delta=256,
                      cap_idx=1024, cap_idx_delta=512, d_f32=2, d_i32=2)
    store = ObjectStore()
    log = ReplicationLog(store)
    db = GraphDB(cfg, replication_log=log)
    log.db = db
    db.vertex_type("node", f_attrs=("w",))
    db.edge_type("link")
    # vertices first (edge staging validates endpoints against committed
    # state), then the edges as one transactional wave
    vs = [db.create_vertex("node", i, {"w": float(i)}) for i in range(n)]
    t = db.create_transaction()
    for i in range(1, n):
        db.create_edge(vs[0] if i % 3 else vs[i - 1], vs[i], "link", txn=t)
    assert db.commit(t) == "COMMITTED"
    assert log.lag() == 0

    t_cons, _, _ = timeit(lambda: consistent_recover(store, db, cfg),
                          warmup=1, iters=2)
    cache = FastRestartCache()
    cache.hold("proc0", db)
    t_fast, _, _ = timeit(lambda: cache.restart("proc0"), warmup=1, iters=2)
    r = cache.restart("proc0")           # semantic spot-check, not just time
    assert r is not None and r.get_vertex("node", n - 1)["w"] == float(n - 1)
    emit("recovery_consistent", t_cons * 1e6, f"n={n};objectstore_replay")
    emit("recovery_fast_restart", t_fast * 1e6,
         f"n={n};region_reattach;speedup={t_cons / t_fast:.0f}x")


# ---------------------------------------------------------------------------
# failover recovery: primary kill -> first served write (membership + §4)
# ---------------------------------------------------------------------------

def _failover_db(n, cap_v, cap_e):
    cfg = StoreConfig(n_shards=4, cap_v=cap_v, cap_e=cap_e, cap_delta=256,
                      cap_idx=2 * cap_v, cap_idx_delta=cap_v,
                      d_f32=2, d_i32=2)
    db = GraphDB(cfg)
    db.vertex_type("node", f_attrs=("w",))
    db.edge_type("link")
    vs = [db.create_vertex("node", i, {"w": float(i)}) for i in range(n)]
    t = db.create_transaction()
    for i in range(1, n):
        db.create_edge(vs[0] if i % 3 else vs[i - 1], vs[i], "link", txn=t)
    assert db.commit(t) == "COMMITTED"
    db.run_compaction()
    return db


def _fleet_write(fe, key):
    from repro.core.writes import CreateVertex
    pub = fe.submit_write([CreateVertex("node", key, {"w": 0.0})])
    for _ in range(200):
        r = fe.write_result(pub)
        if r is not None:
            assert r["status"] == "COMMITTED", r
            return
        fe.flush()
    raise AssertionError("write never terminated")


def _bench_failover(smoke):
    """Time from primary kill to the first write served by the promoted
    replica, vs graph size — the membership/failover analogue of the §4
    recovery rows.  The gate: losing the primary costs less than 10
    steady-state write waves (evict + elect + promote is bookkeeping, not
    a restart)."""
    from repro.launch.cluster import A1Frontend
    sizes = [(48, 512, 4096), (192, 1024, 8192)]
    if not smoke:
        sizes.append((768, 4096, 32768))
    key = 10_000
    for n, cap_v, cap_e in sizes:
        db = _failover_db(n, cap_v, cap_e)
        with A1Frontend(db, 3, caps=CAPS, write_batch=1,
                        name=f"bench_fo{n}") as fe:
            _fleet_write(fe, key)              # warm the write path (jit)
            key += 1
            steady = []
            for _ in range(5):                 # steady single-txn waves
                t0 = time.perf_counter()
                _fleet_write(fe, key)
                key += 1
                steady.append(time.perf_counter() - t0)
            steady_s = sorted(steady)[len(steady) // 2]
            t0 = time.perf_counter()
            fe.kill_worker(fe.membership.primary)
            _fleet_write(fe, key)              # first post-failover write
            key += 1
            rec_s = time.perf_counter() - t0
            assert fe.stats["failovers"] == 1
            ratio = rec_s / steady_s
            assert ratio < 10.0, (
                f"failover recovery {rec_s * 1e3:.2f}ms is {ratio:.1f}x "
                f"the steady write wave {steady_s * 1e3:.2f}ms (n={n})")
            emit(f"recovery_failover_n{n}", rec_s * 1e6,
                 f"steady_wave_us={steady_s * 1e6:.1f};"
                 f"ratio={ratio:.1f}x;epoch={fe.membership.epoch}")


def run(smoke: bool = False):
    _bench_overload(smoke)
    _bench_cluster(smoke)
    _bench_recovery()
    _bench_failover(smoke)


if __name__ == "__main__":
    run(smoke=True)
