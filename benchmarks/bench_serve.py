"""Serving-tier benchmark: open-loop overload + §4/§5.3 recovery.

Two claims, each with rows and an asserted gate:

* **overload safety** — a closed calibration loop measures the sustainable
  wave throughput, then open-loop arrivals are replayed at 1x and 2x that
  rate.  2x is typically *absorbed*: bigger admission waves amortize the
  fixed per-wave cost, so capacity grows with load (that IS the overload
  story's first line of defense).  A third run escalates the rate until
  the shed watermark trips — configured *below* the wave size there,
  because the synchronous wave close bounds the queue at ``read_batch``
  (a production watermark sheds what the next wave cannot drain, instead
  of queueing it).  Gates (asserted, not just reported): goodput at 2x
  and at saturation >= 0.8x the 1x goodput, shed responses are
  sub-millisecond at the median, and **every** submitted request id
  terminates in a stored result;

* **recovery** — §4 consistent recovery (replay the versioned tables
  through the transactional write path) vs §5.3 fast restart (re-attach
  process-external regions): the wall-time gap is the paper's
  order-of-magnitude restart story (``recovery_consistent`` vs
  ``recovery_fast_restart``).
"""
import time

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.query.executor import QueryCaps
from repro.launch.serve import A1Server

N_HUB, DEG = 8, 12
CAPS = QueryCaps(frontier=64, expand=256, results=8)


def _db():
    cfg = StoreConfig(n_shards=4, cap_v=2048, cap_e=16384, cap_delta=256,
                      cap_idx=4096, cap_idx_delta=2048, d_f32=2, d_i32=2)
    db = GraphDB(cfg)
    db.vertex_type("hub")
    db.vertex_type("spoke")
    db.edge_type("link")
    hubs = [db.create_vertex("hub", i) for i in range(N_HUB)]
    spokes = [db.create_vertex("spoke", 1000 + k)
              for k in range(N_HUB * DEG)]
    k = 0
    for h in hubs:                       # one wave per hub: modest txn sizes
        t = db.create_transaction()
        for _ in range(DEG):
            db.create_edge(h, spokes[k], "link", txn=t)
            k += 1
        assert db.commit(t) == "COMMITTED"
    db.run_compaction()
    return db


def _doc(i):
    return {"type": "hub", "id": i % N_HUB,
            "_out_edge": {"type": "link",
                          "_target": {"type": "spoke", "select": "count"}}}


def _server(db, read_batch=8, watermark=None):
    return A1Server(db, caps=CAPS, read_batch=read_batch,
                    read_deadline_ms=2.0,
                    shed_watermark=watermark or 2 * read_batch)


def _warmup(db, read_batch):
    """Trace every wave size the admission tier can close (1..read_batch)
    so the timed loops measure dispatch, not jit tracing."""
    srv = _server(db, read_batch)
    for q in range(1, read_batch + 1):
        srv.execute([_doc(i) for i in range(q)], qclass="warmup")


def _calibrate(db, read_batch, waves=12):
    """Closed loop: full waves back to back -> sustainable QPS."""
    srv = _server(db, read_batch)
    t0 = time.perf_counter()
    for w in range(waves):
        for i in range(read_batch):
            srv.submit_query(_doc(w * read_batch + i))
        srv.flush_queries()
    wall = time.perf_counter() - t0
    return waves * read_batch / wall


def _open_loop(db, read_batch, rate_qps, n_req, watermark=None):
    """Open-loop arrivals at ``rate_qps``; the server sheds what it must.

    Returns per-run metrics; asserts the no-silent-termination gate."""
    srv = _server(db, read_batch, watermark)
    submit_dt = {}
    t0 = time.perf_counter()
    next_t = t0
    i = 0
    while i < n_req:
        now = time.perf_counter()
        if now >= next_t:
            s0 = time.perf_counter()
            qid = srv.submit_query(_doc(i))
            submit_dt[qid] = time.perf_counter() - s0
            next_t += 1.0 / rate_qps
            i += 1
        # pump every iteration, not just when idle: the deadline clock must
        # advance even while a burst of overdue arrivals is being admitted
        srv.pump()
    srv.flush_queries()
    wall = time.perf_counter() - t0
    rows = {q: srv.query_result(q) for q in submit_dt}
    # the overload contract: no admitted request terminates silently
    assert all(r is not None for r in rows.values())
    assert srv.stats["admitted"] == srv.stats["served"]
    ok = sum(r["status"] == "OK" for r in rows.values())
    shed = [q for q, r in rows.items() if r["status"] == "SHED"]
    lat = np.asarray(srv.latencies.get("q", [0.0])) * 1e3
    shed_ms = (float(np.median([submit_dt[q] for q in shed])) * 1e3
               if shed else 0.0)
    return {"goodput": ok / wall, "ok": ok, "shed": len(shed),
            "shed_rate": len(shed) / n_req, "shed_p50_ms": shed_ms,
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99))}


def _bench_overload(smoke):
    db = _db()
    B = 8
    _warmup(db, B)
    qps = _calibrate(db, B)
    n = 300 if smoke else 1200
    r1 = _open_loop(db, B, qps, n)
    r2 = _open_loop(db, B, 2 * qps, n)
    emit("serve_open_1x", 1e6 / r1["goodput"],
         f"rate={qps:.0f}qps;p50_ms={r1['p50_ms']:.2f};"
         f"p99_ms={r1['p99_ms']:.2f};shed_rate={r1['shed_rate']:.3f}")
    emit("serve_open_2x", 1e6 / r2["goodput"],
         f"rate={2 * qps:.0f}qps;p50_ms={r2['p50_ms']:.2f};"
         f"p99_ms={r2['p99_ms']:.2f};shed_rate={r2['shed_rate']:.3f};"
         f"shed_p50_ms={r2['shed_p50_ms']:.3f};"
         f"goodput_ratio={r2['goodput'] / r1['goodput']:.2f}")
    # the overload gate: shedding preserves goodput instead of collapsing
    # the wave pipeline under queue growth
    assert r2["goodput"] >= 0.8 * r1["goodput"], (r1, r2)
    if r2["shed"]:
        assert r2["shed_p50_ms"] < 1.0, r2   # sheds are immediate, not queued
    # 2x is often still absorbed — bigger admission waves amortize the fixed
    # per-wave cost, so capacity grows with load.  The synchronous wave
    # close bounds the queue at read_batch, so for the saturation run the
    # watermark sits BELOW the wave size (shed what the next wave cannot
    # drain).  Escalate until it actually trips, then gate THAT regime:
    # goodput holds and shed responses are immediate.
    mult, rs = 4, r2
    while rs["shed"] == 0 and mult <= 32:
        rs = _open_loop(db, B, mult * qps, n, watermark=B - 1)
        mult *= 2
    emit("serve_open_sat", 1e6 / rs["goodput"],
         f"rate={mult // 2 * qps:.0f}qps;p50_ms={rs['p50_ms']:.2f};"
         f"p99_ms={rs['p99_ms']:.2f};shed_rate={rs['shed_rate']:.3f};"
         f"shed_p50_ms={rs['shed_p50_ms']:.3f};"
         f"goodput_ratio={rs['goodput'] / r1['goodput']:.2f}")
    assert rs["shed"] > 0, rs                # saturation was actually reached
    assert rs["shed_p50_ms"] < 1.0, rs       # sheds are immediate, not queued
    assert rs["goodput"] >= 0.8 * r1["goodput"], (r1, rs)


# ---------------------------------------------------------------------------
# §4 consistent recovery vs §5.3 fast restart
# ---------------------------------------------------------------------------

def _bench_recovery(n=48):
    from repro.core.recovery import FastRestartCache, consistent_recover
    from repro.core.replication import ObjectStore, ReplicationLog
    cfg = StoreConfig(n_shards=4, cap_v=512, cap_e=4096, cap_delta=256,
                      cap_idx=1024, cap_idx_delta=512, d_f32=2, d_i32=2)
    store = ObjectStore()
    log = ReplicationLog(store)
    db = GraphDB(cfg, replication_log=log)
    log.db = db
    db.vertex_type("node", f_attrs=("w",))
    db.edge_type("link")
    # vertices first (edge staging validates endpoints against committed
    # state), then the edges as one transactional wave
    vs = [db.create_vertex("node", i, {"w": float(i)}) for i in range(n)]
    t = db.create_transaction()
    for i in range(1, n):
        db.create_edge(vs[0] if i % 3 else vs[i - 1], vs[i], "link", txn=t)
    assert db.commit(t) == "COMMITTED"
    assert log.lag() == 0

    t_cons, _, _ = timeit(lambda: consistent_recover(store, db, cfg),
                          warmup=1, iters=2)
    cache = FastRestartCache()
    cache.hold("proc0", db)
    t_fast, _, _ = timeit(lambda: cache.restart("proc0"), warmup=1, iters=2)
    r = cache.restart("proc0")           # semantic spot-check, not just time
    assert r is not None and r.get_vertex("node", n - 1)["w"] == float(n - 1)
    emit("recovery_consistent", t_cons * 1e6, f"n={n};objectstore_replay")
    emit("recovery_fast_restart", t_fast * 1e6,
         f"n={n};region_reattach;speedup={t_cons / t_fast:.0f}x")


def run(smoke: bool = False):
    _bench_overload(smoke)
    _bench_recovery()


if __name__ == "__main__":
    run(smoke=True)
