"""Q4 throughput / vertex-reads-per-second (paper §6).

The paper's stress result: Q4 (actor -> films -> co-stars -> their films)
touches ~24k vertices per query; at 15k QPS the cluster sustains 365M
vertex reads/s.  We measure the same ratio on the CPU build: queries/s x
vertices-touched/query = vertex reads/s, plus the raw batched vertex-read
rate of the storage layer (the paper's "350M+ vertex reads per second"
headline is this number at 245-machine scale).
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.query.executor import QueryCaps
from repro.core.store import gather_headers
from repro.data.kg import build_film_kg


def q4(aid):
    return {"type": "actor", "id": int(aid),
            "_in_edge": {"type": "film.actor",
                         "_target": {"type": "film",
                                     "_out_edge": {"type": "film.actor",
                                                   "_target": {
                                                       "type": "actor",
                                                       "select": "count"}}}}}


def run(kg=None):
    kg = kg or build_film_kg(n_films=150, n_actors=200, n_directors=30)
    db = kg.db
    rng = np.random.default_rng(0)
    B = 16
    caps = QueryCaps(frontier=4096, expand=32768, results=32)

    queries = [q4(a) for a in rng.choice(kg.actor_keys[:50], B)]
    res = db.query(queries, caps=caps)
    verts_per_q = float(np.mean(res.counts)) + 2.0  # rough touched-vertices
    avg, p99, _ = timeit(lambda: db.query(queries, caps=caps),
                         warmup=1, iters=5)
    qps = B / avg
    emit("Q4_costar_stress", avg / B * 1e6,
         f"qps={qps:.0f};verts_per_q~{verts_per_q:.0f};"
         f"vertex_reads_per_s~{qps*verts_per_q:.0f}")

    # raw storage-layer batched vertex read rate (headers at a snapshot)
    n = db.cfg.total_v
    gids = jnp.asarray(rng.integers(0, min(n, 4096),
                                    size=65536).astype(np.int32))
    rts = jnp.int32(db.snapshot_ts())

    def read():
        vt, k, alive = gather_headers(db.store, db.cfg, gids, rts)
        vt.block_until_ready()

    avg, p99, _ = timeit(read, warmup=1, iters=5)
    emit("raw_vertex_reads", avg / 65536 * 1e6,
         f"reads_per_s={65536/avg:.0f}")
    return db


if __name__ == "__main__":
    run()
