"""Hybrid vector+graph benchmark: the fused ``Nearest`` probe wave.

The claim the Nearest operator makes is the same amortization claim as the
rest of the serving tier: a *batch* of k-NN-seeded expansions shares one
``knn_topk`` distance+top-k pass, one lookup wave, and one hop wave, so
per-query cost at batch 16 lands well under batch 1.  Two rows pin it:

* ``knn_expand_b1``  — one ``{"nearest": ...} -> 1-hop count`` query alone;
* ``knn_expand_b16`` — 16 of them (distinct query vectors) as one fused
  program group; the ``derived`` field records the measured per-query
  speedup.  ``tests/test_vector.py::test_knn_amortization_gate`` enforces
  the <= 0.5x gate on the ref backend; these rows keep the number
  observable across commits (the BENCH_*.json trajectory + compare gate).
"""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB

BATCH = 16


def _db(n_docs=256, n_tags=16, d=8, seed=7):
    cfg = StoreConfig(n_shards=4, cap_v=1024, cap_e=8192, cap_delta=512,
                      cap_idx=1024, cap_idx_delta=512, cap_vec=512,
                      d_f32=d, d_i32=2)
    db = GraphDB(cfg)
    fa = tuple(f"f{i}" for i in range(d))
    db.vertex_type("doc", f_attrs=fa, i_attrs=("x", "y"))
    db.vertex_type("tag", f_attrs=fa, i_attrs=("x", "y"))
    db.edge_type("doc.tag")
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(n_docs, d)).astype(np.float32)
    docs = [db.create_vertex("doc", i,
                             dict(zip(fa, map(float, emb[i])), x=i, y=0))
            for i in range(n_docs)]
    tags = [db.create_vertex("tag", 10_000 + i) for i in range(n_tags)]
    t = db.create_transaction()
    for i, g in enumerate(docs):
        db.create_edge(g, tags[i % n_tags], "doc.tag", txn=t)
        db.create_edge(g, tags[(i * 7 + 3) % n_tags], "doc.tag", txn=t)
    db.write([t])
    db.vector_index("doc")
    return db, rng, d


def _q(vec, k=8):
    return {"nearest": {"type": "doc", "vector": [float(x) for x in vec],
                        "k": k},
            "_out_edge": {"type": "doc.tag",
                          "_target": {"type": "tag", "select": "count"}}}


def run(smoke: bool = False) -> None:
    db, rng, d = _db(n_docs=128 if smoke else 256)
    qs = [_q(rng.normal(size=d)) for _ in range(BATCH)]

    def b1():
        db.query([qs[0]])

    def b16():
        db.query(qs)

    t1, _, _ = timeit(b1, warmup=2, iters=5 if smoke else 10)
    tB, _, _ = timeit(b16, warmup=2, iters=5 if smoke else 10)
    perq = tB / BATCH
    emit("knn_expand_b1", t1 * 1e6, "B=1;nearest_k8_1hop")
    emit("knn_expand_b16", perq * 1e6,
         f"B={BATCH};perq_speedup={t1 / perq:.1f}x")
