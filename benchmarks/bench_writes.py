"""Write-path benchmark: mutation waves + background compaction (§3, §2.2).

Three claims the PR makes, each with a row:

* **wave amortization** — committing B staged transactions as one fused
  mutation wave costs far less per txn than B sequential commits
  (``write_seq_b1`` vs ``write_wave_b16``: one OCC validation gather and one
  cached apply program instead of B of each);

* **compaction off the commit path** — a sustained mixed read/write closed
  loop (the serving shape: ingest wave, snapshot read, task pump) with
  *background* compaction keeps the edge-delta window at the minimum pow2
  bucket and the commit latency flat, while the *inline-only* baseline lets
  the window grow to ``cap_delta`` and eats a stop-the-world fold on the
  commit path when the log saturates (``write_ingest_inline`` vs
  ``write_ingest_bg``: compare ``dwin_max`` and ``spike`` in the derived
  fields);

* **parity** — a batched ``write([t1..tn])`` leaves bit-identical store
  arrays to sequential ``commit()`` replay (asserted here, not just in the
  test suite, so the perf row can never drift from the semantics).
"""
import time

import jax
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.query.planner import delta_window
from repro.core.tasks import TaskQueue
from repro.core.txn import BatchCaps
from repro.core.writes import CreateEdge, CreateVertex, UpdateVertex


def _db(cap_delta=64):
    cfg = StoreConfig(n_shards=4, cap_v=2048, cap_e=16384,
                      cap_delta=cap_delta, cap_idx=4096, cap_idx_delta=2048,
                      d_f32=2, d_i32=2)
    db = GraphDB(cfg)
    db.vertex_type("hub")
    db.vertex_type("spoke", f_attrs=("w",))
    db.edge_type("link")
    return db


# ---------------------------------------------------------------------------
# wave amortization: B txns, one wave
# ---------------------------------------------------------------------------

def _bench_amortization(B=16):
    db = _db()
    gids = db.write([CreateVertex("spoke", i, {"w": 0.0})
                     for i in range(B)]).gids

    def stage_all():
        txns = []
        for i, g in enumerate(gids):
            t = db.create_transaction()
            db.write([UpdateVertex(g, "spoke", {"w": float(i)})], txn=t)
            txns.append(t)
        return txns

    def seq():
        for t in stage_all():
            db.write([t])

    def wave():
        db.write(stage_all())

    t_seq, _, _ = timeit(seq, warmup=2, iters=8)
    t_wave, _, _ = timeit(wave, warmup=2, iters=8)
    emit("write_seq_b1", t_seq / B * 1e6, f"B={B};sequential_commits")
    emit("write_wave_b16", t_wave / B * 1e6,
         f"B={B};amortization={t_seq / t_wave:.1f}x")


# ---------------------------------------------------------------------------
# sustained ingest closed loop: inline-only vs background compaction
# ---------------------------------------------------------------------------

def _ingest_loop(db, hub, iters, key0, pump):
    """The serving quantum: one ingest wave, one snapshot read, one task
    pump.  Returns (per-wave seconds, per-wave delta windows)."""
    lats, wins = [], []
    for i in range(iters):
        t = db.create_transaction()
        g = db.write([CreateVertex("spoke", key0 + i, {"w": 1.0})],
                     txn=t).gids[0]
        db.write([CreateEdge(hub, g, "link", check=False)], txn=t)
        t0 = time.perf_counter()            # commit latency: the wave only
        db.write([t])
        lats.append(time.perf_counter() - t0)
        db.get_edges(hub)                       # the read half of the mix
        wins.append(delta_window(db))
        if pump:
            db.task_queue.pump(1)
    return np.asarray(lats), np.asarray(wins)


def _bench_ingest(iters):
    results = {}
    for mode in ("inline", "bg"):
        db = _db(cap_delta=64)
        hub = db.write([CreateVertex("hub", 0)]).gids[0]
        if mode == "bg":
            db.task_queue = TaskQueue(db)
            # trigger the two-phase fold as soon as a couple of slots fill:
            # with a pump every quantum the window never leaves the bottom
            # bucket (the §2.2 "GC keeps up with the mutation rate" regime)
            db.compaction_watermark = 2 / db.cfg.cap_delta
        # warmup: trace the wave programs (+ one full bg cycle in bg mode),
        # and the fold itself — so the inline spike measures the
        # stop-the-world execution on the commit path, not jit tracing
        _ingest_loop(db, hub, 8, 1_000_000, pump=(mode == "bg"))
        if mode == "inline":
            db.run_compaction()
            db.stats["compactions"] = 0
        lats, wins = _ingest_loop(db, hub, iters, 0, pump=(mode == "bg"))
        results[mode] = (db, lats, wins)

    db_i, lat_i, win_i = results["inline"]
    db_b, lat_b, win_b = results["bg"]
    spike = float(lat_i.max() / np.median(lat_i))       # the saturation fold
    emit("write_ingest_inline", float(lat_i.mean()) * 1e6,
         f"iters={iters};p99_us={np.percentile(lat_i, 99)*1e6:.0f};"
         f"dwin_max={int(win_i.max())};spike={spike:.1f}x;"
         f"compactions={db_i.stats['compactions']}")
    spike_b = float(lat_b.max() / np.median(lat_b))
    emit("write_ingest_bg", float(lat_b.mean()) * 1e6,
         f"iters={iters};p99_us={np.percentile(lat_b, 99)*1e6:.0f};"
         f"dwin_max={int(win_b.max())};spike={spike_b:.1f}x;"
         f"bg_compactions={db_b.stats['bg_compactions']};"
         f"inline_compactions={db_b.stats['compactions']}")
    # the PR's claim, enforced: background folding pins the window to the
    # bottom pow2 buckets and never falls back to the commit-path fold
    assert int(win_b.max()) <= 4, win_b.max()
    assert db_b.stats["compactions"] == 0
    assert db_b.stats["bg_compactions"] >= 1


# ---------------------------------------------------------------------------
# parity: batched wave == sequential commit, bit for bit
# ---------------------------------------------------------------------------

def _bench_parity(n=8):
    def staged(db):
        base = db.write([CreateVertex("spoke", i, {"w": 0.0})
                         for i in range(n)]).gids
        txns = []
        for i, g in enumerate(base):
            t = db.create_transaction()
            db.write([UpdateVertex(g, "spoke", {"w": 1.0 + i}),
                      CreateVertex("spoke", 100 + i)], txn=t)
            txns.append(t)
        return txns

    db1, db2 = _db(), _db()
    db1.write(staged(db1), caps=BatchCaps(create_v=1, update_v=1))
    for t in staged(db2):
        db2.write([t])
    same = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(db1.store),
                               jax.tree.leaves(db2.store)))
    assert same and db1.clock == db2.clock
    emit("write_parity_batched_vs_seq", 0.0, f"bit_identical=ok;txns={n}")


def run(smoke: bool = False):
    _bench_amortization()
    _bench_ingest(iters=40 if smoke else 120)
    _bench_parity()


if __name__ == "__main__":
    run()
