"""Shared benchmark utilities."""
import sys
import time

sys.path.insert(0, "src")

import numpy as np


def timeit(fn, *, warmup: int = 2, iters: int = 10):
    """Returns (avg_s, p99_s, all_times)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    a = np.asarray(ts)
    return float(a.mean()), float(np.percentile(a, 99)), a


# every emit() is recorded here so run.py can dump a machine-readable
# artifact (CI uploads BENCH_<sha>.json per PR — the perf trajectory)
ROWS: list = []

# run-level metadata stamped onto every row (backend, platform, ...) so
# trajectory points stay comparable across backends and toolchains
CONTEXT: dict = {}


def set_context(**kv) -> None:
    CONTEXT.update({k: v for k, v in kv.items() if v is not None})
    reset_counters()


def reset_counters() -> None:
    """Zero the process-global planner/write observability counters so a
    run's rows (hit rates, frontier peaks, overflow tallies) never carry
    another run's traffic."""
    from repro.core import writes
    from repro.core.query import planner
    planner.reset_stats()
    writes.reset_stats()


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV rows."""
    ROWS.append({"name": name, "us_per_call": round(float(us_per_call), 1),
                 "derived": derived, **CONTEXT})
    print(f"{name},{us_per_call:.1f},{derived}")
