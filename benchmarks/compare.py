"""Compare two BENCH_*.json trajectory points; flag per-row regressions.

    python -m benchmarks.compare PREV.json CUR.json [--threshold 2.0]
                                                    [--warn-only] [--github]

Rows are joined by benchmark name; rows that carry a ``backend`` field on
both sides must also agree on it (points from different backends are never
compared).  A row regresses when ``cur/prev > threshold`` on us_per_call.
Exit status is 1 when any row regresses, unless ``--warn-only`` (what CI
uses while the trajectory is short — micro-benchmarks on shared runners are
noisy).  ``--github`` additionally emits ::warning workflow annotations.
"""
import argparse
import json
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for row in doc.get("rows", []):
        out[row["name"]] = row
    return out


def compare(prev: dict, cur: dict, threshold: float):
    """Returns (regressions, improvements, report_lines)."""
    regressions, improvements, lines = [], [], []
    for name, c in cur.items():
        p = prev.get(name)
        if p is None:
            lines.append(f"  new        {name}: {c['us_per_call']}us")
            continue
        pb, cb = p.get("backend"), c.get("backend")
        if pb is not None and cb is not None and pb != cb:
            lines.append(f"  skip       {name}: backend {pb} vs {cb}")
            continue
        pv, cv = float(p["us_per_call"]), float(c["us_per_call"])
        if pv <= 0 or cv <= 0:          # derived-only rows emit 0.0
            continue
        ratio = cv / pv
        tag = "ok"
        if ratio > threshold:
            tag = "REGRESSION"
            regressions.append((name, pv, cv, ratio))
        elif ratio < 1 / threshold:
            tag = "improved"
            improvements.append((name, pv, cv, ratio))
        lines.append(f"  {tag:10s} {name}: {pv} -> {cv}us ({ratio:.2f}x)")
    for name in prev:
        if name not in cur:
            lines.append(f"  dropped    {name}")
    return regressions, improvements, lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("prev")
    ap.add_argument("cur")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="flag rows slower than this ratio (default 2.0)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0")
    ap.add_argument("--github", action="store_true",
                    help="emit ::warning annotations for regressions")
    args = ap.parse_args()

    prev, cur = load_rows(args.prev), load_rows(args.cur)
    regressions, improvements, lines = compare(prev, cur, args.threshold)
    print(f"# compare {args.prev} -> {args.cur} "
          f"(threshold {args.threshold}x)")
    print("\n".join(lines))
    print(f"# {len(regressions)} regression(s), "
          f"{len(improvements)} improvement(s)")
    if args.github:
        for name, pv, cv, ratio in regressions:
            print(f"::warning title=bench regression::{name} "
                  f"{pv}us -> {cv}us ({ratio:.2f}x)")
    if regressions and not args.warn_only:
        sys.exit(1)


if __name__ == "__main__":
    main()
