"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only queries,throughput,...]
                                            [--smoke] [--json OUT.json]
                                            [--backend auto|ref|pallas]

Emits ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit);
``--json`` additionally writes the rows as a JSON artifact (what CI
uploads per commit, accumulating the perf trajectory).  ``--smoke`` runs a
reduced knowledge graph and only the cheap suites — a per-PR signal, not a
paper-scale number.
"""
import argparse
import json
import os
import platform
import sys
import time

# work as `python -m benchmarks.run` (repo root) or `python benchmarks/run.py`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced KG + cheap suites (CI per-PR signal)")
    ap.add_argument("--json", default="",
                    help="also write rows to this JSON file")
    ap.add_argument("--backend", default="", choices=["", "auto", "ref",
                                                      "pallas"],
                    help="read-path backend (default: $REPRO_BACKEND/auto)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.smoke and only is None:
        only = {"queries", "reads", "multiquery", "writes", "serve",
                "vector"}
    if args.backend:
        # before any repro import: every suite resolves the env default
        os.environ["REPRO_BACKEND"] = args.backend

    import jax

    from benchmarks import (bench_multiquery, bench_queries, bench_reads,
                            bench_scaling, bench_serve, bench_throughput,
                            bench_vector, bench_writes)
    from benchmarks import common
    from repro.core import backend as backend_mod
    from repro.data.kg import build_film_kg

    be = backend_mod.resolve(args.backend or None)
    meta = {"backend": be.kind,
            "backend_interpret": be.interpret,
            "jax": jax.__version__,
            "jax_platform": jax.default_backend(),
            "device_kind": jax.devices()[0].device_kind}
    common.set_context(backend=be.kind)

    print("name,us_per_call,derived")
    t0 = time.time()
    kg = None
    if only is None or {"queries", "throughput", "reads", "multiquery"} & only:
        kg = (build_film_kg(n_films=40, n_actors=60, n_directors=8)
              if args.smoke else
              build_film_kg(n_films=150, n_actors=200, n_directors=30))
    if only is None or "queries" in only:
        bench_queries.run(kg)
    if only is None or "multiquery" in only:
        bench_multiquery.run(kg)
    if only is None or "throughput" in only:
        bench_throughput.run(kg)
    if only is None or "reads" in only:
        bench_reads.run(kg)
    if only is None or "writes" in only:
        bench_writes.run(smoke=args.smoke)
    if only is None or "serve" in only:
        bench_serve.run(smoke=args.smoke)
    if only is None or "vector" in only:
        bench_vector.run(smoke=args.smoke)
    if only is None or "scaling" in only:
        bench_scaling.run()
    wall = time.time() - t0
    print(f"# total {wall:.1f}s", file=sys.stderr)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": common.ROWS,
                       "smoke": args.smoke,
                       "wall_s": round(wall, 1),
                       "python": platform.python_version(),
                       "unix_time": int(time.time()),
                       **meta}, f, indent=1)
        print(f"# wrote {args.json} ({len(common.ROWS)} rows)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
