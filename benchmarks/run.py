"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only queries,throughput,...]

Emits ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (bench_queries, bench_reads, bench_scaling,
                            bench_throughput)
    from repro.data.kg import build_film_kg

    print("name,us_per_call,derived")
    t0 = time.time()
    kg = None
    if only is None or {"queries", "throughput", "reads"} & only:
        kg = build_film_kg(n_films=150, n_actors=200, n_directors=30)
    if only is None or "queries" in only:
        bench_queries.run(kg)
    if only is None or "throughput" in only:
        bench_throughput.run(kg)
    if only is None or "reads" in only:
        bench_reads.run(kg)
    if only is None or "scaling" in only:
        bench_scaling.run()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
