"""GNN training on top of the A1 graph store.

The integration the DESIGN.md §5 table promises: load a graph into the
transactional store, pull its CSR snapshot with one batched ``db.query``
(N neighbor selects fused into a single compiled program), train GraphSAGE
with the fanout sampler (a bounded A1 traversal), and keep training correctly
*after* live updates mutate the graph (the snapshot/compaction machinery
hands the sampler a consistent view).

    PYTHONPATH=src python examples/gnn_on_a1.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.query.executor import QueryCaps
from repro.data.sampler import build_sampled_batch, csr_from_coo
from repro.models.gnn import sage
from repro.optim.optimizers import AdamWConfig, init_opt_state, opt_update


def main():
    rng = np.random.default_rng(0)
    N, deg, d_feat, n_classes = 200, 6, 32, 5

    # ---- load a social-ish graph through the A1 write path ---------------
    cfg = StoreConfig(n_shards=4, cap_v=128, cap_e=4096, cap_delta=512,
                      cap_idx=256, cap_idx_delta=128, d_f32=2, d_i32=2)
    db = GraphDB(cfg)
    db.vertex_type("user", i_attrs=("grp",))
    db.edge_type("follows")
    labels_host = rng.integers(0, n_classes, N).astype(np.int32)
    from repro.core.writes import CreateEdge, CreateVertex, DeleteVertex
    res = db.write([CreateVertex("user", i, {"grp": int(labels_host[i])})
                    for i in range(N)])
    assert not res.failed
    gids = res.gids
    e_ops, seen = [], set()
    for i in range(N):
        for j in rng.choice(N, deg, replace=False):
            if int(j) != i and (i, int(j)) not in seen:
                seen.add((i, int(j)))
                e_ops.append(CreateEdge(gids[i], gids[int(j)], "follows",
                                        check=False))
    for off in range(0, len(e_ops), 400):   # stay under the commit batch caps
        assert not db.write(e_ops[off:off + 400]).failed
    db.run_compaction()

    # ---- pull a consistent CSR snapshot through the query engine ----------
    # one batched A1QL select per vertex, all N fused into a single compiled
    # program (uniform plan shape) instead of N host round-trips; user keys
    # are the dense ids, so neighbor keys are the CSR column indices
    nbr_q = [{"type": "user", "id": i,
              "_out_edge": {"type": "follows",
                            "_target": {"type": "user", "select": ["key"]}}}
             for i in range(N)]
    # fused=True: each query gets its own small §3.4 budget instead of one
    # shared frontier sized for all N — the serving-shaped wave path
    res = db.query(nbr_q, caps=QueryCaps(frontier=64, expand=256,
                                         results=2 * deg), fused=True)
    assert not res.failed and not res.truncated.any()
    nbr_keys = res.rows[("key", 0)]
    src, dst = np.nonzero(nbr_keys >= 0)
    dst = nbr_keys[src, dst]
    indptr, indices = csr_from_coo(N, src.astype(np.int32),
                                   dst.astype(np.int32))
    print(f"snapshot: {len(src)} edges at ts={db.snapshot_ts()}")

    # ---- features correlate with labels so training can succeed ----------
    onehot = np.zeros((N, d_feat), np.float32)
    onehot[np.arange(N), labels_host % d_feat] = 2.0
    feats = (rng.normal(size=(N, d_feat)) * 0.5 + onehot).astype(np.float32)
    features = jnp.asarray(feats)
    labels = jnp.asarray(labels_host)

    scfg = sage.SageConfig(d_in=d_feat, d_hidden=32, n_classes=n_classes)
    params = sage.init_params(scfg, jax.random.key(0))
    ocfg = AdamWConfig(lr=5e-3)
    opt = init_opt_state(params, ocfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, aux), g = jax.value_and_grad(sage.loss_fn, has_aux=True)(
            params, scfg, batch)
        params, opt, _ = opt_update(params, g, opt, ocfg)
        return params, opt, loss, aux["acc"]

    key = jax.random.key(1)
    for it in range(60):
        key, k1, k2 = jax.random.split(key, 3)
        seeds = jax.random.choice(k1, N, (32,), replace=False)
        batch = build_sampled_batch(features, labels, indptr, indices,
                                    seeds, k2, fanouts=(5, 3))
        params, opt, loss, acc = step(params, opt, batch)
        if it % 10 == 0:
            print(f"iter {it:3d} loss={float(loss):.3f} "
                  f"seed-acc={float(acc):.2f}")
    print("final seed accuracy:", float(acc))

    # ---- live mutation + fresh snapshot keeps working ---------------------
    db.write([DeleteVertex(gids[0])])
    db.run_compaction()
    print("deleted a vertex; store still serves: ",
          len(db.get_edges(gids[1])), "edges at vertex 1")


if __name__ == "__main__":
    main()
