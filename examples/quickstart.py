"""Quickstart: the A1 graph database in 60 seconds.

Builds a small film knowledge graph through the transactional API, runs
A1QL traversal queries (the paper's Fig. 8 example), demonstrates snapshot
isolation + OCC aborts, and recovers the database from durable storage.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.query.executor import QueryCaps
from repro.core.recovery import best_effort_recover
from repro.core.replication import ObjectStore, ReplicationLog
from repro.core.writes import CreateEdge, CreateVertex, UpdateVertex


def main():
    # -- a database with a replication pipeline (disaster recovery, §4) ----
    store = ObjectStore()
    log = ReplicationLog(store)
    cfg = StoreConfig(n_shards=4, cap_v=256, cap_e=2048, cap_delta=256,
                      cap_idx=512, cap_idx_delta=128, d_f32=2, d_i32=2)
    db = GraphDB(cfg, replication_log=log)
    log.db = db

    # -- schema (strongly typed vertices/edges, §3) -------------------------
    db.vertex_type("director", i_attrs=("dob",))
    db.vertex_type("actor", i_attrs=("dob",))
    db.vertex_type("film", f_attrs=("gross",), i_attrs=("year", "genre"))
    db.edge_type("film.director")
    db.edge_type("film.actor")

    # -- one atomic transaction builds the graph ----------------------------
    # mutation-op records stage into an open transaction (gids returned
    # positionally at staging time); the transaction then commits as a
    # mutation wave.  The intra-txn edges use check=False — their endpoints
    # are uncommitted until the same wave lands.
    t = db.create_transaction()
    staged = db.write([
        CreateVertex("director", 1, {"dob": 1946}),
        CreateVertex("actor", 100, {"dob": 1956}),
        CreateVertex("actor", 101, {"dob": 1961}),
        CreateVertex("film", 1000, {"year": 1998, "genre": 1, "gross": 482.0}),
        CreateVertex("film", 1001, {"year": 1998, "genre": 2, "gross": 250.0}),
    ], txn=t)
    spielberg, hanks, ryan, private_ryan, mail = staged.gids
    db.write([
        CreateEdge(spielberg, private_ryan, "film.director", check=False),
        CreateEdge(private_ryan, hanks, "film.actor", check=False),
        CreateEdge(mail, hanks, "film.actor", check=False),
        CreateEdge(mail, ryan, "film.actor", check=False),
    ], txn=t)
    assert db.write([t]).statuses == ["COMMITTED"]
    print("graph committed; replication lag:", log.lag())

    # -- the paper's Fig. 8 query: actors who worked with Spielberg ---------
    q = {"type": "director", "id": 1,
         "_out_edge": {"type": "film.director",
                       "_target": {"type": "film",
                                   "_out_edge": {"type": "film.actor",
                                                 "_target": {"type": "actor",
                                                             "select": "count"}}}}}
    res = db.query([q], caps=QueryCaps())
    print("actors who worked with Spielberg:", int(res.counts[0]))

    # -- star pattern + chain in ONE batched call (fused operator waves) ----
    star = {"intersect": [
        {"type": "director", "id": 1,
         "_out_edge": {"type": "film.director", "_target": {"type": "film"}}},
        {"type": "actor", "id": 100,
         "_in_edge": {"type": "film.actor", "_target": {"type": "film"}}}],
        "select": "count"}
    both = db.query([q, star], caps=QueryCaps())
    print("films by Spielberg AND starring Hanks:", int(both.counts[1]),
          "(chain answer still", int(both.counts[0]), "— one fused program)")

    # -- snapshot isolation: readers never block on writers -----------------
    old_ts = db.snapshot_ts()
    db.write([UpdateVertex(hanks, "actor", {"dob": 1900})])
    f, i = db._read_data_host(hanks, old_ts)
    print("dob at old snapshot:", int(i[0]), "(still 1956)")

    # -- OCC: conflicting writers fused into one wave; first wins -----------
    t1, t2 = db.create_transaction(), db.create_transaction()
    db.write([UpdateVertex(ryan, "actor", {"dob": 1})], txn=t1)
    db.write([UpdateVertex(ryan, "actor", {"dob": 2})], txn=t2)
    wave = db.write([t1, t2])
    print("conflicting commits:", wave.statuses, "-", wave.reasons[1])

    # -- disaster recovery from ObjectStore ---------------------------------
    recovered = best_effort_recover(store, db, cfg)
    res2 = recovered.query([q], caps=QueryCaps())
    print("recovered DB answers the same query:", int(res2.counts[0]))
    assert res2.counts[0] == res.counts[0]
    print("OK")


if __name__ == "__main__":
    main()
