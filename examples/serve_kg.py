"""End-to-end serving driver: the paper's production workload (§5-6).

Builds a film knowledge graph at configurable scale through the
transactional write path, then serves the paper's query classes (Q1-Q4
analogues) through a 2-coordinator :class:`A1Frontend` fleet — SLB-style
least-loaded routing over ONE shared store, SLO-budget wave scheduling,
owner-stamped continuation tokens, live updates through the
write-admission queue — and finishes with the cluster front's signature
trick: a coordinator is killed mid-pagination and the surviving worker
takes the continuation over at the pinned snapshot, invisibly to the
client.

    PYTHONPATH=src python examples/serve_kg.py [--films 300] [--batches 30]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.query.executor import QueryCaps
from repro.core.writes import UpdateVertex
from repro.data.kg import build_film_kg
from repro.launch.cluster import A1Frontend


def q1(did):
    return {"type": "director", "id": int(did),
            "_out_edge": {"type": "film.director",
                          "_target": {"type": "film",
                                      "_out_edge": {"type": "film.actor",
                                                    "_target": {
                                                        "type": "actor",
                                                        "select": "count"}}}}}


def q3(did, aid):
    """Star pattern (paper Q3): films by director X AND starring actor Y —
    fused into the same wave batch as the chains since A1QL v2."""
    return {"intersect": [
        {"type": "director", "id": int(did),
         "_out_edge": {"type": "film.director", "_target": {"type": "film"}}},
        {"type": "actor", "id": int(aid),
         "_in_edge": {"type": "film.actor", "_target": {"type": "film"}}}],
        "select": "count"}


def q4(aid):
    """Co-star stress query (paper Q4: 3-hop, large fan-out)."""
    return {"type": "actor", "id": int(aid),
            "_in_edge": {"type": "film.actor",
                         "_target": {"type": "film",
                                     "_out_edge": {"type": "film.actor",
                                                   "_target": {
                                                       "type": "actor",
                                                       "select": "count"}}}}}


def drain(fe, pubs):
    """Poll every submitted id to its stored result (flush closes waves)."""
    fe.flush()
    rows = [fe.query_result(p) for p in pubs]
    assert all(r is not None for r in rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--films", type=int, default=300)
    ap.add_argument("--actors", type=int, default=400)
    ap.add_argument("--batches", type=int, default=30)
    ap.add_argument("--batch-size", type=int, default=16)
    args = ap.parse_args()

    print(f"building KG: {args.films} films / {args.actors} actors ...")
    t0 = time.time()
    kg = build_film_kg(n_films=args.films, n_actors=args.actors)
    db = kg.db
    print(f"  built in {time.time()-t0:.1f}s; commits={db.stats['commits']}")

    # 2 coordinators over ONE shared store (FastRestartCache rehydration);
    # a generous SLO budget keeps first-wave jit compiles from truncating
    # the warmup traffic — steady-state waves run far under it
    fe = A1Frontend(db, 2, caps=QueryCaps(frontier=2048, expand=16384,
                                          results=32),
                    read_batch=args.batch_size, budget_ms=60_000.0)
    rng = np.random.default_rng(0)

    for b in range(args.batches):
        # mixed chain + star batch: one fused wave program per batch shape
        dirs = rng.choice(kg.director_keys, args.batch_size)
        half = args.batch_size // 2
        pubs = [fe.submit_query(q1(d), qclass="Q1+Q3")
                for d in dirs[:half]]
        pubs += [fe.submit_query(q3(d, a), qclass="Q1+Q3")
                 for d, a in zip(dirs[half:],
                                 rng.choice(kg.actor_keys[:50],
                                            args.batch_size - half))]
        drain(fe, pubs)
        if b % 3 == 0:          # interleave the paper's stress query
            acts = rng.choice(kg.actor_keys[:50], args.batch_size)
            drain(fe, [fe.submit_query(q4(a), qclass="Q4") for a in acts])
        if b % 5 == 0:          # live updates via the write-admission queue:
            # staged at the admission snapshot, committed when the owning
            # coordinator's mutation wave closes — and visible to BOTH
            # coordinators at once, because the fleet shares one store
            f = int(rng.choice(kg.film_keys))
            gid, found = fe.db.lookup_vertex("film", f)
            if found:
                fe.submit_write([UpdateVertex(
                    gid, "film", {"gross": float(rng.uniform(1, 500))})])
    fe.flush()                  # close any wave still waiting on its budget

    # continuation handoff: kill the owning coordinator after page 1 and
    # let the survivor adopt the token at the pinned snapshot
    star = int(kg.actor_keys[0])
    sel = {"type": "actor", "id": star,
           "_in_edge": {"type": "film.actor",
                        "_target": {"type": "film", "select": ["key"]}}}
    page, token = fe.select_paged(sel)
    pages, rows = 1, len(page)
    owner = fe._tokmeta[token]["cid"] if token is not None else None
    if owner is not None:
        fe.kill_worker(owner)
        print(f"killed coordinator {owner} mid-pagination ...")
    while token is not None:
        page, token = fe.next_page(token)
        pages += 1
        rows += len(page)
    print(f"paged select for mega-actor {star}: {pages} page(s), "
          f"{rows} row(s), takeovers={fe.stats['takeovers']}")

    st = fe.cluster_stats()
    print("\nfrontend:", st["frontend"])
    print("budget spend (ms buckets):", st["budget_spend_ms"])
    for cid, ws in st["workers"].items():
        print(f"coordinator {cid}: admitted={ws['admitted']} "
              f"served={ws['served']} waves={ws['read_waves']}")
    print("db stats:", fe.db.stats)
    fe.close()


if __name__ == "__main__":
    main()
