"""End-to-end serving driver: the paper's production workload (§5-6).

Builds a film knowledge graph at configurable scale through the
transactional write path, then serves the paper's query classes (Q1-Q4
analogues) through the A1Server loop — batched execution at snapshot
timestamps, continuation tokens, hedged retries, background compaction —
while a writer thread applies live updates (the "real-time updates"
requirement that motivated A1 over the old immutable stack, §5).

    PYTHONPATH=src python examples/serve_kg.py [--films 300] [--batches 30]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.query.executor import QueryCaps
from repro.core.writes import UpdateVertex
from repro.data.kg import build_film_kg
from repro.launch.serve import A1Server


def q1(did):
    return {"type": "director", "id": int(did),
            "_out_edge": {"type": "film.director",
                          "_target": {"type": "film",
                                      "_out_edge": {"type": "film.actor",
                                                    "_target": {
                                                        "type": "actor",
                                                        "select": "count"}}}}}


def q3(did, aid):
    """Star pattern (paper Q3): films by director X AND starring actor Y —
    fused into the same wave batch as the chains since A1QL v2."""
    return {"intersect": [
        {"type": "director", "id": int(did),
         "_out_edge": {"type": "film.director", "_target": {"type": "film"}}},
        {"type": "actor", "id": int(aid),
         "_in_edge": {"type": "film.actor", "_target": {"type": "film"}}}],
        "select": "count"}


def q4(aid):
    """Co-star stress query (paper Q4: 3-hop, large fan-out)."""
    return {"type": "actor", "id": int(aid),
            "_in_edge": {"type": "film.actor",
                         "_target": {"type": "film",
                                     "_out_edge": {"type": "film.actor",
                                                   "_target": {
                                                       "type": "actor",
                                                       "select": "count"}}}}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--films", type=int, default=300)
    ap.add_argument("--actors", type=int, default=400)
    ap.add_argument("--batches", type=int, default=30)
    ap.add_argument("--batch-size", type=int, default=16)
    args = ap.parse_args()

    print(f"building KG: {args.films} films / {args.actors} actors ...")
    t0 = time.time()
    kg = build_film_kg(n_films=args.films, n_actors=args.actors)
    db = kg.db
    print(f"  built in {time.time()-t0:.1f}s; commits={db.stats['commits']}")

    server = A1Server(db, caps=QueryCaps(frontier=2048, expand=16384,
                                         results=32))
    server.enqueue_maintenance()
    rng = np.random.default_rng(0)

    for b in range(args.batches):
        # mixed chain + star batch: one fused wave program per batch shape
        dirs = rng.choice(kg.director_keys, args.batch_size)
        batch = [q1(d) for d in dirs[: args.batch_size // 2]]
        batch += [q3(d, a) for d, a in
                  zip(dirs[args.batch_size // 2:],
                      rng.choice(kg.actor_keys[:50],
                                 args.batch_size - len(batch)))]
        res = server.execute(batch, qclass="Q1+Q3")
        if b % 3 == 0:          # interleave the paper's stress query
            acts = rng.choice(kg.actor_keys[:50], args.batch_size)
            server.execute([q4(a) for a in acts], qclass="Q4")
        if b % 5 == 0:          # live updates via the write-admission queue:
            # staged at the admission snapshot, committed when the next
            # query batch closes the mutation wave (max-batch-or-deadline)
            f = int(rng.choice(kg.film_keys))
            gid, found = db.lookup_vertex("film", f)
            if found:
                server.submit_write([UpdateVertex(
                    gid, "film", {"gross": float(rng.uniform(1, 500))})])
    server.flush_writes()       # close any wave still waiting on a deadline

    # continuation tokens: a select query with a larger-than-page result
    star = int(kg.actor_keys[0])
    sel = {"type": "actor", "id": star,
           "_in_edge": {"type": "film.actor",
                        "_target": {"type": "film", "select": ["key"]}}}
    page, token = server.select_paged(sel)
    pages = 1
    while token is not None:
        page, token = server.next_page(token)
        pages += 1
    print(f"paged select for mega-actor {star}: {pages} page(s)")

    print("\nlatency report (ms):")
    for k, v in server.latency_report().items():
        print(f"  {k}: avg={v['avg_ms']:.1f}  p99={v['p99_ms']:.1f} "
              f"(n={v['n']})")
    print("server stats:", server.stats)
    print("db stats:", db.stats)


if __name__ == "__main__":
    main()
