"""Train a ~100M-parameter LM with the full production loop.

Uses the real substrate stack: data pipeline with prefetch, AdamW with
cosine schedule, checkpoint/restart (kill it mid-run and re-launch — it
resumes), and the same step builder the dry-run lowers at 405B scale.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 20 --d-model 128  # demo
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.data.tokens import token_pipeline
from repro.launch.mesh import make_test_mesh
from repro.models.transformer import LMConfig, init_params, loss_fn
from repro.optim.optimizers import AdamWConfig, init_opt_state, opt_update
from repro.optim.schedules import linear_warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = LMConfig(
        name="lm-100m", n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, n_kv_heads=args.d_model // 128,
        d_head=64, d_ff=4 * args.d_model, vocab=32768,
        dtype=jnp.float32, remat=False)
    print(f"model: {cfg.n_params()/1e6:.1f}M params")

    ocfg = AdamWConfig(lr=3e-4)
    params = init_params(cfg, jax.random.key(0))
    opt_state = init_opt_state(params, ocfg)

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if mgr.latest_step() is not None:
        (params, opt_state), manifest = mgr.restore((params, opt_state))
        start = manifest["step"]
        print(f"resumed from checkpoint at step {start}")

    @jax.jit
    def step_fn(params, opt_state, tokens, targets):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, tokens, targets)
        lr_scale = linear_warmup_cosine(opt_state.step, warmup_steps=20,
                                        total_steps=args.steps)
        params, opt_state, gnorm = opt_update(params, grads, opt_state,
                                              ocfg, lr_scale)
        return params, opt_state, loss, gnorm

    data = token_pipeline(batch=args.batch, seq=args.seq, vocab=cfg.vocab)
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        toks, tgts = next(data)
        params, opt_state, loss, gnorm = step_fn(params, opt_state, toks,
                                                 tgts)
        losses.append(float(loss))
        if step % 10 == 0:
            rate = args.batch * args.seq / ((time.time() - t0)
                                            / max(step - start + 1, 1))
            print(f"step {step:4d}  loss={float(loss):.4f} "
                  f"gnorm={float(gnorm):.2f}  {rate/1e3:.1f}k tok/s")
        if (step + 1) % 50 == 0:
            mgr.save(step + 1, (params, opt_state), meta={"loss": float(loss)})
    mgr.wait()
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f}); "
          f"checkpoints at {args.ckpt_dir}")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
