"""Training checkpoint/restart with elastic resume.

Fault-tolerance contract for the training driver:
  * async save (a worker thread serializes off the critical path — the step
    loop never blocks on disk);
  * atomic publish (write to tmp dir, rename) so a crash mid-save never
    corrupts the latest checkpoint;
  * keep-N retention;
  * **elastic resume**: checkpoints store unsharded logical arrays + the
    pytree structure; ``restore`` re-device_puts onto whatever mesh/sharding
    the *new* job uses — restarting 512-chip training on 256 chips (or vice
    versa) is a sharding change, not a format change.

bf16 is serialized via ml_dtypes (numpy-compatible).  No orbax/tensorstore
in this environment — this manager IS the substrate.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    if hasattr(jax.tree, "flatten_with_path"):
        flat, treedef = jax.tree.flatten_with_path(tree)
    else:                              # jax 0.4.x spelling
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, meta: Optional[dict] = None,
             blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write to disk async."""
        self.wait()                      # one in-flight save at a time
        host_leaves = jax.tree.map(np.asarray, tree)   # D2H copy now

        def work():
            try:
                self._write(step, host_leaves, meta or {})
                self._retain()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _write(self, step: int, tree, meta: dict) -> None:
        paths, leaves, _ = _flatten_with_paths(tree)
        tmp = os.path.join(self.dir, f".tmp_ckpt_{step}")
        final = os.path.join(self.dir, f"ckpt_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "meta": meta, "leaves": []}
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(leaf)
            fn = f"leaf_{i}.npy"
            dtype_name = arr.dtype.name
            if dtype_name == "bfloat16":
                np.save(os.path.join(tmp, fn), arr.view(np.uint16))
            else:
                np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append(
                {"path": p, "file": fn, "dtype": dtype_name,
                 "shape": list(arr.shape)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish

    def _retain(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"ckpt_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("ckpt_"):
                out.append(int(fn.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, *, step: Optional[int] = None,
                shardings=None):
        """Rebuild the pytree.  ``template`` provides structure; values come

        from disk.  ``shardings`` (same structure) re-shards onto the new
        mesh — the elastic-resume path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.dir, f"ckpt_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, leaves, treedef = _flatten_with_paths(template)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        out = []
        shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(leaves))
        import ml_dtypes
        for p, tmpl, sh in zip(paths, leaves, shard_flat):
            e = by_path[p]
            arr = np.load(os.path.join(d, e["file"]))
            if e["dtype"] == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out), manifest
