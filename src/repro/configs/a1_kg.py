"""a1-kg — the paper's own workload (§6) as an architecture config.

The Bing film/entertainment knowledge graph served by A1: one weakly-typed
``entity`` vertex type (~220-byte payloads -> 32 f32 + 16 i32 columns),
strongly-typed edges, paper-scale 3.7 B vertices / 6.2 B edges sharded over
the whole pod (the cluster's 245 machines -> 256 chips; DESIGN.md §2 #4 on
the replication budget).  Shape cells mirror the paper's query classes:

  serve_q1   Q=64  2-hop count     (Fig. 10: "actors who worked with X")
  serve_q2   Q=64  3-hop count     (Fig. 12: "actors who played Batman")
  serve_q3   Q=64  star intersect  (Fig. 13: director AND actor AND genre)
  update     commit-batch apply    (the OLTP write path)
"""
import dataclasses

from repro.configs.registry import ArchSpec, ShapeCell, register
from repro.core.addressing import StoreConfig

# paper scale: 3.7B vertices, 6.2B edges (both halves stored) over 256 chips
FULL = StoreConfig(
    n_shards=256,
    cap_v=15_000_000,          # 3.84B vertex slots
    cap_e=50_000_000,          # 12.8B half-edge slots (6.2B edges x 2)
    cap_delta=16_384,
    cap_idx=16_000_000,
    cap_idx_delta=16_384,
    d_f32=32, d_i32=16,        # ~220-byte schematized payload
    replication=1,             # in-pod replication=1 at paper scale (16GB
                               # HBM/chip); the pod axis is the DR replica
)

REDUCED = StoreConfig(n_shards=8, cap_v=512, cap_e=4096, cap_delta=512,
                      cap_idx=1024, cap_idx_delta=256, d_f32=4, d_i32=4)

# §Perf iter 2: A1QL capacity *hints* sized to the measured Q1-Q3 working
# sets (was frontier=8192, expand=65536, bucket=512) — every sort/gather in
# the BSP hop scales with these.
_QCAPS = dict(frontier=4096, expand=16384, bucket=256, results=64)

SPEC = register(ArchSpec(
    arch_id="a1-kg", family="a1", model=FULL, reduced=REDUCED,
    shapes=(
        ShapeCell("serve_q1", "a1_serve",
                  dict(n_queries=64, hops=2, caps=_QCAPS)),
        ShapeCell("serve_q2", "a1_serve",
                  dict(n_queries=64, hops=3, caps=_QCAPS)),
        ShapeCell("serve_q3", "a1_serve",
                  dict(n_queries=64, hops=1, star=2, caps=_QCAPS)),
        ShapeCell("update", "a1_update", dict()),
    ),
    source="SIGMOD'20 A1 paper §6",
    note="the reproduction target itself: distributed traversal with query "
         "shipping, MVCC snapshot reads, fast-fail capacities.",
))
