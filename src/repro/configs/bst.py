"""bst [arXiv:1905.06874; paper]

Behavior Sequence Transformer (Alibaba): embed_dim 32, seq_len 20,
1 transformer block, 8 heads, MLP 1024-512-256.  The item table is the
huge-embedding regime: 10^8 rows, row-sharded over the whole mesh, fetched
with the A1 query-shipping lookup — the arch where the paper's technique is
most directly load-bearing.
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, recsys_shapes, register
from repro.models.recsys import BSTConfig

FULL = BSTConfig(name="bst", n_items=100_000_000, embed_dim=32, seq_len=20,
                 n_blocks=1, n_heads=8, d_ff=128,
                 mlp_dims=(1024, 512, 256), n_dense=8, dtype=jnp.float32)

REDUCED = BSTConfig(name="bst-reduced", n_items=1000, embed_dim=32,
                    seq_len=20, n_blocks=1, n_heads=8, d_ff=64,
                    mlp_dims=(64, 32), n_dense=8, dtype=jnp.float32)

SPEC = register(ArchSpec(
    arch_id="bst", family="recsys", model=FULL, reduced=REDUCED,
    shapes=recsys_shapes(),
    source="arXiv:1905.06874; verified-tier: paper",
    note="embedding lookup = distributed A1 vertex read (query shipping); "
         "retrieval_cand = one batched matmul against 1M candidates.",
))
