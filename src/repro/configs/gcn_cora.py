"""gcn-cora [arXiv:1609.02907; paper]

2 layers, d_hidden 16, mean/sym aggregation — the classic Kipf & Welling
citation-network configuration.
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, gnn_shapes, register
from repro.models.gnn.gcn import GCNConfig

FULL = GCNConfig(name="gcn-cora", n_layers=2, d_in=1433, d_hidden=16,
                 n_classes=7, norm="sym", dtype=jnp.float32)

REDUCED = GCNConfig(name="gcn-reduced", n_layers=2, d_in=64, d_hidden=8,
                    n_classes=7, norm="sym", dtype=jnp.float32)

SPEC = register(ArchSpec(
    arch_id="gcn-cora", family="gnn", model=FULL, reduced=REDUCED,
    shapes=gnn_shapes(d_feat_sm=1433, n_classes=7),
    source="arXiv:1609.02907; verified-tier: paper",
    note="full-graph SpMM over the A1 CSR store (segment_sum message "
         "passing; segment_spmm Pallas kernel on TPU).",
))
