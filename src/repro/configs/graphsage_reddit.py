"""graphsage-reddit [arXiv:1706.02216; paper]

2 layers, d_hidden 128, mean aggregator, fanout 25-10.  The minibatch cell
uses the real fanout sampler (data/sampler.py) — a bounded A1 traversal.
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, gnn_shapes, register
from repro.models.gnn.sage import SageConfig

FULL = SageConfig(name="graphsage-reddit", n_layers=2, d_in=602,
                  d_hidden=128, n_classes=41, dtype=jnp.float32)

REDUCED = SageConfig(name="sage-reduced", n_layers=2, d_in=32, d_hidden=16,
                     n_classes=8, dtype=jnp.float32)

SPEC = register(ArchSpec(
    arch_id="graphsage-reddit", family="gnn", model=FULL, reduced=REDUCED,
    shapes=gnn_shapes(d_feat_sm=1433, n_classes=41),
    source="arXiv:1706.02216; verified-tier: paper",
    note="fanout sampling IS an A1 multi-hop traversal with per-hop "
         "capacity (DESIGN.md §5); sampler: data/sampler.py.",
))
