"""h2o-danube-3-4b [arXiv:2401.16818; unverified]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 — llama+mistral mix
with sliding-window attention.  SWA (window 4096) makes this the one
assigned LM that runs the sub-quadratic ``long_500k`` cell: the decode KV
cache is a window-bounded ring buffer.
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="h2o-danube-3-4b",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_head=120,
    d_ff=10240, vocab=32000, window=4096,
    block_pattern=("dense",), dtype=jnp.bfloat16, remat=True)

REDUCED = LMConfig(
    name="danube-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab=512, window=32, block_pattern=("dense",),
    dtype=jnp.float32, remat=False)

SPEC = register(ArchSpec(
    arch_id="h2o-danube-3-4b", family="lm", model=FULL, reduced=REDUCED,
    shapes=lm_shapes(window=4096, accum_train=1),   # §Perf iter 2: accum 1
    source="arXiv:2401.16818; unverified",
    note="SWA window 4096; long_500k decode uses the ring-buffer cache "
         "(memory O(window), compute O(window) per token).",
    # §Perf iter 2 (after iter 1's ZeRO-1 was refuted — the 375GB of
    # all-reduce was TP *activation* traffic, not FSDP gathers): a 4B model
    # on 256 chips wants NO tensor parallelism at train_4k.  Pure DP over
    # the whole mesh (1 seq/device), params replicated, optimizer states +
    # grad accumulator ZeRO-sharded over all 256 devices.  Collectives
    # reduce to one grad reduce + one param gather per step.
    rules_override={"fsdp": None, "tensor": None, "heads": None,
                    "kv_heads": None, "ff": None, "vocab": None,
                    "batch": ("pod", "data", "model")},
    opt_rules_override={"fsdp": ("data", "model")},
))
