"""llama3-405b [arXiv:2407.21783; unverified]

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.  Dense.
The memory budget on a 16 GB/chip v5e pod forces Adafactor-class optimizer
states + sequence-parallel activations + gradient accumulation
(DESIGN.md §4); the multi-pod mesh can alternatively run the pod axis as
pipeline stages (dist/pipeline.py).
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="llama3-405b",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
    d_ff=53248, vocab=128256,
    block_pattern=("dense",), dtype=jnp.bfloat16, remat=True)

REDUCED = LMConfig(
    name="llama3-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab=512, block_pattern=("dense",), dtype=jnp.float32,
    remat=False)

SPEC = register(ArchSpec(
    arch_id="llama3-405b", family="lm", model=FULL, reduced=REDUCED,
    shapes=lm_shapes(window=0, accum_train=16),
    source="arXiv:2407.21783; unverified",
    note="A1 technique inapplicable (dense, no sparse lookup on the hot "
         "path) — built without it, per DESIGN.md §5.",
))
