"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1, early fusion.  Llama-4 interleaves dense and MoE layers; we model
the assigned config as ("dense","moe") cycles with per-expert d_ff=8192
(~400B total, ~17B active with top-1).  The modality frontend of the
early-fusion stack is a stub per the assignment (input_specs provides
token/patch embeddings).
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=202048,
    block_pattern=("dense", "moe"), n_experts=128, top_k=1,
    expert_d_ff=8192, dtype=jnp.bfloat16, remat=True)

REDUCED = LMConfig(
    name="llama4-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, block_pattern=("dense", "moe"), n_experts=8,
    top_k=1, expert_d_ff=128, dtype=jnp.float32, remat=False)

SPEC = register(ArchSpec(
    arch_id="llama4-maverick-400b-a17b", family="lm", model=FULL,
    reduced=REDUCED, shapes=lm_shapes(window=0, accum_train=16),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    note="top-1 routing; dense|moe interleave; early-fusion frontend "
         "stubbed (precomputed patch embeddings).",
))
