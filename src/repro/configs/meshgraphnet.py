"""meshgraphnet [arXiv:2010.03409; unverified]

15 processor layers, d_hidden 128, sum aggregation, 2-layer MLPs.
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, gnn_shapes, register
from repro.models.gnn.meshgraphnet import MGNConfig

FULL = MGNConfig(name="meshgraphnet", n_layers=15, d_hidden=128,
                 mlp_layers=2, d_in=8, d_edge_in=4, d_out=3,
                 dtype=jnp.float32)

REDUCED = MGNConfig(name="mgn-reduced", n_layers=3, d_hidden=32,
                    mlp_layers=2, d_in=8, d_edge_in=4, d_out=3,
                    dtype=jnp.float32)

SPEC = register(ArchSpec(
    arch_id="meshgraphnet", family="gnn", model=FULL, reduced=REDUCED,
    shapes=gnn_shapes(d_feat_sm=1433, n_classes=3),
    note="mesh edges live in the A1 CSR store; message passing = edge "
         "enumeration + scatter.",
    source="arXiv:2010.03409; unverified",
))
