"""nequip [arXiv:2101.03164; paper]

5 interaction layers, hidden mul 32, l_max=2, 8 RBF, 5 A cutoff,
E(3)-tensor-product equivariance (irrep regime of the kernel taxonomy).
Graph cells that lack positions get synthetic 3D coordinates from the data
pipeline (input_specs supplies them).
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, gnn_shapes, register
from repro.models.gnn.nequip import NequIPConfig

FULL = NequIPConfig(name="nequip", n_layers=5, mul=32, l_max=2, n_rbf=8,
                    cutoff=5.0, n_species=8, dtype=jnp.float32)

REDUCED = NequIPConfig(name="nequip-reduced", n_layers=2, mul=8, l_max=2,
                       n_rbf=4, cutoff=5.0, n_species=4, dtype=jnp.float32)

SPEC = register(ArchSpec(
    arch_id="nequip", family="gnn", model=FULL, reduced=REDUCED,
    shapes=gnn_shapes(d_feat_sm=1433, n_classes=7),
    source="arXiv:2101.03164; verified-tier: paper",
    note="neighbor lists come from the A1 store's edge enumeration; "
         "energies rotation-invariant (property-tested).  eSCN O(L^3) "
         "contraction unnecessary at l_max=2 (paths are tiny).",
))
