"""qwen1.5-32b [hf:Qwen/Qwen1.5-0.5B; hf]

64L d_model=5120 40H (GQA kv=40 = full MHA) d_ff=27392 vocab=152064,
QKV bias (the Qwen1.5 signature).
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen1.5-32b",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_head=128,
    d_ff=27392, vocab=152064, qkv_bias=True,
    block_pattern=("dense",), dtype=jnp.bfloat16, remat=True)

REDUCED = LMConfig(
    name="qwen15-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=8, d_head=16,
    d_ff=256, vocab=512, qkv_bias=True, block_pattern=("dense",),
    dtype=jnp.float32, remat=False)

SPEC = register(ArchSpec(
    arch_id="qwen1.5-32b", family="lm", model=FULL, reduced=REDUCED,
    shapes=lm_shapes(window=0, accum_train=8),
    source="hf:Qwen/Qwen1.5-0.5B (family layout); verified-tier: hf",
    note="QKV bias; kv_heads == heads (MHA); A1 technique inapplicable "
         "(dense).",
))
