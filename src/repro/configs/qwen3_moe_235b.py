"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B; hf]

94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128 experts
top-8.  Every layer is MoE (Qwen3-MoE layout); d_ff=1536 is the per-expert
width.  ~235B total / ~22B active.
"""
import jax.numpy as jnp

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151936,
    block_pattern=("moe",), n_experts=128, top_k=8, expert_d_ff=1536,
    moe_groups=16,          # §Perf iter 1: group-local dispatch (was 0)
    dtype=jnp.bfloat16, remat=True)

REDUCED = LMConfig(
    name="qwen3-moe-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=96, vocab=512, block_pattern=("moe",), n_experts=8, top_k=2,
    expert_d_ff=96, dtype=jnp.float32, remat=False)

SPEC = register(ArchSpec(
    arch_id="qwen3-moe-235b-a22b", family="lm", model=FULL, reduced=REDUCED,
    shapes=lm_shapes(window=0, accum_train=8),   # §Perf iter 2 (was 16)
    source="hf:Qwen/Qwen3-30B-A3B (scaled family layout); verified-tier: hf",
    note="MoE token dispatch = A1 query shipping (all_to_all to expert "
         "owners); see DESIGN.md §5.",
    rules_override={"seq": "model"},   # sequence parallelism for activations
))
