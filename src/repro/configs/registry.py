"""Architecture registry: --arch <id> -> model config + shape cells.

Every assigned architecture registers an :class:`ArchSpec` carrying its
full-size model config, a *reduced* config (CPU smoke tests), and its shape
cells.  The dry-run driver enumerates ``spec.shapes`` and lowers one step
function per (arch x shape x mesh) through launch/steps.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

_REGISTRY: dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    shape_id: str
    kind: str                  # 'train' | 'prefill' | 'decode' | 'serve' |
    #                            'retrieval' | 'graph_train' | 'a1_serve'
    geometry: dict             # family-specific geometry numbers
    skip: Optional[str] = None   # reason string when the cell is N/A


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                # 'lm' | 'gnn' | 'recsys' | 'a1'
    model: Any                 # full-size config (dry-run only)
    reduced: Any               # reduced config (CPU smoke tests)
    shapes: tuple              # tuple[ShapeCell, ...]
    source: str = ""
    note: str = ""
    rules_override: dict = dataclasses.field(default_factory=dict)
    # optimizer-state/grad-accum sharding rules (ZeRO-style splits where
    # params and optimizer shard differently); defaults to rules_override
    opt_rules_override: dict = dataclasses.field(default_factory=dict)

    def cell(self, shape_id: str) -> ShapeCell:
        for c in self.shapes:
            if c.shape_id == shape_id:
                return c
        raise KeyError(f"{self.arch_id} has no shape {shape_id!r}")


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    return _REGISTRY[arch_id]


def all_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) pair, including skipped cells."""
    _ensure_loaded()
    return [(a, c.shape_id) for a in all_archs()
            for c in _REGISTRY[a].shapes]


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in ("qwen3_moe_235b", "llama4_maverick_400b", "llama3_405b",
                "h2o_danube_3_4b", "qwen15_32b", "nequip", "gcn_cora",
                "meshgraphnet", "graphsage_reddit", "bst", "a1_kg"):
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True


# ---------------------------------------------------------------------------
# family shape templates
# ---------------------------------------------------------------------------

def lm_shapes(*, window: int = 0, accum_train: int = 16) -> tuple:
    """The 4 assigned LM cells.  long_500k runs only for sub-quadratic
    attention (SWA); full-attention archs record the skip (DESIGN.md §5)."""
    long_skip = (None if window > 0 else
                 "pure full-attention arch: 524k-token cell would be "
                 "quadratic; run only for SWA/SSM/linear-attn per assignment")
    return (
        ShapeCell("train_4k", "train",
                  dict(seq_len=4096, global_batch=256,
                       accum=accum_train)),
        ShapeCell("prefill_32k", "prefill",
                  dict(seq_len=32768, global_batch=32)),
        ShapeCell("decode_32k", "decode",
                  dict(seq_len=32768, global_batch=128)),
        ShapeCell("long_500k", "decode",
                  dict(seq_len=524288, global_batch=1), skip=long_skip),
    )


def gnn_shapes(*, d_feat_sm: int, n_classes: int) -> tuple:
    """The 4 assigned GNN cells (geometry is shape-owned; d_feat per cell)."""
    return (
        ShapeCell("full_graph_sm", "graph_train",
                  dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                       n_classes=n_classes)),
        ShapeCell("minibatch_lg", "graph_train",
                  dict(n_base_nodes=232_965, n_base_edges=114_615_892,
                       batch_nodes=1024, fanout=(15, 10), d_feat=602,
                       n_classes=n_classes, sampled=True)),
        ShapeCell("ogb_products", "graph_train",
                  dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                       n_classes=n_classes)),
        ShapeCell("molecule", "graph_train",
                  dict(batch=128, n_nodes=30, n_edges=64, d_feat=8,
                       n_classes=n_classes, molecule=True)),
    )


def recsys_shapes() -> tuple:
    return (
        ShapeCell("train_batch", "train", dict(batch=65_536)),
        ShapeCell("serve_p99", "serve", dict(batch=512)),
        ShapeCell("serve_bulk", "serve", dict(batch=262_144)),
        ShapeCell("retrieval_cand", "retrieval",
                  dict(batch=1, n_candidates=1_000_000)),
    )
