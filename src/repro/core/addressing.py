"""Addressing: the FaRM 64-bit (region, offset) pointer, adapted to TPU shards.

A1/FaRM addresses are ``(region_id:32, offset:32)`` pairs; the Configuration
Manager maps region -> machine.  On a TPU mesh the "machine" is a mesh shard,
and we encode the mapping *arithmetically* so that pointer -> owner resolution
is a pure local computation (the paper's "mapping pointers to physical hosts is
a local metadata operation with no remote accesses"):

    gid   = slot * n_shards + shard        (global vertex id, int32)
    owner = gid %  n_shards                (which shard holds the record)
    slot  = gid // n_shards                (offset within the shard)

Sequential allocation round-robins shards, reproducing A1's "vertices are
placed randomly across the whole cluster".  Allocation *hints* (FaRM's
``Alloc(size, hint)``) are honored by allocating in the hint's shard.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Sentinels
# ---------------------------------------------------------------------------
NULL = np.int32(-1)            # null pointer / empty slot marker
TS_INF = np.int32(2**31 - 1)   # "live forever" delete timestamp
TS_ZERO = np.int32(0)

I32 = jnp.int32
F32 = jnp.float32


def owner_of(gid, n_shards: int):
    """Shard that owns a global id.  Works on ints or arrays."""
    return gid % n_shards


def slot_of(gid, n_shards: int):
    """Local slot of a global id within its owner shard."""
    return gid // n_shards


def gid_of(shard, slot, n_shards: int):
    """Compose a global id from (shard, slot)."""
    return slot * n_shards + shard


def hash_route(key, salt, n_shards: int):
    """Route a primary key to an index shard (A1 routes through the BTree;

    we hash-partition the sorted index).  Knuth multiplicative mix keeps
    adjacent keys from landing on the same shard.
    """
    h = (key * np.int32(-1640531527)) ^ (salt * np.int32(97))  # 2654435769 as i32
    return (h % n_shards + n_shards) % n_shards


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    """Static layout of a sharded graph store (the FaRM region geometry).

    Capacities are *per shard*.  All device arrays derived from this config
    have static shapes; running out of capacity is surfaced as a fast-fail
    flag (the paper fast-fails queries whose working set outgrows memory).
    """

    n_shards: int = 1            # number of storage shards (devices)
    cap_v: int = 1024            # vertex slots per shard
    cap_e: int = 8192            # out-edge CSR pool entries per shard
    cap_delta: int = 1024        # edge delta-log entries per shard
    cap_idx: int = 2048          # primary-index entries per shard
    cap_idx_delta: int = 512     # primary-index delta entries per shard
    cap_vec: int = 0             # vector-index entries per shard (0 = off)
    d_f32: int = 4               # float32 attribute columns per vertex
    d_i32: int = 4               # int32 attribute columns per vertex
    d_ef32: int = 0              # float32 attribute columns per edge
    with_in_edges: bool = True   # maintain incoming half-edges (reverse CSR)
    replication: int = 1         # in-pod replica groups (fault domains)

    @property
    def total_v(self) -> int:
        return self.n_shards * self.cap_v

    @property
    def total_e(self) -> int:
        return self.n_shards * self.cap_e

    def row_of_gid(self, gid):
        """Row index into the flat (shard-major) vertex arrays."""
        return (gid % self.n_shards) * self.cap_v + gid // self.n_shards

    def indptr_row(self, gid):
        """Row into the flat indptr array (shard-major, cap_v+1 per shard)."""
        shard = gid % self.n_shards
        slot = gid // self.n_shards
        return shard * (self.cap_v + 1) + slot

    def validate(self) -> None:
        assert self.n_shards >= 1
        assert self.cap_v >= 1 and self.cap_e >= 1
        assert self.cap_v * self.n_shards < 2**31, "gid space overflow"


def ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m
