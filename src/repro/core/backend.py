"""Backend dispatch for the read hot path (edge enumeration + index probes).

A1's headline read throughput comes from a purpose-built RDMA read path
(§3.4); ours comes from the Pallas kernels under ``repro.kernels``.  This
module is the seam between the *semantics* layer (``core/edges.py``,
``core/index.py`` — pure jnp, the oracle) and the *hardware* layer (the
``edge_expand`` and ``sorted_lookup`` kernels): every hot read operator asks
the backend which implementation to run.

Contract
--------
A :class:`Backend` is a frozen (hashable) value threaded through the jitted
query programs as part of their cache key:

  * ``kind="ref"``     — the branchless jnp reference path.  Defines the
    semantics; always available.
  * ``kind="pallas"``  — the Pallas kernels.  Compiled on TPU; everywhere
    else they run in interpret mode (bit-identical by the kernel test
    suites, and by construction here: the kernel output is scattered into
    the reference layout, see ``edges.expand``).

Selection (first match wins):

  1. an explicit ``backend=`` argument to ``run_queries`` /
     ``compile_query`` / ``GraphDB(backend=...)``;
  2. the ``REPRO_BACKEND`` environment variable (``ref``/``pallas``/``auto``);
  3. ``auto``: ``pallas`` when the default jax backend is TPU (the hardware
     the kernels were written for), ``ref`` otherwise — CPU CI keeps running
    the cheap oracle, TPU runs at line rate, no code changes anywhere.

Adding the next kernel: give the op a jnp reference in the semantics layer,
add a ``Backend``-dispatched helper here, and key any program cache on the
backend.  See ``src/repro/core/README.md`` for the worked ``segment_spmm``
example.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax

_VALID = ("ref", "pallas", "auto")
ENV_VAR = "REPRO_BACKEND"


@dataclasses.dataclass(frozen=True)
class Backend:
    """Resolved backend choice.  Frozen: usable in jit/program cache keys."""

    kind: str                 # 'ref' | 'pallas'
    interpret: bool = False   # pallas kernels run in interpret mode (no TPU)

    @property
    def is_pallas(self) -> bool:
        return self.kind == "pallas"


REF = Backend("ref")


def resolve(spec: Optional[str] = None) -> Backend:
    """Resolve a backend name (or None) to a concrete :class:`Backend`.

    ``None`` falls back to ``$REPRO_BACKEND``, then ``auto``.
    """
    name = spec or os.environ.get(ENV_VAR, "") or "auto"
    if name not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {name!r}")
    on_tpu = jax.default_backend() == "tpu"
    if name == "auto":
        name = "pallas" if on_tpu else "ref"
    if name == "ref":
        return REF
    return Backend("pallas", interpret=not on_tpu)


# ---------------------------------------------------------------------------
# dispatched primitives
# ---------------------------------------------------------------------------

def expand_tiles(starts, degs, pools, *, tile: int, cap_tiles: int,
                 backend: Backend):
    """Tile-padded ragged CSR span gather (the edge-enumeration primitive).

    Returns (outs, item_of_tile, tw_of_tile, n_tiles): ``outs[i]`` is
    ``pools[i]`` gathered to (cap_tiles*tile,) with -1 in invalid lanes;
    lane j of tile t is edge ``tw_of_tile[t]*tile + j`` of frontier item
    ``item_of_tile[t]`` (item == F marks a padding tile).
    """
    from repro.kernels.edge_expand import ref as _ref
    item, tw, n_tiles, _ = _ref.plan(degs, tile, cap_tiles)
    if backend.is_pallas:
        from repro.kernels.edge_expand.kernel import expand as _kernel
        outs = _kernel(starts, degs, tuple(pools), item, tw, tile=tile,
                       cap_tiles=cap_tiles, interpret=backend.interpret)
    else:
        outs, _, _ = _ref.expand(starts, degs, tuple(pools), tile, cap_tiles)
    return outs, item, tw, n_tiles


def searchsorted_blocked(keys, queries, lo, *, block: int, backend: Backend):
    """Left insertion position of each query within its own sorted block.

    ``keys`` is a flat block-major array whose slice ``[lo[q], lo[q]+block)``
    is sorted for every query q.  Returns block-relative positions, exactly
    ``jnp.searchsorted(keys[lo:lo+block], query, side='left')``.
    """
    import jax.numpy as jnp
    if backend.is_pallas:
        from repro.kernels.sorted_lookup.kernel import searchsorted_left_ranged
        return searchsorted_left_ranged(keys, queries, lo, lo + block,
                                        interpret=backend.interpret)
    # reference: per-query dynamic slice + binary search
    def one(q, l):
        blk = jax.lax.dynamic_slice(keys, (l,), (block,))
        return jnp.searchsorted(blk, q, side="left").astype(jnp.int32)
    return jax.vmap(one)(queries, lo)


def searchsorted(keys, queries, *, backend: Backend):
    """Left insertion position of each query in one flat sorted array."""
    import jax.numpy as jnp
    if backend.is_pallas:
        from repro.kernels.sorted_lookup.kernel import searchsorted_left
        return searchsorted_left(keys, queries, interpret=backend.interpret)
    return jnp.searchsorted(keys, queries, side="left").astype(jnp.int32)


def searchsorted_ranged(keys, queries, lo, hi, *, backend: Backend):
    """Per-query windowed probe: ``count(keys[lo:hi] < q)`` for each query.

    ``keys`` need only be sorted within each query's ``[lo, hi)`` window
    (variable-width, unlike :func:`searchsorted_blocked`) — the shared
    frontier's per-segment runs, the shard-major primary index, etc.
    """
    if backend.is_pallas:
        from repro.kernels.sorted_lookup.kernel import searchsorted_left_ranged
        return searchsorted_left_ranged(keys, queries, lo, hi,
                                        interpret=backend.interpret)
    from repro.kernels.sorted_lookup.ref import searchsorted_left_ranged
    return searchsorted_left_ranged(keys, queries, lo, hi)


def sort_rows(x, *, backend: Backend):
    """Row-wise ascending sort of an (R, W) i32 matrix (the full-width sort
    behind every dedup/merge wave).  The pallas path runs the VMEM-resident
    bitonic network of ``kernels/dedup_compact``; both are bit-identical."""
    if backend.is_pallas:
        from repro.kernels.dedup_compact.kernel import sort_rows as _k
        return _k(x, interpret=backend.interpret)
    from repro.kernels.dedup_compact.ref import sort_rows as _r
    return _r(x)


def dedup_compact_rows(x, cap: int, *, backend: Backend):
    """(R, W) candidates (PAD = invalid) -> ((R, cap) sorted-unique regions,
    (R,) unique counts).  The §3.4 per-hop compaction; counts > cap is the
    fast-fail condition."""
    if backend.is_pallas:
        from repro.kernels.dedup_compact.kernel import dedup_compact_rows as _k
        return _k(x, cap, interpret=backend.interpret)
    from repro.kernels.dedup_compact.ref import dedup_compact_rows as _r
    return _r(x, cap)


def sort_pairs(k1, k2, *, backend: Backend):
    """Lexicographic ascending sort of flat (k1, k2) i32 pairs (the shared
    frontier's one compaction sort per hop)."""
    if backend.is_pallas:
        from repro.kernels.dedup_compact.kernel import sort_pairs as _k
        return _k(k1, k2, interpret=backend.interpret)
    from repro.kernels.dedup_compact.ref import sort_pairs as _r
    return _r(k1, k2)


def knn_topk(vecs, emb, gid, vtype, create, delete, q_vt, q_ts, k: int, *,
             backend: Backend):
    """Batched squared-L2 distance + per-query top-k over the vector index
    (the `Nearest` probe wave).  Entries are filtered by type and MVCC
    visibility per query; ties break by ascending gid, invalid slots come
    back as (+inf, I32MAX).  Both paths are bit-identical — the pallas
    kernel streams VMEM-resident embedding tiles through a running two-key
    bitonic top-k merge."""
    if backend.is_pallas:
        from repro.kernels.knn_topk.kernel import knn_topk as _k
        return _k(vecs, emb, gid, vtype, create, delete, q_vt, q_ts, k,
                  interpret=backend.interpret)
    from repro.kernels.knn_topk.ref import knn_topk as _r
    return _r(vecs, emb, gid, vtype, create, delete, q_vt, q_ts, k)
