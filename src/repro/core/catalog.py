"""Catalog: tenants / graphs / types / schemas + proxy cache (§3, §3.1).

The paper's catalog is a FaRM-resident KV store mapping names to the root
pointers of data structures, fronted by a TTL'd in-memory *proxy* cache so
data-plane calls don't pay repeated remote reads.  Here the control plane runs
on the host (the coordinator): the catalog is host state, checkpointed with
the store, and the proxy cache reproduces the TTL/refresh behavior (it's also
what makes repeated data-plane calls cheap — schema resolution is pure host
metadata, no device work).

Schema model (Bond analogue): a vertex type declares typed attribute columns
('f32' or 'i32') mapped onto contiguous column ranges of the store's
``vdata_f`` / ``vdata_i`` matrices, plus a mandatory int primary key.  String
attributes are dictionary-encoded to i32 by the data pipeline (noted in
DESIGN.md: TPU stores numbers, the dictionary lives with the loader).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AttrDef:
    name: str
    kind: str            # 'f32' | 'i32'
    col: int             # column index within the store matrix


@dataclasses.dataclass(frozen=True)
class VertexType:
    name: str
    type_id: int
    attrs: tuple[AttrDef, ...]
    primary_key: str = "key"     # implicit i32 key column (store.vkey)

    def attr(self, name: str) -> AttrDef:
        for a in self.attrs:
            if a.name == name:
                return a
        raise KeyError(f"vertex type {self.name!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class EdgeType:
    name: str
    type_id: int
    attrs: tuple[AttrDef, ...] = ()


class _Proxy:
    """TTL'd cached handle to a catalog object (§3.1)."""

    __slots__ = ("obj", "version", "expires")

    def __init__(self, obj, version, ttl, now):
        self.obj, self.version, self.expires = obj, version, now + ttl


@dataclasses.dataclass
class GraphMeta:
    name: str
    state: str = "Active"            # Active | Deleting  (async delete, §3.3)
    vtypes: dict = dataclasses.field(default_factory=dict)
    etypes: dict = dataclasses.field(default_factory=dict)
    next_vtype: int = 0
    next_etype: int = 0
    f_cols_used: int = 0
    i_cols_used: int = 0


class Catalog:
    """Host-side control plane: tenant -> graph -> types."""

    def __init__(self, *, proxy_ttl: float = 60.0, clock=time.monotonic):
        self.tenants: dict[str, dict[str, GraphMeta]] = {}
        self.version = 0                     # bumped on every control-plane op
        self._proxies: dict[tuple, _Proxy] = {}
        self._ttl = proxy_ttl
        self._clock = clock

    # -- control plane (each op runs under its own implicit txn, §3) ---------
    def create_tenant(self, tenant: str) -> None:
        if tenant in self.tenants:
            raise ValueError(f"tenant {tenant!r} exists")
        self.tenants[tenant] = {}
        self.version += 1

    def create_graph(self, tenant: str, graph: str) -> GraphMeta:
        graphs = self.tenants.setdefault(tenant, {})
        if graph in graphs:
            raise ValueError(f"graph {graph!r} exists")
        graphs[graph] = GraphMeta(name=graph)
        self.version += 1
        return graphs[graph]

    def get_graph(self, tenant: str, graph: str) -> GraphMeta:
        g = self.tenants[tenant][graph]
        if g.state != "Active":
            raise ValueError(f"graph {graph!r} is {g.state}")
        return g

    def mark_deleting(self, tenant: str, graph: str) -> GraphMeta:
        g = self.tenants[tenant][graph]
        g.state = "Deleting"
        self.version += 1
        return g

    def drop_graph(self, tenant: str, graph: str) -> None:
        del self.tenants[tenant][graph]
        self.version += 1

    def create_vertex_type(self, tenant: str, graph: str, name: str,
                           f_attrs=(), i_attrs=(), *,
                           max_f_cols: int, max_i_cols: int) -> VertexType:
        g = self.get_graph(tenant, graph)
        if name in g.vtypes:
            raise ValueError(f"vertex type {name!r} exists")
        # column ranges are per-type: a vertex row has exactly one type, so
        # different types reuse the same physical columns (columnar Bond).
        attrs = []
        for col, a in enumerate(f_attrs):
            if col >= max_f_cols:
                raise ValueError("out of f32 attribute columns")
            attrs.append(AttrDef(a, "f32", col))
        for col, a in enumerate(i_attrs):
            if col >= max_i_cols:
                raise ValueError("out of i32 attribute columns")
            attrs.append(AttrDef(a, "i32", col))
        g.f_cols_used = max(g.f_cols_used, len(f_attrs))
        g.i_cols_used = max(g.i_cols_used, len(i_attrs))
        vt = VertexType(name=name, type_id=g.next_vtype, attrs=tuple(attrs))
        g.next_vtype += 1
        g.vtypes[name] = vt
        self.version += 1
        return vt

    def create_edge_type(self, tenant: str, graph: str, name: str) -> EdgeType:
        g = self.get_graph(tenant, graph)
        if name in g.etypes:
            raise ValueError(f"edge type {name!r} exists")
        et = EdgeType(name=name, type_id=g.next_etype)
        g.next_etype += 1
        g.etypes[name] = et
        self.version += 1
        return et

    # -- proxy cache (data plane resolution, §3.1) ----------------------------
    def proxy(self, tenant: str, graph: str, kind: str, name: str):
        """Resolve a type by name through the TTL'd proxy cache."""
        key = (tenant, graph, kind, name)
        now = self._clock()
        p = self._proxies.get(key)
        if p is not None:
            if now < p.expires:
                return p.obj
            if p.version == self.version:      # unchanged: extend the TTL
                p.expires = now + self._ttl
                return p.obj
        g = self.get_graph(tenant, graph)
        obj = (g.vtypes if kind == "v" else g.etypes)[name]
        self._proxies[key] = _Proxy(obj, self.version, self._ttl, now)
        return obj

    def invalidate_proxies(self) -> None:
        self._proxies.clear()
