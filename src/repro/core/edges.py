"""Edge enumeration and compaction (the two-tier edge lists of §3.2).

Enumeration merges the compacted CSR (tier 1) with the append-only delta log
(tier 2) at a snapshot timestamp.  Expansion over a ragged frontier is the
vectorized form of A1's "edge enumeration" operator: every output position
finds its frontier item with a branchless ``searchsorted`` over the cumulative
degree — the same access pattern the ``edge_expand`` Pallas kernel implements
with scalar-prefetched CSR spans.

Compaction is the asynchronous-workflow analogue (§3.3): merge delta into CSR,
drop records dead before ``gc_ts`` (versions are only GC'd once no running
query can see them), and rebuild the per-slot offsets.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.core.addressing import NULL, TS_INF, StoreConfig
from repro.core.store import GraphStore, visible

ANY_TYPE = jnp.int32(-1)

TILE = 128          # edge_expand lane width (the TPU vector-lane count)


def _tiled_csr_expand(qids, deg, start, pools, etype, read_ts, cap_out: int,
                      backend: backend_mod.Backend):
    """Kernel-backed CSR expansion, scattered back to the reference layout.

    The edge_expand kernel streams whole CSR spans tile-by-tile (scalar-
    prefetched span starts drive the DMA pipeline) instead of the reference
    path's one searchsorted + 4 gathers *per output slot*.  Its tile-padded
    output is consumed in place: the edge-visibility/type mask is evaluated
    directly on the tile buffers and surviving lanes are scattered into the
    dense (cap_out,) frontier buffer at exactly the position the reference
    path would have written, so downstream (dedup, checks, results) is
    bit-identical between backends.  Tile-padding therefore never inflates
    the dedup sort width — cap_tiles is sized so that any expansion the
    reference path accepts (total <= cap_out) also fits the tile plan.

    pools = (nbr, typ, create, delete); returns (out_q, out_n) of (cap_out,).
    """
    F = deg.shape[0]
    cap_tiles = F + (cap_out + TILE - 1) // TILE
    (nbr_t, typ_t, cre_t, del_t), item, tw, _ = backend_mod.expand_tiles(
        start, deg, pools, tile=TILE, cap_tiles=cap_tiles, backend=backend)
    item_c = jnp.minimum(item, F - 1)
    excl = jnp.cumsum(deg) - deg                      # dense span offsets
    lane = jnp.arange(TILE, dtype=jnp.int32)
    shape = (cap_tiles, TILE)
    nbr_t, typ_t = nbr_t.reshape(shape), typ_t.reshape(shape)
    cre_t, del_t = cre_t.reshape(shape), del_t.reshape(shape)
    # invalid lanes carry -1 in every pool: visible(-1, -1, ts) is False,
    # so the reference e_ok predicate needs no extra lane mask here
    e_ok = (visible(cre_t, del_t, read_ts)
            & ((etype < 0) | (typ_t == etype))
            & (nbr_t >= 0))
    pos = excl[item_c][:, None] + tw[:, None] * TILE + lane[None, :]
    pos = jnp.where(e_ok, pos, cap_out)               # drop masked lanes
    out_q = jnp.full((cap_out,), NULL, jnp.int32).at[pos.reshape(-1)].set(
        jnp.broadcast_to(qids[item_c][:, None], shape).reshape(-1),
        mode="drop")
    out_n = jnp.full((cap_out,), NULL, jnp.int32).at[pos.reshape(-1)].set(
        nbr_t.reshape(-1), mode="drop")
    return out_q, out_n


# ---------------------------------------------------------------------------
# Ragged CSR expansion
# ---------------------------------------------------------------------------

def _csr_arrays(store: GraphStore, direction: str):
    if direction == "out":
        return (store.oe_indptr, store.oe_dst, store.oe_type,
                store.oe_create, store.oe_delete)
    elif direction == "in":
        return (store.ie_indptr, store.ie_src, store.ie_type,
                store.ie_create, store.ie_delete)
    raise ValueError(direction)


def _delta_arrays(store: GraphStore, direction: str):
    if direction == "out":
        return (store.dl_slot, store.dl_nbr, store.dl_type,
                store.dl_create, store.dl_delete)
    elif direction == "in":
        return (store.il_slot, store.il_nbr, store.il_type,
                store.il_create, store.il_delete)
    raise ValueError(direction)


def expand(store: GraphStore, cfg: StoreConfig, qids, gids, valid, *,
           etype, direction: str, read_ts, cap_out: int,
           backend: backend_mod.Backend = backend_mod.REF):
    """Enumerate edges of ``gids`` (global-array mode).

    Args:
      qids, gids, valid: frontier of shape (F,): query ids, vertex gids, mask.
      etype: int32 edge type to follow, or ANY_TYPE.
      direction: 'out' or 'in'.
      read_ts: snapshot timestamp.
      cap_out: static capacity for the CSR expansion segment.
      backend: read-path backend; the pallas path streams spans through the
        edge_expand kernel and produces bit-identical output (same layout).

    Returns:
      (out_qids, out_nbr, out_valid, overflow): the expansion, shape
      (cap_out + F*cap_delta_scan,), plus a bool overflow flag (fast-fail).
    """
    S, cap_v, cap_e = cfg.n_shards, cfg.cap_v, cfg.cap_e
    indptr, nbr, typ, ecre, edel = _csr_arrays(store, direction)

    safe_g = jnp.where(valid, gids, 0)
    shard = safe_g % S
    slot = safe_g // S
    iprow = shard * (cap_v + 1) + slot
    start = indptr[iprow] + shard * cap_e           # absolute pool offset
    deg = (indptr[iprow + 1] - indptr[iprow]) * valid

    cum = jnp.cumsum(deg)
    total = cum[-1] if deg.shape[0] > 0 else jnp.int32(0)
    overflow = total > cap_out

    if backend.is_pallas:
        out_q, out_n = _tiled_csr_expand(qids, deg, start,
                                         (nbr, typ, ecre, edel), etype,
                                         read_ts, cap_out, backend)
    else:
        k = jnp.arange(cap_out, dtype=jnp.int32)
        item = jnp.searchsorted(cum, k, side="right").astype(jnp.int32)
        item_c = jnp.minimum(item, deg.shape[0] - 1)
        base = cum[item_c] - deg[item_c]
        epos = start[item_c] + (k - base)
        in_range = k < total
        epos = jnp.where(in_range, epos, 0)

        e_ok = (in_range
                & visible(ecre[epos], edel[epos], read_ts)
                & ((etype < 0) | (typ[epos] == etype))
                & (nbr[epos] >= 0))
        out_q = jnp.where(e_ok, qids[item_c], NULL)
        out_n = jnp.where(e_ok, nbr[epos], NULL)

    # ---- tier 2: delta-log merge (recent, not yet compacted edges) --------
    dslot, dnbr, dtyp, dts, ddel = _delta_arrays(store, direction)
    D = dslot.shape[0]
    d_shard = jnp.arange(D, dtype=jnp.int32) // cfg.cap_delta
    d_gid = dslot * S + d_shard                       # gid of the delta's owner
    # match matrix: frontier item x delta entry
    m = (valid[:, None]
         & (d_gid[None, :] == safe_g[:, None])
         & visible(dts, ddel, read_ts)[None, :]
         & ((etype < 0) | (dtyp[None, :] == etype))
         & (dnbr[None, :] >= 0))
    dq = jnp.where(m, qids[:, None], NULL).reshape(-1)
    dn = jnp.where(m, dnbr[None, :] + jnp.zeros_like(qids)[:, None], NULL).reshape(-1)

    out_qids = jnp.concatenate([out_q, dq])
    out_nbr = jnp.concatenate([out_n, dn])
    return out_qids, out_nbr, out_nbr >= 0, overflow


def degrees(store: GraphStore, cfg: StoreConfig, gids, valid, *, etype,
            direction: str, read_ts):
    """Visible degree of each frontier vertex (CSR span + delta matches)."""
    S, cap_v, cap_e = cfg.n_shards, cfg.cap_v, cfg.cap_e
    indptr, nbr, typ, ecre, edel = _csr_arrays(store, direction)
    safe_g = jnp.where(valid, gids, 0)
    shard, slot = safe_g % S, safe_g // S
    iprow = shard * (cap_v + 1) + slot
    start, end = indptr[iprow], indptr[iprow + 1]
    # CSR spans can contain dead or other-type edges; count exactly by scanning
    # a bounded window is avoided here — this helper reports the raw span size
    # (used for capacity planning), not the filtered degree.
    return (end - start) * valid


# ---------------------------------------------------------------------------
# Compaction (async workflow, §3.3)
# ---------------------------------------------------------------------------

def _compact_one_shard(slot_c, nbr_c, typ_c, cre_c, del_c,      # CSR (cap_e,)
                       slot_d, nbr_d, typ_d, ts_d, del_d,       # delta (cap_d,)
                       gc_ts, cap_v: int):
    """Merge one shard's CSR pool with its delta log; returns new CSR arrays.

    Entries dead at ``gc_ts`` are dropped; survivors sorted by
    (slot, etype, nbr, create) so future enumerations are contiguous.
    """
    cap_e = nbr_c.shape[0]
    slot_all = jnp.concatenate([slot_c, slot_d])
    nbr_all = jnp.concatenate([nbr_c, nbr_d])
    typ_all = jnp.concatenate([typ_c, typ_d])
    cre_all = jnp.concatenate([cre_c, ts_d])
    del_all = jnp.concatenate([del_c, del_d])

    live = (nbr_all >= 0) & (del_all > gc_ts)
    skey = jnp.where(live, slot_all, jnp.int32(cap_v))      # dead sorts last
    skey, typ_s, nbr_s, cre_s, del_s, slot_s = jax.lax.sort(
        (skey, typ_all, nbr_all, cre_all, del_all, slot_all), num_keys=3)
    n_live = jnp.sum(live.astype(jnp.int32))
    overflow = n_live > cap_e

    idx = jnp.arange(cap_e, dtype=jnp.int32)
    keep = idx < n_live
    new_nbr = jnp.where(keep, nbr_s[:cap_e], NULL)
    new_typ = jnp.where(keep, typ_s[:cap_e], NULL)
    new_cre = jnp.where(keep, cre_s[:cap_e], TS_INF)
    new_del = jnp.where(keep, del_s[:cap_e], TS_INF)
    new_slot = jnp.where(keep, skey[:cap_e], cap_v)

    counts = jax.ops.segment_sum(keep.astype(jnp.int32),
                                 jnp.minimum(new_slot, cap_v),
                                 num_segments=cap_v + 1)[:cap_v]
    indptr = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts).astype(jnp.int32)])
    return indptr, new_nbr, new_typ, new_cre, new_del, overflow


def _slot_of_pool(indptr, cap_e):
    """Recover per-entry slot from an indptr (entries below indptr[-1])."""
    k = jnp.arange(cap_e, dtype=jnp.int32)
    return jnp.searchsorted(indptr[1:], k, side="right").astype(jnp.int32)


@partial(jax.jit, static_argnames=("cfg",))
def compact(store: GraphStore, cfg: StoreConfig, gc_ts) -> GraphStore:
    """Compact both edge CSRs and the primary index (all shards, vmapped)."""
    S, cap_v, cap_e, cap_d = cfg.n_shards, cfg.cap_v, cfg.cap_e, cfg.cap_delta

    def per_direction(indptr, nbr, typ, cre, dele, dslot, dnbr, dtyp, dts, ddel):
        ip = indptr.reshape(S, cap_v + 1)
        slot_c = jax.vmap(_slot_of_pool, in_axes=(0, None))(ip, cap_e)
        fn = jax.vmap(partial(_compact_one_shard, gc_ts=gc_ts, cap_v=cap_v))
        nip, nnbr, ntyp, ncre, ndel, ovf = fn(
            slot_c, nbr.reshape(S, cap_e), typ.reshape(S, cap_e),
            cre.reshape(S, cap_e), dele.reshape(S, cap_e),
            dslot.reshape(S, cap_d), dnbr.reshape(S, cap_d),
            dtyp.reshape(S, cap_d), dts.reshape(S, cap_d),
            ddel.reshape(S, cap_d))
        return (nip.reshape(-1), nnbr.reshape(-1), ntyp.reshape(-1),
                ncre.reshape(-1), ndel.reshape(-1), jnp.any(ovf))

    o_ip, o_nbr, o_typ, o_cre, o_del, _ = per_direction(
        store.oe_indptr, store.oe_dst, store.oe_type, store.oe_create,
        store.oe_delete, store.dl_slot, store.dl_nbr, store.dl_type,
        store.dl_create, store.dl_delete)
    i_ip, i_nbr, i_typ, i_cre, i_del, _ = per_direction(
        store.ie_indptr, store.ie_src, store.ie_type, store.ie_create,
        store.ie_delete, store.il_slot, store.il_nbr, store.il_type,
        store.il_create, store.il_delete)

    D = store.dl_slot.shape[0]
    empty_d = dict(
        dl_slot=jnp.full((D,), NULL), dl_nbr=jnp.full((D,), NULL),
        dl_type=jnp.full((D,), NULL), dl_create=jnp.full((D,), TS_INF),
        dl_delete=jnp.full((D,), TS_INF), dl_count=jnp.zeros((S,), jnp.int32),
        il_slot=jnp.full((D,), NULL), il_nbr=jnp.full((D,), NULL),
        il_type=jnp.full((D,), NULL), il_create=jnp.full((D,), TS_INF),
        il_delete=jnp.full((D,), TS_INF), il_count=jnp.zeros((S,), jnp.int32),
    )

    return dataclasses_replace(
        store,
        oe_indptr=o_ip, oe_dst=o_nbr, oe_type=o_typ,
        oe_create=o_cre, oe_delete=o_del,
        ie_indptr=i_ip, ie_src=i_nbr, ie_type=i_typ,
        ie_create=i_cre, ie_delete=i_del,
        **empty_d)


def dataclasses_replace(obj, **kw):
    import dataclasses
    return dataclasses.replace(obj, **kw)
