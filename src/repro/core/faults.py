"""Deterministic fault injection for the serving tier (chaos harness).

Production A1 survives worker crashes, raced structural mutations, and
latency outliers because every layer has an attributed failure path: a
query wave that dies is retried or aborted *with a reason*, a raced
compaction handoff rebuilds, a stale continuation makes the client restart
(§3.4).  This module lets tests drive those paths on demand: a
:class:`FaultInjector` is attached to a ``GraphDB`` (``db.faults``) and the
serve/engine/tasks layers consult it at **named sites**.  With no injector
attached every site is a no-op — zero overhead on the production path.

Sites wired in this repo (see core/README.md for the guarantees each one
must preserve):

================================  =========================================
``engine.wave``                   start of ``GraphDB.query`` — a wave
                                  execution exception (``raise``) or a
                                  slow-wave straggler (``stall``)
``serve.wave.stall``              serve dispatch, before the base run
``serve.continuation.stale``      serve sweep — ``race`` force-expires all
                                  continuation tokens (stale-token storm)
``tasks.quantum``                 task-queue pump — a low-priority worker
                                  crash mid-quantum
``tasks.compaction.handoff``      background compaction, before
                                  ``try_handoff`` — ``race`` simulates a
                                  concurrent structural mutation so the
                                  shadow must rebuild
``cluster.worker.crash``          cluster frontend, before routing a
                                  request to its coordinator — ``race``
                                  kills the target worker first (the
                                  mid-pagination crash the takeover
                                  contract must survive)
``cluster.route.stale``           cluster frontend, on continuation
                                  routing — ``race`` routes the token to
                                  a *wrong* coordinator (stale SLB view);
                                  the receiver must bounce it back by
                                  ownership stamp, never answer from the
                                  wrong state
``transport.drop``                transport channel, per frame — ``race``
                                  drops (or duplicates, site-armed twice)
                                  the frame; clients must retransmit and
                                  result polling must stay idempotent
``membership.heartbeat.drop``     membership renewal — ``race`` loses that
                                  heartbeat (the frame never arrived); the
                                  lease keeps aging toward suspect/evict
``membership.lease.expire``       membership ``tick`` — ``race``
                                  force-expires the current primary's
                                  lease (straight to evict) so failover
                                  runs without waiting out real time
``replication.ship.drop``         replication sweep / the frontend's wave
                                  fan-out — ``race`` drops the whole ship
                                  round; lag grows, watermarks must NOT
                                  advance, and acked commits stay acked
``primary.crash.midwave``         serve ``_close_write_wave``, after the
                                  commit but before results are stored —
                                  ``raise`` kills the primary at the
                                  worst moment; failover must answer the
                                  committed-but-unacked txns exactly once
================================  =========================================

Firing is **seeded and deterministic**: a site fires on an explicit
schedule of visit indices (``times=``) and/or with probability ``prob``
drawn from a per-``(seed, site)`` ``numpy`` generator — replaying the same
schedule against the same workload reproduces the identical fault
sequence, which is what lets chaos tests assert bit-identical
pinned-snapshot reads.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Optional

import numpy as np


class InjectedFault(RuntimeError):
    """Raised by a ``raise``-action site; carries the site for attribution."""

    def __init__(self, site: str, visit: int):
        super().__init__(f"injected fault at {site} (visit {visit})")
        self.site = site
        self.visit = visit


@dataclasses.dataclass
class FaultSpec:
    """One armed site.  ``action`` is ``raise`` | ``stall`` | ``race``."""
    site: str
    action: str = "raise"
    prob: float = 0.0                  # per-visit firing probability
    times: tuple = ()                  # explicit 0-based visit indices
    stall_s: float = 0.0               # sleep length for ``stall``
    max_fires: Optional[int] = None    # total-fire cap (None = unbounded)
    fires: int = 0


class FaultInjector:
    """Named-site fault oracle, deterministic under a fixed seed."""

    ACTIONS = ("raise", "stall", "race")

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._specs: dict[str, list[FaultSpec]] = {}
        self._visits: dict[str, int] = {}
        self._rng: dict[str, np.random.Generator] = {}
        self.fired: list[tuple[str, int, str]] = []   # (site, visit, action)

    def inject(self, site: str, *, action: str = "raise", prob: float = 0.0,
               times=(), stall_s: float = 0.0,
               max_fires: Optional[int] = None) -> "FaultInjector":
        """Arm ``site``; chainable.  ``times`` and ``prob`` compose (OR)."""
        if action not in self.ACTIONS:
            raise ValueError(f"unknown fault action {action!r}")
        spec = FaultSpec(site=site, action=action, prob=float(prob),
                         times=tuple(int(t) for t in times),
                         stall_s=float(stall_s), max_fires=max_fires)
        self._specs.setdefault(site, []).append(spec)
        return self

    def _site_rng(self, site: str) -> np.random.Generator:
        rng = self._rng.get(site)
        if rng is None:
            # stable across processes (hash() is salted; crc32 is not)
            rng = np.random.default_rng([self.seed,
                                         zlib.crc32(site.encode())])
            self._rng[site] = rng
        return rng

    def check(self, site: str) -> bool:
        """Consult ``site``; called once per visit by the instrumented code.

        ``raise`` fires by raising :class:`InjectedFault`; ``stall`` sleeps
        ``stall_s`` and returns ``False``; ``race`` returns ``True`` — the
        caller interprets it (e.g. "a concurrent mutation happened").
        """
        visit = self._visits.get(site, 0)
        self._visits[site] = visit + 1
        raced = False
        for spec in self._specs.get(site, ()):
            if spec.max_fires is not None and spec.fires >= spec.max_fires:
                continue
            fire = visit in spec.times
            if not fire and spec.prob > 0.0:
                # always draw so the stream stays aligned with the visit
                fire = bool(self._site_rng(site).random() < spec.prob)
            if not fire:
                continue
            spec.fires += 1
            self.fired.append((site, visit, spec.action))
            if spec.action == "raise":
                raise InjectedFault(site, visit)
            if spec.action == "stall":
                time.sleep(spec.stall_s)
            else:                                   # "race"
                raced = True
        return raced

    def visits(self, site: str) -> int:
        return self._visits.get(site, 0)


def check(owner, site: str) -> bool:
    """Site hook: consult ``owner.faults`` when armed, else no-op.

    ``owner`` is whatever object carries the injector (a ``GraphDB``).
    Instrumented code calls this unconditionally; production pays one
    ``getattr`` per site visit.
    """
    inj = getattr(owner, "faults", None)
    if inj is None:
        return False
    return inj.check(site)
