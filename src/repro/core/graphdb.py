"""GraphDB: the A1 database facade (data-plane + control-plane APIs, §3).

The host process plays the role of an A1 *backend machine acting as
coordinator*: it owns the catalog, the global clock, allocation metadata, and
drives jitted device programs for everything data-touching.  The device arrays
are "the cluster's memory"; the host never holds vertex data (only allocation
bookkeeping), matching the coprocessor split of §2.2.

Data-plane ops stage into :class:`Transaction` objects and are committed in
batches (see txn.py).  If no transaction is supplied, each call runs under an
implicit transaction committed immediately (§3: "a transaction is implicitly
created for that operation").
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import edges as edges_mod
from repro.core import index as index_mod
from repro.core import txn as txn_mod
from repro.core import writes as writes_mod
from repro.core.addressing import NULL, TS_INF, StoreConfig, gid_of
from repro.core.catalog import Catalog, EdgeType, VertexType
from repro.core.store import (GraphStore, gather_data, gather_headers,
                              make_store, replay_log_tail)
from repro.core.writes import CapacityError


class GraphDB:
    """One graph's storage + transactional data plane."""

    def __init__(self, cfg: StoreConfig, *, catalog: Optional[Catalog] = None,
                 tenant: str = "default", graph: str = "g",
                 caps: Optional[txn_mod.BatchCaps] = None,
                 replication_log=None, backend: Optional[str] = None):
        cfg.validate()
        self.cfg = cfg
        self.caps = caps or txn_mod.BatchCaps()
        # read-path backend ('ref'|'pallas'|'auto'|None = env/auto); resolved
        # by the query executors per call — host conveniences (lookup_vertex,
        # get_edges) always use the cheap jnp reference path
        self.backend = backend
        self.store: GraphStore = make_store(cfg)
        self.catalog = catalog or Catalog()
        if tenant not in self.catalog.tenants:
            self.catalog.create_tenant(tenant)
        if graph not in self.catalog.tenants[tenant]:
            self.catalog.create_graph(tenant, graph)
        self.tenant, self.graph = tenant, graph

        # -- coordinator metadata (host-side, checkpointed) -------------------
        self.clock: int = 1                          # FaRMv2 global clock
        S = cfg.n_shards
        self.v_next = np.zeros(S, np.int64)          # next fresh slot per shard
        self.v_free: list[list[int]] = [[] for _ in range(S)]   # vacuumed slots
        self._rr = 0                                 # round-robin shard cursor
        self.dl_count = np.zeros(S, np.int64)        # delta-log fill mirrors
        self.il_count = np.zeros(S, np.int64)
        self.xd_count = np.zeros(S, np.int64)
        self.vx_count = np.zeros(S, np.int64)        # vector-index fill mirror
        self._vindexed: set[int] = set()             # vector-indexed type_ids
        self._vx_pos: dict[int, tuple[int, int]] = {}  # gid -> (pos, type_id)
        self.replication_log = replication_log       # recovery hook (§4)
        self.stats = {"commits": 0, "aborts": 0, "compactions": 0,
                      "write_waves": 0, "bg_compactions": 0,
                      "compaction_rebuilds": 0, "vindex_compactions": 0}
        self.active_query_ts: list[int] = []         # pins for GC (§2.2)
        # -- background compaction (§2.2 concurrent GC; §3.3 tasks) -----------
        # Structural epochs: a shadow compaction built at epoch E can only be
        # handed off if the epochs it depends on are still E — deletes
        # tombstone CSR/index positions that shift under compaction, and a
        # concurrent inline compaction makes the shadow's base stale.
        self.epochs = {"delete_e": 0, "delete_v": 0,
                       "compact_edges": 0, "compact_index": 0}
        self.task_queue = None              # attached by the serving tier
        self.compaction_watermark = 0.5     # delta fill fraction that triggers
        self._bg_compaction_pending = False
        self.faults = None                  # FaultInjector (chaos tests only)
        # -- fleet replication (§4: primary-backup over committed waves) ------
        self.config_epoch = 0               # membership epoch last adopted
        self.wave_seq = 0                   # last wave applied here (frontier)
        self.wave_log: collections.deque = collections.deque(maxlen=512)
        self.wave_inbox: collections.deque = collections.deque()
        self.applied_rids: collections.OrderedDict = collections.OrderedDict()
        self.fleet_pins: list[int] = []     # frontend-of-record snapshot pins

    # ------------------------------------------------------------------
    # schema (control plane; each call = its own implicit txn, §3)
    # ------------------------------------------------------------------
    def vertex_type(self, name: str, f_attrs=(), i_attrs=()) -> VertexType:
        return self.catalog.create_vertex_type(
            self.tenant, self.graph, name, f_attrs, i_attrs,
            max_f_cols=self.cfg.d_f32, max_i_cols=self.cfg.d_i32)

    def edge_type(self, name: str) -> EdgeType:
        return self.catalog.create_edge_type(self.tenant, self.graph, name)

    def vt(self, name: str) -> VertexType:
        return self.catalog.proxy(self.tenant, self.graph, "v", name)

    def vector_index(self, name: str) -> VertexType:
        """Register a vertex type for `Nearest` queries (core/vindex.py).

        The type's f32 payload row becomes its embedding; vertices alive now
        are backfilled, future mutation waves maintain the index inline."""
        from repro.core import vindex as vindex_mod
        return vindex_mod.register(self, name)

    def et(self, name: str) -> EdgeType:
        return self.catalog.proxy(self.tenant, self.graph, "e", name)

    # ------------------------------------------------------------------
    # transactions
    # ------------------------------------------------------------------
    def create_transaction(self) -> txn_mod.Transaction:
        return txn_mod.Transaction(read_ts=self.clock)

    def snapshot_ts(self) -> int:
        return self.clock

    # ------------------------------------------------------------------
    # allocation (FaRM Alloc with locality hint)
    # ------------------------------------------------------------------
    def _alloc_vertex(self, hint_gid: Optional[int] = None) -> int:
        S = self.cfg.n_shards
        if hint_gid is not None and hint_gid >= 0:
            order = [int(hint_gid) % S] + [s for s in range(S)
                                           if s != int(hint_gid) % S]
        else:
            order = [(self._rr + i) % S for i in range(S)]
            self._rr = (self._rr + 1) % S
        for s in order:
            if self.v_free[s]:
                return gid_of(s, self.v_free[s].pop(), S)
            if self.v_next[s] < self.cfg.cap_v:
                slot = int(self.v_next[s])
                self.v_next[s] += 1
                return gid_of(s, slot, S)
        raise CapacityError("vertex store full on all shards")

    # ------------------------------------------------------------------
    # writes (the one entry point; per-op methods are staging wrappers)
    # ------------------------------------------------------------------
    def write(self, ops, *, txn=None, caps=None) -> writes_mod.WriteResult:
        """Execute a batch of mutations — the write twin of :meth:`query`.

        ``ops`` is either a list of mutation-op records
        (:class:`~repro.core.writes.CreateVertex` et al.) or a list of staged
        :class:`~repro.core.txn.Transaction` objects (never mixed):

        * op records + ``txn=`` — stage into the open transaction, return
          per-op ``STAGED`` statuses and created gids positionally;
        * op records alone — one implicit atomic transaction, committed
          immediately (§3);
        * transactions — fuse them into batched mutation waves: one jitted
          OCC-validation wave over all read sets, one fused apply program per
          mutation-shape group (programs cached like the read planner's),
          per-txn status/abort-reason positionally.

        Staging contract violations (duplicate key, missing endpoint, ...)
        raise ``ValueError`` synchronously; OCC outcomes come back as
        statuses.  ``caps=`` overrides the per-chunk :class:`BatchCaps`.
        """
        return writes_mod.write(self, ops, txn=txn, caps=caps)

    def create_vertex(self, vtype: str, key: int, attrs: Optional[dict] = None,
                      txn: Optional[txn_mod.Transaction] = None,
                      hint: Optional[int] = None) -> int:
        return self.write([writes_mod.CreateVertex(vtype, int(key), attrs,
                                                   hint)], txn=txn).gids[0]

    def update_vertex(self, gid: int, vtype: str, attrs: dict,
                      txn: Optional[txn_mod.Transaction] = None) -> None:
        self.write([writes_mod.UpdateVertex(int(gid), vtype, attrs)], txn=txn)

    def delete_vertex(self, gid: int, txn: Optional[txn_mod.Transaction] = None
                      ) -> None:
        """Delete a vertex and all its half-edges (§3.2 cascade)."""
        self.write([writes_mod.DeleteVertex(int(gid))], txn=txn)

    def create_edge(self, src: int, dst: int, etype: str,
                    txn: Optional[txn_mod.Transaction] = None,
                    check: bool = True) -> None:
        """``check=False`` skips the endpoint/duplicate reads — the bulk-load

        fast path (the paper's daily map-reduce KG build bypasses the
        read-validate round-trips too; uniqueness is then the loader's
        contract)."""
        self.write([writes_mod.CreateEdge(int(src), int(dst), etype, check)],
                   txn=txn)

    def delete_edge(self, src: int, dst: int, etype: str,
                    txn: Optional[txn_mod.Transaction] = None) -> None:
        self.write([writes_mod.DeleteEdge(int(src), int(dst), etype)],
                   txn=txn)

    # ------------------------------------------------------------------
    # queries (A1QL v2: the one entry point)
    # ------------------------------------------------------------------
    def query(self, queries: list[dict], **kw):
        """Execute a batch of A1QL queries (chains and star patterns).

        The unified entry point (``core.query.engine.execute``): parses each
        document to the logical-plan IR and routes internally — local vs
        SPMD (``mesh=``), per-plan-shape vs fused multi-query waves
        (``fused=None`` auto, ``True`` forces per-query ``failed_q``
        flags).  ``budget="shared"`` pools all queries' frontiers into one
        shared-capacity pool (O(F*sqrt(Q)) peak memory — the serving-cap
        shape; overflow is owner-attributed fast-fail).  Accepts ``caps=``,
        ``backend=``, ``read_ts=`` (scalar or per-query), ``parsed=``;
        returns a ``QueryResult``."""
        from repro.core.query.engine import execute
        return execute(self, queries, **kw)

    # ------------------------------------------------------------------
    # reads (host conveniences; bulk reads go through the query engine)
    # ------------------------------------------------------------------
    def lookup_vertex(self, vtype: str, key: int, read_ts: Optional[int] = None
                      ) -> tuple[int, bool]:
        vt = self.vt(vtype)
        rts = self.clock if read_ts is None else read_ts
        g, found = index_mod.lookup(
            self.store, self.cfg,
            jnp.asarray([vt.type_id], jnp.int32),
            jnp.asarray([int(key)], jnp.int32),
            jnp.asarray([True]), jnp.int32(rts))
        return int(g[0]), bool(found[0])

    def get_vertex(self, vtype: str, key: int) -> Optional[dict]:
        vt = self.vt(vtype)
        gid, found = self.lookup_vertex(vtype, key)
        if not found:
            return None
        f, i = self._read_data_host(gid, self.clock)
        out = {"gid": gid, "key": key}
        for a in vt.attrs:
            out[a.name] = float(f[a.col]) if a.kind == "f32" else int(i[a.col])
        return out

    def get_edges(self, gid: int, *, direction: str = "out",
                  read_ts: Optional[int] = None, etype: int = -1,
                  cap: int = 4096) -> list[tuple[int, int]]:
        rts = self.clock if read_ts is None else read_ts
        q, n, v, ovf = edges_mod.expand(
            self.store, self.cfg,
            jnp.zeros((1,), jnp.int32), jnp.asarray([gid], jnp.int32),
            jnp.asarray([True]), etype=jnp.int32(etype), direction=direction,
            read_ts=jnp.int32(rts), cap_out=cap)
        if bool(ovf):
            raise CapacityError("edge enumeration overflow; raise cap")
        # recover edge types by re-expanding per type is wasteful; instead
        # return (nbr, etype) pairs from a typed expansion
        nbrs = np.asarray(n)
        valid = np.asarray(v)
        types = np.asarray(self._expand_types(gid, direction, rts, cap))
        out = []
        for nbr, ok, et in zip(nbrs, valid, types):
            if ok:
                out.append((int(nbr), int(et)))
        return out

    def _expand_types(self, gid, direction, rts, cap):
        """Edge types aligned with expand()'s output layout."""
        st, cfg = self.store, self.cfg
        S, cap_v, cap_e = cfg.n_shards, cfg.cap_v, cfg.cap_e
        if direction == "out":
            indptr, typ = st.oe_indptr, st.oe_type
            dslot, dtyp, dnbr = st.dl_slot, st.dl_type, st.dl_nbr
        else:
            indptr, typ = st.ie_indptr, st.ie_type
            dslot, dtyp, dnbr = st.il_slot, st.il_type, st.il_nbr
        sh, sl = gid % S, gid // S
        start = int(indptr[sh * (cap_v + 1) + sl]) + sh * cap_e
        k = np.arange(cap)
        csr_t = np.asarray(typ)[np.minimum(start + k, S * cap_e - 1)]
        D = dslot.shape[0]
        d_shard = np.arange(D) // cfg.cap_delta
        d_gid = np.asarray(dslot) * S + d_shard
        dt = np.where(d_gid == gid, np.asarray(dtyp), -1)
        return np.concatenate([csr_t, dt])

    # ------------------------------------------------------------------
    # commit (deprecated shims; the wave lives in core/writes.py)
    # ------------------------------------------------------------------
    def commit(self, txn: txn_mod.Transaction) -> str:
        """Deprecated: use ``write([txn])``."""
        warnings.warn(
            "GraphDB.commit is deprecated; use GraphDB.write([txn])",
            DeprecationWarning, stacklevel=2)
        return self.write([txn]).statuses[0]

    def commit_many(self, txns: Sequence[txn_mod.Transaction]) -> list[str]:
        """Deprecated: use ``write(txns)``.  Returns per-txn status."""
        warnings.warn(
            "GraphDB.commit_many is deprecated; use GraphDB.write(txns)",
            DeprecationWarning, stacklevel=2)
        txns = list(txns)
        if not txns:
            return []
        return self.write(txns).statuses

    # ------------------------------------------------------------------
    # maintenance (invoked by the Task framework)
    # ------------------------------------------------------------------
    def gc_ts(self) -> int:
        """Records with delete_ts <= gc_ts are invisible to every running or

        future query (visibility is ``rts < delete_ts``), so they may be
        reclaimed — the paper GC's versions once no query pins them (§2.2).

        Fleet pins count too: in a cluster the frontend is pin-of-record
        for routed continuations, and it ships that list to every worker
        (heartbeat/replicate frames) so no replica GCs a snapshot some
        *other* coordinator's client is still paging."""
        pins = list(self.active_query_ts) + list(self.fleet_pins)
        return min(pins) if pins else self.clock

    def run_compaction(self) -> None:
        """Inline (stop-the-world) edge compaction — overflow backstop."""
        self.store = edges_mod.compact(self.store, self.cfg,
                                       jnp.int32(self.gc_ts()))
        self.dl_count[:] = 0
        self.il_count[:] = 0
        self.stats["compactions"] += 1
        self.epochs["compact_edges"] += 1

    def run_index_compaction(self) -> None:
        self.store = index_mod.compact_index(self.store, self.cfg,
                                             jnp.int32(self.gc_ts()))
        self.xd_count[:] = 0
        self.epochs["compact_index"] += 1

    def run_vindex_compaction(self) -> None:
        """Fold the vector index: age out entries dead before gc_ts."""
        from repro.core import vindex as vindex_mod
        vindex_mod.run_compaction(self)

    # -- background compaction: build a shadow, hand it off (§2.2) ----------
    def _kinds_needed(self) -> list:
        """Compaction kinds whose delta fill crossed the watermark."""
        kinds = []
        wm = self.compaction_watermark
        fill = max(self.dl_count.max(initial=0), self.il_count.max(initial=0))
        if fill >= wm * self.cfg.cap_delta:
            kinds.append("edges")
        if self.xd_count.max(initial=0) >= wm * self.cfg.cap_idx_delta:
            kinds.append("index")
        if (self._vindexed
                and self.vx_count.max(initial=0) >= wm * self.cfg.cap_vec):
            kinds.append("vindex")
        return kinds

    def _maybe_schedule_compaction(self) -> None:
        """Called after every write wave: threshold-trigger the background
        task instead of compacting on the commit path.  Without an attached
        task queue the inline overflow backstop still guarantees capacity."""
        if self.task_queue is None or self._bg_compaction_pending:
            return
        if self._kinds_needed():
            from repro.core.tasks import background_compaction_task
            self._bg_compaction_pending = True
            self.task_queue.enqueue(background_compaction_task())

    def begin_compaction(self, kinds=("edges", "index")) -> dict:
        """Phase 1 of background compaction: build compacted shadow state.

        Folds the delta logs into base CSR/index at ``gc_ts()`` (respecting
        ``active_query_ts`` pins, §2.2) *without* touching the live store —
        ``edges.compact``/``index.compact_index`` are pure.  Returns a handle
        carrying the shadow, the per-shard fill watermarks at build time, and
        the structural-epoch snapshot that :meth:`try_handoff` validates.
        """
        handle = {"gc_ts": self.gc_ts(), "kinds": tuple(kinds),
                  "epochs": dict(self.epochs), "shadow": {}, "marks": {}}
        if "edges" in kinds:
            handle["shadow"]["edges"] = edges_mod.compact(
                self.store, self.cfg, jnp.int32(handle["gc_ts"]))
            handle["marks"]["dl"] = self.dl_count.copy()
            handle["marks"]["il"] = self.il_count.copy()
        if "index" in kinds:
            handle["shadow"]["index"] = index_mod.compact_index(
                self.store, self.cfg, jnp.int32(handle["gc_ts"]))
            handle["marks"]["xd"] = self.xd_count.copy()
        # "vindex" builds no shadow: the fold is a cheap host-side prefix
        # compaction whose positions are referenced only by host metadata,
        # so it runs synchronously at handoff and cannot go stale
        return handle

    def try_handoff(self, handle: dict) -> dict:
        """Phase 2: merge the shadow into the live store, or refuse.

        Per kind, succeeds only if the structural epochs the shadow depends
        on are unchanged since the build (edge/vertex deletes tombstone
        CSR/index *positions*, which the fold moved; an inline compaction
        staled the base).  On success the store keeps its live vertex-data
        arrays, adopts the shadow's compacted CSR/index, and replays the
        delta-log tail appended since the build (``replay_log_tail``), so
        concurrent create-only ingest loses nothing.  MVCC pin safety: any
        pin taken after the build is >= the build's ``gc_ts``, so every
        record the fold dropped was already invisible to it.

        Only the shadow's *compacted* fields are read here — the shadow
        shares its other arrays with a store version that later waves may
        have donated back to jax.

        Returns ``{kind: bool}``; a ``False`` kind needs a rebuild.
        """
        out = {}
        for kind in handle["kinds"]:
            if kind == "edges":
                ok = (self.epochs["delete_e"] == handle["epochs"]["delete_e"]
                      and self.epochs["compact_edges"]
                      == handle["epochs"]["compact_edges"])
                if ok:
                    self._handoff_edges(handle)
                out[kind] = ok
            elif kind == "index":
                ok = (self.epochs["delete_v"] == handle["epochs"]["delete_v"]
                      and self.epochs["compact_index"]
                      == handle["epochs"]["compact_index"])
                if ok:
                    self._handoff_index(handle)
                out[kind] = ok
            elif kind == "vindex":
                self.run_vindex_compaction()
                out[kind] = True
        return out

    def _handoff_edges(self, handle: dict) -> None:
        sh = handle["shadow"]["edges"]
        cap = self.cfg.cap_delta
        w_dl = jnp.asarray(handle["marks"]["dl"], jnp.int32)
        w_il = jnp.asarray(handle["marks"]["il"], jnp.int32)
        n_dl = jnp.asarray(self.dl_count, jnp.int32)
        n_il = jnp.asarray(self.il_count, jnp.int32)
        repl = {f: getattr(sh, f) for f in (
            "oe_indptr", "oe_dst", "oe_type", "oe_create", "oe_delete",
            "ie_indptr", "ie_src", "ie_type", "ie_create", "ie_delete")}
        for f in ("dl_slot", "dl_nbr", "dl_type", "dl_create", "dl_delete"):
            repl[f] = replay_log_tail(getattr(sh, f), getattr(self.store, f),
                                      w_dl, n_dl, cap=cap)
        for f in ("il_slot", "il_nbr", "il_type", "il_create", "il_delete"):
            repl[f] = replay_log_tail(getattr(sh, f), getattr(self.store, f),
                                      w_il, n_il, cap=cap)
        self.dl_count -= handle["marks"]["dl"]
        self.il_count -= handle["marks"]["il"]
        repl["dl_count"] = jnp.asarray(self.dl_count, jnp.int32)
        repl["il_count"] = jnp.asarray(self.il_count, jnp.int32)
        self.store = dataclasses.replace(self.store, **repl)
        self.epochs["compact_edges"] += 1
        self.stats["bg_compactions"] += 1

    def _handoff_index(self, handle: dict) -> None:
        sh = handle["shadow"]["index"]
        cap = self.cfg.cap_idx_delta
        w_xd = jnp.asarray(handle["marks"]["xd"], jnp.int32)
        n_xd = jnp.asarray(self.xd_count, jnp.int32)
        repl = {f: getattr(sh, f) for f in (
            "ix_vtype", "ix_key", "ix_gid", "ix_create", "ix_delete",
            "ix_count")}
        for f in ("xd_vtype", "xd_key", "xd_gid", "xd_create", "xd_delete"):
            repl[f] = replay_log_tail(getattr(sh, f), getattr(self.store, f),
                                      w_xd, n_xd, cap=cap)
        self.xd_count -= handle["marks"]["xd"]
        repl["xd_count"] = jnp.asarray(self.xd_count, jnp.int32)
        self.store = dataclasses.replace(self.store, **repl)
        self.epochs["compact_index"] += 1
        self.stats["bg_compactions"] += 1

    def vacuum(self) -> int:
        """Reclaim vertex slots dead before gc_ts (offline GC of tombstones)."""
        gc = self.gc_ts()
        v_delete = np.asarray(self.store.v_delete)
        vtype = np.asarray(self.store.vtype)
        S, cap_v = self.cfg.n_shards, self.cfg.cap_v
        n = 0
        for s in range(S):
            blk = slice(s * cap_v, (s + 1) * cap_v)
            dead = np.where((v_delete[blk] <= gc) & (vtype[blk] >= 0))[0]
            for slot in dead:
                if int(slot) < self.v_next[s]:
                    self.v_free[s].append(int(slot))
                    n += 1
        if n:
            # wipe headers so reclaimed slots read as empty
            rows = []
            for s in range(S):
                rows += [s * cap_v + sl for sl in self.v_free[s]]
            r = jnp.asarray(rows, jnp.int32)
            self.store = dataclasses.replace(
                self.store,
                vtype=self.store.vtype.at[r].set(NULL),
                v_create=self.store.v_create.at[r].set(TS_INF),
                v_delete=self.store.v_delete.at[r].set(TS_INF))
        return n

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _txn(self, txn):
        if txn is None:
            return self.create_transaction(), True
        if txn.status != "OPEN":
            raise txn_mod.Aborted(f"transaction is {txn.status}")
        return txn, False

    def _encode_attrs(self, vt: VertexType, attrs: dict,
                      base_f=None, base_i=None):
        f = np.zeros(self.cfg.d_f32, np.float32) if base_f is None \
            else np.array(base_f, np.float32)
        i = np.zeros(self.cfg.d_i32, np.int32) if base_i is None \
            else np.array(base_i, np.int32)
        for name, val in attrs.items():
            a = vt.attr(name)
            if a.kind == "f32":
                f[a.col] = float(val)
            else:
                i[a.col] = int(val)
        return f, i

    def _read_header_host(self, gid: int, rts: int):
        vt, key, alive = gather_headers(
            self.store, self.cfg, jnp.asarray([gid], jnp.int32),
            jnp.int32(rts))
        return int(vt[0]), int(key[0]), bool(alive[0])

    def _read_data_host(self, gid: int, rts: int):
        f, i, alive = gather_data(
            self.store, self.cfg, jnp.asarray([gid], jnp.int32),
            jnp.int32(rts))
        return np.asarray(f[0]), np.asarray(i[0])
