"""GraphDB: the A1 database facade (data-plane + control-plane APIs, §3).

The host process plays the role of an A1 *backend machine acting as
coordinator*: it owns the catalog, the global clock, allocation metadata, and
drives jitted device programs for everything data-touching.  The device arrays
are "the cluster's memory"; the host never holds vertex data (only allocation
bookkeeping), matching the coprocessor split of §2.2.

Data-plane ops stage into :class:`Transaction` objects and are committed in
batches (see txn.py).  If no transaction is supplied, each call runs under an
implicit transaction committed immediately (§3: "a transaction is implicitly
created for that operation").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import edges as edges_mod
from repro.core import index as index_mod
from repro.core import txn as txn_mod
from repro.core.addressing import NULL, TS_INF, StoreConfig, gid_of
from repro.core.catalog import Catalog, EdgeType, VertexType
from repro.core.store import (GraphStore, gather_data, gather_headers,
                              make_store)


class CapacityError(RuntimeError):
    pass


class GraphDB:
    """One graph's storage + transactional data plane."""

    def __init__(self, cfg: StoreConfig, *, catalog: Optional[Catalog] = None,
                 tenant: str = "default", graph: str = "g",
                 caps: Optional[txn_mod.BatchCaps] = None,
                 replication_log=None, backend: Optional[str] = None):
        cfg.validate()
        self.cfg = cfg
        self.caps = caps or txn_mod.BatchCaps()
        # read-path backend ('ref'|'pallas'|'auto'|None = env/auto); resolved
        # by the query executors per call — host conveniences (lookup_vertex,
        # get_edges) always use the cheap jnp reference path
        self.backend = backend
        self.store: GraphStore = make_store(cfg)
        self.catalog = catalog or Catalog()
        if tenant not in self.catalog.tenants:
            self.catalog.create_tenant(tenant)
        if graph not in self.catalog.tenants[tenant]:
            self.catalog.create_graph(tenant, graph)
        self.tenant, self.graph = tenant, graph

        # -- coordinator metadata (host-side, checkpointed) -------------------
        self.clock: int = 1                          # FaRMv2 global clock
        S = cfg.n_shards
        self.v_next = np.zeros(S, np.int64)          # next fresh slot per shard
        self.v_free: list[list[int]] = [[] for _ in range(S)]   # vacuumed slots
        self._rr = 0                                 # round-robin shard cursor
        self.dl_count = np.zeros(S, np.int64)        # delta-log fill mirrors
        self.il_count = np.zeros(S, np.int64)
        self.xd_count = np.zeros(S, np.int64)
        self.replication_log = replication_log       # recovery hook (§4)
        self.stats = {"commits": 0, "aborts": 0, "compactions": 0}
        self.active_query_ts: list[int] = []         # pins for GC (§2.2)

    # ------------------------------------------------------------------
    # schema (control plane; each call = its own implicit txn, §3)
    # ------------------------------------------------------------------
    def vertex_type(self, name: str, f_attrs=(), i_attrs=()) -> VertexType:
        return self.catalog.create_vertex_type(
            self.tenant, self.graph, name, f_attrs, i_attrs,
            max_f_cols=self.cfg.d_f32, max_i_cols=self.cfg.d_i32)

    def edge_type(self, name: str) -> EdgeType:
        return self.catalog.create_edge_type(self.tenant, self.graph, name)

    def vt(self, name: str) -> VertexType:
        return self.catalog.proxy(self.tenant, self.graph, "v", name)

    def et(self, name: str) -> EdgeType:
        return self.catalog.proxy(self.tenant, self.graph, "e", name)

    # ------------------------------------------------------------------
    # transactions
    # ------------------------------------------------------------------
    def create_transaction(self) -> txn_mod.Transaction:
        return txn_mod.Transaction(read_ts=self.clock)

    def snapshot_ts(self) -> int:
        return self.clock

    # ------------------------------------------------------------------
    # allocation (FaRM Alloc with locality hint)
    # ------------------------------------------------------------------
    def _alloc_vertex(self, hint_gid: Optional[int] = None) -> int:
        S = self.cfg.n_shards
        if hint_gid is not None and hint_gid >= 0:
            order = [int(hint_gid) % S] + [s for s in range(S)
                                           if s != int(hint_gid) % S]
        else:
            order = [(self._rr + i) % S for i in range(S)]
            self._rr = (self._rr + 1) % S
        for s in order:
            if self.v_free[s]:
                return gid_of(s, self.v_free[s].pop(), S)
            if self.v_next[s] < self.cfg.cap_v:
                slot = int(self.v_next[s])
                self.v_next[s] += 1
                return gid_of(s, slot, S)
        raise CapacityError("vertex store full on all shards")

    # ------------------------------------------------------------------
    # data plane (stage into txn; commit immediately when txn is None)
    # ------------------------------------------------------------------
    def create_vertex(self, vtype: str, key: int, attrs: Optional[dict] = None,
                      txn: Optional[txn_mod.Transaction] = None,
                      hint: Optional[int] = None) -> int:
        t, implicit = self._txn(txn)
        vt = self.vt(vtype)
        # uniqueness: probe the primary index inside the transaction
        g, found = self.lookup_vertex(vtype, key, read_ts=t.read_ts)
        if found:
            raise ValueError(f"vertex ({vtype}, {key}) already exists")
        f, i = self._encode_attrs(vt, attrs or {})
        gid = self._alloc_vertex(hint)
        t.create_v.append((gid, vt.type_id, int(key), f, i))
        if implicit:
            self.commit(t)
        return gid

    def update_vertex(self, gid: int, vtype: str, attrs: dict,
                      txn: Optional[txn_mod.Transaction] = None) -> None:
        t, implicit = self._txn(txn)
        vt = self.vt(vtype)
        cur_f, cur_i = self._read_data_host(gid, t.read_ts)
        t.record_read(gid)
        f, i = self._encode_attrs(vt, attrs, base_f=cur_f, base_i=cur_i)
        t.update_v.append((gid, f, i))
        if implicit:
            self.commit(t)

    def delete_vertex(self, gid: int, txn: Optional[txn_mod.Transaction] = None
                      ) -> None:
        """Delete a vertex and all its half-edges (the paper's §3.2 cascade:

        the incoming edge list tells us every source vertex whose outgoing
        half-edge must also be retired)."""
        t, implicit = self._txn(txn)
        vtid, key, alive = self._read_header_host(gid, t.read_ts)
        t.record_read(gid)
        if not alive:
            raise ValueError(f"vertex {gid} not found")
        outs = self.get_edges(gid, direction="out", read_ts=t.read_ts)
        ins = self.get_edges(gid, direction="in", read_ts=t.read_ts)
        for nbr, et in outs:
            t.delete_e.append((gid, int(nbr), int(et)))
        for nbr, et in ins:
            t.delete_e.append((int(nbr), gid, int(et)))
        t.delete_v.append((gid, int(vtid), int(key)))
        if implicit:
            self.commit(t)

    def create_edge(self, src: int, dst: int, etype: str,
                    txn: Optional[txn_mod.Transaction] = None,
                    check: bool = True) -> None:
        """``check=False`` skips the endpoint/duplicate reads — the bulk-load

        fast path (the paper's daily map-reduce KG build bypasses the
        read-validate round-trips too; uniqueness is then the loader's
        contract)."""
        t, implicit = self._txn(txn)
        et = self.et(etype)
        if check:
            # endpoints must exist; reads recorded for OCC
            for g in (src, dst):
                _, _, alive = self._read_header_host(g, t.read_ts)
                t.record_read(g)
                if not alive:
                    raise ValueError(f"endpoint {g} not found")
            # single-edge-per-(src,type,dst) invariant (§3)
            existing = self.get_edges(src, direction="out",
                                      read_ts=t.read_ts, etype=et.type_id)
            t.reads.append((int(src), "e"))
            if any(int(n) == int(dst) for n, _ in existing):
                raise ValueError("edge already exists")
        t.create_e.append((int(src), int(dst), et.type_id))
        if implicit:
            self.commit(t)

    def delete_edge(self, src: int, dst: int, etype: str,
                    txn: Optional[txn_mod.Transaction] = None) -> None:
        t, implicit = self._txn(txn)
        et = self.et(etype)
        t.reads.append((int(src), "e"))
        t.delete_e.append((int(src), int(dst), et.type_id))
        if implicit:
            self.commit(t)

    # ------------------------------------------------------------------
    # queries (A1QL v2: the one entry point)
    # ------------------------------------------------------------------
    def query(self, queries: list[dict], **kw):
        """Execute a batch of A1QL queries (chains and star patterns).

        The unified entry point (``core.query.engine.execute``): parses each
        document to the logical-plan IR and routes internally — local vs
        SPMD (``mesh=``), per-plan-shape vs fused multi-query waves
        (``fused=None`` auto, ``True`` forces per-query ``failed_q``
        flags).  ``budget="shared"`` pools all queries' frontiers into one
        shared-capacity pool (O(F*sqrt(Q)) peak memory — the serving-cap
        shape; overflow is owner-attributed fast-fail).  Accepts ``caps=``,
        ``backend=``, ``read_ts=`` (scalar or per-query), ``parsed=``;
        returns a ``QueryResult``."""
        from repro.core.query.engine import execute
        return execute(self, queries, **kw)

    # ------------------------------------------------------------------
    # reads (host conveniences; bulk reads go through the query engine)
    # ------------------------------------------------------------------
    def lookup_vertex(self, vtype: str, key: int, read_ts: Optional[int] = None
                      ) -> tuple[int, bool]:
        vt = self.vt(vtype)
        rts = self.clock if read_ts is None else read_ts
        g, found = index_mod.lookup(
            self.store, self.cfg,
            jnp.asarray([vt.type_id], jnp.int32),
            jnp.asarray([int(key)], jnp.int32),
            jnp.asarray([True]), jnp.int32(rts))
        return int(g[0]), bool(found[0])

    def get_vertex(self, vtype: str, key: int) -> Optional[dict]:
        vt = self.vt(vtype)
        gid, found = self.lookup_vertex(vtype, key)
        if not found:
            return None
        f, i = self._read_data_host(gid, self.clock)
        out = {"gid": gid, "key": key}
        for a in vt.attrs:
            out[a.name] = float(f[a.col]) if a.kind == "f32" else int(i[a.col])
        return out

    def get_edges(self, gid: int, *, direction: str = "out",
                  read_ts: Optional[int] = None, etype: int = -1,
                  cap: int = 4096) -> list[tuple[int, int]]:
        rts = self.clock if read_ts is None else read_ts
        q, n, v, ovf = edges_mod.expand(
            self.store, self.cfg,
            jnp.zeros((1,), jnp.int32), jnp.asarray([gid], jnp.int32),
            jnp.asarray([True]), etype=jnp.int32(etype), direction=direction,
            read_ts=jnp.int32(rts), cap_out=cap)
        if bool(ovf):
            raise CapacityError("edge enumeration overflow; raise cap")
        # recover edge types by re-expanding per type is wasteful; instead
        # return (nbr, etype) pairs from a typed expansion
        nbrs = np.asarray(n)
        valid = np.asarray(v)
        types = np.asarray(self._expand_types(gid, direction, rts, cap))
        out = []
        for nbr, ok, et in zip(nbrs, valid, types):
            if ok:
                out.append((int(nbr), int(et)))
        return out

    def _expand_types(self, gid, direction, rts, cap):
        """Edge types aligned with expand()'s output layout."""
        st, cfg = self.store, self.cfg
        S, cap_v, cap_e = cfg.n_shards, cfg.cap_v, cfg.cap_e
        if direction == "out":
            indptr, typ = st.oe_indptr, st.oe_type
            dslot, dtyp, dnbr = st.dl_slot, st.dl_type, st.dl_nbr
        else:
            indptr, typ = st.ie_indptr, st.ie_type
            dslot, dtyp, dnbr = st.il_slot, st.il_type, st.il_nbr
        sh, sl = gid % S, gid // S
        start = int(indptr[sh * (cap_v + 1) + sl]) + sh * cap_e
        k = np.arange(cap)
        csr_t = np.asarray(typ)[np.minimum(start + k, S * cap_e - 1)]
        D = dslot.shape[0]
        d_shard = np.arange(D) // cfg.cap_delta
        d_gid = np.asarray(dslot) * S + d_shard
        dt = np.where(d_gid == gid, np.asarray(dtyp), -1)
        return np.concatenate([csr_t, dt])

    # ------------------------------------------------------------------
    # commit
    # ------------------------------------------------------------------
    def commit(self, txn: txn_mod.Transaction) -> str:
        return self.commit_many([txn])[0]

    def commit_many(self, txns: Sequence[txn_mod.Transaction]) -> list[str]:
        """Validate + apply a commit batch.  Returns per-txn status."""
        caps = self.caps
        # 1) OCC validation against committed state -------------------------
        gids, kinds, owner = [], [], []
        for i, t in enumerate(txns):
            for g, kind in t.reads:
                gids.append(g)
                kinds.append(1 if kind == "e" else 0)
                owner.append(i)
        status = ["COMMITTED"] * len(txns)
        R = self.caps.reads
        for off in range(0, len(gids), R):
            lw = np.asarray(txn_mod.last_write_ts(
                self.store, self.cfg,
                txn_mod.pad_i32(gids[off:off + R], R),
                txn_mod.pad_i32(kinds[off:off + R], R, fill=0)))
            for g, k, i, w in zip(gids[off:off + R], kinds[off:off + R],
                                  owner[off:off + R], lw):
                if int(w) > txns[i].read_ts:
                    status[i] = "ABORTED"
        # 2) intra-batch conflicts, first-wins: a later txn aborts if it
        #    writes an object an earlier winner wrote, or reads an object an
        #    earlier winner wrote (so every winner reads pre-batch state and
        #    the batch serializes in any order).
        taken: set = set()
        for i, t in enumerate(txns):
            if status[i] == "ABORTED":
                continue
            wk = t.write_keys()
            if (wk & taken) or (t.read_keys() & taken):
                status[i] = "ABORTED"
            else:
                taken |= wk
        winners = [t for i, t in enumerate(txns) if status[i] == "COMMITTED"]
        for i, t in enumerate(txns):
            t.status = status[i]
        if not winners:
            self.stats["aborts"] += len(txns)
            return status

        # 3) capacity management: compact if the logs would overflow ----------
        n_ce = sum(len(t.create_e) for t in winners)
        n_cv = sum(len(t.create_v) for t in winners)
        n_dv = sum(len(t.delete_v) for t in winners)
        if (self.dl_count.max(initial=0) + n_ce > self.cfg.cap_delta
                or self.il_count.max(initial=0) + n_ce > self.cfg.cap_delta):
            self.run_compaction()
        if self.xd_count.max(initial=0) + n_cv + n_dv > self.cfg.cap_idx_delta:
            self.run_index_compaction()

        # 4) apply winners, chunked under the static batch caps.  Winners are
        #    mutually conflict-free, so chunked application at increasing
        #    timestamps preserves the batch's serializable order.
        for chunk in self._chunks(winners):
            ts = self.clock + 1
            b = self._build_batch(chunk)
            assert b is not None
            self.store = txn_mod.apply_batch(self.store, self.cfg,
                                             jnp.int32(ts), *b)
            self.clock = ts
            if self.replication_log is not None:
                self.replication_log.append(ts, chunk)
        self.stats["commits"] += len(winners)
        self.stats["aborts"] += len(txns) - len(winners)
        return status

    def _chunks(self, winners):
        caps = self.caps
        out, acc = [], []
        ncv = nuv = ndv = nce = nde = 0
        for t in winners:
            if acc and (ncv + len(t.create_v) > caps.create_v
                        or nuv + len(t.update_v) > caps.update_v
                        or ndv + len(t.delete_v) > caps.delete_v
                        or nce + len(t.create_e) > caps.create_e
                        or nde + len(t.delete_e) > caps.delete_e):
                out.append(acc)
                acc, ncv, nuv, ndv, nce, nde = [], 0, 0, 0, 0, 0
            acc.append(t)
            ncv += len(t.create_v)
            nuv += len(t.update_v)
            ndv += len(t.delete_v)
            nce += len(t.create_e)
            nde += len(t.delete_e)
            if (len(t.create_v) > caps.create_v or len(t.update_v) > caps.update_v
                    or len(t.delete_v) > caps.delete_v
                    or len(t.create_e) > caps.create_e
                    or len(t.delete_e) > caps.delete_e):
                raise CapacityError(
                    "single transaction exceeds batch caps; raise BatchCaps")
        if acc:
            out.append(acc)
        return out

    def _build_batch(self, winners):
        caps, cfg = self.caps, self.cfg
        S = cfg.n_shards
        cv, uv, dv, ce, de = [], [], [], [], []
        for t in winners:
            cv += t.create_v
            uv += t.update_v
            dv += t.delete_v
            ce += t.create_e
            de += t.delete_e
        if (len(cv) > caps.create_v or len(uv) > caps.update_v
                or len(dv) > caps.delete_v or len(ce) > caps.create_e
                or len(de) > caps.delete_e):
            return None

        # index-delta positions for creates (host-assigned, per index shard)
        xpos = []
        for gid, vtid, key, f, i in cv:
            sh = index_mod.route_host(vtid, key, S)
            xpos.append(sh * cfg.cap_idx_delta + int(self.xd_count[sh]))
            self.xd_count[sh] += 1
        # delta-log positions for edge creates
        opos, ipos = [], []
        for s, d, et in ce:
            so, sd = s % S, d % S
            opos.append(so * cfg.cap_delta + int(self.dl_count[so]))
            self.dl_count[so] += 1
            ipos.append(sd * cfg.cap_delta + int(self.il_count[sd]))
            self.il_count[sd] += 1

        p32 = txn_mod.pad_i32
        b = (
            p32([x[0] for x in cv], caps.create_v),
            p32([x[1] for x in cv], caps.create_v),
            p32([x[2] for x in cv], caps.create_v),
            txn_mod.pad_f32([x[3] for x in cv], caps.create_v, cfg.d_f32),
            txn_mod.pad_i32_2d([x[4] for x in cv], caps.create_v, cfg.d_i32),
            p32(xpos, caps.create_v),
            p32([x[0] for x in uv], caps.update_v),
            txn_mod.pad_f32([x[1] for x in uv], caps.update_v, cfg.d_f32),
            txn_mod.pad_i32_2d([x[2] for x in uv], caps.update_v, cfg.d_i32),
            p32([x[0] for x in dv], caps.delete_v),
            p32([x[1] for x in dv], caps.delete_v),
            p32([x[2] for x in dv], caps.delete_v),
            p32([x[0] for x in ce], caps.create_e),
            p32([x[1] for x in ce], caps.create_e),
            p32([x[2] for x in ce], caps.create_e),
            p32(opos, caps.create_e),
            p32(ipos, caps.create_e),
            p32([x[0] for x in de], caps.delete_e),
            p32([x[1] for x in de], caps.delete_e),
            p32([x[2] for x in de], caps.delete_e),
            jnp.asarray(self.dl_count, jnp.int32),
            jnp.asarray(self.il_count, jnp.int32),
            jnp.asarray(self.xd_count, jnp.int32),
        )
        return b

    # ------------------------------------------------------------------
    # maintenance (invoked by the Task framework)
    # ------------------------------------------------------------------
    def gc_ts(self) -> int:
        """Records with delete_ts <= gc_ts are invisible to every running or

        future query (visibility is ``rts < delete_ts``), so they may be
        reclaimed — the paper GC's versions once no query pins them (§2.2)."""
        pins = self.active_query_ts
        return min(pins) if pins else self.clock

    def run_compaction(self) -> None:
        self.store = edges_mod.compact(self.store, self.cfg,
                                       jnp.int32(self.gc_ts()))
        self.dl_count[:] = 0
        self.il_count[:] = 0
        self.stats["compactions"] += 1

    def run_index_compaction(self) -> None:
        self.store = index_mod.compact_index(self.store, self.cfg,
                                             jnp.int32(self.gc_ts()))
        self.xd_count[:] = 0

    def vacuum(self) -> int:
        """Reclaim vertex slots dead before gc_ts (offline GC of tombstones)."""
        gc = self.gc_ts()
        v_delete = np.asarray(self.store.v_delete)
        vtype = np.asarray(self.store.vtype)
        S, cap_v = self.cfg.n_shards, self.cfg.cap_v
        n = 0
        for s in range(S):
            blk = slice(s * cap_v, (s + 1) * cap_v)
            dead = np.where((v_delete[blk] <= gc) & (vtype[blk] >= 0))[0]
            for slot in dead:
                if int(slot) < self.v_next[s]:
                    self.v_free[s].append(int(slot))
                    n += 1
        if n:
            # wipe headers so reclaimed slots read as empty
            rows = []
            for s in range(S):
                rows += [s * cap_v + sl for sl in self.v_free[s]]
            r = jnp.asarray(rows, jnp.int32)
            self.store = dataclasses.replace(
                self.store,
                vtype=self.store.vtype.at[r].set(NULL),
                v_create=self.store.v_create.at[r].set(TS_INF),
                v_delete=self.store.v_delete.at[r].set(TS_INF))
        return n

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _txn(self, txn):
        if txn is None:
            return self.create_transaction(), True
        if txn.status != "OPEN":
            raise txn_mod.Aborted(f"transaction is {txn.status}")
        return txn, False

    def _encode_attrs(self, vt: VertexType, attrs: dict,
                      base_f=None, base_i=None):
        f = np.zeros(self.cfg.d_f32, np.float32) if base_f is None \
            else np.array(base_f, np.float32)
        i = np.zeros(self.cfg.d_i32, np.int32) if base_i is None \
            else np.array(base_i, np.int32)
        for name, val in attrs.items():
            a = vt.attr(name)
            if a.kind == "f32":
                f[a.col] = float(val)
            else:
                i[a.col] = int(val)
        return f, i

    def _read_header_host(self, gid: int, rts: int):
        vt, key, alive = gather_headers(
            self.store, self.cfg, jnp.asarray([gid], jnp.int32),
            jnp.int32(rts))
        return int(vt[0]), int(key[0]), bool(alive[0])

    def _read_data_host(self, gid: int, rts: int):
        f, i, alive = gather_data(
            self.store, self.cfg, jnp.asarray([gid], jnp.int32),
            jnp.int32(rts))
        return np.asarray(f[0]), np.asarray(i[0])
