"""Primary index: the BTree of §3.1-3.2, as per-shard sorted arrays.

A1 looks a vertex up by (type, primary-key) through a distributed BTree whose
internal nodes are aggressively cached, so a probe is ~one RDMA read.  The
TPU-native equivalent of a high-fanout cached BTree is a *sorted array* probed
with vectorized binary search (the ``sorted_lookup`` Pallas kernel): zero
pointer chasing, one streamed memory pass, perfectly batched.

Entries are sorted by a 32-bit mix ``h(vtype,key)``; equal-hash runs are
resolved by a short window scan (hash collisions within one shard are
~n^2/2^33).  The index has the same two-tier shape as edge lists: a compacted
sorted main array plus a small append delta, merged by the async compaction
task.  Entries carry MVCC intervals so index probes are snapshot reads.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core.addressing import NULL, TS_INF, StoreConfig
from repro.core.store import GraphStore, visible, window_shard_major

_C1 = np.int32(-1640531527)   # 2654435769: Knuth multiplicative
_C2 = np.int32(-2048144789)   # murmur3 c1-ish odd constant
_WINDOW = 16                  # max same-hash run scanned on probe


def mix32(vtype, key):
    """Deterministic 32-bit mix of (vtype, key); int32 wrap-around arithmetic."""
    h = key * _C1
    h = h ^ (vtype * _C2)
    h = h ^ ((h >> 15) & 0x1FFFF)
    return h


def route(vtype, key, n_shards: int):
    """Index shard for a (vtype, key) pair."""
    h = mix32(vtype, key)
    return (h % n_shards + n_shards) % n_shards


def mix32_host(vtype: int, key: int) -> int:
    """Pure-python mirror of :func:`mix32` (no numpy overflow warnings)."""
    M = 0xFFFFFFFF
    h = ((key & M) * 2654435769) & M
    h ^= ((vtype & M) * 2246822507) & M
    h ^= (h >> 15) & 0x1FFFF
    return h - 2**32 if h >= 2**31 else h


def route_host(vtype: int, key: int, n_shards: int) -> int:
    return mix32_host(vtype, key) % n_shards


def lookup(store: GraphStore, cfg: StoreConfig, vtypes, keys, valid, read_ts,
           backend: backend_mod.Backend = backend_mod.REF,
           xd_win: int = None):
    """Batched primary-index probe at a snapshot (global-array mode).

    Returns (gids, found): gid of the live vertex for each (vtype, key), or
    NULL.  Two-tier: binary search of the sorted main index + linear scan of
    the delta.  Later (newer create_ts) entries win, so an uncompacted
    re-insert after delete resolves correctly.

    ``read_ts`` is a scalar snapshot, or a ``(Q,)`` vector of per-query
    snapshots (the multi-query planner fuses queries pinned at different
    MVCC timestamps into one probe wave).

    ``xd_win`` is a static per-shard window on the index-delta scan: the
    delta fills prefix-first per shard (host count mirrors are exact), so
    scanning ``[:W]`` of each shard block sees every live entry — slots
    beyond the fill hold ``xd_gid == NULL`` and can never match.  ``None``
    scans the full ``cap_idx_delta`` (identical results, more work); callers
    pass ``planner.index_window(db)``, pow2-rounded so program-cache keys
    only change when the fill band crosses a boundary.

    The pallas backend probes every shard block in one streamed pass of the
    sorted_lookup kernel (window-ranged compare-and-count); the ref backend
    binary-searches each query's block.  Both produce the same positions, so
    the window scan below is shared and results are bit-identical.
    """
    S, cap_x, cap_xd = cfg.n_shards, cfg.cap_idx, cfg.cap_idx_delta
    q = vtypes.shape[0]
    h = mix32(vtypes, keys)
    shard = route(vtypes, keys, S)
    base = shard * cap_x

    # main index is shard-major and sorted by mix32 hash (empty slots pad with
    # INT32_MAX); recompute the hash column identically to the compaction sort.
    ix_h = jnp.where(store.ix_gid >= 0, mix32(store.ix_vtype, store.ix_key),
                     jnp.int32(2**31 - 1))

    pos0 = backend_mod.searchsorted_blocked(ix_h, h, base, block=cap_x,
                                            backend=backend)
    best_g = jnp.full((q,), NULL, jnp.int32)
    best_ts = jnp.full((q,), -1, jnp.int32)
    for w in range(_WINDOW):
        p = jnp.minimum(pos0 + w, cap_x - 1)
        row = base + p
        hit = ((store.ix_gid[row] >= 0)
               & (store.ix_vtype[row] == vtypes)
               & (store.ix_key[row] == keys)
               & visible(store.ix_create[row], store.ix_delete[row],
                         read_ts))
        newer = hit & (store.ix_create[row] > best_ts)
        best_g = jnp.where(newer, store.ix_gid[row], best_g)
        best_ts = jnp.where(newer, store.ix_create[row], best_ts)
    g_main = jnp.where(valid, best_g, NULL)
    ts_main = jnp.where(valid, best_ts, -1)

    # delta scan (small): (Q, S*W) match matrix, newest visible entry wins
    W = cap_xd if xd_win is None else min(int(xd_win), cap_xd)
    xd_vt, xd_k, xd_g, xd_c, xd_d = window_shard_major(
        (store.xd_vtype, store.xd_key, store.xd_gid,
         store.xd_create, store.xd_delete), S, cap_xd, W)
    xd_shard = jnp.arange(S * W, dtype=jnp.int32) // W
    rts_row = read_ts[:, None] if jnp.ndim(read_ts) == 1 else read_ts
    m = (valid[:, None]
         & (xd_vt[None, :] == vtypes[:, None])
         & (xd_k[None, :] == keys[:, None])
         & (xd_shard[None, :] == shard[:, None])
         & (xd_g >= 0)[None, :]
         & visible(xd_c[None, :], xd_d[None, :], rts_row))
    ts_d = jnp.where(m, xd_c[None, :], -1)
    best_d = jnp.argmax(ts_d, axis=1)
    ts_delta = jnp.max(ts_d, axis=1)
    g_delta = jnp.where(ts_delta >= 0, xd_g[best_d], NULL)

    use_delta = ts_delta > ts_main
    gids = jnp.where(use_delta, g_delta, g_main)
    return gids, gids >= 0


@partial(jax.jit, static_argnames=("cfg",))
def compact_index(store: GraphStore, cfg: StoreConfig, gc_ts) -> GraphStore:
    """Merge the index delta into the sorted main index (all shards)."""
    import dataclasses
    S, cap_x, cap_xd = cfg.n_shards, cfg.cap_idx, cfg.cap_idx_delta

    def one(vt_m, k_m, g_m, c_m, d_m, vt_d, k_d, g_d, c_d, d_d):
        vt = jnp.concatenate([vt_m, vt_d])
        k = jnp.concatenate([k_m, k_d])
        g = jnp.concatenate([g_m, g_d])
        c = jnp.concatenate([c_m, c_d])
        d = jnp.concatenate([d_m, d_d])
        live = (g >= 0) & (d > gc_ts)
        h = jnp.where(live, mix32(vt, k), jnp.int32(2**31 - 1))
        h_s, vt_s, k_s, g_s, c_s, d_s = jax.lax.sort(
            (h, vt, k, g, c, d), num_keys=3)
        n_live = jnp.sum(live.astype(jnp.int32))
        idx = jnp.arange(cap_x, dtype=jnp.int32)
        keep = idx < n_live
        return (jnp.where(keep, vt_s[:cap_x], TS_INF),
                jnp.where(keep, k_s[:cap_x], TS_INF),
                jnp.where(keep, g_s[:cap_x], NULL),
                jnp.where(keep, c_s[:cap_x], TS_INF),
                jnp.where(keep, d_s[:cap_x], TS_INF),
                n_live, n_live > cap_x)

    fn = jax.vmap(one)
    vt, k, g, c, d, n, ovf = fn(
        store.ix_vtype.reshape(S, cap_x), store.ix_key.reshape(S, cap_x),
        store.ix_gid.reshape(S, cap_x), store.ix_create.reshape(S, cap_x),
        store.ix_delete.reshape(S, cap_x),
        store.xd_vtype.reshape(S, cap_xd), store.xd_key.reshape(S, cap_xd),
        store.xd_gid.reshape(S, cap_xd), store.xd_create.reshape(S, cap_xd),
        store.xd_delete.reshape(S, cap_xd))

    XD = S * cap_xd
    return dataclasses.replace(
        store,
        ix_vtype=vt.reshape(-1), ix_key=k.reshape(-1), ix_gid=g.reshape(-1),
        ix_create=c.reshape(-1), ix_delete=d.reshape(-1),
        ix_count=n.astype(jnp.int32),
        xd_vtype=jnp.full((XD,), TS_INF, jnp.int32),
        xd_key=jnp.full((XD,), TS_INF, jnp.int32),
        xd_gid=jnp.full((XD,), NULL, jnp.int32),
        xd_create=jnp.full((XD,), TS_INF, jnp.int32),
        xd_delete=jnp.full((XD,), TS_INF, jnp.int32),
        xd_count=jnp.zeros((S,), jnp.int32))
