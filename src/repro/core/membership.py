"""Lease-based membership + configuration epochs (§2, FaRM §3).

A1 inherits FaRM's failure model: every machine holds a *lease* with the
configuration manager; a machine that misses its lease renewal is
suspected, then evicted, and every eviction/election advances a
monotonically increasing **configuration epoch**.  The epoch is the
fencing token — a message stamped with an old epoch is bounced
(``STALE_EPOCH``) and a deposed primary can never get a commit past a
fleet that has moved on.  Here the frontend (the SLB of
:mod:`repro.launch.cluster`) plays the CM role: it owns the
:class:`Membership` table, renews leases by heartbeating its workers,
and completes failover when the elected write-primary changes.

State machine per member::

    alive --(lease expires)--> suspect --(grace expires)--> evicted
      ^           |
      +--(renewal)+          evicted is terminal until ``readmit``

Election picks the most caught-up routable member (max replicated
``applied_seq``; ties break to the lowest cid — deterministic, so every
observer agrees).  Every configuration change (evict / elect / readmit)
bumps the epoch.

The clock is injectable: chaos tests drive lease expiry deterministically
by advancing a fake clock instead of sleeping through real lease windows.

Fault sites (``core/faults.py``): ``membership.heartbeat.drop`` —
consulted per renewal, ``race`` loses that renewal (the heartbeat frame
never arrived); ``membership.lease.expire`` — consulted once per
``tick``, ``race`` force-expires the current primary's lease (the
primary-partition schedule that must end in a clean failover).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.core import faults as faults_mod


@dataclasses.dataclass
class Lease:
    member: int
    expires: float
    state: str = "alive"            # 'alive' | 'suspect' | 'evicted'


class Membership:
    """The CM-side membership table: leases, epochs, one write-primary."""

    def __init__(self, members, *, lease_s: float = 2.0,
                 grace_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 owner=None):
        members = sorted(int(m) for m in members)
        if not members:
            raise ValueError("membership needs at least one member")
        self.lease_s = float(lease_s)
        self.grace_s = float(lease_s if grace_s is None else grace_s)
        self.clock = clock
        self._owner = owner                       # carries .faults (chaos)
        self.epoch = 1
        self.primary: Optional[int] = members[0]
        now = clock()
        self.members: dict[int, Lease] = {
            m: Lease(m, now + self.lease_s) for m in members}
        self.applied: dict[int, int] = {m: 0 for m in members}
        self.events: list[dict] = []              # full config-change history

    # -- renewals -------------------------------------------------------
    def heartbeat(self, cid: int, *, applied_seq: Optional[int] = None
                  ) -> bool:
        """Renew ``cid``'s lease; returns False when the renewal is lost
        (evicted member, or an injected ``membership.heartbeat.drop``)."""
        m = self.members.get(int(cid))
        if m is None or m.state == "evicted":
            return False
        if faults_mod.check(self._owner, "membership.heartbeat.drop"):
            return False                          # renewal frame lost
        m.expires = self.clock() + self.lease_s
        if m.state == "suspect":
            m.state = "alive"                     # recovered before eviction
        if applied_seq is not None:
            self.applied[int(cid)] = max(self.applied.get(int(cid), 0),
                                         int(applied_seq))
        return True

    def suspect(self, cid: int) -> None:
        """External suspicion signal (e.g. a transport recv timeout): the
        member stops being routable now and its lease stops renewing —
        eviction follows at ``tick`` unless a heartbeat lands first."""
        m = self.members.get(int(cid))
        if m is not None and m.state == "alive":
            m.state = "suspect"
            m.expires = min(m.expires, self.clock())

    # -- the lease clock ------------------------------------------------
    def tick(self) -> list[dict]:
        """Advance the lease state machine; returns config-change events
        (``{"type": "suspect"|"evict"|"elect", ...}``) in order."""
        now = self.clock()
        events: list[dict] = []
        forced = faults_mod.check(self._owner, "membership.lease.expire")
        if forced and self.primary is not None:
            m = self.members[self.primary]
            if m.state != "evicted":              # straight through suspect
                m.expires = now - self.grace_s - 1.0
        for cid in sorted(self.members):
            m = self.members[cid]
            if m.state == "alive" and now >= m.expires:
                m.state = "suspect"
                events.append({"type": "suspect", "member": cid,
                               "epoch": self.epoch})
            if m.state == "suspect" and now >= m.expires + self.grace_s:
                events += self._evict(cid, reason="lease-expired")
        self.events += events
        return events

    # -- configuration changes ------------------------------------------
    def evict(self, cid: int, *, reason: str = "crash") -> list[dict]:
        """Evict ``cid`` immediately (detected crash).  Idempotent."""
        events = self._evict(int(cid), reason=reason)
        self.events += events
        return events

    def _evict(self, cid: int, *, reason: str) -> list[dict]:
        m = self.members.get(cid)
        if m is None or m.state == "evicted":
            return []
        m.state = "evicted"
        self.epoch += 1                           # every config change fences
        events = [{"type": "evict", "member": cid, "reason": reason,
                   "epoch": self.epoch}]
        if cid == self.primary:
            self.primary = self._elect()
            events.append({"type": "elect", "primary": self.primary,
                           "epoch": self.epoch})
        return events

    def _elect(self) -> Optional[int]:
        """Most caught-up non-evicted member (max applied_seq, tie ->
        lowest cid); None when the fleet is empty."""
        cands = [c for c, m in self.members.items() if m.state != "evicted"]
        if not cands:
            return None
        return min(cands, key=lambda c: (-self.applied.get(c, 0), c))

    def readmit(self, cid: int) -> list[dict]:
        """Re-admit an evicted member (operator action after a restart).
        It re-enters as a replica at the *current* epoch — it can never
        resume a primaryship it lost."""
        m = self.members.get(int(cid))
        if m is None or m.state != "evicted":
            return []
        m.state = "alive"
        m.expires = self.clock() + self.lease_s
        self.epoch += 1
        ev = [{"type": "readmit", "member": int(cid), "epoch": self.epoch}]
        self.events += ev
        return ev

    # -- queries --------------------------------------------------------
    def is_primary(self, cid: int, epoch: Optional[int] = None) -> bool:
        """The commit-time fence: is ``cid`` THE primary (at ``epoch``)?"""
        return (self.primary == int(cid)
                and (epoch is None or int(epoch) == self.epoch))

    def routable(self) -> list[int]:
        """Members requests may be routed to (alive, lease current)."""
        return [c for c, m in sorted(self.members.items())
                if m.state == "alive"]

    def admitted(self) -> list[int]:
        """Members still in the configuration (not evicted)."""
        return [c for c, m in sorted(self.members.items())
                if m.state != "evicted"]

    def view(self) -> dict:
        """The /stats projection: epoch, primary, per-member lease state."""
        now = self.clock()
        return {
            "epoch": self.epoch,
            "primary": self.primary,
            "leases": {
                c: {"state": m.state,
                    "remaining_s": round(max(0.0, m.expires - now), 3),
                    "applied_seq": self.applied.get(c, 0)}
                for c, m in sorted(self.members.items())},
        }
