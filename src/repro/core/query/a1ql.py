"""A1QL: the MQL-like JSON traversal language (§3.4, Fig. 8).

A query is a nested JSON document; each nesting level is one traversal step.
Example (the paper's "actors who worked with Steven Spielberg", Fig. 8):

    {"type": "director", "id": 4242,
     "_out_edge": {"type": "film.director",
                   "_target": {"type": "film",
                               "_out_edge": {"type": "film.actor",
                                             "_target": {"select": "count"}}}}}

Supported constructs:
  * ``type`` / ``id``           — start vertex via primary index
  * ``_out_edge`` / ``_in_edge``— traverse typed (or any: type "*") edges
  * ``_target``                 — the next level; may carry ``type`` (target
                                  vertex type check) and ``filter``
  * ``filter``                  — {"attr": name, "op": ..., "value": v}
  * ``select``                  — "count" | "*" | [attr, ...]  (terminal)
  * ``{"intersect": [q1, q2, ...], "select": ...}`` — star pattern (Q3):
    vertices reached by *every* branch.  Stars do not nest.
  * ``{"nearest": {"type": t, "vector": [...], "k": n}, ...}`` — k-NN probe
    root over ``t``'s vector index, replacing ``type``/``id``; the chain
    (if any) continues from the k seed vertices.  Not allowed inside
    intersect branches.
  * ``hints``                   — {"frontier"|"expand"|"results"|"bucket":
                                  n, ...}: per-plan §3.4 capacity overrides
                                  (the paper's optional query hints map 1:1
                                  onto our static working-set knobs).  May
                                  sit at the terminal node and/or the query
                                  root; per-key merge, root wins.  Stars
                                  carry hints at the root only (branch
                                  hints are a ParseError).

The parser resolves names against the catalog and produces one typed
logical-plan IR tree (:mod:`repro.core.query.ir`) per query — the paper's
logical plan; A1 has no optimizer ("most queries are straightforward and
executed without any optimization").  Chains and star patterns are the same
tree shape; ``ir.lower`` produces the physical plan + runtime start keys the
executors compile.  ``Plan``/``Hop``/``Pred`` are re-exported here for the
executor layer.
"""
from __future__ import annotations

from typing import Optional

from repro.core.query import ir
from repro.core.query.ir import (_OPS, CapHints, Hop,  # noqa: F401 (re-export)
                                 Plan, Pred)


class ParseError(ValueError):
    pass


def _parse_pred(db, vtype_name: Optional[str], node) -> Pred:
    attr, op, val = node.get("attr"), node.get("op", "=="), node.get("value")
    if op not in _OPS:
        raise ParseError(f"bad op {op!r}")
    if attr == "key" or vtype_name is None:
        return Pred("key", 0, op, float(val))
    a = db.vt(vtype_name).attr(attr)
    return Pred(a.kind, a.col, op, float(val))


_HINT_KEYS = ("frontier", "expand", "results", "bucket")


def _parse_hints(node) -> CapHints:
    h = node.get("hints")
    if not h:
        return ir.NO_HINTS
    bad = set(h) - set(_HINT_KEYS)
    if bad:
        raise ParseError(f"unknown hint(s) {sorted(bad)}; "
                         f"valid: {_HINT_KEYS}")
    vals = {}
    for k, v in h.items():
        try:
            iv = int(v)
        except (TypeError, ValueError):
            raise ParseError(f"hint {k!r} must be a positive int, "
                             f"got {v!r}") from None
        # reject bools and non-integral floats (int() would silently
        # truncate 7.9 -> 7); integral floats (64.0) are fine — JSON
        if isinstance(v, bool) or iv != v or iv <= 0:
            raise ParseError(f"hint {k!r} must be a positive int, got {v!r}")
        vals[k] = iv
    return CapHints(**vals)


def _parse_cursor(q) -> int:
    """Root-level ``gid_cursor``: a runtime final predicate ``gid > cursor``
    (deep-pagination refills page in O(page) without retracing — the cursor
    never enters the physical plan)."""
    v = q.get("gid_cursor")
    if v is None:
        return -1
    if isinstance(v, bool) or not isinstance(v, int) or v < 0:
        raise ParseError(f"gid_cursor must be a non-negative int, got {v!r}")
    return int(v)


def parse(db, q: dict):
    """Parse one A1QL document into its logical-plan IR root."""
    if "intersect" in q:
        branches = []
        for b in q["intersect"]:
            if "intersect" in b:
                raise ParseError("nested intersect is not supported")
            if "nearest" in b:
                raise ParseError(
                    "nearest is not supported in intersect branches")
            body, leaf = _parse_chain(db, b)
            if "hints" in b or "hints" in leaf[0]:
                raise ParseError("hints belong on the star root, "
                                 "not its branches")
            branches.append(body)
        node = ir.Intersect(branches=tuple(branches))
        if "filter" in q:
            node = ir.Filter(child=node,
                             pred=_parse_pred(db, q.get("type"), q["filter"]))
        return _terminal(db, q, node, vtype_name=q.get("type"))
    body, leaf = _parse_chain(db, q)
    if isinstance(body, ir.Scan):
        raise ParseError("query needs at least one traversal step")
    return _terminal(db, leaf[0], body, vtype_name=leaf[1], root=q)


def _parse_chain(db, q: dict):
    """Parse a chain document body.  Returns (body node, (leaf dict, leaf
    vertex-type name)) — the leaf carries the terminal/final filter."""
    node = q
    if "nearest" in q:
        # k-NN probe root replacing {'type', 'id'}: the chain continues from
        # the k seed vertices exactly as it would from a scanned one
        body, vtype_name = _parse_nearest(db, q)
    else:
        if "type" not in q or "id" not in q:
            raise ParseError("query must start with {'type', 'id'}")
        vt = db.vt(q["type"])
        vtype_name = q["type"]
        body = ir.Scan(vtype=vt.type_id, key=int(q["id"]))
    while True:
        edge_key = ("_out_edge" if "_out_edge" in node
                    else "_in_edge" if "_in_edge" in node else None)
        if edge_key is None:
            return body, (node, vtype_name)
        if node is not q and "hints" in node:
            # ``node`` has an outgoing step, so it is an intermediate
            # _target — hints only bind at the root or the terminal
            raise ParseError("hints belong on the query root or the "
                             "terminal node, not an intermediate step")
        e = node[edge_key]
        et_name = e.get("type", "*")
        etid = -1 if et_name == "*" else db.et(et_name).type_id
        tgt = e.get("_target", {})
        t_name = tgt.get("type")
        t_id = db.vt(t_name).type_id if t_name else -1
        body = ir.Expand(child=body,
                         direction="out" if edge_key == "_out_edge" else "in",
                         etype=etid, target_vtype=t_id)
        if "filter" in tgt:
            body = ir.Filter(child=body,
                             pred=_parse_pred(db, t_name, tgt["filter"]))
        node = tgt
        vtype_name = t_name


def _parse_nearest(db, q: dict):
    """Validate a ``"nearest"`` root document -> (ir.Nearest, vtype name)."""
    spec = q["nearest"]
    if "type" in q or "id" in q:
        raise ParseError("'nearest' replaces the {'type', 'id'} root")
    if not isinstance(spec, dict) or "type" not in spec or "vector" not in spec:
        raise ParseError("nearest needs {'type', 'vector'[, 'k']}")
    vt = db.vt(spec["type"])
    if vt.type_id not in db._vindexed:
        raise ParseError(
            f"vertex type {spec['type']!r} has no vector index; "
            "call GraphDB.vector_index() first")
    k = spec.get("k", 1)
    if isinstance(k, bool) or not isinstance(k, int) or k < 1:
        raise ParseError(f"nearest k must be a positive int, got {k!r}")
    vec = spec["vector"]
    if (not isinstance(vec, (list, tuple))
            or len(vec) != db.cfg.d_f32
            or not all(isinstance(x, (int, float)) and not isinstance(x, bool)
                       for x in vec)):
        raise ParseError(
            f"nearest vector must be {db.cfg.d_f32} numbers "
            f"(the type's f32 payload width)")
    body = ir.Nearest(vtype=vt.type_id, k=int(k),
                      vector=tuple(float(x) for x in vec))
    return body, spec["type"]


def _terminal(db, node, body, vtype_name: Optional[str], root=None):
    term, kinds, cols = _parse_select(db, node, vtype_name=vtype_name)
    hints = _parse_hints(node)
    cursor = _parse_cursor(root if root is not None else node)
    if root is not None and root is not node:
        # chains: hints may sit at the terminal AND/OR the root; per-key
        # merge with the ROOT winning, so a caller can wrap any document
        # with an override (serve's continuation refills do exactly this)
        hints = hints.override(_parse_hints(root))
    if term == "count":
        return ir.Count(child=body, hints=hints, gid_cursor=cursor)
    return ir.Select(child=body, kinds=kinds, cols=cols, hints=hints,
                     gid_cursor=cursor)


def parse_legacy(db, q: dict):
    """Historical entry point: returns ``(plan, start_key)`` for chains and
    ``(plan, [branch keys])`` for stars.  Prefer :func:`parse` + ``ir.lower``.
    """
    lo = ir.lower(parse(db, q))
    if lo.is_intersect:
        return lo.plan, list(lo.keys)
    return lo.plan, lo.keys[0]


def _parse_select(db, node, vtype_name: Optional[str] = None):
    sel = node.get("select", "count")
    if sel == "count":
        return "count", (), ()
    if sel == "*" or sel == ["*"]:
        if vtype_name is None:
            return "select", ("key",), (0,)
        vt = db.vt(vtype_name)
        kinds = ("key",) + tuple(a.kind for a in vt.attrs)
        cols = (0,) + tuple(a.col for a in vt.attrs)
        return "select", kinds, cols
    if isinstance(sel, (list, tuple)):
        kinds, cols = [], []
        for name in sel:
            if name == "key":
                kinds.append("key")
                cols.append(0)
            else:
                a = db.vt(vtype_name).attr(name)
                kinds.append(a.kind)
                cols.append(a.col)
        return "select", tuple(kinds), tuple(cols)
    raise ParseError(f"bad select {sel!r}")
