"""A1QL: the MQL-like JSON traversal language (§3.4, Fig. 8).

A query is a nested JSON document; each nesting level is one traversal step.
Example (the paper's "actors who worked with Steven Spielberg", Fig. 8):

    {"type": "director", "id": 4242,
     "_out_edge": {"type": "film.director",
                   "_target": {"type": "film",
                               "_out_edge": {"type": "film.actor",
                                             "_target": {"select": "count"}}}}}

Supported constructs:
  * ``type`` / ``id``           — start vertex via primary index
  * ``_out_edge`` / ``_in_edge``— traverse typed (or any: type "*") edges
  * ``_target``                 — the next level; may carry ``type`` (target
                                  vertex type check) and ``filter``
  * ``filter``                  — {"attr": name, "op": ..., "value": v}
  * ``select``                  — "count" | "*" | [attr, ...]  (terminal)
  * ``{"intersect": [q1, q2, ...], "select": ...}`` — star pattern (Q3):
    vertices reached by *every* branch.

The parser resolves names against the catalog and produces a :class:`Plan`
(the paper's logical plan; A1 has no optimizer — "most queries are
straightforward and executed without any optimization", and optional hints
map 1:1 onto our static capacity knobs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

_OPS = ("==", "!=", "<", "<=", ">", ">=")


@dataclasses.dataclass(frozen=True)
class Pred:
    kind: str        # 'f32' | 'i32' | 'key'
    col: int
    op: str
    val: float


@dataclasses.dataclass(frozen=True)
class Hop:
    direction: str               # 'out' | 'in'
    etype: int                   # resolved edge-type id, -1 = any
    target_vtype: int = -1       # -1 = unchecked
    pred: Optional[Pred] = None


@dataclasses.dataclass(frozen=True)
class Plan:
    start_vtype: int
    hops: tuple[Hop, ...]
    terminal: str                        # 'count' | 'select'
    select_kind: tuple = ()              # per col: 'f32'|'i32'|'key'
    select_cols: tuple = ()              # column ids (parallel to kinds)
    branches: tuple["Plan", ...] = ()    # intersect-of-branches when set
    final_pred: Optional[Pred] = None

    @property
    def is_intersect(self) -> bool:
        return bool(self.branches)

    def signature(self):
        """Structural key for the compiled-executor cache."""
        if self.is_intersect:
            return ("intersect", tuple(b.signature() for b in self.branches),
                    self.terminal, self.select_kind, self.select_cols,
                    _psig(self.final_pred))
        return ("chain", tuple((h.direction, _psig(h.pred)) for h in self.hops),
                self.terminal, self.select_kind, self.select_cols,
                _psig(self.final_pred))


def _psig(p: Optional[Pred]):
    return None if p is None else (p.kind, p.op)


class ParseError(ValueError):
    pass


def _parse_pred(db, vtype_name: Optional[str], node) -> Pred:
    attr, op, val = node.get("attr"), node.get("op", "=="), node.get("value")
    if op not in _OPS:
        raise ParseError(f"bad op {op!r}")
    if attr == "key" or vtype_name is None:
        return Pred("key", 0, op, float(val))
    a = db.vt(vtype_name).attr(attr)
    return Pred(a.kind, a.col, op, float(val))


def parse(db, q: dict) -> tuple[Plan, int]:
    """Parse one A1QL document.  Returns (plan, start_key)."""
    if "intersect" in q:
        parsed = [parse(db, b) for b in q["intersect"]]
        plans = tuple(p for p, _ in parsed)
        keys = [k for _, k in parsed]
        term, kinds, cols = _parse_select(db, q)
        fp = None
        if "filter" in q:
            fp = _parse_pred(db, q.get("type"), q["filter"])
        plan = Plan(start_vtype=-1, hops=(), terminal=term,
                    select_kind=kinds, select_cols=cols, branches=plans,
                    final_pred=fp)
        return plan, keys          # list of per-branch start keys
    if "type" not in q or "id" not in q:
        raise ParseError("query must start with {'type', 'id'}")
    vt = db.vt(q["type"])
    hops = []
    node = q
    vtype_name = q["type"]
    term, kinds, cols, fp = "count", (), (), None
    while True:
        edge_key = ("_out_edge" if "_out_edge" in node
                    else "_in_edge" if "_in_edge" in node else None)
        if edge_key is None:
            term, kinds, cols = _parse_select(db, node,
                                              vtype_name=vtype_name)
            if "filter" in node and node is not q:
                fp = _parse_pred(db, vtype_name, node["filter"])
            break
        e = node[edge_key]
        et_name = e.get("type", "*")
        etid = -1 if et_name == "*" else db.et(et_name).type_id
        tgt = e.get("_target", {})
        t_name = tgt.get("type")
        t_id = db.vt(t_name).type_id if t_name else -1
        pred = (_parse_pred(db, t_name, tgt["filter"])
                if "filter" in tgt else None)
        hops.append(Hop(direction="out" if edge_key == "_out_edge" else "in",
                        etype=etid, target_vtype=t_id, pred=pred))
        node = tgt
        vtype_name = t_name
    if not hops:
        raise ParseError("query needs at least one traversal step")
    plan = Plan(start_vtype=vt.type_id, hops=tuple(hops), terminal=term,
                select_kind=kinds, select_cols=cols, final_pred=fp)
    return plan, int(q["id"])


def _parse_select(db, node, vtype_name: Optional[str] = None):
    sel = node.get("select", "count")
    if sel == "count":
        return "count", (), ()
    if sel == "*" or sel == ["*"]:
        if vtype_name is None:
            return "select", ("key",), (0,)
        vt = db.vt(vtype_name)
        kinds = ("key",) + tuple(a.kind for a in vt.attrs)
        cols = (0,) + tuple(a.col for a in vt.attrs)
        return "select", kinds, cols
    if isinstance(sel, (list, tuple)):
        kinds, cols = [], []
        for name in sel:
            if name == "key":
                kinds.append("key")
                cols.append(0)
            else:
                a = db.vt(vtype_name).attr(name)
                kinds.append(a.kind)
                cols.append(a.col)
        return "select", tuple(kinds), tuple(cols)
    raise ParseError(f"bad select {sel!r}")
