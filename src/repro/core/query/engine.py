"""A1QL v2: the unified query entry point (§3.4).

One function — :func:`execute`, exported as ``GraphDB.query`` — replaces the
historical four-way split (``run_queries`` / ``run_queries_spmd`` /
``run_queries_batched`` / ``run_queries_batched_spmd``, all still available
as deprecated shims).  Every query parses to the typed logical-plan IR
(:mod:`repro.core.query.ir`), and routing is internal:

  * ``mesh=None`` runs the single-address-space executors; a mesh runs the
    shard_map'd SPMD programs — same results, property-tested;
  * **uniform** batches (every query lowers to the same physical plan, cap
    hints, and snapshot) run the per-plan-shape executor: one compiled
    program whose §3.4 working-set budget is shared by the batch — the
    historical ``run_queries`` semantics, and the parity oracle;
  * everything else — mixed plan shapes, star patterns next to chains,
    per-query MVCC snapshots, per-query cap hints — runs the fused
    multi-query waves (:mod:`repro.core.query.planner`) with *per-query*
    budgets, bit-identical to running each query alone.
    ``fused=True`` forces this path (per-query budgets + ``failed_q`` flags
    even for uniform batches — what serving's hedged retries want);
    ``fused=False`` forbids it (raises on non-uniform batches).

``read_ts`` is ``None`` (one fresh snapshot), a scalar, or per-query
timestamps; every distinct timestamp is pinned for the duration of the call
(the §2.2 GC barrier).  ``parsed`` short-circuits parsing: a list of IR
roots, ``ir.Lowered``, or historical ``(plan, key)`` tuples.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core.query import ir
from repro.core.query.a1ql import parse
from repro.core.query.executor import (QueryCaps, QueryResult, _to_result,
                                       compile_query)


def _normalize_parsed(db, queries, parsed) -> list[ir.Lowered]:
    if parsed is None:
        return [ir.lower(parse(db, q)) for q in queries]
    out = []
    for p in parsed:
        if isinstance(p, ir.Lowered):
            out.append(p)
        elif ir.is_root(p):
            out.append(ir.lower(p))
        elif isinstance(p, tuple) and len(p) == 2:
            out.append(ir.from_legacy(*p))       # historical (plan, key)
        else:
            raise TypeError(f"bad parsed entry {type(p).__name__}")
    if len(out) != len(queries):
        raise ValueError(f"{len(out)} parsed entries for "
                         f"{len(queries)} queries")
    return out


def _normalize_ts(db, Q: int,
                  read_ts: Union[None, int, Sequence[int]]) -> list[int]:
    if read_ts is None:
        return [db.snapshot_ts()] * Q
    if isinstance(read_ts, (int, np.integer)):
        return [int(read_ts)] * Q
    ts = [int(t) for t in read_ts]
    if len(ts) != Q:
        raise ValueError(f"read_ts has {len(ts)} entries for {Q} queries")
    return ts


def execute(db, queries: list[dict], *, caps: Optional[QueryCaps] = None,
            backend: Optional[str] = None,
            read_ts: Union[None, int, Sequence[int]] = None,
            mesh=None, storage_axes=("data", "model"),
            parsed: Optional[list] = None,
            fused: Optional[bool] = None,
            budget: Optional[str] = None,
            deadline: Optional[float] = None) -> QueryResult:
    """Execute a batch of A1QL queries at consistent snapshot timestamps.

    See the module docstring for routing; all queries in one call observe
    MVCC snapshots pinned for the whole call, and results (``counts`` /
    ``rows_gid`` / ``rows`` / ``truncated`` / fast-fail flags) scatter back
    into input order.

    ``budget`` selects the fused frontier discipline: ``"per-query"`` (the
    default) gives every query its own §3.4 working-set budget —
    bit-identical to solo runs; ``"shared"`` pools all live queries'
    frontiers into one shared-capacity pool (O(F*sqrt(Q)) peak memory, the
    serving-cap shape) whose overflow is owner-attributed via ``failed_q``
    — results can differ from per-query mode only via those flags.
    ``budget="shared"`` always runs the fused planner.

    Documents may carry a root-level ``"gid_cursor": <gid>`` — a runtime
    final predicate ``gid > cursor`` (deep-pagination refills); cursor
    batches always run fused, and the cursor never retraces a program.
    Cursors are local-executor only: SPMD select rows are ordered
    shard-major, so a max-gid cursor could silently skip rows — a cursor
    under ``mesh=`` raises (serve's refills fall back to the pow2 growing
    window there).

    ``deadline`` is an absolute ``time.monotonic()`` instant — the hard
    edge of the serving tier's SLO budget.  Fusion groups past the
    deadline are skipped, their queries flagged ``deadline_q`` (truncated,
    *not* failed).  A deadline forces the fused path: the uniform executor
    is a single all-or-nothing program with no per-group skip point.
    """
    from repro.core import faults as faults_mod
    from repro.core.query import planner
    if not queries:
        raise ValueError("execute() needs at least one query")
    # chaos site: a wave-execution crash ("raise") or straggler ("stall").
    # Raising here — before any snapshot is pinned — models a worker dying
    # mid-wave; the serving tier must retry or abort with attribution.
    faults_mod.check(db, "engine.wave")
    if budget not in (None, "per-query", "shared"):
        raise ValueError(f"budget must be 'per-query' or 'shared', "
                         f"got {budget!r}")
    caps = caps or QueryCaps()
    be = backend_mod.resolve(backend or getattr(db, "backend", None))
    lowered = _normalize_parsed(db, queries, parsed)
    Q = len(lowered)
    ts_list = _normalize_ts(db, Q, read_ts)
    eff_caps = [lo.hints.apply(caps) for lo in lowered]
    cursors = [lo.cursor for lo in lowered]
    any_cursor = any(c >= 0 for c in cursors)
    if any_cursor and mesh is not None:
        # SPMD select truncation is shard-major, not gid-ascending: paging
        # by max-gid cursor could permanently skip rows on later shards
        raise ValueError("gid_cursor is not supported under mesh= "
                         "(SPMD rows are shard-major; use the growing-"
                         "window continuation instead)")

    # Nearest-rooted plans only exist as fused probe-wave rows (the
    # per-plan-shape executors have no knn wave); a "per-query" oracle for
    # them is a fused batch of one
    any_nearest = any(p.nearest_k > 0 for lo in lowered
                      for p in lo.plan.chain_units())
    uniform = (all(lo.plan == lowered[0].plan for lo in lowered[1:])
               and all(c == eff_caps[0] for c in eff_caps[1:])
               and len(set(ts_list)) == 1
               and not any_cursor
               and not any_nearest)
    if fused is False and not uniform:
        raise ValueError("fused=False requires a uniform batch "
                         "(one plan shape, caps, snapshot, no cursors, "
                         "no nearest)")
    if fused is False and budget == "shared":
        raise ValueError("budget='shared' requires the fused planner")
    if fused is False and deadline is not None:
        raise ValueError("deadline= requires the fused planner (the "
                         "uniform executor has no per-group skip point)")
    run_fused = (bool(fused) or not uniform or budget == "shared"
                 or deadline is not None)

    pins = sorted(set(ts_list))
    for t in pins:                            # pin versions (GC barrier)
        db.active_query_ts.append(t)
    try:
        if run_fused:
            return planner.execute_fused(db, lowered, eff_caps, ts_list, be,
                                         mesh=mesh, storage_axes=storage_axes,
                                         budget=budget or "per-query",
                                         cursors=cursors, deadline=deadline)
        return _execute_uniform(db, lowered, eff_caps[0], ts_list[0], be,
                                mesh, storage_axes)
    finally:
        for t in pins:
            db.active_query_ts.remove(t)


def _execute_uniform(db, lowered: list[ir.Lowered], caps: QueryCaps,
                     read_ts: int, be, mesh, storage_axes) -> QueryResult:
    """One plan shape, shared working-set budget: the per-plan executors."""
    from repro.core.query.planner import index_window
    plan = lowered[0].plan
    Q = len(lowered)
    xwin = index_window(db)
    if plan.is_intersect:
        # (branches, Q) key layout: branch bi of query qi probes keys[bi, qi]
        keys = jnp.asarray(np.array(
            [[lo.keys[bi] for lo in lowered]
             for bi in range(len(plan.branches))], np.int32))
    else:
        keys = jnp.asarray(np.array([lo.keys[0] for lo in lowered], np.int32))
    if mesh is not None:
        from repro.core.query.executor_spmd import compile_query_spmd
        fn = compile_query_spmd(db.cfg, plan, caps, Q, mesh, storage_axes,
                                backend=be, xwin=xwin)
    else:
        fn = compile_query(db.cfg, plan, caps, Q, be, xwin=xwin)
    out = fn(db.store, keys, jnp.ones((Q,), bool), jnp.int32(read_ts))
    return _to_result(plan, out)
