"""Query execution, single-address-space mode (§3.4).

This is the *logical* executor: it runs the physical plan against the global
store arrays on one device.  It defines the semantics; the distributed
executor (executor_spmd.py) must produce bit-identical results (property
tested), the same way A1's shipped operators must agree with coordinator-side
evaluation.

Execution mirrors the paper's operator set: index scan -> [edge enumeration ->
predicate evaluation -> dedup/repartition]* -> aggregate, all at one snapshot
timestamp, with fixed working-set capacities and a fast-fail flag instead of
spill (§3.4).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import edges as edges_mod
from repro.core import index as index_mod
from repro.core.addressing import NULL, TS_INF, StoreConfig
from repro.core.query.a1ql import Hop, Plan, Pred
from repro.core.store import GraphStore, visible

I32MAX = jnp.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class QueryCaps:
    """Static working-set capacities (the paper's §3.4 memory budget; optional

    A1QL hints map to these)."""
    frontier: int = 1024       # live (qid, gid) pairs between hops
    expand: int = 4096         # CSR expansion slots per hop
    results: int = 64          # rows returned per query (continuation beyond)
    # spmd-only:
    bucket: int = 256          # per-destination-shard routing bucket
    # shared-frontier mode only (GraphDB.query(..., budget="shared")):
    # explicit shared-pool sizes; 0 = the planner's auto policy
    # (per-cap * ceil(sqrt(units)), pow2 — see planner.shared_budget)
    shared_frontier: int = 0
    shared_expand: int = 0
    shared_bucket: int = 0


@dataclasses.dataclass
class QueryResult:
    counts: Optional[np.ndarray] = None      # (Q,) for terminal 'count'
    rows_gid: Optional[np.ndarray] = None    # (Q, K) for terminal 'select'
    rows: Optional[dict] = None              # attr name -> (Q, K)
    truncated: Optional[np.ndarray] = None   # (Q,) rows overflowed K
    failed: bool = False                     # fast-fail (capacity overflow)
    failed_q: Optional[np.ndarray] = None    # (Q,) per-query fast-fail flags
                                             # (set by the multi-query planner;
                                             # plain run_queries flags the
                                             # whole batch)
    shared_ovf_q: Optional[np.ndarray] = None  # (Q,) subset of failed_q that
                                             # was caused by the *shared* pool
                                             # (budget="shared" truncation /
                                             # bucket drops) rather than the
                                             # query's own per-unit caps —
                                             # serving re-dispatches these
                                             # per-query instead of re-entering
                                             # the saturated pool
    deadline_q: Optional[np.ndarray] = None  # (Q,) SLO-budget truncation: the
                                             # query's wave group was skipped
                                             # because the execution deadline
                                             # passed (engine deadline=).  NOT
                                             # a capacity failure: failed_q
                                             # stays False and serving answers
                                             # truncated-with-flag instead of
                                             # hedging


# ---------------------------------------------------------------------------
# shared primitives
# ---------------------------------------------------------------------------

def eval_pred(pred: Pred, f_data, i_data, keys):
    """Vertex predicate evaluation (one of the paper's basic operators).

    ``f_data``/``i_data`` may carry any leading batch shape (the planner's
    fused waves evaluate predicates on ``(Q, F, d)`` row blocks)."""
    if pred.kind == "f32":
        x = f_data[..., pred.col]
        v = jnp.float32(pred.val)
    elif pred.kind == "i32":
        x = i_data[..., pred.col]
        v = jnp.int32(int(pred.val))
    else:
        x = keys
        v = jnp.int32(int(pred.val))
    if pred.op == "==":
        return x == v
    if pred.op == "!=":
        return x != v
    if pred.op == "<":
        return x < v
    if pred.op == "<=":
        return x <= v
    if pred.op == ">":
        return x > v
    return x >= v


def sort_pairs(qids, gids, valid):
    """Sort (qid, gid) pairs; invalid entries to the end.  Returns sorted

    (qids, gids, valid, first_of_run mask)."""
    k1 = jnp.where(valid, qids, I32MAX)
    k2 = jnp.where(valid, gids, I32MAX)
    k1, k2 = jax.lax.sort((k1, k2), num_keys=2)
    valid_s = k1 != I32MAX
    prev1 = jnp.concatenate([jnp.full((1,), -1, k1.dtype), k1[:-1]])
    prev2 = jnp.concatenate([jnp.full((1,), -1, k2.dtype), k2[:-1]])
    first = valid_s & ((k1 != prev1) | (k2 != prev2))
    return jnp.where(valid_s, k1, NULL), jnp.where(valid_s, k2, NULL), valid_s, first


def dedup_compact(qids, gids, valid, cap: int):
    """Dedup (qid, gid) pairs and compact to ``cap`` slots.

    The coordinator's "aggregated, duplicates removed" step.  Returns
    (qids', gids', valid', overflow).
    """
    q_s, g_s, v_s, first = sort_pairs(qids, gids, valid)
    n_unique = jnp.sum(first.astype(jnp.int32))
    pos = jnp.cumsum(first.astype(jnp.int32)) - 1
    pos = jnp.where(first, pos, I32MAX)          # drop non-first
    out_q = jnp.full((cap,), NULL, jnp.int32).at[pos].set(q_s, mode="drop")
    out_g = jnp.full((cap,), NULL, jnp.int32).at[pos].set(g_s, mode="drop")
    return out_q, out_g, out_q >= 0, n_unique > cap


def check_vertices(store: GraphStore, cfg: StoreConfig, qids, gids, valid,
                   read_ts, target_vtype: int, pred: Optional[Pred]):
    """Liveness + type + predicate check of arrived vertices (worker-side

    'predicate evaluation against vertex data')."""
    ok = valid & (gids >= 0)
    rows = cfg.row_of_gid(jnp.where(ok, gids, 0))
    alive = ok & visible(store.v_create[rows], store.v_delete[rows], read_ts)
    if target_vtype >= 0:
        alive = alive & (store.vtype[rows] == jnp.int32(target_vtype))
    if pred is not None:
        use_cur = store.vdata_ts[rows] <= read_ts
        f = jnp.where(use_cur[:, None], store.vdata_f[rows],
                      store.vprev_f[rows])
        i = jnp.where(use_cur[:, None], store.vdata_i[rows],
                      store.vprev_i[rows])
        alive = alive & eval_pred(pred, f, i, store.vkey[rows])
    return alive


def build_select(store: GraphStore, cfg: StoreConfig, plan: Plan,
                 qids, gids, valid, read_ts, n_queries: int, k: int):
    """Scatter final (qid, gid) pairs into per-query rows + gather attrs."""
    q_s, g_s, v_s, first = sort_pairs(qids, gids, valid)
    # position within each query's run (dedup'd); NB: q_s pads invalid with
    # NULL(-1) which breaks sortedness, so search over an I32MAX-padded view.
    q_srch = jnp.where(v_s, q_s, I32MAX)
    c = jnp.cumsum(first.astype(jnp.int32))
    run_start = jnp.searchsorted(q_srch, q_srch, side="left").astype(jnp.int32)
    excl = c - first.astype(jnp.int32)           # exclusive cumsum
    pos_in_q = excl - excl[run_start]
    row = jnp.where(first & (q_s >= 0), q_s, I32MAX)
    col = jnp.where(first, pos_in_q, I32MAX)
    over = first & (pos_in_q >= k)
    col = jnp.where(over, I32MAX, col)

    rows_gid = jnp.full((n_queries, k), NULL, jnp.int32)
    rows_gid = rows_gid.at[row, col].set(g_s, mode="drop")
    truncated = jnp.zeros((n_queries,), bool).at[
        jnp.where(over, q_s, I32MAX)].set(True, mode="drop")

    safe = jnp.where(rows_gid >= 0, rows_gid, 0)
    r = cfg.row_of_gid(safe)
    use_cur = store.vdata_ts[r] <= read_ts
    out = {}
    for kind, colid in zip(plan.select_kind, plan.select_cols):
        if kind == "key":
            vals = jnp.where(rows_gid >= 0, store.vkey[r], NULL)
        elif kind == "f32":
            v = jnp.where(use_cur, store.vdata_f[r][..., colid],
                          store.vprev_f[r][..., colid])
            vals = v * (rows_gid >= 0)
        else:
            v = jnp.where(use_cur, store.vdata_i[r][..., colid],
                          store.vprev_i[r][..., colid])
            vals = v * (rows_gid >= 0)
        out[(kind, colid)] = vals
    return rows_gid, out, truncated


# ---------------------------------------------------------------------------
# chain execution (lookup -> hops -> terminal)
# ---------------------------------------------------------------------------

def _chain_frontier(store, cfg: StoreConfig, plan: Plan, caps: QueryCaps,
                    keys, valid, read_ts,
                    backend: backend_mod.Backend = backend_mod.REF,
                    xwin: Optional[int] = None):
    """Run index lookup + all hops; returns final (qids, gids, valid, failed)."""
    Q = keys.shape[0]
    F = caps.frontier
    vt = jnp.full((Q,), plan.start_vtype, jnp.int32)
    gids, found = index_mod.lookup(store, cfg, vt, keys, valid, read_ts,
                                   backend=backend, xd_win=xwin)
    qids = jnp.arange(Q, dtype=jnp.int32)
    ok = valid & found
    pad = F - Q
    if pad < 0:
        raise ValueError("frontier capacity below query batch size")
    qids = jnp.concatenate([jnp.where(ok, qids, NULL),
                            jnp.full((pad,), NULL, jnp.int32)])
    gids = jnp.concatenate([jnp.where(ok, gids, NULL),
                            jnp.full((pad,), NULL, jnp.int32)])
    vmask = gids >= 0
    failed = jnp.zeros((), bool)

    for hop in plan.hops:
        oq, on, ov, ovf = edges_mod.expand(
            store, cfg, qids, gids, vmask, etype=jnp.int32(hop.etype),
            direction=hop.direction, read_ts=read_ts, cap_out=caps.expand,
            backend=backend)
        failed = failed | ovf
        qids, gids, vmask, ovf2 = dedup_compact(oq, on, ov, F)
        failed = failed | ovf2
        alive = check_vertices(store, cfg, qids, gids, vmask, read_ts,
                               hop.target_vtype, hop.pred)
        vmask = vmask & alive
        gids = jnp.where(vmask, gids, NULL)
        qids = jnp.where(vmask, qids, NULL)
    return qids, gids, vmask, failed


def _terminal(store, cfg, plan, caps, qids, gids, vmask, read_ts, Q: int):
    if plan.final_pred is not None:
        keep = check_vertices(store, cfg, qids, gids, vmask, read_ts,
                              -1, plan.final_pred)
        vmask = vmask & keep
        gids = jnp.where(vmask, gids, NULL)
        qids = jnp.where(vmask, qids, NULL)
    if plan.terminal == "count":
        q_s, g_s, v_s, first = sort_pairs(qids, gids, vmask)
        counts = jax.ops.segment_sum(
            first.astype(jnp.int32),
            jnp.where(first, q_s, Q).astype(jnp.int32),
            num_segments=Q + 1)[:Q]
        return {"counts": counts}
    rows_gid, attrs, trunc = build_select(store, cfg, plan, qids, gids, vmask,
                                          read_ts, Q, caps.results)
    return {"rows_gid": rows_gid, "attrs": attrs, "truncated": trunc}


def _run_intersect(store, cfg, plan: Plan, caps: QueryCaps, keys_b, valid,
                   read_ts, Q: int,
                   backend: backend_mod.Backend = backend_mod.REF,
                   xwin: Optional[int] = None):
    """Star-pattern intersection (Q3): keep vertices reached by all branches."""
    B = len(plan.branches)
    all_q, all_g, all_v = [], [], []
    failed = jnp.zeros((), bool)
    for bi, branch in enumerate(plan.branches):
        q, g, v, f = _chain_frontier(store, cfg, branch, caps,
                                     keys_b[bi], valid, read_ts, backend,
                                     xwin)
        failed = failed | f
        all_q.append(q)
        all_g.append(g)
        all_v.append(v)
    qids = jnp.concatenate(all_q)
    gids = jnp.concatenate(all_g)
    vmask = jnp.concatenate(all_v)
    q_s, g_s, v_s, first = sort_pairs(qids, gids, vmask)
    run_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    run_id = jnp.where(v_s, run_id, q_s.shape[0] - 1)
    run_len = jax.ops.segment_sum(v_s.astype(jnp.int32), run_id,
                                  num_segments=q_s.shape[0])
    keep = first & (run_len[run_id] == B)
    kq = jnp.where(keep, q_s, NULL)
    kg = jnp.where(keep, g_s, NULL)
    return _terminal(store, cfg, plan, caps, kq, kg, keep, read_ts, Q), failed


# compiled-executor cache (the paper parses per query; we compile per plan
# *shape* so repeated patterns — the common case in serving — are free).
# CACHE_STATS is observable so tests/benchmarks can assert no retracing.
_CACHE: dict = {}
CACHE_STATS = {"hits": 0, "misses": 0}


def compile_query(cfg: StoreConfig, plan: Plan, caps: QueryCaps,
                  n_queries: int,
                  backend: backend_mod.Backend = backend_mod.REF,
                  xwin: Optional[int] = None):
    """Build the jitted program for one plan shape (shared-budget batch).

    ``xwin`` is the static primary-index delta window (see
    ``planner.index_window``) — semantics-preserving (skipped slots are
    provably empty), part of the cache key like the planner's ``dwin``."""
    key = (cfg, plan, caps, n_queries, backend, xwin, "local")
    if key in _CACHE:
        CACHE_STATS["hits"] += 1
        return _CACHE[key]
    CACHE_STATS["misses"] += 1

    if plan.is_intersect:
        @jax.jit
        def run(store, keys_b, valid, read_ts):
            out, failed = _run_intersect(store, cfg, plan, caps, keys_b,
                                         valid, read_ts, n_queries, backend,
                                         xwin)
            out["failed"] = failed
            return out
    else:
        @jax.jit
        def run(store, keys, valid, read_ts):
            q, g, v, failed = _chain_frontier(store, cfg, plan, caps, keys,
                                              valid, read_ts, backend, xwin)
            out = _terminal(store, cfg, plan, caps, q, g, v, read_ts,
                            n_queries)
            out["failed"] = failed
            return out

    _CACHE[key] = run
    return run


def run_queries(db, queries: list[dict], caps: Optional[QueryCaps] = None,
                backend: Optional[str] = None,
                read_ts: Optional[int] = None) -> QueryResult:
    """Deprecated shim: use ``GraphDB.query`` / ``engine.execute``.

    Uniform batches keep the historical shared-budget semantics; mixed
    batches route to the fused multi-query waves — exactly what
    ``execute`` does with ``fused=None``.
    """
    import warnings
    warnings.warn("run_queries is deprecated; use GraphDB.query(...) "
                  "(core.query.engine.execute)", DeprecationWarning,
                  stacklevel=2)
    from repro.core.query.engine import execute
    return execute(db, queries, caps=caps, backend=backend, read_ts=read_ts)


def _to_result(plan: Plan, out: dict) -> QueryResult:
    res = QueryResult(failed=bool(np.any(np.asarray(out["failed"]))))
    if plan.terminal == "count":
        res.counts = np.asarray(out["counts"])
    else:
        res.rows_gid = np.asarray(out["rows_gid"])
        res.truncated = np.asarray(out["truncated"])
        res.rows = {k: np.asarray(v) for k, v in out["attrs"].items()}
    return res


