"""Distributed query execution: query shipping on a TPU mesh (§3.4).

This is the paper's coordinator/worker protocol compiled into one SPMD
program.  Per hop:

  1. *map pointers -> hosts*: each shard buckets its live frontier pairs by
     ``owner = gid % S`` — pure local arithmetic, like A1's CM metadata;
  2. *batched RPCs*: one ``all_to_all`` ships every bucket to its owner
     (operators move, not data);
  3. *worker step*: the owner checks arrived vertices (liveness, type,
     predicate — A1's "predicate evaluation" operator), enumerates edges from
     its local CSR block + delta log ("edge enumeration"), and emits
     (qid, dst) pairs;
  4. *repartition*: emitted pairs stay put — the next hop's routing step is
     exactly the paper's "repartitioned by pointer address".

Dedup happens shard-locally after routing (each gid has one owner, so local
dedup is global dedup — the coordinator's "duplicates removed" with no extra
collective).  Counts aggregate with one psum.  Capacity overflow anywhere
raises the fast-fail flag (§3.4: no spill, the query is discarded).

The local executor (executor.py) defines the semantics; tests assert this
program produces identical results.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import index as index_mod
from repro.core.edges import _tiled_csr_expand
from repro.dist import compat
from repro.core.addressing import NULL, TS_INF, StoreConfig
from repro.core.query.a1ql import Hop, Plan, Pred
from repro.core.query.executor import (I32MAX, QueryCaps, QueryResult,
                                       eval_pred, sort_pairs, dedup_compact)
from repro.core.store import GraphStore, visible
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# local-block primitives (the "worker" operators)
# ---------------------------------------------------------------------------

def _lookup_local(st: GraphStore, cfg: StoreConfig, me, vtypes, keys, valid,
                  read_ts, backend: backend_mod.Backend = backend_mod.REF,
                  xd_win: Optional[int] = None):
    """Primary-index probe against *my* index block.  Only queries whose key

    routes to me produce a gid; everyone else emits NULL (they find it on
    their own shard).  Inside shard_map the local index block is one sorted
    array, so the pallas backend probes the whole batch with a single
    sorted_lookup kernel call.  ``read_ts`` may be scalar or a per-query
    ``(Q,)`` vector (fused multi-query waves).  ``xd_win`` statically
    windows the index-delta scan to the host fill counts (see
    ``planner.index_window``); slots beyond the window are provably empty."""
    S, cap_x, cap_xd = cfg.n_shards, cfg.cap_idx, cfg.cap_idx_delta
    mine = valid & (index_mod.route(vtypes, keys, S) == me)
    h = index_mod.mix32(vtypes, keys)
    ix_h = jnp.where(st.ix_gid >= 0, index_mod.mix32(st.ix_vtype, st.ix_key),
                     I32MAX)

    pos0 = backend_mod.searchsorted(ix_h, h, backend=backend)
    best_g = jnp.full(h.shape, NULL, jnp.int32)
    best_ts = jnp.full(h.shape, -1, jnp.int32)
    for w in range(16):
        p = jnp.minimum(pos0 + w, cap_x - 1)
        hit = ((st.ix_gid[p] >= 0) & (st.ix_vtype[p] == vtypes)
               & (st.ix_key[p] == keys)
               & visible(st.ix_create[p], st.ix_delete[p], read_ts))
        newer = hit & (st.ix_create[p] > best_ts)
        best_g = jnp.where(newer, st.ix_gid[p], best_g)
        best_ts = jnp.where(newer, st.ix_create[p], best_ts)
    g_main = jnp.where(mine, best_g, NULL)
    ts_main = best_ts
    # delta scan (inside shard_map the local block is one shard: window [:W])
    W = cap_xd if xd_win is None else min(int(xd_win), cap_xd)
    xd_vt, xd_k, xd_g, xd_c, xd_d = (
        a[:W] for a in (st.xd_vtype, st.xd_key, st.xd_gid, st.xd_create,
                        st.xd_delete))
    rts_row = read_ts[:, None] if jnp.ndim(read_ts) == 1 else read_ts
    m = (mine[:, None]
         & (xd_vt[None, :] == vtypes[:, None])
         & (xd_k[None, :] == keys[:, None])
         & (xd_g >= 0)[None, :]
         & visible(xd_c[None, :], xd_d[None, :], rts_row))
    ts_d = jnp.where(m, xd_c[None, :], -1)
    best_d = jnp.argmax(ts_d, axis=1)
    ts_delta = jnp.max(ts_d, axis=1)
    g_delta = jnp.where(ts_delta >= 0, xd_g[best_d], NULL)
    return jnp.where(ts_delta > ts_main, g_delta, g_main)


def _expand_local(st: GraphStore, cfg: StoreConfig, qids, gids, valid, *,
                  etype: int, direction: str, read_ts, cap_out: int,
                  backend: backend_mod.Backend = backend_mod.REF):
    """Edge enumeration from my CSR block + delta log (gids owned by me)."""
    S = cfg.n_shards
    if direction == "out":
        indptr, nbr, typ, ecre, edel = (st.oe_indptr, st.oe_dst, st.oe_type,
                                        st.oe_create, st.oe_delete)
        dslot, dnbr, dtyp, dcre, ddel = (st.dl_slot, st.dl_nbr, st.dl_type,
                                         st.dl_create, st.dl_delete)
    else:
        indptr, nbr, typ, ecre, edel = (st.ie_indptr, st.ie_src, st.ie_type,
                                        st.ie_create, st.ie_delete)
        dslot, dnbr, dtyp, dcre, ddel = (st.il_slot, st.il_nbr, st.il_type,
                                         st.il_create, st.il_delete)
    slot = jnp.where(valid, gids // S, 0)
    start = indptr[slot]
    deg = (indptr[slot + 1] - indptr[slot]) * valid
    cum = jnp.cumsum(deg)
    total = cum[-1]
    overflow = total > cap_out
    et = jnp.int32(etype)
    if backend.is_pallas:
        out_q, out_n = _tiled_csr_expand(qids, deg, start,
                                         (nbr, typ, ecre, edel), et,
                                         read_ts, cap_out, backend)
    else:
        k = jnp.arange(cap_out, dtype=jnp.int32)
        item = jnp.searchsorted(cum, k, side="right").astype(jnp.int32)
        item_c = jnp.minimum(item, deg.shape[0] - 1)
        base = cum[item_c] - deg[item_c]
        epos = jnp.where(k < total, start[item_c] + (k - base), 0)
        e_ok = ((k < total)
                & visible(ecre[epos], edel[epos], read_ts)
                & ((et < 0) | (typ[epos] == et))
                & (nbr[epos] >= 0))
        out_q = jnp.where(e_ok, qids[item_c], NULL)
        out_n = jnp.where(e_ok, nbr[epos], NULL)

    # ---- delta merge (tier 2), §Perf a1-kg iter 1 --------------------------
    # The naive (frontier x delta) match matrix flattens to F*cap_delta
    # entries (134M at serving caps) that the dedup then has to SORT —
    # measured 40GB/device/batch of pure memory traffic.  Instead sort the
    # frontier by slot once and binary-search each delta entry into it,
    # emitting at most MULTI_Q frontier matches per entry (more than
    # MULTI_Q concurrent queries parked on one hot vertex fast-fails, the
    # paper's §3.4 capacity contract).  Output: cap_delta*MULTI_Q entries.
    MULTI_Q = 8
    D = dslot.shape[0]
    slot_key = jnp.where(valid, slot, I32MAX)
    slot_s, qid_s = jax.lax.sort((slot_key, qids), num_keys=1)
    d_ok = ((dnbr >= 0) & visible(dcre, ddel, read_ts)
            & ((et < 0) | (dtyp == et)))
    d_slot_q = jnp.where(d_ok, dslot, I32MAX)
    lo = jnp.searchsorted(slot_s, d_slot_q, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(slot_s, d_slot_q, side="right").astype(jnp.int32)
    overflow = overflow | jnp.any(d_ok & (hi - lo > MULTI_Q))
    w = jnp.arange(MULTI_Q, dtype=jnp.int32)
    pos = jnp.minimum(lo[:, None] + w[None, :],
                      slot_s.shape[0] - 1)                  # (D, MULTI_Q)
    hit = (lo[:, None] + w[None, :] < hi[:, None]) & d_ok[:, None]
    dq = jnp.where(hit, qid_s[pos], NULL).reshape(-1)
    dn = jnp.where(hit, jnp.broadcast_to(dnbr[:, None], hit.shape),
                   NULL).reshape(-1)
    return (jnp.concatenate([out_q, dq]), jnp.concatenate([out_n, dn]),
            overflow)


def _check_local(st: GraphStore, cfg: StoreConfig, gids, valid, read_ts,
                 target_vtype: int, pred: Optional[Pred]):
    """Liveness/type/predicate of vertices I own (arrived via routing)."""
    S = cfg.n_shards
    rows = jnp.where(valid, gids // S, 0)
    alive = valid & visible(st.v_create[rows], st.v_delete[rows], read_ts)
    if target_vtype >= 0:
        alive = alive & (st.vtype[rows] == jnp.int32(target_vtype))
    if pred is not None:
        use_cur = st.vdata_ts[rows] <= read_ts
        f = jnp.where(use_cur[:, None], st.vdata_f[rows], st.vprev_f[rows])
        i = jnp.where(use_cur[:, None], st.vdata_i[rows], st.vprev_i[rows])
        alive = alive & eval_pred(pred, f, i, st.vkey[rows])
    return alive


def _route(qids, gids, valid, S: int, B: int, axes):
    """Bucket by owner + one all_to_all (the batched per-machine RPCs)."""
    N = qids.shape[0]
    owner = jnp.where(valid, gids % S, S)
    o_s, q_s, g_s = jax.lax.sort((owner, qids, gids), num_keys=1)
    starts = jnp.searchsorted(o_s, jnp.arange(S, dtype=jnp.int32),
                              side="left").astype(jnp.int32)
    idx = jnp.arange(N, dtype=jnp.int32)
    ow = jnp.minimum(o_s, S - 1)
    col = idx - starts[ow]
    ok = o_s < S
    overflow = jnp.any(ok & (col >= B))
    row = jnp.where(ok & (col < B), o_s, I32MAX)
    colc = jnp.where(ok & (col < B), col, I32MAX)
    bq = jnp.full((S, B), NULL, jnp.int32).at[row, colc].set(q_s, mode="drop")
    bg = jnp.full((S, B), NULL, jnp.int32).at[row, colc].set(g_s, mode="drop")
    rq = jax.lax.all_to_all(bq, axes, split_axis=0, concat_axis=0, tiled=True)
    rg = jax.lax.all_to_all(bg, axes, split_axis=0, concat_axis=0, tiled=True)
    return rq.reshape(-1), rg.reshape(-1), overflow


# ---------------------------------------------------------------------------
# the SPMD program
# ---------------------------------------------------------------------------

def _spmd_chain(st, cfg, plan, caps, axes, keys, valid, read_ts,
                backend: backend_mod.Backend = backend_mod.REF,
                xwin: Optional[int] = None):
    """Index scan + hops; returns local (qids, gids, valid, pending, failed).

    ``pending`` is the (vtype, pred) check owed to the *next* routing step —
    vertex predicates are evaluated at the vertex's owner (query shipping).
    """
    S, F, B = cfg.n_shards, caps.frontier, caps.bucket
    Q = keys.shape[0]
    me = jax.lax.axis_index(axes).astype(jnp.int32)
    vt = jnp.full((Q,), plan.start_vtype, jnp.int32)
    g0 = _lookup_local(st, cfg, me, vt, keys, valid, read_ts, backend,
                       xd_win=xwin)
    qids = jnp.where(g0 >= 0, jnp.arange(Q, dtype=jnp.int32), NULL)
    pad = F - Q
    if pad < 0:
        raise ValueError("frontier capacity below query batch")
    qids = jnp.concatenate([qids, jnp.full((pad,), NULL, jnp.int32)])
    gids = jnp.concatenate([jnp.where(g0 >= 0, g0, NULL),
                            jnp.full((pad,), NULL, jnp.int32)])
    vmask = gids >= 0
    failed = jnp.zeros((), bool)
    pending = (plan.start_vtype, None)

    for hop in plan.hops:
        rq, rg, ovf = _route(qids, gids, vmask, S, B, axes)
        failed = failed | ovf
        rq, rg, rv, ovf2 = dedup_compact(rq, rg, rg >= 0, F)
        failed = failed | ovf2
        alive = _check_local(st, cfg, rg, rv, read_ts, pending[0], pending[1])
        oq, on, ovf3 = _expand_local(st, cfg, rq, rg, rv & alive,
                                     etype=hop.etype,
                                     direction=hop.direction,
                                     read_ts=read_ts, cap_out=caps.expand,
                                     backend=backend)
        failed = failed | ovf3
        qids, gids, vmask, ovf4 = dedup_compact(oq, on, on >= 0, F)
        failed = failed | ovf4
        pending = (hop.target_vtype, hop.pred)
    return qids, gids, vmask, pending, failed


def _finalize(st, cfg, plan, caps, axes, qids, gids, vmask, pending, read_ts,
              Q: int, failed):
    """Final route -> owner-side checks -> dedup -> aggregate."""
    S, F, B = cfg.n_shards, caps.frontier, caps.bucket
    rq, rg, ovf = _route(qids, gids, vmask, S, B, axes)
    failed = failed | ovf
    rq, rg, rv, ovf2 = dedup_compact(rq, rg, rg >= 0, F)
    failed = failed | ovf2
    alive = _check_local(st, cfg, rg, rv, read_ts, pending[0], pending[1])
    if plan.final_pred is not None:
        alive = alive & _check_local(st, cfg, rg, rv, read_ts, -1,
                                     plan.final_pred)
    rv = rv & alive
    rq = jnp.where(rv, rq, NULL)
    rg = jnp.where(rv, rg, NULL)
    failed_global = jax.lax.psum(failed.astype(jnp.int32), axes) > 0

    if plan.terminal == "count":
        counts = jax.ops.segment_sum(
            rv.astype(jnp.int32), jnp.where(rv, rq, Q), num_segments=Q + 1)[:Q]
        counts = jax.lax.psum(counts, axes)
        return {"counts": counts, "failed": failed_global}

    # ---- select: globally consistent row positions ------------------------
    K = caps.results
    q_s, g_s, v_s, first = sort_pairs(rq, rg, rv)    # local already dedup'd
    local_counts = jax.ops.segment_sum(
        v_s.astype(jnp.int32), jnp.where(v_s, q_s, Q), num_segments=Q + 1)[:Q]
    all_counts = jax.lax.all_gather(local_counts, axes)     # (S, Q)
    me = jax.lax.axis_index(axes)
    mask_before = (jnp.arange(all_counts.shape[0]) < me)[:, None]
    base = jnp.sum(all_counts * mask_before, axis=0)        # (Q,)
    q_srch = jnp.where(v_s, q_s, I32MAX)
    run_start = jnp.searchsorted(q_srch, q_srch, side="left").astype(jnp.int32)
    excl = jnp.cumsum(v_s.astype(jnp.int32)) - v_s.astype(jnp.int32)
    pos_local = excl - excl[run_start]
    qsafe = jnp.where(v_s, q_s, 0)
    pos = base[qsafe] + pos_local
    over = v_s & (pos >= K)
    row = jnp.where(v_s & ~over, q_s, I32MAX)
    col = jnp.where(v_s & ~over, pos, I32MAX)

    rows_gid = jnp.zeros((Q, K), jnp.int32).at[row, col].set(
        g_s + 1, mode="drop")
    trunc = jnp.zeros((Q,), jnp.int32).at[
        jnp.where(over, q_s, I32MAX)].set(1, mode="drop")
    rows_gid = jax.lax.psum(rows_gid, axes) - 1      # 0 -> NULL
    trunc = jax.lax.psum(trunc, axes) > 0

    out_attrs = {}
    rows_local = jnp.where(v_s, g_s // S, 0)
    use_cur = st.vdata_ts[rows_local] <= read_ts
    for kind, colid in zip(plan.select_kind, plan.select_cols):
        if kind == "key":
            vals = st.vkey[rows_local]
            acc = jnp.zeros((Q, K), jnp.int32)
        elif kind == "f32":
            vals = jnp.where(use_cur, st.vdata_f[rows_local][:, colid],
                             st.vprev_f[rows_local][:, colid])
            acc = jnp.zeros((Q, K), jnp.float32)
        else:
            vals = jnp.where(use_cur, st.vdata_i[rows_local][:, colid],
                             st.vprev_i[rows_local][:, colid])
            acc = jnp.zeros((Q, K), jnp.int32)
        summed = jax.lax.psum(acc.at[row, col].set(vals, mode="drop"), axes)
        if kind == "key":     # empty cells must read NULL like the local path
            summed = jnp.where(rows_gid >= 0, summed, NULL)
        out_attrs[(kind, colid)] = summed
    return {"rows_gid": rows_gid, "attrs": out_attrs, "truncated": trunc,
            "failed": failed_global}


_CACHE: dict = {}
CACHE_STATS = {"hits": 0, "misses": 0}


def compile_query_spmd(cfg: StoreConfig, plan: Plan, caps: QueryCaps,
                       n_queries: int, mesh,
                       storage_axes=("data", "model"),
                       query_axis: Optional[str] = None,
                       backend: backend_mod.Backend = backend_mod.REF,
                       xwin: Optional[int] = None):
    """Build the jitted SPMD query program for one plan shape.

    ``xwin``: static primary-index delta window (``planner.index_window``);
    semantics-preserving, part of the program cache key."""
    key = (cfg, plan, caps, n_queries, id(mesh), storage_axes, query_axis,
           backend, xwin)
    if key in _CACHE:
        CACHE_STATS["hits"] += 1
        return _CACHE[key]
    CACHE_STATS["misses"] += 1
    axes = storage_axes
    store_spec = P(axes)
    qspec = P(query_axis) if query_axis else P()
    # intersect keys are (branches, Q): the query axis is axis 1
    kspec = (P(None, query_axis) if (query_axis and plan.is_intersect)
             else qspec)

    def body(store, keys, valid, read_ts):
        if plan.is_intersect:
            B = len(plan.branches)
            allq, allg, allv = [], [], []
            failed = jnp.zeros((), bool)
            pendings = []
            for bi, br in enumerate(plan.branches):
                q, g, v, pend, f = _spmd_chain(store, cfg, br, caps, axes,
                                               keys[bi], valid, read_ts,
                                               backend, xwin)
                # resolve each branch fully: route + check before intersect
                S, F, Bk = cfg.n_shards, caps.frontier, caps.bucket
                rq, rg, ovf = _route(q, g, v, S, Bk, axes)
                rq, rg, rv, ovf2 = dedup_compact(rq, rg, rg >= 0, F)
                alive = _check_local(store, cfg, rg, rv, read_ts,
                                     pend[0], pend[1])
                rv = rv & alive
                failed = failed | f | ovf | ovf2
                allq.append(jnp.where(rv, rq, NULL))
                allg.append(jnp.where(rv, rg, NULL))
                allv.append(rv)
            qids = jnp.concatenate(allq)
            gids = jnp.concatenate(allg)
            vmask = jnp.concatenate(allv)
            # intersection is local: every branch's copy of a gid lives on
            # the gid's owner shard (ownership routing = equi-join locality)
            q_s, g_s, v_s, first = sort_pairs(qids, gids, vmask)
            run_id = jnp.cumsum(first.astype(jnp.int32)) - 1
            run_id = jnp.where(v_s, run_id, q_s.shape[0] - 1)
            run_len = jax.ops.segment_sum(v_s.astype(jnp.int32), run_id,
                                          num_segments=q_s.shape[0])
            keep = first & (run_len[run_id] == B)
            kq = jnp.where(keep, q_s, NULL)
            kg = jnp.where(keep, g_s, NULL)
            out = _finalize(store, cfg, plan, caps, axes, kq, kg, keep,
                            (-1, None), read_ts, n_queries, failed)
        else:
            q, g, v, pend, failed = _spmd_chain(store, cfg, plan, caps,
                                                axes, keys, valid, read_ts,
                                                backend, xwin)
            out = _finalize(store, cfg, plan, caps, axes, q, g, v, pend,
                            read_ts, n_queries, failed)
        if query_axis:
            # scalars can't shard over the pod axis; lift to (1,) per pod
            out["failed"] = out["failed"][None]
        return out

    store_specs = jax.tree.map(lambda _: store_spec, GraphStore(
        **{f.name: 0 for f in dataclasses.fields(GraphStore)}))
    out_specs = {"failed": qspec if query_axis else P()}
    if plan.terminal == "count":
        out_specs["counts"] = qspec
    else:
        out_specs.update(rows_gid=qspec, truncated=qspec,
                         attrs={(k, c): qspec for k, c in
                                zip(plan.select_kind, plan.select_cols)})

    fn = jax.jit(compat.shard_map(
        body, mesh=mesh,
        in_specs=(store_specs, kspec, qspec, P()),
        out_specs=out_specs, check_vma=False))
    _CACHE[key] = fn
    return fn


def run_queries_spmd(db, queries: list[dict], mesh,
                     caps: Optional[QueryCaps] = None,
                     storage_axes=("data", "model"),
                     backend: Optional[str] = None,
                     read_ts: Optional[int] = None,
                     parsed: Optional[list] = None) -> QueryResult:
    """Deprecated shim: use ``GraphDB.query(..., mesh=...)``.

    Uniform batches keep the historical shared-budget semantics; mixed
    batches route to the fused multi-query waves — exactly what
    ``engine.execute`` does with ``fused=None``."""
    import warnings
    warnings.warn("run_queries_spmd is deprecated; use "
                  "GraphDB.query(..., mesh=...) (core.query.engine.execute)",
                  DeprecationWarning, stacklevel=2)
    from repro.core.query.engine import execute
    return execute(db, queries, caps=caps, backend=backend, read_ts=read_ts,
                   mesh=mesh, storage_axes=storage_axes, parsed=parsed)
