"""A1QL v2: the typed logical-plan IR (§3.4).

A1 compiles every query — chain traversals *and* star patterns — into one
small operator set (index scan -> edge enumeration -> predicate evaluation ->
dedup -> aggregate).  This module is that operator set as a typed tree, the
single representation every entry point shares:

  * :class:`Scan`      — start vertex via the primary index (one probe);
  * :class:`Expand`    — one typed edge-enumeration step over the child's
                         frontier (direction, edge type, target-type check);
  * :class:`Filter`    — predicate evaluation on the child's frontier;
  * :class:`Intersect` — star pattern (Q3): vertices reached by *every*
                         branch.  Branches are chain bodies; nesting stars
                         inside stars is rejected at parse time;
  * :class:`Select` / :class:`Count` — the aggregate terminals.  Terminals
    are the tree roots and carry the per-plan :class:`CapHints` (the paper's
    optional query hints map 1:1 onto our static §3.4 capacity knobs).

``a1ql.parse`` produces one IR root per query — chains and stars are the
same tree shape instead of the historical ``(plan, int)`` vs ``(plan, list)``
tuple split.  The executors run *lowered* physical plans (:class:`Plan`,
a flat hop list per chain unit); :func:`lower` produces one
:class:`Lowered` per root: the physical plan, the runtime start key(s) (one
per chain unit — a star contributes one per branch), and the cap hints.

Signatures
----------
``node.signature()`` is the *structural* key: it keeps tree shape, hop
directions, and predicate kinds/ops but drops runtime values (start keys,
predicate constants).  Two queries with equal signatures group into the same
fusion family; program-cache identity is the full lowered ``Plan`` (which
bakes edge types and predicate constants into the compiled program) — keys
always stay runtime data, so re-keying a query never retraces.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

_OPS = ("==", "!=", "<", "<=", ">", ">=")


# ---------------------------------------------------------------------------
# the physical (lowered) form — what the executors compile
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Pred:
    kind: str        # 'f32' | 'i32' | 'key'
    col: int
    op: str
    val: float


@dataclasses.dataclass(frozen=True)
class Hop:
    direction: str               # 'out' | 'in'
    etype: int                   # resolved edge-type id, -1 = any
    target_vtype: int = -1       # -1 = unchecked
    pred: Optional[Pred] = None


@dataclasses.dataclass(frozen=True)
class Plan:
    """Lowered physical plan: a flat chain (or intersect-of-chains).

    This is what the compiled programs are keyed on; start keys are *not*
    part of it (they stay runtime data)."""
    start_vtype: int
    hops: tuple[Hop, ...]
    terminal: str                        # 'count' | 'select'
    select_kind: tuple = ()              # per col: 'f32'|'i32'|'key'
    select_cols: tuple = ()              # column ids (parallel to kinds)
    branches: tuple["Plan", ...] = ()    # intersect-of-branches when set
    final_pred: Optional[Pred] = None
    nearest_k: int = 0                   # >0: k-NN probe root (no start key);
                                         # the query vector stays runtime data

    @property
    def is_intersect(self) -> bool:
        return bool(self.branches)

    def chain_units(self) -> tuple["Plan", ...]:
        """The probe/hop units this plan contributes to the fused waves:

        one per branch for a star, the plan itself for a chain."""
        return self.branches if self.branches else (self,)

    def signature(self):
        """Structural key (no runtime values) — see module docstring."""
        if self.is_intersect:
            return ("intersect", tuple(b.signature() for b in self.branches),
                    self.terminal, self.select_kind, self.select_cols,
                    _psig(self.final_pred))
        return ("chain", self.nearest_k,
                tuple((h.direction, _psig(h.pred)) for h in self.hops),
                self.terminal, self.select_kind, self.select_cols,
                _psig(self.final_pred))


def _psig(p: Optional[Pred]):
    return None if p is None else (p.kind, p.op)


# ---------------------------------------------------------------------------
# cap hints
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CapHints:
    """Per-plan §3.4 capacity-knob overrides (the A1QL ``hints`` document).

    ``None`` means "use the caller's cap".  Hints participate in the fusion
    group key, so queries sharing hints fuse and parity with per-query
    execution is preserved (every query still runs at exactly the budget it
    would get alone)."""
    frontier: Optional[int] = None
    expand: Optional[int] = None
    results: Optional[int] = None
    bucket: Optional[int] = None

    def apply(self, caps):
        """Overlay onto a QueryCaps-like frozen dataclass."""
        over = {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}
        return dataclasses.replace(caps, **over) if over else caps

    def override(self, over: "CapHints") -> "CapHints":
        """Per-key merge where ``over`` wins (root hints over leaf hints)."""
        vals = {k: (o if (o := getattr(over, k)) is not None
                    else getattr(self, k))
                for k in ("frontier", "expand", "results", "bucket")}
        if all(v is None for v in vals.values()):
            return NO_HINTS
        return CapHints(**vals)


NO_HINTS = CapHints()


# ---------------------------------------------------------------------------
# the logical IR nodes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scan:
    """Primary-index probe: the start vertex of one chain unit."""
    vtype: int
    key: int

    def signature(self):
        return ("scan",)


@dataclasses.dataclass(frozen=True)
class Nearest:
    """k-NN probe root: seed the chain with the ``k`` nearest vector-indexed
    vertices of ``vtype`` (squared-L2 over the f32 payload, ties broken by
    ascending gid).  Like start keys, ``vector`` is runtime data — only
    ``k`` enters the physical plan."""
    vtype: int
    k: int
    vector: tuple                # tuple[float, ...] query embedding

    def signature(self):
        return ("nearest", self.k)


@dataclasses.dataclass(frozen=True)
class Expand:
    """One edge-enumeration step over ``child``'s frontier."""
    child: "Body"
    direction: str               # 'out' | 'in'
    etype: int                   # -1 = any
    target_vtype: int = -1       # -1 = unchecked

    def signature(self):
        return ("expand", self.direction, self.child.signature())


@dataclasses.dataclass(frozen=True)
class Filter:
    """Predicate evaluation on ``child``'s frontier."""
    child: "Body"
    pred: Pred

    def signature(self):
        return ("filter", self.pred.kind, self.pred.op,
                self.child.signature())


@dataclasses.dataclass(frozen=True)
class Intersect:
    """Star pattern: vertices reached by every branch (chain bodies only)."""
    branches: tuple["Body", ...]

    def signature(self):
        return ("intersect", tuple(b.signature() for b in self.branches))


@dataclasses.dataclass(frozen=True)
class Count:
    """Terminal: count the final frontier.

    ``gid_cursor`` (-1 = none) is a *runtime* final predicate
    ``gid > cursor`` — like start keys it never enters the physical plan,
    so continuation refills with moving cursors reuse compiled programs."""
    child: "Body"
    hints: CapHints = NO_HINTS
    gid_cursor: int = -1

    def signature(self):
        return ("count", self.child.signature())


@dataclasses.dataclass(frozen=True)
class Select:
    """Terminal: materialize rows (gid + the named attribute columns).

    ``gid_cursor``: see :class:`Count` — runtime data, not plan identity."""
    child: "Body"
    kinds: tuple = ()            # per col: 'f32'|'i32'|'key'
    cols: tuple = ()
    hints: CapHints = NO_HINTS
    gid_cursor: int = -1

    def signature(self):
        return ("select", self.kinds, self.cols, self.child.signature())


Body = Union[Scan, Nearest, Expand, Filter, Intersect]
Node = Union[Body, Count, Select]
TERMINALS = (Count, Select)


def is_root(node) -> bool:
    return isinstance(node, TERMINALS)


# ---------------------------------------------------------------------------
# lowering: IR tree -> physical Plan + runtime keys
# ---------------------------------------------------------------------------

class LoweringError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class Lowered:
    """One query, lowered: physical plan + runtime start key(s) + hints.

    ``keys`` holds one start key per chain unit (1 for a chain, one per
    branch for a star) — always a tuple, never the historical int-vs-list
    split.  ``cursor`` is the runtime gid-cursor (-1 = none)."""
    plan: Plan
    keys: tuple[int, ...]
    hints: CapHints = NO_HINTS
    cursor: int = -1
    vecs: tuple = ()             # per chain unit: None | tuple[float, ...]
                                 # (query embeddings for Nearest-rooted units;
                                 # () from legacy adapters means all-None)

    @property
    def is_intersect(self) -> bool:
        return self.plan.is_intersect


def _lower_chain(body):
    """Walk a chain body (Scan or Nearest at the leaf) ->
    ``(start_vtype, hops, key, nearest_k, vec)``."""
    rev_hops: list[Hop] = []
    node = body
    pending_pred: Optional[Pred] = None
    while True:
        if isinstance(node, Filter):
            if pending_pred is not None:
                raise LoweringError("stacked filters on one step")
            pending_pred = node.pred
            node = node.child
        elif isinstance(node, Expand):
            rev_hops.append(Hop(direction=node.direction, etype=node.etype,
                                target_vtype=node.target_vtype,
                                pred=pending_pred))
            pending_pred = None
            node = node.child
        elif isinstance(node, Scan):
            if pending_pred is not None:
                raise LoweringError("filter on the scan step")
            return node.vtype, tuple(reversed(rev_hops)), node.key, 0, None
        elif isinstance(node, Nearest):
            if pending_pred is not None:
                raise LoweringError("filter on the nearest step")
            return (node.vtype, tuple(reversed(rev_hops)), -1,
                    int(node.k), tuple(float(x) for x in node.vector))
        elif isinstance(node, Intersect):
            raise LoweringError("nested intersect is not supported")
        else:
            raise LoweringError(f"bad chain node {type(node).__name__}")


def lower(root) -> Lowered:
    """Lower one IR root (a terminal node) to its physical plan + keys."""
    if not is_root(root):
        raise LoweringError(
            f"plan root must be Count or Select, got {type(root).__name__}")
    if isinstance(root, Count):
        terminal, kinds, cols = "count", (), ()
    else:
        terminal, kinds, cols = "select", root.kinds, root.cols
    body = root.child
    final_pred = None
    if isinstance(body, Filter) and isinstance(body.child, Intersect):
        final_pred = body.pred
        body = body.child
    if isinstance(body, Intersect):
        if len(body.branches) < 2:
            raise LoweringError("intersect needs at least two branches")
        chains, keys = [], []
        for br in body.branches:
            vt, hops, key, nk, _vec = _lower_chain(br)
            if nk:
                raise LoweringError(
                    "nearest is not supported in intersect branches")
            if not hops:
                raise LoweringError("intersect branch needs a traversal step")
            chains.append(Plan(start_vtype=vt, hops=hops, terminal=terminal,
                               select_kind=kinds, select_cols=cols))
            keys.append(key)
        plan = Plan(start_vtype=-1, hops=(), terminal=terminal,
                    select_kind=kinds, select_cols=cols,
                    branches=tuple(chains), final_pred=final_pred)
        return Lowered(plan=plan, keys=tuple(keys), hints=root.hints,
                       cursor=root.gid_cursor,
                       vecs=(None,) * len(chains))
    vt, hops, key, nk, vec = _lower_chain(body)
    if not hops and not nk:
        # a Nearest root is itself the probe step; a bare Scan is not
        raise LoweringError("query needs at least one traversal step")
    plan = Plan(start_vtype=vt, hops=hops, terminal=terminal,
                select_kind=kinds, select_cols=cols, final_pred=final_pred,
                nearest_k=nk)
    return Lowered(plan=plan, keys=(key,), hints=root.hints,
                   cursor=root.gid_cursor, vecs=(vec,))


def from_legacy(plan: Plan, key_or_keys) -> Lowered:
    """Adapt the historical ``(plan, key-or-list)`` parse output."""
    if plan.is_intersect:
        keys = tuple(int(k) for k in key_or_keys)
    else:
        keys = (int(key_or_keys),)
    return Lowered(plan=plan, keys=keys)
