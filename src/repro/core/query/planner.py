"""Multi-query planner: fused operator waves across plan shapes (§3.4, §5).

A1 reaches 350M+ reads/sec by batching many *concurrent* queries into shared
operator waves over RDMA: every in-flight query contributes its probes and
frontier expansions to one batched network round per operator, so per-query
overhead amortizes across the fleet of users.  The executors in this package
run one *plan shape* at a time; this module adds the serving-shaped layer on
top: take a batch of arbitrary A1QL plans, group same-operator steps across
queries, and execute each group as one fused wave program through the
``core/backend.py`` seam.

Wave fusion
-----------
All chain plans that share a terminal signature fuse into **one** jitted
program, regardless of hop count, edge types, directions, predicates, or
per-query MVCC snapshots:

  * **lookup wave** — every query's ``(start_vtype, key)`` probe concatenated
    into a single ``index.lookup`` call (one ``sorted_lookup`` kernel pass on
    the pallas backend);
  * **hop wave k** — every query whose plan has a k-th hop expands its
    frontier in one ``edge_expand`` tile plan per direction; frontier items
    carry their query id (the per-query *segment id*), and edge types /
    snapshot timestamps are per-segment vectors instead of scalars.  Queries
    whose plans are already exhausted are *parked*: their frontier regions
    ride along untouched until the terminal wave.

The fused frontier is a ``(Q, frontier)`` matrix — row q is query q's private
region, holding its sorted-unique frontier gids.  Capacities therefore apply
**per query** (exactly the budgets a per-query ``run_queries`` call would
get), so results — including §3.4 fast-fail flags — are bit-identical to
running each query alone, while MVCC timestamps stay independent per query.
Star-pattern (intersect) plans are not fused yet; the planner runs each as
its own single-query program.

Program caches are keyed on the *batch shape* — the tuple of plans (+caps,
batch size, backend) — and hits/misses are observable via ``CACHE_STATS``,
so serving loops can assert that a steady query mix never retraces.

The same wave structure runs distributed: ``run_queries_batched_spmd``
builds one shard_map'd program per batch shape, with per-(query, owner)
routing buckets, pending vertex checks deferred to the owner shard, and one
final routing step for parked and active frontiers alike.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import edges as edges_mod
from repro.core import index as index_mod
from repro.core.addressing import NULL, StoreConfig
from repro.core.edges import TILE
from repro.core.query.a1ql import Plan, Pred
from repro.core.query.executor import (I32MAX, QueryCaps, QueryResult,
                                       eval_pred)
from repro.core.store import GraphStore, visible

PAD = I32MAX    # empty frontier slot; sorts last, keeps rows ascending


# ---------------------------------------------------------------------------
# static wave tables (host-side, derived from the plan tuple)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Wave:
    """Per-wave static tables: one entry per query in the batch."""
    act: np.ndarray        # (Q,) bool  — query has a hop at this wave
    is_out: np.ndarray     # (Q,) bool  — hop direction (False = 'in')
    etype: np.ndarray      # (Q,) i32   — edge type to follow (-1 = any)
    tvt: np.ndarray        # (Q,) i32   — target vtype check (-1 = none)
    preds: list            # [(Pred, (Q,) bool qmask)] — hop predicates
    any_out: bool
    any_in: bool


def _pred_groups(entries) -> list:
    """Group (query_index, Pred) pairs by identical predicate."""
    groups: dict = {}
    for qi, pred, n in entries:
        groups.setdefault(pred, np.zeros(n, bool))[qi] = True
    return list(groups.items())


def _wave_tables(plans: Sequence[Plan]) -> list[_Wave]:
    Q = len(plans)
    W = max(len(p.hops) for p in plans)
    waves = []
    for w in range(W):
        act = np.array([len(p.hops) > w for p in plans])
        is_out = np.array([len(p.hops) > w and p.hops[w].direction == "out"
                           for p in plans])
        etype = np.array([p.hops[w].etype if len(p.hops) > w else -1
                          for p in plans], np.int32)
        tvt = np.array([p.hops[w].target_vtype if len(p.hops) > w else -1
                        for p in plans], np.int32)
        preds = _pred_groups([(qi, p.hops[w].pred, Q)
                              for qi, p in enumerate(plans)
                              if len(p.hops) > w and p.hops[w].pred])
        waves.append(_Wave(act=act, is_out=is_out, etype=etype, tvt=tvt,
                           preds=preds, any_out=bool((act & is_out).any()),
                           any_in=bool((act & ~is_out).any())))
    return waves


def _final_pred_groups(plans: Sequence[Plan]) -> list:
    return _pred_groups([(qi, p.final_pred, len(plans))
                         for qi, p in enumerate(plans) if p.final_pred])


# ---------------------------------------------------------------------------
# fused wave primitives (shared by the local and SPMD programs)
# ---------------------------------------------------------------------------

def _dedup_rows(cand_g, cand_v, F: int):
    """Per-query dedup/compact: (Q, W) candidates -> (Q, F) regions.

    Row q ends up with its first F unique gids in ascending order (PAD
    beyond), exactly what ``dedup_compact`` produces for query q alone.
    Returns (gids, valid, overflow_q)."""
    Q = cand_g.shape[0]
    key = jnp.where(cand_v, cand_g, PAD)
    key_s = jax.lax.sort(key, dimension=1)
    valid_s = key_s != PAD
    prev = jnp.concatenate(
        [jnp.full((Q, 1), -1, key_s.dtype), key_s[:, :-1]], axis=1)
    first = valid_s & (key_s != prev)
    f32i = first.astype(jnp.int32)
    n_q = jnp.sum(f32i, axis=1)
    rank = jnp.cumsum(f32i, axis=1) - 1
    col = jnp.where(first & (rank < F), rank, F)     # F = out of range, drop
    rows = jnp.broadcast_to(jnp.arange(Q, dtype=jnp.int32)[:, None],
                            col.shape)
    g = jnp.full((Q, F), PAD, jnp.int32).at[rows, col].set(key_s, mode="drop")
    return g, g != PAD, n_q > F


def _expand_rows(start, deg, pools, et_q, ts_q, E: int,
                 backend: backend_mod.Backend):
    """Fused CSR expansion: (Q, F) spans -> (Q, E) neighbor matrix.

    Row q receives the first E raw span entries of query q's frontier —
    masked by per-query MVCC visibility (``ts_q``) and edge type (``et_q``)
    — at exactly the positions the per-query reference path computes, so
    both backends emit bit-identical buffers (a per-query budget clamp on
    the tile plan makes even the overflow truncation match).
    """
    nbr, typ, ecre, edel = pools
    Q, F = deg.shape
    cum = jnp.cumsum(deg, axis=1)
    excl = cum - deg
    if backend.is_pallas:
        # one tile plan for the whole wave; each query's span budget is
        # clamped to its remaining E so no query can starve another's tiles
        deg_eff = jnp.clip(E - excl, 0, deg)
        cap_tiles = Q * (min(F, E) + 1 + (E + TILE - 1) // TILE)
        (nbr_t, typ_t, cre_t, del_t), item, tw, _ = backend_mod.expand_tiles(
            start.reshape(-1), deg_eff.reshape(-1), pools,
            tile=TILE, cap_tiles=cap_tiles, backend=backend)
        item_c = jnp.minimum(item, Q * F - 1)
        row = item_c // F
        lane = jnp.arange(TILE, dtype=jnp.int32)
        shape = (cap_tiles, TILE)
        nbr_t, typ_t = nbr_t.reshape(shape), typ_t.reshape(shape)
        cre_t, del_t = cre_t.reshape(shape), del_t.reshape(shape)
        et_t = et_q[row][:, None]
        # invalid lanes carry -1 in every pool: visible(-1,-1,ts) is False
        e_ok = (visible(cre_t, del_t, ts_q[row][:, None])
                & ((et_t < 0) | (typ_t == et_t))
                & (nbr_t >= 0))
        posq = (excl.reshape(-1)[item_c][:, None] + tw[:, None] * TILE
                + lane[None, :])
        pos = jnp.where(e_ok, row[:, None] * E + posq, Q * E)
        out = jnp.full((Q * E,), NULL, jnp.int32).at[pos.reshape(-1)].set(
            nbr_t.reshape(-1), mode="drop")
        return out.reshape(Q, E)

    k = jnp.arange(E, dtype=jnp.int32)

    def one(cum_r, deg_r, start_r, ts, et):
        item = jnp.searchsorted(cum_r, k, side="right").astype(jnp.int32)
        item_c = jnp.minimum(item, F - 1)
        base = cum_r[item_c] - deg_r[item_c]
        in_range = k < cum_r[-1]
        epos = jnp.where(in_range, start_r[item_c] + (k - base), 0)
        e_ok = (in_range & visible(ecre[epos], edel[epos], ts)
                & ((et < 0) | (typ[epos] == et)) & (nbr[epos] >= 0))
        return jnp.where(e_ok, nbr[epos], NULL)

    return jax.vmap(one)(cum, deg, start, ts_q, et_q)


def _delta_rows(key_rows, m, d_key, dnbr, dtyp, dcre, ddel, et_q, ts_q):
    """Per-query delta-log matches: (Q, F) regions x (D,) log -> (Q, D).

    Frontier regions hold sorted-unique keys, so each delta entry matches at
    most one slot per query — a row-wise binary search replaces the
    (F x D) match matrix the single-query path materializes, with identical
    per-query match sets."""
    Q, F = key_rows.shape
    pos = jax.vmap(lambda row, v: jnp.searchsorted(row, v))(
        key_rows, jnp.broadcast_to(d_key, (Q,) + d_key.shape))
    pos_c = jnp.minimum(pos, F - 1).astype(jnp.int32)
    at_k = jnp.take_along_axis(key_rows, pos_c, axis=1)
    at_m = jnp.take_along_axis(m, pos_c, axis=1)
    hit = (at_m & (at_k == d_key[None, :])
           & (dnbr >= 0)[None, :]
           & visible(dcre[None, :], ddel[None, :], ts_q[:, None])
           & ((et_q[:, None] < 0) | (dtyp[None, :] == et_q[:, None])))
    return jnp.where(hit, jnp.broadcast_to(dnbr[None, :], hit.shape), NULL)


def _check_rows(st, rows, valid, ts_q, tvt_q, preds):
    """Fused liveness/type/predicate check on (Q, F) frontier regions.

    ``rows`` indexes the vertex arrays of ``st`` (global store or a
    shard_map local block); ``tvt_q``/``preds`` are per-query tables —
    parked queries carry -1 / no predicate, so only re-(idempotent)
    liveness applies to them."""
    ts2 = ts_q[:, None]
    alive = valid & visible(st.v_create[rows], st.v_delete[rows], ts2)
    tvt2 = tvt_q[:, None]
    alive = alive & ((tvt2 < 0) | (st.vtype[rows] == tvt2))
    if preds:
        use_cur = (st.vdata_ts[rows] <= ts2)[..., None]
        f = jnp.where(use_cur, st.vdata_f[rows], st.vprev_f[rows])
        i = jnp.where(use_cur, st.vdata_i[rows], st.vprev_i[rows])
        keys = st.vkey[rows]
        for pred, qmask in preds:
            pm = jnp.asarray(qmask)[:, None]
            alive = alive & (~pm | eval_pred(pred, f, i, keys))
    return alive


def _select_rows(st, rows, g, valid, ts_q, select, K: int):
    """Fused select terminal: (Q, F) regions -> (Q, K) rows + attrs."""
    Q = g.shape[0]
    vi = valid.astype(jnp.int32)
    rank = jnp.cumsum(vi, axis=1) - vi
    over = valid & (rank >= K)
    col = jnp.where(valid & ~over, rank, K)
    rowi = jnp.broadcast_to(jnp.arange(Q, dtype=jnp.int32)[:, None],
                            col.shape)
    rows_gid = jnp.full((Q, K), NULL, jnp.int32).at[rowi, col].set(
        jnp.where(valid, g, NULL), mode="drop")
    safe = jnp.where(rows_gid >= 0, rows_gid, 0)
    r = rows(safe)
    use_cur = st.vdata_ts[r] <= ts_q[:, None]
    attrs = {}
    for kind, colid in select:
        if kind == "key":
            vals = jnp.where(rows_gid >= 0, st.vkey[r], NULL)
        elif kind == "f32":
            v = jnp.where(use_cur, st.vdata_f[r][..., colid],
                          st.vprev_f[r][..., colid])
            vals = v * (rows_gid >= 0)
        else:
            v = jnp.where(use_cur, st.vdata_i[r][..., colid],
                          st.vprev_i[r][..., colid])
            vals = v * (rows_gid >= 0)
        attrs[(kind, colid)] = vals
    return rows_gid, attrs, jnp.any(over, axis=1)


# ---------------------------------------------------------------------------
# the local fused program
# ---------------------------------------------------------------------------

# compiled per batch *shape* (tuple of plans); hits mean a steady serving
# query mix never retraces, observable exactly like the executor caches.
# Unlike the per-plan executor caches (small fixed cardinality), batch
# shapes are combinatorial, so this one is LRU-bounded.
_CACHE: collections.OrderedDict = collections.OrderedDict()
CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
CACHE_MAX_PROGRAMS = 256


def _cache_get(key):
    fn = _CACHE.get(key)
    if fn is not None:
        _CACHE.move_to_end(key)
        CACHE_STATS["hits"] += 1
    return fn


def _cache_put(key, fn):
    CACHE_STATS["misses"] += 1
    _CACHE[key] = fn
    while len(_CACHE) > CACHE_MAX_PROGRAMS:
        _CACHE.popitem(last=False)
        CACHE_STATS["evictions"] += 1


def _pow2ceil(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def delta_window(db) -> int:
    """Static per-shard delta-log window for the next fused program.

    The delta logs fill prefix-first per shard (host count mirrors are
    exact), so scanning ``[:W]`` of each shard block sees every live entry.
    Rounded to a power of two and clamped, so the program-cache key only
    changes when the fill band crosses a boundary (and compaction resets
    it) — a steady serving mix keeps hitting the same program."""
    n = int(max(db.dl_count.max(initial=0), db.il_count.max(initial=0), 1))
    return min(_pow2ceil(n), db.cfg.cap_delta)


def _delta_windowed(arrs, S: int, cap_delta: int, W: int):
    """Slice shard-major (S*cap_delta,) delta arrays to (S*W,)."""
    return tuple(a.reshape(S, cap_delta)[:, :W].reshape(-1) for a in arrs)


def compile_batch(cfg: StoreConfig, plans: tuple, caps: QueryCaps,
                  backend: backend_mod.Backend = backend_mod.REF,
                  dwin: Optional[int] = None):
    """Build the jitted fused-wave program for one batch shape.

    ``plans`` is a tuple of chain plans sharing a terminal signature; keys
    and per-query snapshot timestamps stay runtime data, so any same-shape
    batch reuses the compiled program.  ``dwin`` is the static delta-log
    window (see :func:`delta_window`)."""
    dwin = cfg.cap_delta if dwin is None else min(dwin, cfg.cap_delta)
    key = (cfg, plans, caps, len(plans), backend, dwin, "local")
    fn = _cache_get(key)
    if fn is not None:
        return fn

    Q = len(plans)
    F, E, K = caps.frontier, caps.expand, caps.results
    S, cap_v, cap_e = cfg.n_shards, cfg.cap_v, cfg.cap_e
    waves = _wave_tables(plans)
    final_preds = _final_pred_groups(plans)
    start_vt = jnp.asarray([p.start_vtype for p in plans], jnp.int32)
    terminal = plans[0].terminal
    select = tuple(zip(plans[0].select_kind, plans[0].select_cols))

    @jax.jit
    def run(store, keys, valid_in, ts_q):
        failed_q = jnp.zeros((Q,), bool)
        # ---- lookup wave: one probe for the whole batch -------------------
        gids0, found = index_mod.lookup(store, cfg, start_vt, keys, valid_in,
                                        ts_q, backend=backend)
        g = jnp.full((Q, F), PAD, jnp.int32).at[:, 0].set(
            jnp.where(found & valid_in, gids0, PAD))
        valid = g != PAD

        for wave in waves:
            act = jnp.asarray(wave.act)
            is_out = jnp.asarray(wave.is_out)
            et_q = jnp.asarray(wave.etype)
            # parked queries carry their finished frontier through the wave
            parts_g, parts_v = [g], [valid & ~act[:, None]]
            for direction, dmask, present in (
                    ("out", is_out, wave.any_out),
                    ("in", ~is_out, wave.any_in)):
                if not present:
                    continue
                m = valid & act[:, None] & dmask[:, None]
                indptr, nbr, typ, ecre, edel = edges_mod._csr_arrays(
                    store, direction)
                safe_g = jnp.where(m, g, 0)
                shard = safe_g % S
                iprow = shard * (cap_v + 1) + safe_g // S
                start = indptr[iprow] + shard * cap_e
                deg = (indptr[iprow + 1] - indptr[iprow]) * m
                failed_q = failed_q | (jnp.sum(deg, axis=1) > E)
                out_n = _expand_rows(start, deg, (nbr, typ, ecre, edel),
                                     et_q, ts_q, E, backend)
                dslot, dnbr, dtyp, dcre, ddel = _delta_windowed(
                    edges_mod._delta_arrays(store, direction),
                    S, cfg.cap_delta, dwin)
                D = dslot.shape[0]
                d_gid = dslot * S + jnp.arange(D, dtype=jnp.int32) // dwin
                dn = _delta_rows(g, m, d_gid, dnbr, dtyp, dcre, ddel,
                                 et_q, ts_q)
                parts_g += [out_n, dn]
                parts_v += [out_n >= 0, dn >= 0]
            g, valid, ovf = _dedup_rows(jnp.concatenate(parts_g, axis=1),
                                        jnp.concatenate(parts_v, axis=1), F)
            failed_q = failed_q | ovf
            rows = cfg.row_of_gid(jnp.where(valid, g, 0))
            valid = valid & _check_rows(store, rows, valid, ts_q,
                                        jnp.asarray(wave.tvt), wave.preds)

        # ---- terminal wave ------------------------------------------------
        if final_preds:
            rows = cfg.row_of_gid(jnp.where(valid, g, 0))
            valid = valid & _check_rows(store, rows, valid, ts_q,
                                        jnp.full((Q,), -1, jnp.int32),
                                        final_preds)
        out = {"failed_q": failed_q}
        if terminal == "count":
            out["counts"] = jnp.sum(valid.astype(jnp.int32), axis=1)
        else:
            rows_gid, attrs, trunc = _select_rows(
                store, cfg.row_of_gid, g, valid, ts_q, select, K)
            out.update(rows_gid=rows_gid, attrs=attrs, truncated=trunc)
        return out

    _cache_put(key, run)
    return run


# ---------------------------------------------------------------------------
# host entry points
# ---------------------------------------------------------------------------

def _normalize_ts(db, Q: int, read_ts) -> list[int]:
    if read_ts is None:
        return [db.snapshot_ts()] * Q
    if isinstance(read_ts, (int, np.integer)):
        return [int(read_ts)] * Q
    ts = [int(t) for t in read_ts]
    if len(ts) != Q:
        raise ValueError(f"read_ts has {len(ts)} entries for {Q} queries")
    return ts


class _Assembly:
    """Scatter per-group results back into input order."""

    def __init__(self, Q: int, K: int):
        self.Q, self.K = Q, K
        self.failed_q = np.zeros(Q, bool)
        self.counts = None
        self.rows_gid = None
        self.truncated = None
        self.rows: dict = {}

    def _ensure_select(self):
        if self.rows_gid is None:
            self.rows_gid = np.full((self.Q, self.K), NULL, np.int32)
            self.truncated = np.zeros(self.Q, bool)

    def put(self, idxs, out: dict) -> None:
        self.failed_q[idxs] = np.asarray(out["failed_q"])
        if "counts" in out:
            if self.counts is None:
                self.counts = np.full(self.Q, NULL, np.int32)
            self.counts[idxs] = np.asarray(out["counts"])
        else:
            self._ensure_select()
            self.rows_gid[idxs] = np.asarray(out["rows_gid"])
            self.truncated[idxs] = np.asarray(out["truncated"])
            for k, v in out["attrs"].items():
                if k not in self.rows:
                    v0 = np.asarray(v)
                    fill = NULL if k[0] == "key" else 0
                    self.rows[k] = np.full((self.Q, self.K), fill, v0.dtype)
                self.rows[k][idxs] = np.asarray(v)

    def result(self) -> QueryResult:
        return QueryResult(
            counts=self.counts, rows_gid=self.rows_gid,
            rows=self.rows or None, truncated=self.truncated,
            failed=bool(self.failed_q.any()), failed_q=self.failed_q)


def _plan_groups(parsed) -> tuple[list[list[int]], list[int]]:
    """Fusion groups: chains grouped by terminal signature; stars alone.

    Each group's indices are canonically ordered by plan, so any
    permutation of the same batch mix resolves to the same plans tuple —
    one compiled program, not one per arrival order."""
    chain_groups: dict = {}
    stars = []
    for i, (p, _) in enumerate(parsed):
        if p.is_intersect:
            stars.append(i)
        else:
            key = (p.terminal, p.select_kind, p.select_cols)
            chain_groups.setdefault(key, []).append(i)
    groups = [sorted(idxs, key=lambda i: repr(parsed[i][0]))
              for idxs in chain_groups.values()]
    return groups, stars


def run_queries_batched(db, queries: list[dict],
                        caps: Optional[QueryCaps] = None,
                        backend: Optional[str] = None,
                        read_ts: Union[None, int, Sequence[int]] = None,
                        parsed: Optional[list] = None) -> QueryResult:
    """Execute a batch of A1QL queries as fused multi-query waves.

    Unlike :func:`executor.run_queries` (one plan shape, shared working-set
    budget), every query here gets its *own* §3.4 capacity budget and MVCC
    snapshot, and arbitrary chain shapes fuse into one program per terminal
    signature.  Results (and per-query ``failed_q`` flags) are bit-identical
    to running each query through ``run_queries`` alone.

    ``read_ts``: None (one fresh snapshot), a scalar, or per-query
    timestamps — mixed-snapshot batches execute in one wave program.
    ``parsed``: optional pre-parsed ``[(plan, key), ...]`` (callers that
    already parsed to route here need not pay the parse twice).
    """
    from repro.core.query.a1ql import parse
    from repro.core.query import executor as _ex
    caps = caps or QueryCaps()
    be = backend_mod.resolve(backend or getattr(db, "backend", None))
    Q = len(queries)
    parsed = parsed if parsed is not None else [parse(db, q)
                                               for q in queries]
    ts_list = _normalize_ts(db, Q, read_ts)
    pins = sorted(set(ts_list))
    for t in pins:                          # pin versions (GC barrier)
        db.active_query_ts.append(t)
    try:
        groups, stars = _plan_groups(parsed)
        out = _Assembly(Q, caps.results)
        dwin = delta_window(db)
        for idxs in groups:
            plans_g = tuple(parsed[i][0] for i in idxs)
            keys = jnp.asarray([parsed[i][1] for i in idxs], jnp.int32)
            ts = jnp.asarray([ts_list[i] for i in idxs], jnp.int32)
            fn = compile_batch(db.cfg, plans_g, caps, be, dwin)
            out.put(idxs, fn(db.store, keys, jnp.ones((len(idxs),), bool),
                             ts))
        for i in stars:                     # star patterns: not fused yet
            plan, keys_b = parsed[i]
            fn = _ex.compile_query(db.cfg, plan, caps, 1, be)
            kb = jnp.asarray(np.array([[k] for k in keys_b], np.int32))
            r = fn(db.store, kb, jnp.ones((1,), bool),
                   jnp.int32(ts_list[i]))
            r = dict(r, failed_q=jnp.asarray([r["failed"]]))
            out.put([i], r)
        return out.result()
    finally:
        for t in pins:
            db.active_query_ts.remove(t)


# ---------------------------------------------------------------------------
# the SPMD fused program (query shipping, one program per batch shape)
# ---------------------------------------------------------------------------

def _route_rows(g, m, S: int, B: int, axes):
    """Fused routing: (Q, F) pairs -> all_to_all -> (Q, S*B) arrivals.

    Buckets are per (query, owner) — B slots each, the per-query analogue of
    ``caps.bucket`` — so one hot query cannot evict another's RPCs.  Returns
    (arrived_gids, arrived_mask, overflow_q)."""
    Q, F = g.shape
    ow = jnp.where(m, g % S, S)
    ow_s, g_s = jax.lax.sort((ow, g), dimension=1, num_keys=1)
    starts = jax.vmap(
        lambda o: jnp.searchsorted(o, jnp.arange(S, dtype=o.dtype))
    )(ow_s).astype(jnp.int32)
    col = (jnp.arange(F, dtype=jnp.int32)[None, :]
           - jnp.take_along_axis(starts, jnp.minimum(ow_s, S - 1), axis=1))
    ok = ow_s < S
    overflow_q = jnp.any(ok & (col >= B), axis=1)
    keep = ok & (col >= 0) & (col < B)
    dest = jnp.where(keep, ow_s, S)                     # S = out of range
    qcol = jnp.arange(Q, dtype=jnp.int32)[:, None] * B \
        + jnp.clip(col, 0, B - 1)
    bg = jnp.full((S, Q * B), NULL, jnp.int32).at[dest, qcol].set(
        g_s, mode="drop")
    rg = jax.lax.all_to_all(bg, axes, split_axis=0, concat_axis=0,
                            tiled=True)
    arr = rg.reshape(S, Q, B).transpose(1, 0, 2).reshape(Q, S * B)
    return arr, arr >= 0, overflow_q


def compile_batch_spmd(cfg: StoreConfig, plans: tuple, caps: QueryCaps,
                       mesh, storage_axes=("data", "model"),
                       backend: backend_mod.Backend = backend_mod.REF,
                       dwin: Optional[int] = None):
    """Fused-wave program on a mesh: the §3.4 coordinator/worker protocol
    for a whole heterogeneous batch in one SPMD program."""
    from jax.sharding import PartitionSpec as P
    from repro.core.query.executor_spmd import _lookup_local
    from repro.dist import compat

    dwin = cfg.cap_delta if dwin is None else min(dwin, cfg.cap_delta)
    key = (cfg, plans, caps, len(plans), id(mesh), storage_axes, backend,
           dwin, "spmd")
    fn = _cache_get(key)
    if fn is not None:
        return fn

    Q = len(plans)
    F, E, B, K = caps.frontier, caps.expand, caps.bucket, caps.results
    S = cfg.n_shards
    axes = storage_axes
    waves = _wave_tables(plans)
    final_preds = _final_pred_groups(plans)
    start_vt_np = np.array([p.start_vtype for p in plans], np.int32)
    terminal = plans[0].terminal
    select = tuple(zip(plans[0].select_kind, plans[0].select_cols))
    # pending owner-side checks: wave w validates what wave w-1 emitted
    # (w=0 validates the index scan's start vertices); queries parked at
    # wave w keep -1/no-pred entries.  The *last* hop's check runs in the
    # finalize step, after the final routing — per query.
    pend_tvt, pend_preds = [], []
    for w in range(len(waves)):
        if w == 0:
            pend_tvt.append(start_vt_np)
            pend_preds.append([])
        else:
            pend_tvt.append(np.array(
                [p.hops[w - 1].target_vtype if len(p.hops) > w else -1
                 for p in plans], np.int32))
            pend_preds.append(_pred_groups(
                [(qi, p.hops[w - 1].pred, Q) for qi, p in enumerate(plans)
                 if len(p.hops) > w and p.hops[w - 1].pred]))
    fin_tvt = np.array([p.hops[-1].target_vtype for p in plans], np.int32)
    fin_preds = _pred_groups([(qi, p.hops[-1].pred, Q)
                              for qi, p in enumerate(plans)
                              if p.hops[-1].pred])

    def _local_rows(st, g, valid):
        return jnp.where(valid, g // S, 0)

    def body(st, keys, valid_in, ts_q):
        me = jax.lax.axis_index(axes).astype(jnp.int32)
        failed_q = jnp.zeros((Q,), bool)
        g0 = _lookup_local(st, cfg, me, jnp.asarray(start_vt_np), keys,
                           valid_in, ts_q, backend)
        g = jnp.full((Q, F), PAD, jnp.int32).at[:, 0].set(
            jnp.where(g0 >= 0, g0, PAD))
        valid = g != PAD

        for w, wave in enumerate(waves):
            act = jnp.asarray(wave.act)
            is_out = jnp.asarray(wave.is_out)
            et_q = jnp.asarray(wave.etype)
            # 1) batched RPCs: ship active pairs to their owners
            arr, am, ovf = _route_rows(g, valid & act[:, None], S, B, axes)
            failed_q = failed_q | ovf
            ag, am, ovf2 = _dedup_rows(arr, am, F)
            failed_q = failed_q | ovf2
            # 2) owner-side pending checks (previous hop's vertex checks)
            alive = am & _check_rows(st, _local_rows(st, ag, am), am, ts_q,
                                     jnp.asarray(pend_tvt[w]),
                                     pend_preds[w])
            # 3) worker step: enumerate edges from my CSR block + delta log
            parts_g = [g]
            parts_v = [valid & ~act[:, None]]       # parked pairs stay put
            for direction, dmask, present in (
                    ("out", is_out, wave.any_out),
                    ("in", ~is_out, wave.any_in)):
                if not present:
                    continue
                m = alive & act[:, None] & dmask[:, None]
                if direction == "out":
                    indptr, nbr, typ, ecre, edel = (
                        st.oe_indptr, st.oe_dst, st.oe_type, st.oe_create,
                        st.oe_delete)
                    dslot, dnbr, dtyp, dcre, ddel = (
                        st.dl_slot, st.dl_nbr, st.dl_type, st.dl_create,
                        st.dl_delete)
                else:
                    indptr, nbr, typ, ecre, edel = (
                        st.ie_indptr, st.ie_src, st.ie_type, st.ie_create,
                        st.ie_delete)
                    dslot, dnbr, dtyp, dcre, ddel = (
                        st.il_slot, st.il_nbr, st.il_type, st.il_create,
                        st.il_delete)
                slot = jnp.where(m, ag // S, 0)
                start = indptr[slot]
                deg = (indptr[slot + 1] - indptr[slot]) * m
                failed_q = failed_q | (jnp.sum(deg, axis=1) > E)
                out_n = _expand_rows(start, deg, (nbr, typ, ecre, edel),
                                     et_q, ts_q, E, backend)
                # inside shard_map the delta block is one shard: window [:W]
                dslot, dnbr, dtyp, dcre, ddel = (
                    a[:dwin] for a in (dslot, dnbr, dtyp, dcre, ddel))
                dn = _delta_rows(ag // S, m, dslot, dnbr, dtyp, dcre, ddel,
                                 et_q, ts_q)
                parts_g += [out_n, dn]
                parts_v += [out_n >= 0, dn >= 0]
            g, valid, ovf3 = _dedup_rows(jnp.concatenate(parts_g, axis=1),
                                         jnp.concatenate(parts_v, axis=1), F)
            failed_q = failed_q | ovf3

        # ---- finalize: route everything, owed checks, aggregate -----------
        arr, am, ovf = _route_rows(g, valid, S, B, axes)
        failed_q = failed_q | ovf
        ag, valid, ovf2 = _dedup_rows(arr, am, F)
        failed_q = failed_q | ovf2
        rows_l = _local_rows(st, ag, valid)
        valid = valid & _check_rows(st, rows_l, valid, ts_q,
                                    jnp.asarray(fin_tvt), fin_preds)
        if final_preds:
            valid = valid & _check_rows(st, rows_l, valid, ts_q,
                                        jnp.full((Q,), -1, jnp.int32),
                                        final_preds)
        out = {"failed_q":
               jax.lax.psum(failed_q.astype(jnp.int32), axes) > 0}
        if terminal == "count":
            out["counts"] = jax.lax.psum(
                jnp.sum(valid.astype(jnp.int32), axis=1), axes)
            return out

        # select: globally consistent row positions (shard-rank offsets)
        vi = valid.astype(jnp.int32)
        local_counts = jnp.sum(vi, axis=1)                    # (Q,)
        all_counts = jax.lax.all_gather(local_counts, axes)   # (S, Q)
        before = (jnp.arange(all_counts.shape[0]) < me)[:, None]
        base = jnp.sum(all_counts * before, axis=0)           # (Q,)
        rank = jnp.cumsum(vi, axis=1) - vi
        pos = base[:, None] + rank
        over = valid & (pos >= K)
        keep = valid & ~over
        rowi = jnp.broadcast_to(jnp.arange(Q, dtype=jnp.int32)[:, None],
                                pos.shape)
        col = jnp.where(keep, pos, K)
        rows_gid = jnp.zeros((Q, K), jnp.int32).at[rowi, col].set(
            jnp.where(valid, ag, 0) + 1, mode="drop")
        rows_gid = jax.lax.psum(rows_gid, axes) - 1           # 0 -> NULL
        trunc = jax.lax.psum(jnp.any(over, axis=1).astype(jnp.int32),
                             axes) > 0
        use_cur = st.vdata_ts[rows_l] <= ts_q[:, None]
        attrs = {}
        for kind, colid in select:
            if kind == "key":
                vals = st.vkey[rows_l]
                acc = jnp.zeros((Q, K), jnp.int32)
            elif kind == "f32":
                vals = jnp.where(use_cur, st.vdata_f[rows_l][..., colid],
                                 st.vprev_f[rows_l][..., colid])
                acc = jnp.zeros((Q, K), jnp.float32)
            else:
                vals = jnp.where(use_cur, st.vdata_i[rows_l][..., colid],
                                 st.vprev_i[rows_l][..., colid])
                acc = jnp.zeros((Q, K), jnp.int32)
            summed = jax.lax.psum(acc.at[rowi, col].set(vals, mode="drop"),
                                  axes)
            if kind == "key":     # empty cells read NULL like the local path
                summed = jnp.where(rows_gid >= 0, summed, NULL)
            attrs[(kind, colid)] = summed
        out.update(rows_gid=rows_gid, attrs=attrs, truncated=trunc)
        return out

    store_specs = jax.tree.map(lambda _: P(axes), GraphStore(
        **{f.name: 0 for f in dataclasses.fields(GraphStore)}))
    out_specs = {"failed_q": P()}
    if terminal == "count":
        out_specs["counts"] = P()
    else:
        out_specs.update(rows_gid=P(), truncated=P(),
                         attrs={k: P() for k in select})
    fn = jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(store_specs, P(), P(), P()),
        out_specs=out_specs, check_vma=False))
    _cache_put(key, fn)
    return fn


def run_queries_batched_spmd(db, queries: list[dict], mesh,
                             caps: Optional[QueryCaps] = None,
                             storage_axes=("data", "model"),
                             backend: Optional[str] = None,
                             read_ts: Union[None, int, Sequence[int]] = None,
                             parsed: Optional[list] = None) -> QueryResult:
    """Distributed :func:`run_queries_batched`: same grouping, same
    per-query budgets/snapshots, executed as shard_map'd wave programs."""
    from repro.core.query.a1ql import parse
    from repro.core.query.executor_spmd import compile_query_spmd
    caps = caps or QueryCaps()
    be = backend_mod.resolve(backend or getattr(db, "backend", None))
    Q = len(queries)
    parsed = parsed if parsed is not None else [parse(db, q)
                                               for q in queries]
    ts_list = _normalize_ts(db, Q, read_ts)
    pins = sorted(set(ts_list))
    for t in pins:
        db.active_query_ts.append(t)
    try:
        groups, stars = _plan_groups(parsed)
        out = _Assembly(Q, caps.results)
        dwin = delta_window(db)
        for idxs in groups:
            plans_g = tuple(parsed[i][0] for i in idxs)
            keys = jnp.asarray([parsed[i][1] for i in idxs], jnp.int32)
            ts = jnp.asarray([ts_list[i] for i in idxs], jnp.int32)
            fn = compile_batch_spmd(db.cfg, plans_g, caps, mesh,
                                    storage_axes, be, dwin)
            out.put(idxs, fn(db.store, keys, jnp.ones((len(idxs),), bool),
                             ts))
        for i in stars:
            plan, keys_b = parsed[i]
            fn = compile_query_spmd(db.cfg, plan, caps, 1, mesh,
                                    storage_axes, backend=be)
            kb = jnp.asarray(np.array([[k] for k in keys_b], np.int32))
            r = fn(db.store, kb, jnp.ones((1,), bool),
                   jnp.int32(ts_list[i]))
            r = dict(r, failed_q=jnp.asarray([r["failed"]]))
            out.put([i], r)
        return out.result()
    finally:
        for t in pins:
            db.active_query_ts.remove(t)
