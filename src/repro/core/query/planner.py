"""Multi-query planner: fused operator waves across plan shapes (§3.4, §5).

A1 reaches 350M+ reads/sec by batching many *concurrent* queries into shared
operator waves over RDMA: every in-flight query contributes its probes and
frontier expansions to one batched network round per operator, so per-query
overhead amortizes across the fleet of users.  The executors in this package
run one *plan shape* at a time; this module adds the serving-shaped layer on
top: take a batch of arbitrary A1QL logical plans — chains *and* star
patterns — group same-operator steps across queries, and execute each group
as one fused wave program through the ``core/backend.py`` seam.

Wave fusion
-----------
All plans that share a terminal signature (and effective cap hints) fuse
into **one** jitted program, regardless of hop count, edge types,
directions, predicates, star-ness, or per-query MVCC snapshots.  The unit
of wave fusion is the **chain unit**: a chain plan contributes one unit, a
star (intersect) plan contributes one unit per branch, all sharing the
query's segment id machinery:

  * **lookup wave** — every unit's ``(start_vtype, key)`` probe concatenated
    into a single ``index.lookup`` call (one ``sorted_lookup`` kernel pass on
    the pallas backend), with the primary-index delta scan windowed to the
    host fill counts (:func:`index_window`);
  * **hop wave k** — every unit whose chain has a k-th hop expands its
    frontier in one ``edge_expand`` tile plan per direction; frontier items
    carry their unit id (the per-query *segment id*), and edge types /
    snapshot timestamps are per-segment vectors instead of scalars.  Units
    whose chains are already exhausted are *parked*: their frontier regions
    ride along untouched until the terminal wave;
  * **intersect-merge wave** — when the group contains star plans, one
    merge step folds each query's branch regions into its final region:
    branch rows are sorted-unique, so a sort + run-length pass keeps exactly
    the gids reached by *every* branch.  Chains pass through unchanged
    (their single "branch" trivially intersects with itself), so mixed
    chain+star batches are still one fused program end to end.

The fused frontier is a ``(R, frontier)`` matrix over the R chain units —
row r is unit r's private region, holding its sorted-unique frontier gids.
Capacities therefore apply **per unit** (exactly the budgets a per-query
``compile_query`` call would give each chain / star branch), so results —
including §3.4 fast-fail flags, OR-reduced over a star's branches — are
bit-identical to running each query alone, while MVCC timestamps stay
independent per query.

Program caches are keyed on the *batch shape* — the tuple of per-query
plans (+caps, batch size, backend, delta windows) — and hits/misses are
observable via ``CACHE_STATS``, so serving loops can assert that a steady
query mix never retraces.

The same wave structure runs distributed: ``compile_batch_spmd`` builds one
shard_map'd program per batch shape, with per-(unit, owner) routing
buckets, pending vertex checks deferred to the owner shard, one final
routing step for parked and active frontiers alike, and the intersect merge
running shard-locally (each gid has one owner, so local intersection is
global intersection).

Entry point: ``core.query.engine.execute`` (exported as ``GraphDB.query``);
``run_queries_batched(_spmd)`` remain as deprecated shims.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import edges as edges_mod
from repro.core import index as index_mod
from repro.core.addressing import NULL, StoreConfig
from repro.core.edges import TILE
from repro.core.query.a1ql import Plan, Pred
from repro.core.query.executor import (I32MAX, QueryCaps, QueryResult,
                                       eval_pred)
from repro.core.store import GraphStore, visible, window_shard_major

PAD = I32MAX    # empty frontier slot; sorts last, keeps rows ascending


# ---------------------------------------------------------------------------
# static wave tables (host-side, derived from the plan tuple)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Wave:
    """Per-wave static tables: one entry per chain unit in the batch."""
    act: np.ndarray        # (R,) bool  — unit has a hop at this wave
    is_out: np.ndarray     # (R,) bool  — hop direction (False = 'in')
    etype: np.ndarray      # (R,) i32   — edge type to follow (-1 = any)
    tvt: np.ndarray        # (R,) i32   — target vtype check (-1 = none)
    preds: list            # [(Pred, (R,) bool mask)] — hop predicates
    any_out: bool
    any_in: bool


def _pred_groups(entries) -> list:
    """Group (row_index, Pred) pairs by identical predicate."""
    groups: dict = {}
    for qi, pred, n in entries:
        groups.setdefault(pred, np.zeros(n, bool))[qi] = True
    return list(groups.items())


def _wave_tables(chains: Sequence[Plan]) -> list[_Wave]:
    R = len(chains)
    W = max(len(p.hops) for p in chains)
    waves = []
    for w in range(W):
        act = np.array([len(p.hops) > w for p in chains])
        is_out = np.array([len(p.hops) > w and p.hops[w].direction == "out"
                           for p in chains])
        etype = np.array([p.hops[w].etype if len(p.hops) > w else -1
                          for p in chains], np.int32)
        tvt = np.array([p.hops[w].target_vtype if len(p.hops) > w else -1
                        for p in chains], np.int32)
        preds = _pred_groups([(ri, p.hops[w].pred, R)
                              for ri, p in enumerate(chains)
                              if len(p.hops) > w and p.hops[w].pred])
        waves.append(_Wave(act=act, is_out=is_out, etype=etype, tvt=tvt,
                           preds=preds, any_out=bool((act & is_out).any()),
                           any_in=bool((act & ~is_out).any())))
    return waves


def _final_pred_groups(plans: Sequence[Plan]) -> list:
    return _pred_groups([(qi, p.final_pred, len(plans))
                         for qi, p in enumerate(plans) if p.final_pred])


def _unit_tables(plans: Sequence[Plan]):
    """Flatten per-query plans into chain units + the query<->row maps.

    Returns (chains, row2q, n_br, rows_of_q) where ``rows_of_q[q]`` lists
    query q's unit rows padded with R (the all-PAD ghost row)."""
    chains, row2q = [], []
    for qi, p in enumerate(plans):
        for br in p.chain_units():
            chains.append(br)
            row2q.append(qi)
    R = len(chains)
    n_br = np.asarray([len(p.chain_units()) for p in plans], np.int32)
    rows_of_q = np.full((len(plans), int(n_br.max())), R, np.int32)
    r = 0
    for qi, p in enumerate(plans):
        for bi in range(int(n_br[qi])):
            rows_of_q[qi, bi] = r
            r += 1
    return chains, np.asarray(row2q, np.int32), n_br, rows_of_q


# ---------------------------------------------------------------------------
# fused wave primitives (shared by the local and SPMD programs)
# ---------------------------------------------------------------------------

def _dedup_rows(cand_g, cand_v, F: int,
                backend: backend_mod.Backend = backend_mod.REF):
    """Per-unit dedup/compact: (R, W) candidates -> (R, F) regions.

    Row r ends up with its first F unique gids in ascending order (PAD
    beyond), exactly what ``dedup_compact`` produces for the unit alone.
    Dispatches through ``backend.dedup_compact_rows`` — the jnp sort oracle
    on ref, the VMEM-resident ``kernels/dedup_compact`` bitonic network on
    pallas, bit-identical.  Returns (gids, valid, overflow_r)."""
    key = jnp.where(cand_v, cand_g, PAD)
    g, n_q = backend_mod.dedup_compact_rows(key, F, backend=backend)
    return g, g != PAD, n_q > F


def _expand_rows(start, deg, pools, et_q, ts_q, E: int,
                 backend: backend_mod.Backend):
    """Fused CSR expansion: (R, F) spans -> (R, E) neighbor matrix.

    Row r receives the first E raw span entries of unit r's frontier —
    masked by per-unit MVCC visibility (``ts_q``) and edge type (``et_q``)
    — at exactly the positions the per-query reference path computes, so
    both backends emit bit-identical buffers (a per-unit budget clamp on
    the tile plan makes even the overflow truncation match).
    """
    nbr, typ, ecre, edel = pools
    Q, F = deg.shape
    cum = jnp.cumsum(deg, axis=1)
    excl = cum - deg
    if backend.is_pallas:
        # one tile plan for the whole wave; each unit's span budget is
        # clamped to its remaining E so no unit can starve another's tiles
        deg_eff = jnp.clip(E - excl, 0, deg)
        cap_tiles = Q * (min(F, E) + 1 + (E + TILE - 1) // TILE)
        (nbr_t, typ_t, cre_t, del_t), item, tw, _ = backend_mod.expand_tiles(
            start.reshape(-1), deg_eff.reshape(-1), pools,
            tile=TILE, cap_tiles=cap_tiles, backend=backend)
        item_c = jnp.minimum(item, Q * F - 1)
        row = item_c // F
        lane = jnp.arange(TILE, dtype=jnp.int32)
        shape = (cap_tiles, TILE)
        nbr_t, typ_t = nbr_t.reshape(shape), typ_t.reshape(shape)
        cre_t, del_t = cre_t.reshape(shape), del_t.reshape(shape)
        et_t = et_q[row][:, None]
        # invalid lanes carry -1 in every pool: visible(-1,-1,ts) is False
        e_ok = (visible(cre_t, del_t, ts_q[row][:, None])
                & ((et_t < 0) | (typ_t == et_t))
                & (nbr_t >= 0))
        posq = (excl.reshape(-1)[item_c][:, None] + tw[:, None] * TILE
                + lane[None, :])
        pos = jnp.where(e_ok, row[:, None] * E + posq, Q * E)
        out = jnp.full((Q * E,), NULL, jnp.int32).at[pos.reshape(-1)].set(
            nbr_t.reshape(-1), mode="drop")
        return out.reshape(Q, E)

    k = jnp.arange(E, dtype=jnp.int32)

    def one(cum_r, deg_r, start_r, ts, et):
        item = jnp.searchsorted(cum_r, k, side="right").astype(jnp.int32)
        item_c = jnp.minimum(item, F - 1)
        base = cum_r[item_c] - deg_r[item_c]
        in_range = k < cum_r[-1]
        epos = jnp.where(in_range, start_r[item_c] + (k - base), 0)
        e_ok = (in_range & visible(ecre[epos], edel[epos], ts)
                & ((et < 0) | (typ[epos] == et)) & (nbr[epos] >= 0))
        return jnp.where(e_ok, nbr[epos], NULL)

    return jax.vmap(one)(cum, deg, start, ts_q, et_q)


def _delta_rows(key_rows, m, d_key, dnbr, dtyp, dcre, ddel, et_q, ts_q):
    """Per-unit delta-log matches: (R, F) regions x (D,) log -> (R, D).

    Frontier regions hold sorted-unique keys, so each delta entry matches at
    most one slot per unit — a row-wise binary search replaces the
    (F x D) match matrix the single-query path materializes, with identical
    per-unit match sets."""
    Q, F = key_rows.shape
    pos = jax.vmap(lambda row, v: jnp.searchsorted(row, v))(
        key_rows, jnp.broadcast_to(d_key, (Q,) + d_key.shape))
    pos_c = jnp.minimum(pos, F - 1).astype(jnp.int32)
    at_k = jnp.take_along_axis(key_rows, pos_c, axis=1)
    at_m = jnp.take_along_axis(m, pos_c, axis=1)
    hit = (at_m & (at_k == d_key[None, :])
           & (dnbr >= 0)[None, :]
           & visible(dcre[None, :], ddel[None, :], ts_q[:, None])
           & ((et_q[:, None] < 0) | (dtyp[None, :] == et_q[:, None])))
    return jnp.where(hit, jnp.broadcast_to(dnbr[None, :], hit.shape), NULL)


def _check_rows(st, rows, valid, ts_q, tvt_q, preds):
    """Fused liveness/type/predicate check on (R, F) frontier regions.

    ``rows`` indexes the vertex arrays of ``st`` (global store or a
    shard_map local block); ``tvt_q``/``preds`` are per-unit tables —
    parked units carry -1 / no predicate, so only re-(idempotent)
    liveness applies to them."""
    ts2 = ts_q[:, None]
    alive = valid & visible(st.v_create[rows], st.v_delete[rows], ts2)
    tvt2 = tvt_q[:, None]
    alive = alive & ((tvt2 < 0) | (st.vtype[rows] == tvt2))
    if preds:
        use_cur = (st.vdata_ts[rows] <= ts2)[..., None]
        f = jnp.where(use_cur, st.vdata_f[rows], st.vprev_f[rows])
        i = jnp.where(use_cur, st.vdata_i[rows], st.vprev_i[rows])
        keys = st.vkey[rows]
        for pred, qmask in preds:
            pm = jnp.asarray(qmask)[:, None]
            alive = alive & (~pm | eval_pred(pred, f, i, keys))
    return alive


def _merge_rows(g, valid, n_br, rows_of_q, F: int,
                backend: backend_mod.Backend = backend_mod.REF):
    """The intersect-merge wave: (R, F) unit regions -> (Q, F) query regions.

    Each query keeps the gids present in *every* one of its branch rows
    (run length == branch count after a sort of the gathered rows; branch
    rows are sorted-unique, so multiplicity == branch coverage).  Chains
    (one branch) pass through unchanged modulo compaction.  The merged
    region cannot overflow: a full-coverage gid consumes one slot per
    branch, so uniques with full runs never exceed F.  The sort dispatches
    through ``backend.sort_rows`` (``kernels/dedup_compact`` on pallas)."""
    Q, Bmax = rows_of_q.shape
    gp = jnp.concatenate([jnp.where(valid, g, PAD),
                          jnp.full((1, F), PAD, jnp.int32)], axis=0)
    key = gp[jnp.asarray(rows_of_q)].reshape(Q, Bmax * F)
    key_s = backend_mod.sort_rows(key, backend=backend)
    valid_s = key_s != PAD
    prev = jnp.concatenate([jnp.full((Q, 1), -1, key_s.dtype),
                            key_s[:, :-1]], axis=1)
    first = valid_s & (key_s != prev)
    lo = jax.vmap(lambda r: jnp.searchsorted(r, r, side="left"))(key_s)
    hi = jax.vmap(lambda r: jnp.searchsorted(r, r, side="right"))(key_s)
    run = (hi - lo).astype(jnp.int32)
    keep = first & (run == jnp.asarray(n_br)[:, None])
    ki = keep.astype(jnp.int32)
    col = jnp.where(keep, jnp.cumsum(ki, axis=1) - ki, Bmax * F)
    rows = jnp.broadcast_to(jnp.arange(Q, dtype=jnp.int32)[:, None],
                            col.shape)
    out = jnp.full((Q, F), PAD, jnp.int32).at[rows, col].set(
        key_s, mode="drop")
    return out, out != PAD


def _select_rows(st, rows, g, valid, ts_q, select, K: int):
    """Fused select terminal: (Q, F) regions -> (Q, K) rows + attrs."""
    Q = g.shape[0]
    vi = valid.astype(jnp.int32)
    rank = jnp.cumsum(vi, axis=1) - vi
    over = valid & (rank >= K)
    col = jnp.where(valid & ~over, rank, K)
    rowi = jnp.broadcast_to(jnp.arange(Q, dtype=jnp.int32)[:, None],
                            col.shape)
    rows_gid = jnp.full((Q, K), NULL, jnp.int32).at[rowi, col].set(
        jnp.where(valid, g, NULL), mode="drop")
    safe = jnp.where(rows_gid >= 0, rows_gid, 0)
    r = rows(safe)
    use_cur = st.vdata_ts[r] <= ts_q[:, None]
    attrs = {}
    for kind, colid in select:
        if kind == "key":
            vals = jnp.where(rows_gid >= 0, st.vkey[r], NULL)
        elif kind == "f32":
            v = jnp.where(use_cur, st.vdata_f[r][..., colid],
                          st.vprev_f[r][..., colid])
            vals = v * (rows_gid >= 0)
        else:
            v = jnp.where(use_cur, st.vdata_i[r][..., colid],
                          st.vprev_i[r][..., colid])
            vals = v * (rows_gid >= 0)
        attrs[(kind, colid)] = vals
    return rows_gid, attrs, jnp.any(over, axis=1)


# ---------------------------------------------------------------------------
# the local fused program
# ---------------------------------------------------------------------------

# compiled per batch *shape* (tuple of plans); hits mean a steady serving
# query mix never retraces, observable exactly like the executor caches.
# Unlike the per-plan executor caches (small fixed cardinality), batch
# shapes are combinatorial, so this one is LRU-bounded.
_CACHE: collections.OrderedDict = collections.OrderedDict()
CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
CACHE_MAX_PROGRAMS = 256


def _cache_get(key):
    fn = _CACHE.get(key)
    if fn is not None:
        _CACHE.move_to_end(key)
        CACHE_STATS["hits"] += 1
    return fn


def _cache_put(key, fn):
    CACHE_STATS["misses"] += 1
    _CACHE[key] = fn
    while len(_CACHE) > CACHE_MAX_PROGRAMS:
        _CACHE.popitem(last=False)
        CACHE_STATS["evictions"] += 1


def _pow2ceil(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


# peak frontier footprint (bytes) of the programs executed so far, per
# budget mode — the memory claim of the shared-frontier mode, observable
# the same way CACHE_STATS is (serve /stats and bench metadata stamp it)
FRONTIER_STATS = {"per_query_peak_bytes": 0, "shared_peak_bytes": 0}

# running overflow tallies across every fused dispatch — the serving tier's
# hedge/breaker policy reads these (serve /stats surfaces them): how many
# query slots fast-failed at all, and how many of those were evicted by the
# *shared* pool rather than their own per-unit budget
OVERFLOW_STATS = {"failed_queries": 0, "shared_ovf_queries": 0,
                  "deadline_skipped_queries": 0}


def reset_stats() -> None:
    """Zero the observability counters (NOT the program cache).

    The counters are process-global while compiled programs are shared, so
    a fresh ``GraphDB``/``A1Server`` (and each benchmark run) must reset
    them or its hit-rate / overflow assertions read the previous
    instance's traffic."""
    for d in (CACHE_STATS, FRONTIER_STATS, OVERFLOW_STATS):
        for k in d:
            d[k] = 0


def _ceil_sqrt(n: int) -> int:
    import math
    return math.isqrt(max(0, int(n) - 1)) + 1


def shared_budget(n_units: int, per_cap: int, explicit: int = 0) -> int:
    """The shared-capacity policy: ``per_cap * ceil(sqrt(R))`` (pow2).

    Concurrent queries' frontiers rarely peak together, so the shared pool
    grows sub-linearly in the unit count R — O(F*sqrt(R)) instead of the
    per-query mode's O(F*R) — while still giving every unit its full
    per-unit budget when few peak at once.  ``explicit`` (from
    ``QueryCaps.shared_*``) overrides the policy; the result is clamped to
    the per-query footprint (never pay more than per-query mode would).
    """
    r = max(1, int(n_units))
    if explicit:
        return min(int(explicit), r * per_cap)
    # round the sqrt term only: per_cap is already pow2, so pow2-rounding
    # the *product* doubled the pool for every non-pow2 ceil(sqrt(R))
    # (R=9, per_cap=64 -> 256 instead of the intended 192).  The floor is
    # plain R — one slot per unit — not pow2ceil(R), which overshot the
    # policy curve the same way whenever R > per_cap**2
    auto = max(per_cap * _ceil_sqrt(r), r)
    return min(r * per_cap, auto)


def delta_window(db) -> int:
    """Static per-shard edge-delta-log window for the next fused program.

    The delta logs fill prefix-first per shard (host count mirrors are
    exact), so scanning ``[:W]`` of each shard block sees every live entry.
    Rounded to a power of two and clamped, so the program-cache key only
    changes when the fill band crosses a boundary (and compaction resets
    it) — a steady serving mix keeps hitting the same program."""
    n = int(max(db.dl_count.max(initial=0), db.il_count.max(initial=0), 1))
    return min(_pow2ceil(n), db.cfg.cap_delta)


def index_window(db) -> int:
    """Static per-shard primary-index delta window (same contract as
    :func:`delta_window`, for the ``index.lookup`` delta scan — the
    ``xd_*`` arrays fill prefix-first per shard and index compaction
    resets them)."""
    n = int(max(db.xd_count.max(initial=0), 1))
    return min(_pow2ceil(n), db.cfg.cap_idx_delta)


# shared with index.lookup's xd-delta scan: store.window_shard_major
_delta_windowed = window_shard_major


def _nearest_tables(chains, F: int):
    """Static k-NN probe tables: per-unit k (0 = scan-rooted), the batch
    KMAX, and the static ``k <= frontier`` check."""
    kvec = np.array([c.nearest_k for c in chains], np.int32)
    has_nearest = bool((kvec > 0).any())
    kmax = int(kvec.max()) if has_nearest else 0
    if kmax > F:
        raise ValueError(f"nearest k={kmax} exceeds the frontier cap {F}; "
                         "raise caps.frontier (or the 'frontier' hint)")
    return kvec, has_nearest, kmax


def compile_batch(cfg: StoreConfig, plans: tuple, caps: QueryCaps,
                  backend: backend_mod.Backend = backend_mod.REF,
                  dwin: Optional[int] = None, xwin: Optional[int] = None,
                  vwin: Optional[int] = None):
    """Build the jitted fused-wave program for one batch shape.

    ``plans`` is a tuple of logical plans (chains and/or stars) sharing a
    terminal signature; start keys (one per chain unit, branch-major per
    query) and per-query snapshot timestamps stay runtime data, so any
    same-shape batch reuses the compiled program.  ``dwin``/``xwin`` are the
    static edge / primary-index delta windows (see :func:`delta_window`,
    :func:`index_window`); ``vwin`` is the vector-index window
    (``vindex.vindex_window``), only used — and only part of the cache key —
    when the batch holds ``Nearest``-rooted units, whose programs take an
    extra ``vecs`` operand: ``run(store, keys, vecs, valid_in, ts_q,
    cur_q)``."""
    from repro.core import vindex as vindex_mod

    dwin = cfg.cap_delta if dwin is None else min(dwin, cfg.cap_delta)
    key = (cfg, plans, caps, len(plans), backend, dwin, xwin, vwin, "local")
    fn = _cache_get(key)
    if fn is not None:
        return fn

    Q = len(plans)
    F, E, K = caps.frontier, caps.expand, caps.results
    S, cap_v, cap_e = cfg.n_shards, cfg.cap_v, cfg.cap_e
    chains, row2q, n_br, rows_of_q = _unit_tables(plans)
    R = len(chains)
    has_star = any(p.is_intersect for p in plans)
    waves = _wave_tables(chains)
    final_preds = _final_pred_groups(plans)
    start_vt = jnp.asarray([c.start_vtype for c in chains], jnp.int32)
    terminal = plans[0].terminal
    select = tuple(zip(plans[0].select_kind, plans[0].select_cols))
    kvec_np, has_nearest, KMAX = _nearest_tables(chains, F)
    vw = (min(cfg.cap_vec if vwin is None else vwin, cfg.cap_vec)
          if has_nearest else 0)

    def _body(store, keys, vecs, valid_in, ts_q, cur_q):
        ts_r = jnp.take(ts_q, jnp.asarray(row2q))         # (R,) per unit
        failed_r = jnp.zeros((R,), bool)
        # ---- lookup wave: one probe for every chain unit ------------------
        # Nearest-rooted units skip the primary index; their seeds come from
        # the k-NN probe below
        nmask = jnp.asarray(kvec_np > 0)
        look_ok = valid_in & ~nmask if has_nearest else valid_in
        gids0, found = index_mod.lookup(store, cfg, start_vt, keys, look_ok,
                                        ts_r, backend=backend, xd_win=xwin)
        scan_col = jnp.where(found & look_ok, gids0, PAD)
        if has_nearest:
            # ---- k-NN probe wave: one batched distance+top-k kernel pass
            # over the windowed embedding pool; per-unit k masks columns of
            # the shared top-KMAX result.  Seeds land sorted-unique
            # ascending via _dedup_rows — the frontier region invariant —
            # and ties are already gid-deterministic from the kernel.
            vx_g, vx_vt, vx_cr, vx_dl, vx_emb = vindex_mod.window_arrays(
                store, cfg, vw)
            _, knn_g = backend_mod.knn_topk(
                vecs, vx_emb, vx_g, vx_vt, vx_cr, vx_dl, start_vt, ts_r,
                KMAX, backend=backend)
            colk = jnp.arange(KMAX, dtype=jnp.int32)[None, :]
            kvec = jnp.asarray(kvec_np)
            seeds_ok = (nmask[:, None] & (colk < kvec[:, None])
                        & (knn_g != I32MAX) & valid_in[:, None])
            cand = jnp.concatenate(
                [scan_col[:, None], jnp.where(seeds_ok, knn_g, PAD)], axis=1)
            g, valid, ovf = _dedup_rows(cand, cand != PAD, F, backend)
            failed_r = failed_r | ovf
        else:
            g = jnp.full((R, F), PAD, jnp.int32).at[:, 0].set(scan_col)
            valid = g != PAD

        for wave in waves:
            act = jnp.asarray(wave.act)
            is_out = jnp.asarray(wave.is_out)
            et_q = jnp.asarray(wave.etype)
            # parked units carry their finished frontier through the wave
            parts_g, parts_v = [g], [valid & ~act[:, None]]
            for direction, dmask, present in (
                    ("out", is_out, wave.any_out),
                    ("in", ~is_out, wave.any_in)):
                if not present:
                    continue
                m = valid & act[:, None] & dmask[:, None]
                indptr, nbr, typ, ecre, edel = edges_mod._csr_arrays(
                    store, direction)
                safe_g = jnp.where(m, g, 0)
                shard = safe_g % S
                iprow = shard * (cap_v + 1) + safe_g // S
                start = indptr[iprow] + shard * cap_e
                deg = (indptr[iprow + 1] - indptr[iprow]) * m
                failed_r = failed_r | (jnp.sum(deg, axis=1) > E)
                out_n = _expand_rows(start, deg, (nbr, typ, ecre, edel),
                                     et_q, ts_r, E, backend)
                dslot, dnbr, dtyp, dcre, ddel = _delta_windowed(
                    edges_mod._delta_arrays(store, direction),
                    S, cfg.cap_delta, dwin)
                D = dslot.shape[0]
                d_gid = dslot * S + jnp.arange(D, dtype=jnp.int32) // dwin
                dn = _delta_rows(g, m, d_gid, dnbr, dtyp, dcre, ddel,
                                 et_q, ts_r)
                parts_g += [out_n, dn]
                parts_v += [out_n >= 0, dn >= 0]
            g, valid, ovf = _dedup_rows(jnp.concatenate(parts_g, axis=1),
                                        jnp.concatenate(parts_v, axis=1), F,
                                        backend)
            failed_r = failed_r | ovf
            rows = cfg.row_of_gid(jnp.where(valid, g, 0))
            valid = valid & _check_rows(store, rows, valid, ts_r,
                                        jnp.asarray(wave.tvt), wave.preds)

        # ---- intersect-merge wave (units -> queries) ----------------------
        if has_star:
            g, valid = _merge_rows(g, valid, n_br, rows_of_q, F, backend)
        failed_q = jax.ops.segment_sum(
            failed_r.astype(jnp.int32), jnp.asarray(row2q),
            num_segments=Q) > 0

        # ---- terminal wave ------------------------------------------------
        if final_preds:
            rows = cfg.row_of_gid(jnp.where(valid, g, 0))
            valid = valid & _check_rows(store, rows, valid, ts_q,
                                        jnp.full((Q,), -1, jnp.int32),
                                        final_preds)
        # gid-cursor continuations: runtime per-query final predicate
        # ``gid > cursor`` (-1 = no cursor, a no-op) — serve's deep-page
        # refills stay O(page) without baking the cursor into the program
        valid = valid & (g > cur_q[:, None])
        out = {"failed_q": failed_q}
        if terminal == "count":
            out["counts"] = jnp.sum(valid.astype(jnp.int32), axis=1)
        else:
            rows_gid, attrs, trunc = _select_rows(
                store, cfg.row_of_gid, g, valid, ts_q, select, K)
            out.update(rows_gid=rows_gid, attrs=attrs, truncated=trunc)
        return out

    if has_nearest:
        run = jax.jit(_body)
    else:
        # nearest-free batches keep the historical 5-operand signature
        @jax.jit
        def run(store, keys, valid_in, ts_q, cur_q):
            return _body(store, keys, None, valid_in, ts_q, cur_q)

    _cache_put(key, run)
    return run


# ---------------------------------------------------------------------------
# host entry points
# ---------------------------------------------------------------------------

class _Assembly:
    """Scatter per-group results back into input order."""

    def __init__(self, Q: int, K: int):
        self.Q, self.K = Q, K
        self.failed_q = np.zeros(Q, bool)
        # per-query "the shared pool did it" flags: zero for per-query-
        # budget groups (their failures are always self-inflicted)
        self.shared_ovf_q = np.zeros(Q, bool)
        # per-query "the SLO budget ran out" flags: the group was skipped,
        # not failed — serving answers truncated-with-flag, never hedges
        self.deadline_q = np.zeros(Q, bool)
        self.counts = None
        self.rows_gid = None
        self.truncated = None
        self.rows: dict = {}

    def _ensure_select(self):
        if self.rows_gid is None:
            self.rows_gid = np.full((self.Q, self.K), NULL, np.int32)
            self.truncated = np.zeros(self.Q, bool)

    def put(self, idxs, out: dict) -> None:
        self.failed_q[idxs] = np.asarray(out["failed_q"])
        if "shared_q" in out:
            self.shared_ovf_q[idxs] = np.asarray(out["shared_q"])
        if "counts" in out:
            if self.counts is None:
                self.counts = np.full(self.Q, NULL, np.int32)
            self.counts[idxs] = np.asarray(out["counts"])
        else:
            self._ensure_select()
            rg = np.asarray(out["rows_gid"])
            self.rows_gid[idxs, :rg.shape[1]] = rg
            self.truncated[idxs] = np.asarray(out["truncated"])
            for k, v in out["attrs"].items():
                v0 = np.asarray(v)
                if k not in self.rows:
                    fill = NULL if k[0] == "key" else 0
                    self.rows[k] = np.full((self.Q, self.K), fill, v0.dtype)
                self.rows[k][idxs, :v0.shape[1]] = v0

    def skip(self, idxs, select: bool) -> None:
        """Mark a group as budget-truncated without executing its program.

        The queries' slots keep their empty/NULL fill (no rows, no counts);
        select terminals flag ``truncated`` so clients see a partial result,
        and ``deadline_q`` attributes the truncation to the SLO budget."""
        self.deadline_q[idxs] = True
        if select:
            self._ensure_select()
            self.truncated[idxs] = True

    def result(self) -> QueryResult:
        OVERFLOW_STATS["failed_queries"] += int(self.failed_q.sum())
        OVERFLOW_STATS["shared_ovf_queries"] += int(self.shared_ovf_q.sum())
        OVERFLOW_STATS["deadline_skipped_queries"] += int(self.deadline_q.sum())
        return QueryResult(
            counts=self.counts, rows_gid=self.rows_gid,
            rows=self.rows or None, truncated=self.truncated,
            failed=bool(self.failed_q.any()), failed_q=self.failed_q,
            shared_ovf_q=self.shared_ovf_q, deadline_q=self.deadline_q)


def _fusion_groups(lowered, eff_caps):
    """Fusion groups: plans grouped by terminal signature + effective caps
    — chains and stars fuse together.

    Each group's indices are canonically ordered by plan, so any
    permutation of the same batch mix resolves to the same plans tuple —
    one compiled program, not one per arrival order."""
    groups: dict = {}
    for i, (lo, c) in enumerate(zip(lowered, eff_caps)):
        p = lo.plan
        groups.setdefault((p.terminal, p.select_kind, p.select_cols, c),
                          []).append(i)
    return [(key[3], sorted(idxs, key=lambda i: repr(lowered[i].plan)))
            for key, idxs in groups.items()]


def execute_fused(db, lowered: list, eff_caps: list, ts_list: list[int],
                  be: backend_mod.Backend, mesh=None,
                  storage_axes=("data", "model"),
                  budget: str = "per-query",
                  cursors: Optional[Sequence[int]] = None,
                  deadline: Optional[float] = None) -> QueryResult:
    """Run pre-lowered plans as fused multi-query waves.

    The engine (``core.query.engine.execute``) owns parsing, snapshot
    pinning, and routing; this is the fused leg.  With the default
    ``budget="per-query"`` every query gets its *own* §3.4 capacity budget
    and MVCC snapshot, arbitrary plan shapes — chains and stars — fuse into
    one program per (terminal signature, effective caps) group, and results
    (with per-query ``failed_q`` flags) are bit-identical to running each
    query through the per-plan executor alone.  ``budget="shared"`` runs
    the shared-frontier programs (``planner_shared``) instead: one flat
    (seg, gid) frontier pool per group with an O(F*sqrt(R)) shared capacity
    — results can differ from per-query mode only via fast-fail flags under
    shared overflow.  ``cursors`` is the per-query runtime gid-cursor
    vector (-1 = none), applied as a final ``gid > cursor`` predicate
    without retracing (the cursor stays runtime data).

    ``deadline`` is an absolute ``time.monotonic()`` instant (the SLO
    budget's hard edge, threaded down from serving): each fusion group
    checks the clock before dispatching — a group past the deadline is
    *skipped* and its queries come back truncated-with-flag
    (``deadline_q``), never partially executed.  Groups that already ran
    keep their results, so a batch can be half answered, half
    budget-truncated."""
    from repro.core.query import planner_shared
    Q = len(lowered)
    out = _Assembly(Q, max(c.results for c in eff_caps))
    dwin = delta_window(db)
    xwin = index_window(db)
    cursors = [-1] * Q if cursors is None else list(cursors)
    # the vindex window is computed once per call and only when some plan is
    # Nearest-rooted — nearest-free batches keep their existing cache keys
    any_nearest = any(c.nearest_k > 0 for lo in lowered
                      for c in lo.plan.chain_units())
    vwin = None
    if any_nearest:
        from repro.core import vindex as vindex_mod
        vwin = vindex_mod.vindex_window(db)
    for caps_g, idxs in _fusion_groups(lowered, eff_caps):
        plans_g = tuple(lowered[i].plan for i in idxs)
        if deadline is not None and time.monotonic() >= deadline:
            out.skip(idxs, select=plans_g[0].terminal == "select")
            continue
        keys = jnp.asarray([k for i in idxs for k in lowered[i].keys],
                           jnp.int32)
        ts = jnp.asarray([ts_list[i] for i in idxs], jnp.int32)
        cur = jnp.asarray([cursors[i] for i in idxs], jnp.int32)
        R = int(keys.shape[0])
        grp_nearest = any(c.nearest_k > 0 for p in plans_g
                          for c in p.chain_units())
        vw_g = vwin if grp_nearest else None
        if grp_nearest:
            # (R, d_f32) query vectors, unit-major parallel to ``keys``
            # (zeros for scan-rooted units — their knn columns are masked)
            d = db.cfg.d_f32
            vrows = []
            for i in idxs:
                units = lowered[i].plan.chain_units()
                lv = lowered[i].vecs or (None,) * len(units)
                vrows += [v if v is not None else (0.0,) * d for v in lv]
            vecs = jnp.asarray(np.asarray(vrows, np.float32))
        if budget == "shared":
            FS = shared_budget(R, caps_g.frontier, caps_g.shared_frontier)
            FRONTIER_STATS["shared_peak_bytes"] = max(
                FRONTIER_STATS["shared_peak_bytes"], 2 * 4 * FS)
            if mesh is not None:
                fn = planner_shared.compile_batch_shared_spmd(
                    db.cfg, plans_g, caps_g, mesh, storage_axes, be,
                    dwin, xwin, vw_g)
            else:
                fn = planner_shared.compile_batch_shared(
                    db.cfg, plans_g, caps_g, be, dwin, xwin, vw_g)
        else:
            FRONTIER_STATS["per_query_peak_bytes"] = max(
                FRONTIER_STATS["per_query_peak_bytes"],
                4 * R * caps_g.frontier)
            if mesh is not None:
                fn = compile_batch_spmd(db.cfg, plans_g, caps_g, mesh,
                                        storage_axes, be, dwin, xwin, vw_g)
            else:
                fn = compile_batch(db.cfg, plans_g, caps_g, be, dwin, xwin,
                                   vw_g)
        args = ((db.store, keys, vecs) if grp_nearest
                else (db.store, keys))
        out.put(idxs, fn(*args, jnp.ones((R,), bool), ts, cur))
    return out.result()


def run_queries_batched(db, queries: list[dict],
                        caps: Optional[QueryCaps] = None,
                        backend: Optional[str] = None,
                        read_ts: Union[None, int, Sequence[int]] = None,
                        parsed: Optional[list] = None) -> QueryResult:
    """Deprecated shim: use ``GraphDB.query(..., fused=True)``."""
    import warnings
    warnings.warn("run_queries_batched is deprecated; use "
                  "GraphDB.query(..., fused=True)", DeprecationWarning,
                  stacklevel=2)
    from repro.core.query.engine import execute
    return execute(db, queries, caps=caps, backend=backend, read_ts=read_ts,
                   parsed=parsed, fused=True)


def run_queries_batched_spmd(db, queries: list[dict], mesh,
                             caps: Optional[QueryCaps] = None,
                             storage_axes=("data", "model"),
                             backend: Optional[str] = None,
                             read_ts: Union[None, int, Sequence[int]] = None,
                             parsed: Optional[list] = None) -> QueryResult:
    """Deprecated shim: use ``GraphDB.query(..., mesh=..., fused=True)``."""
    import warnings
    warnings.warn("run_queries_batched_spmd is deprecated; use "
                  "GraphDB.query(..., mesh=..., fused=True)",
                  DeprecationWarning, stacklevel=2)
    from repro.core.query.engine import execute
    return execute(db, queries, caps=caps, backend=backend, read_ts=read_ts,
                   parsed=parsed, mesh=mesh, storage_axes=storage_axes,
                   fused=True)


# ---------------------------------------------------------------------------
# the SPMD fused program (query shipping, one program per batch shape)
# ---------------------------------------------------------------------------

def _route_rows(g, m, S: int, B: int, axes):
    """Fused routing: (R, F) pairs -> all_to_all -> (R, S*B) arrivals.

    Buckets are per (unit, owner) — B slots each, the per-query analogue of
    ``caps.bucket`` — so one hot query cannot evict another's RPCs.  Returns
    (arrived_gids, arrived_mask, overflow_r)."""
    Q, F = g.shape
    ow = jnp.where(m, g % S, S)
    ow_s, g_s = jax.lax.sort((ow, g), dimension=1, num_keys=1)
    starts = jax.vmap(
        lambda o: jnp.searchsorted(o, jnp.arange(S, dtype=o.dtype))
    )(ow_s).astype(jnp.int32)
    col = (jnp.arange(F, dtype=jnp.int32)[None, :]
           - jnp.take_along_axis(starts, jnp.minimum(ow_s, S - 1), axis=1))
    ok = ow_s < S
    overflow_q = jnp.any(ok & (col >= B), axis=1)
    keep = ok & (col >= 0) & (col < B)
    dest = jnp.where(keep, ow_s, S)                     # S = out of range
    qcol = jnp.arange(Q, dtype=jnp.int32)[:, None] * B \
        + jnp.clip(col, 0, B - 1)
    bg = jnp.full((S, Q * B), NULL, jnp.int32).at[dest, qcol].set(
        g_s, mode="drop")
    rg = jax.lax.all_to_all(bg, axes, split_axis=0, concat_axis=0,
                            tiled=True)
    arr = rg.reshape(S, Q, B).transpose(1, 0, 2).reshape(Q, S * B)
    return arr, arr >= 0, overflow_q


def compile_batch_spmd(cfg: StoreConfig, plans: tuple, caps: QueryCaps,
                       mesh, storage_axes=("data", "model"),
                       backend: backend_mod.Backend = backend_mod.REF,
                       dwin: Optional[int] = None,
                       xwin: Optional[int] = None,
                       vwin: Optional[int] = None):
    """Fused-wave program on a mesh: the §3.4 coordinator/worker protocol
    for a whole heterogeneous batch — stars included — in one SPMD
    program."""
    from jax.sharding import PartitionSpec as P
    from repro.core.query.executor_spmd import _lookup_local
    from repro.dist import compat

    dwin = cfg.cap_delta if dwin is None else min(dwin, cfg.cap_delta)
    key = (cfg, plans, caps, len(plans), id(mesh), storage_axes, backend,
           dwin, xwin, vwin, "spmd")
    fn = _cache_get(key)
    if fn is not None:
        return fn

    Q = len(plans)
    F, E, B, K = caps.frontier, caps.expand, caps.bucket, caps.results
    S = cfg.n_shards
    axes = storage_axes
    chains, row2q, n_br, rows_of_q = _unit_tables(plans)
    R = len(chains)
    has_star = any(p.is_intersect for p in plans)
    waves = _wave_tables(chains)
    final_preds = _final_pred_groups(plans)
    start_vt_np = np.array([c.start_vtype for c in chains], np.int32)
    terminal = plans[0].terminal
    select = tuple(zip(plans[0].select_kind, plans[0].select_cols))
    kvec_np, has_nearest, KMAX = _nearest_tables(chains, F)
    vw = (min(cfg.cap_vec if vwin is None else vwin, cfg.cap_vec)
          if has_nearest else 0)
    # pending owner-side checks: wave w validates what wave w-1 emitted
    # (w=0 validates the index scan's start vertices); units parked at
    # wave w keep -1/no-pred entries.  The *last* hop's check runs in the
    # finalize step, after the final routing — per unit.
    pend_tvt, pend_preds = [], []
    for w in range(len(waves)):
        if w == 0:
            pend_tvt.append(start_vt_np)
            pend_preds.append([])
        else:
            pend_tvt.append(np.array(
                [c.hops[w - 1].target_vtype if len(c.hops) > w else -1
                 for c in chains], np.int32))
            pend_preds.append(_pred_groups(
                [(ri, c.hops[w - 1].pred, R) for ri, c in enumerate(chains)
                 if len(c.hops) > w and c.hops[w - 1].pred]))
    # zero-hop units (Nearest-rooted with no chain) owe only the start-type
    # check, which their seeds satisfy by construction — an idempotent no-op
    fin_tvt = np.array([c.hops[-1].target_vtype if c.hops else c.start_vtype
                        for c in chains], np.int32)
    fin_preds = _pred_groups([(ri, c.hops[-1].pred, R)
                              for ri, c in enumerate(chains)
                              if c.hops and c.hops[-1].pred])

    def _local_rows(st, g, valid):
        return jnp.where(valid, g // S, 0)

    def body(st, keys, vecs, valid_in, ts_q, cur_q):
        me = jax.lax.axis_index(axes).astype(jnp.int32)
        ts_r = jnp.take(ts_q, jnp.asarray(row2q))         # (R,) per unit
        failed_r = jnp.zeros((R,), bool)
        nmask = jnp.asarray(kvec_np > 0)
        look_ok = valid_in & ~nmask if has_nearest else valid_in
        g0 = _lookup_local(st, cfg, me, jnp.asarray(start_vt_np), keys,
                           look_ok, ts_r, backend, xd_win=xwin)
        scan_col = jnp.where(g0 >= 0, g0, PAD)
        if has_nearest:
            # distributed k-NN probe: each shard scores its local embedding
            # block, the per-shard top-KMAX lists all_gather + merge into
            # one global selection (identical on every shard — each shard's
            # contribution to the global top-k is within its local top-k),
            # then a shard keeps only the seeds it owns — matching the
            # owner-resident pair invariant _lookup_local establishes.
            dd, gg = backend_mod.knn_topk(
                vecs, st.vx_emb[:vw], st.vx_gid[:vw], st.vx_vtype[:vw],
                st.vx_create[:vw], st.vx_delete[:vw],
                jnp.asarray(start_vt_np), ts_r, KMAX, backend=backend)
            ad = jax.lax.all_gather(dd, axes)             # (S, R, KMAX)
            ag0 = jax.lax.all_gather(gg, axes)
            ad = ad.transpose(1, 0, 2).reshape(R, -1)
            ag0 = ag0.transpose(1, 0, 2).reshape(R, -1)
            _, gs = jax.lax.sort((ad, ag0), dimension=1, num_keys=2)
            gsel = gs[:, :KMAX]
            colk = jnp.arange(KMAX, dtype=jnp.int32)[None, :]
            kvec = jnp.asarray(kvec_np)
            seeds_ok = (nmask[:, None] & (colk < kvec[:, None])
                        & (gsel != I32MAX) & valid_in[:, None]
                        & ((gsel % S) == me))
            cand = jnp.concatenate(
                [scan_col[:, None], jnp.where(seeds_ok, gsel, PAD)], axis=1)
            g, valid, ovf = _dedup_rows(cand, cand != PAD, F, backend)
            failed_r = failed_r | ovf
        else:
            g = jnp.full((R, F), PAD, jnp.int32).at[:, 0].set(scan_col)
            valid = g != PAD

        for w, wave in enumerate(waves):
            act = jnp.asarray(wave.act)
            is_out = jnp.asarray(wave.is_out)
            et_q = jnp.asarray(wave.etype)
            # 1) batched RPCs: ship active pairs to their owners
            arr, am, ovf = _route_rows(g, valid & act[:, None], S, B, axes)
            failed_r = failed_r | ovf
            ag, am, ovf2 = _dedup_rows(arr, am, F, backend)
            failed_r = failed_r | ovf2
            # 2) owner-side pending checks (previous hop's vertex checks)
            alive = am & _check_rows(st, _local_rows(st, ag, am), am, ts_r,
                                     jnp.asarray(pend_tvt[w]),
                                     pend_preds[w])
            # 3) worker step: enumerate edges from my CSR block + delta log
            parts_g = [g]
            parts_v = [valid & ~act[:, None]]       # parked pairs stay put
            for direction, dmask, present in (
                    ("out", is_out, wave.any_out),
                    ("in", ~is_out, wave.any_in)):
                if not present:
                    continue
                m = alive & act[:, None] & dmask[:, None]
                if direction == "out":
                    indptr, nbr, typ, ecre, edel = (
                        st.oe_indptr, st.oe_dst, st.oe_type, st.oe_create,
                        st.oe_delete)
                    dslot, dnbr, dtyp, dcre, ddel = (
                        st.dl_slot, st.dl_nbr, st.dl_type, st.dl_create,
                        st.dl_delete)
                else:
                    indptr, nbr, typ, ecre, edel = (
                        st.ie_indptr, st.ie_src, st.ie_type, st.ie_create,
                        st.ie_delete)
                    dslot, dnbr, dtyp, dcre, ddel = (
                        st.il_slot, st.il_nbr, st.il_type, st.il_create,
                        st.il_delete)
                slot = jnp.where(m, ag // S, 0)
                start = indptr[slot]
                deg = (indptr[slot + 1] - indptr[slot]) * m
                failed_r = failed_r | (jnp.sum(deg, axis=1) > E)
                out_n = _expand_rows(start, deg, (nbr, typ, ecre, edel),
                                     et_q, ts_r, E, backend)
                # inside shard_map the delta block is one shard: window [:W]
                dslot, dnbr, dtyp, dcre, ddel = (
                    a[:dwin] for a in (dslot, dnbr, dtyp, dcre, ddel))
                dn = _delta_rows(ag // S, m, dslot, dnbr, dtyp, dcre, ddel,
                                 et_q, ts_r)
                parts_g += [out_n, dn]
                parts_v += [out_n >= 0, dn >= 0]
            g, valid, ovf3 = _dedup_rows(jnp.concatenate(parts_g, axis=1),
                                         jnp.concatenate(parts_v, axis=1), F,
                                         backend)
            failed_r = failed_r | ovf3

        # ---- finalize: route everything, owed checks, merge, aggregate ----
        arr, am, ovf = _route_rows(g, valid, S, B, axes)
        failed_r = failed_r | ovf
        ag, valid, ovf2 = _dedup_rows(arr, am, F, backend)
        failed_r = failed_r | ovf2
        rows_l = _local_rows(st, ag, valid)
        valid = valid & _check_rows(st, rows_l, valid, ts_r,
                                    jnp.asarray(fin_tvt), fin_preds)
        # intersect-merge is shard-local: every branch's copy of a gid
        # lives on the gid's owner shard (ownership routing = equi-join
        # locality), so local run-length == global branch coverage
        if has_star:
            g2, valid = _merge_rows(ag, valid, n_br, rows_of_q, F, backend)
        else:
            g2 = ag
        rows_l = _local_rows(st, g2, valid)
        failed_q = jax.ops.segment_sum(
            failed_r.astype(jnp.int32), jnp.asarray(row2q),
            num_segments=Q) > 0
        if final_preds:
            valid = valid & _check_rows(st, rows_l, valid, ts_q,
                                        jnp.full((Q,), -1, jnp.int32),
                                        final_preds)
        # gid-cursor continuations (runtime; -1 = no cursor, a no-op)
        valid = valid & (g2 > cur_q[:, None])
        out = {"failed_q":
               jax.lax.psum(failed_q.astype(jnp.int32), axes) > 0}
        if terminal == "count":
            out["counts"] = jax.lax.psum(
                jnp.sum(valid.astype(jnp.int32), axis=1), axes)
            return out

        # select: globally consistent row positions (shard-rank offsets)
        vi = valid.astype(jnp.int32)
        local_counts = jnp.sum(vi, axis=1)                    # (Q,)
        all_counts = jax.lax.all_gather(local_counts, axes)   # (S, Q)
        before = (jnp.arange(all_counts.shape[0]) < me)[:, None]
        base = jnp.sum(all_counts * before, axis=0)           # (Q,)
        rank = jnp.cumsum(vi, axis=1) - vi
        pos = base[:, None] + rank
        over = valid & (pos >= K)
        keep = valid & ~over
        rowi = jnp.broadcast_to(jnp.arange(Q, dtype=jnp.int32)[:, None],
                                pos.shape)
        col = jnp.where(keep, pos, K)
        rows_gid = jnp.zeros((Q, K), jnp.int32).at[rowi, col].set(
            jnp.where(valid, g2, 0) + 1, mode="drop")
        rows_gid = jax.lax.psum(rows_gid, axes) - 1           # 0 -> NULL
        trunc = jax.lax.psum(jnp.any(over, axis=1).astype(jnp.int32),
                             axes) > 0
        use_cur = st.vdata_ts[rows_l] <= ts_q[:, None]
        attrs = {}
        for kind, colid in select:
            if kind == "key":
                vals = st.vkey[rows_l]
                acc = jnp.zeros((Q, K), jnp.int32)
            elif kind == "f32":
                vals = jnp.where(use_cur, st.vdata_f[rows_l][..., colid],
                                 st.vprev_f[rows_l][..., colid])
                acc = jnp.zeros((Q, K), jnp.float32)
            else:
                vals = jnp.where(use_cur, st.vdata_i[rows_l][..., colid],
                                 st.vprev_i[rows_l][..., colid])
                acc = jnp.zeros((Q, K), jnp.int32)
            summed = jax.lax.psum(acc.at[rowi, col].set(vals, mode="drop"),
                                  axes)
            if kind == "key":     # empty cells read NULL like the local path
                summed = jnp.where(rows_gid >= 0, summed, NULL)
            attrs[(kind, colid)] = summed
        out.update(rows_gid=rows_gid, attrs=attrs, truncated=trunc)
        return out

    store_specs = jax.tree.map(lambda _: P(axes), GraphStore(
        **{f.name: 0 for f in dataclasses.fields(GraphStore)}))
    out_specs = {"failed_q": P()}
    if terminal == "count":
        out_specs["counts"] = P()
    else:
        out_specs.update(rows_gid=P(), truncated=P(),
                         attrs={k: P() for k in select})
    if has_nearest:
        fn = jax.jit(compat.shard_map(
            body, mesh=mesh,
            in_specs=(store_specs, P(), P(), P(), P(), P()),
            out_specs=out_specs, check_vma=False))
    else:
        def body5(st, keys, valid_in, ts_q, cur_q):
            return body(st, keys, None, valid_in, ts_q, cur_q)
        fn = jax.jit(compat.shard_map(
            body5, mesh=mesh, in_specs=(store_specs, P(), P(), P(), P()),
            out_specs=out_specs, check_vma=False))
    _cache_put(key, fn)
    return fn
