"""Shared-frontier fused execution (§3.4 at serving scale).

The per-query-budget fused waves (``planner.py``) give every chain unit a
private ``(frontier,)`` region — an ``(R, F)`` matrix whose footprint grows
linearly in the number of concurrent units, and whose per-hop compaction is
R row-wise sorts.  A1 sustains its serving batch sizes by keeping per-query
state tiny and letting all in-flight queries share the read machinery; this
module is that shape: **one** flat pool of ``(seg, gid)`` pairs shared by
every live query, compacted once per hop.

  * the frontier is three flat ``(FS,)`` arrays — ``seg`` (which chain unit
    owns the pair; R = empty), ``gid`` (PAD = empty), and a liveness mask —
    kept sorted lexicographically by (seg, gid), so per-segment runs stay
    ascending and binary search works everywhere the per-query mode used
    row-wise search;
  * ``FS = planner.shared_budget(R, caps.frontier)`` — O(F*sqrt(R)) instead
    of O(F*R); the expansion pool (``ES``) and the SPMD routing buckets
    (``SB``) scale the same way;
  * every capacity keeps its **per-unit** meaning too: a segment may hold at
    most ``caps.frontier`` uniques and enumerate at most ``caps.expand``
    raw edges (the same §3.4 flags per-query mode raises), and *on top* the
    shared pools may overflow — in which case every owner whose pair was
    dropped gets its ``failed_q`` flag set (**owner-attributed fast-fail**:
    a hot query can evict its batch mates' slots only by flagging them);
  * consequence (the contract ``tests/test_shared_frontier.py`` pins):
    whenever a query's flag is clear, its results are **bit-identical** to
    per-query-budget mode — shared mode may differ only via fast-fail flags
    under shared overflow.

Entry point: ``GraphDB.query(..., budget="shared")`` →
``engine.execute`` → ``planner.execute_fused(budget="shared")`` → the
compilers here.  Program caches, grouping, and the assembly scatter are
shared with ``planner.py``; the hop compaction goes through the
``kernels/dedup_compact`` seam (one pair sort per hop instead of R row
sorts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import edges as edges_mod
from repro.core import index as index_mod
from repro.core.addressing import NULL, StoreConfig
from repro.core.edges import TILE
from repro.core.query.executor import (I32MAX, QueryCaps, build_select,
                                       eval_pred, sort_pairs)
from repro.core.query.planner import (PAD, _cache_get, _cache_put,
                                      _final_pred_groups, _nearest_tables,
                                      _pred_groups, _unit_tables,
                                      _wave_tables, shared_budget)
from repro.core.store import GraphStore, visible, window_shard_major


# ---------------------------------------------------------------------------
# flat wave primitives
# ---------------------------------------------------------------------------

def _flag_segs(failed_r, cond, segc, R: int):
    """OR per-segment flags: any True in ``cond`` flags its owner segment."""
    hit = jnp.zeros((R + 1,), bool).at[
        jnp.where(cond, segc, R)].set(True, mode="drop")
    return failed_r | hit[:R]


def _dedup_pairs(seg, gid, valid, R: int, F: int, FS: int,
                 backend: backend_mod.Backend):
    """The shared compaction: flat (seg, gid) candidates -> (FS,) pool.

    One lexicographic pair sort (``backend.sort_pairs`` — the
    ``kernels/dedup_compact`` bitonic network on pallas), then: keep the
    first F uniques *per segment* (the per-unit §3.4 budget, flagging
    segments that exceed it exactly like per-query mode), then the first FS
    survivors overall (the shared budget, flagging every owner whose pair
    is dropped — owner-attributed fast-fail).  Returns (seg', gid',
    failed_unit, failed_shared) with outputs sorted by (seg, gid), ghosts
    (R, PAD) last; the two flag vectors separate "my own §3.4 budget blew"
    from "the shared pool evicted me" — serving's hedge policy re-dispatches
    the latter per-query instead of re-entering the saturated pool."""
    s = jnp.where(valid, seg, R)
    g = jnp.where(valid, gid, PAD)
    s, g = backend_mod.sort_pairs(s, g, backend=backend)
    ok = s < R
    prev_s = jnp.concatenate([jnp.full((1,), -1, s.dtype), s[:-1]])
    prev_g = jnp.concatenate([jnp.full((1,), -1, g.dtype), g[:-1]])
    first = ok & ((s != prev_s) | (g != prev_g))
    fi = first.astype(jnp.int32)
    excl = jnp.cumsum(fi) - fi                  # uniques before each slot
    seg_start = jnp.searchsorted(s, s, side="left").astype(jnp.int32)
    rank_seg = excl - excl[seg_start]           # unique rank within my seg
    over_seg = first & (rank_seg >= F)
    keep = first & (rank_seg < F)
    ki = keep.astype(jnp.int32)
    gcol = jnp.cumsum(ki) - ki
    over_shared = keep & (gcol >= FS)
    keep = keep & (gcol < FS)
    col = jnp.where(keep, gcol, FS)
    out_s = jnp.full((FS,), R, jnp.int32).at[col].set(s, mode="drop")
    out_g = jnp.full((FS,), PAD, jnp.int32).at[col].set(g, mode="drop")
    zero = jnp.zeros((R,), bool)
    sc = jnp.minimum(s, R)
    return (out_s, out_g, _flag_segs(zero, over_seg, sc, R),
            _flag_segs(zero, over_shared, sc, R))


def _expand_flat(start, deg, pools, et_s, ts_s, ES: int,
                 backend: backend_mod.Backend):
    """Flat CSR expansion: (FS,) spans -> (ES,) entries + source slots.

    The shared-pool analogue of ``planner._expand_rows``: raw span entry j
    of slot i lands at position ``excl_cumsum[i] + j`` (entries at >= ES
    are truncated — the caller flags their owners), masked by the *slot's*
    MVCC snapshot and edge type.  Both backends emit bit-identical buffers.
    """
    nbr, typ, ecre, edel = pools
    FS = deg.shape[0]
    cum = jnp.cumsum(deg)
    excl = cum - deg
    k = jnp.arange(ES, dtype=jnp.int32)
    item_k = jnp.searchsorted(cum, k, side="right").astype(jnp.int32)
    item_kc = jnp.minimum(item_k, FS - 1)
    if backend.is_pallas:
        deg_eff = jnp.clip(ES - excl, 0, deg)
        cap_tiles = FS + 1 + (ES + TILE - 1) // TILE
        (nbr_t, typ_t, cre_t, del_t), item, tw, _ = backend_mod.expand_tiles(
            start, deg_eff, pools, tile=TILE, cap_tiles=cap_tiles,
            backend=backend)
        item_c = jnp.minimum(item, FS - 1)
        lane = jnp.arange(TILE, dtype=jnp.int32)
        shape = (cap_tiles, TILE)
        nbr_t, typ_t = nbr_t.reshape(shape), typ_t.reshape(shape)
        cre_t, del_t = cre_t.reshape(shape), del_t.reshape(shape)
        et_t = et_s[item_c][:, None]
        # invalid lanes carry -1 in every pool: visible(-1,-1,ts) is False
        e_ok = (visible(cre_t, del_t, ts_s[item_c][:, None])
                & ((et_t < 0) | (typ_t == et_t)) & (nbr_t >= 0))
        posq = excl[item_c][:, None] + tw[:, None] * TILE + lane[None, :]
        pos = jnp.where(e_ok, posq, ES)
        out_n = jnp.full((ES,), NULL, jnp.int32).at[pos.reshape(-1)].set(
            nbr_t.reshape(-1), mode="drop")
    else:
        in_range = k < cum[-1]
        epos = jnp.where(in_range, start[item_kc] + (k - excl[item_kc]), 0)
        e_ok = (in_range & visible(ecre[epos], edel[epos], ts_s[item_kc])
                & ((et_s[item_kc] < 0) | (typ[epos] == et_s[item_kc]))
                & (nbr[epos] >= 0))
        out_n = jnp.where(e_ok, nbr[epos], NULL)
    return out_n, item_kc


def _delta_flat(gid_sorted, m, lo_r, hi_r, d_gid, dnbr, dtyp, dcre, ddel,
                et_r, ts_r, R: int, backend: backend_mod.Backend):
    """Delta-log matches: (R, D) membership probes into the flat pool.

    The pool is sorted by (seg, gid), so "(unit r, delta gid) is a live
    frontier pair" is one windowed binary search per (r, d) — the windows
    ``[lo_r, hi_r)`` are unit r's run, probed through the same
    ``searchsorted_ranged`` seam the primary index uses.  Returns flat
    (R*D,) candidate (seg, nbr) pairs."""
    D = d_gid.shape[0]
    q = jnp.broadcast_to(d_gid[None, :], (R, D)).reshape(-1)
    lo = jnp.broadcast_to(lo_r[:, None], (R, D)).reshape(-1)
    hi = jnp.broadcast_to(hi_r[:, None], (R, D)).reshape(-1)
    pos = backend_mod.searchsorted_ranged(gid_sorted, q, lo, hi,
                                          backend=backend)
    at = jnp.minimum(lo + pos, gid_sorted.shape[0] - 1)
    found = ((lo + pos < hi) & (gid_sorted[at] == q)
             & m[at]).reshape(R, D)
    hit = (found & (dnbr >= 0)[None, :]
           & visible(dcre[None, :], ddel[None, :], ts_r[:, None])
           & ((et_r[:, None] < 0) | (dtyp[None, :] == et_r[:, None])))
    dn = jnp.where(hit, jnp.broadcast_to(dnbr[None, :], hit.shape), NULL)
    ds = jnp.where(hit, jnp.arange(R, dtype=jnp.int32)[:, None], R)
    return ds.reshape(-1), dn.reshape(-1)


def _check_flat(st, rows, valid, ts_s, tvt_s, preds, segc):
    """Per-slot liveness/type/predicate check (flat analogue of
    ``planner._check_rows``); per-slot tables are gathered by ``segc``."""
    alive = valid & visible(st.v_create[rows], st.v_delete[rows], ts_s)
    alive = alive & ((tvt_s < 0) | (st.vtype[rows] == tvt_s))
    if preds:
        use_cur = (st.vdata_ts[rows] <= ts_s)[:, None]
        f = jnp.where(use_cur, st.vdata_f[rows], st.vprev_f[rows])
        i = jnp.where(use_cur, st.vdata_i[rows], st.vprev_i[rows])
        keys = st.vkey[rows]
        for pred, qmask in preds:
            pm = jnp.concatenate([jnp.asarray(qmask),
                                  jnp.zeros((1,), bool)])[segc]
            alive = alive & (~pm | eval_pred(pred, f, i, keys))
    return alive


def _seg_windows(seg, R: int):
    """[lo, hi) of every segment's run in the sorted pool."""
    r = jnp.arange(R, dtype=seg.dtype)
    lo = jnp.searchsorted(seg, r, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(seg, r, side="right").astype(jnp.int32)
    return lo, hi


def _merge_flat(seg, gid, live, row2q_x, n_br, Q: int, FS: int,
                backend: backend_mod.Backend):
    """Intersect-merge on the flat pool: (seg, gid) -> (query, gid) pairs.

    Branch runs are sorted-unique, so after mapping segments to their
    owning query and one pair sort, a gid's run length equals its branch
    coverage; ``run == n_branches`` keeps exactly the §3.4 star semantics
    (chains pass through, run == 1).  Output is compacted and sorted by
    (query, gid); it cannot overflow FS (kept <= input)."""
    segc = jnp.minimum(seg, row2q_x.shape[0] - 1)
    qv = jnp.where(live, row2q_x[segc], Q)
    gv = jnp.where(live, gid, PAD)
    q_s, g_s = backend_mod.sort_pairs(qv, gv, backend=backend)
    ok = q_s < Q
    prev_q = jnp.concatenate([jnp.full((1,), -1, q_s.dtype), q_s[:-1]])
    prev_g = jnp.concatenate([jnp.full((1,), -1, g_s.dtype), g_s[:-1]])
    first = ok & ((q_s != prev_q) | (g_s != prev_g))
    run_id = jnp.where(ok, jnp.cumsum(first.astype(jnp.int32)) - 1, FS - 1)
    run_len = jax.ops.segment_sum(ok.astype(jnp.int32), run_id,
                                  num_segments=FS)
    nbr_x = jnp.concatenate([jnp.asarray(n_br), jnp.full((1,), -1,
                                                         jnp.int32)])
    keep = first & (run_len[run_id] == nbr_x[jnp.minimum(q_s, Q)])
    ki = keep.astype(jnp.int32)
    col = jnp.where(keep, jnp.cumsum(ki) - ki, FS)
    qf = jnp.full((FS,), Q, jnp.int32).at[col].set(q_s, mode="drop")
    gf = jnp.full((FS,), PAD, jnp.int32).at[col].set(g_s, mode="drop")
    return qf, gf, qf < Q


def _ext(a, fill):
    """Append the ghost-segment entry to a per-unit table."""
    a = np.asarray(a)
    return np.concatenate([a, np.asarray([fill], a.dtype)])


# ---------------------------------------------------------------------------
# the local shared-frontier program
# ---------------------------------------------------------------------------

def compile_batch_shared(cfg: StoreConfig, plans: tuple, caps: QueryCaps,
                         backend: backend_mod.Backend = backend_mod.REF,
                         dwin: Optional[int] = None,
                         xwin: Optional[int] = None,
                         vwin: Optional[int] = None):
    """Build the jitted shared-frontier program for one batch shape.

    Same grouping/caching contract as ``planner.compile_batch`` (including
    the ``vwin``/``vecs`` extension for ``Nearest``-rooted units); the
    frontier is the flat shared pool described in the module docstring."""
    from repro.core import vindex as vindex_mod

    dwin = cfg.cap_delta if dwin is None else min(dwin, cfg.cap_delta)
    key = (cfg, plans, caps, len(plans), backend, dwin, xwin, vwin,
           "shared-local")
    fn = _cache_get(key)
    if fn is not None:
        return fn

    Q = len(plans)
    F, E, K = caps.frontier, caps.expand, caps.results
    S, cap_v, cap_e = cfg.n_shards, cfg.cap_v, cfg.cap_e
    chains, row2q, n_br, _rows_of_q = _unit_tables(plans)
    R = len(chains)
    FS = shared_budget(R, F, caps.shared_frontier)
    ES = shared_budget(R, E, caps.shared_expand)
    if FS < R:
        raise ValueError(f"shared frontier budget {FS} below unit count {R}")
    has_star = any(p.is_intersect for p in plans)
    waves = _wave_tables(chains)
    final_preds = _final_pred_groups(plans)
    start_vt = jnp.asarray([c.start_vtype for c in chains], jnp.int32)
    row2q_x = jnp.asarray(np.concatenate([row2q, [Q]]), jnp.int32)
    terminal = plans[0].terminal
    kvec_np, has_nearest, KMAX = _nearest_tables(chains, F)
    vw = (min(cfg.cap_vec if vwin is None else vwin, cfg.cap_vec)
          if has_nearest else 0)
    _delta_windowed = window_shard_major

    def _body(store, keys, vecs, valid_in, ts_q, cur_q):
        ts_r = jnp.take(ts_q, jnp.asarray(row2q))          # (R,) per unit
        ts_x = jnp.concatenate([ts_r, jnp.zeros((1,), ts_r.dtype)])
        failed_r = jnp.zeros((R,), bool)
        shared_r = jnp.zeros((R,), bool)     # subset caused by shared pools
        # ---- lookup wave --------------------------------------------------
        nmask = jnp.asarray(kvec_np > 0)
        look_ok = valid_in & ~nmask if has_nearest else valid_in
        gids0, found = index_mod.lookup(store, cfg, start_vt, keys, look_ok,
                                        ts_r, backend=backend, xd_win=xwin)
        seg0 = jnp.where(found & look_ok, jnp.arange(R, dtype=jnp.int32), R)
        gid0 = jnp.where(found & look_ok, gids0, PAD)
        if has_nearest:
            # k-NN seeds enter the flat (seg, gid) pool alongside the scan
            # probes; _dedup_pairs restores the sorted-run invariant
            vx_g, vx_vt, vx_cr, vx_dl, vx_emb = vindex_mod.window_arrays(
                store, cfg, vw)
            _, knn_g = backend_mod.knn_topk(
                vecs, vx_emb, vx_g, vx_vt, vx_cr, vx_dl, start_vt, ts_r,
                KMAX, backend=backend)
            colk = jnp.arange(KMAX, dtype=jnp.int32)[None, :]
            kvec = jnp.asarray(kvec_np)
            seeds_ok = (nmask[:, None] & (colk < kvec[:, None])
                        & (knn_g != I32MAX) & valid_in[:, None])
            seg_n = jnp.where(seeds_ok,
                              jnp.arange(R, dtype=jnp.int32)[:, None], R)
            cand_s = jnp.concatenate([seg0, seg_n.reshape(-1)])
            cand_g = jnp.concatenate(
                [gid0, jnp.where(seeds_ok, knn_g, PAD).reshape(-1)])
        else:
            cand_s, cand_g = seg0, gid0
        seg, gid, fu, fs = _dedup_pairs(cand_s, cand_g, cand_s < R, R, F, FS,
                                        backend)
        failed_r = failed_r | fu | fs
        shared_r = shared_r | fs
        live = seg < R

        for wave in waves:
            segc = jnp.minimum(seg, R)
            act_x = jnp.asarray(_ext(wave.act, False))
            out_x = jnp.asarray(_ext(wave.is_out, False))
            et_x = jnp.asarray(_ext(wave.etype, -1))
            a_slot = live & act_x[segc]
            parked = live & ~act_x[segc]
            parts_s = [jnp.where(parked, seg, R)]
            parts_g = [jnp.where(parked, gid, PAD)]
            lo_r, hi_r = _seg_windows(seg, R)
            for direction, dmask, present in (
                    ("out", out_x, wave.any_out),
                    ("in", ~out_x, wave.any_in)):
                if not present:
                    continue
                m = a_slot & dmask[segc]
                indptr, nbr, typ, ecre, edel = edges_mod._csr_arrays(
                    store, direction)
                safe_g = jnp.where(m, gid, 0)
                shard = safe_g % S
                iprow = shard * (cap_v + 1) + safe_g // S
                start = indptr[iprow] + shard * cap_e
                deg = (indptr[iprow + 1] - indptr[iprow]) * m
                # per-unit expand budget: the same §3.4 flag per-query
                # mode raises, so flags agree whenever shared caps idle
                segdeg = jax.ops.segment_sum(deg, segc,
                                             num_segments=R + 1)[:R]
                failed_r = failed_r | (segdeg > E)
                # shared-pool truncation: flag every owner it touches
                es_f = _flag_segs(jnp.zeros((R,), bool),
                                  m & (jnp.cumsum(deg) > ES), segc, R)
                failed_r = failed_r | es_f
                shared_r = shared_r | es_f
                out_n, item = _expand_flat(start, deg,
                                           (nbr, typ, ecre, edel),
                                           et_x[segc], ts_x[segc], ES,
                                           backend)
                out_s = jnp.where(out_n >= 0, segc[item], R)
                dslot, dnbr, dtyp, dcre, ddel = _delta_windowed(
                    edges_mod._delta_arrays(store, direction),
                    S, cfg.cap_delta, dwin)
                D = dslot.shape[0]
                d_gid = dslot * S + jnp.arange(D, dtype=jnp.int32) // dwin
                ds, dn = _delta_flat(gid, m, lo_r, hi_r, d_gid, dnbr, dtyp,
                                     dcre, ddel, jnp.asarray(wave.etype),
                                     ts_r, R, backend)
                parts_s += [out_s, ds]
                parts_g += [out_n, dn]
            cand_s = jnp.concatenate(parts_s)
            cand_g = jnp.concatenate(parts_g)
            seg, gid, fu, fs = _dedup_pairs(cand_s, cand_g, cand_s < R,
                                            R, F, FS, backend)
            failed_r = failed_r | fu | fs
            shared_r = shared_r | fs
            live = seg < R
            segc = jnp.minimum(seg, R)
            rows = cfg.row_of_gid(jnp.where(live, gid, 0))
            live = live & _check_flat(store, rows, live, ts_x[segc],
                                      jnp.asarray(_ext(wave.tvt, -1))[segc],
                                      wave.preds, segc)

        # ---- merge units -> queries --------------------------------------
        if has_star:
            qf, gf, live = _merge_flat(seg, gid, live, row2q_x, n_br, Q, FS,
                                       backend)
        else:          # chains: seg == query index, pairs already sorted
            qf, gf = jnp.minimum(seg, Q), gid
        failed_q = jax.ops.segment_sum(
            failed_r.astype(jnp.int32), jnp.asarray(row2q),
            num_segments=Q) > 0
        shared_q = jax.ops.segment_sum(
            shared_r.astype(jnp.int32), jnp.asarray(row2q),
            num_segments=Q) > 0

        # ---- terminal wave ------------------------------------------------
        qc = jnp.minimum(qf, Q)
        ts_qx = jnp.concatenate([ts_q, jnp.zeros((1,), ts_q.dtype)])
        if final_preds:
            rows = cfg.row_of_gid(jnp.where(live, gf, 0))
            live = live & _check_flat(store, rows, live, ts_qx[qc],
                                      jnp.full(rows.shape, -1, jnp.int32),
                                      final_preds, qc)
        cur_x = jnp.concatenate([cur_q, jnp.full((1,), -1, jnp.int32)])
        live = live & (gf > cur_x[qc])          # gid-cursor continuations
        out = {"failed_q": failed_q, "shared_q": shared_q}
        if terminal == "count":
            out["counts"] = jax.ops.segment_sum(
                live.astype(jnp.int32), jnp.where(live, qf, Q),
                num_segments=Q + 1)[:Q]
        else:
            plan0 = plans[0]
            rows_gid, attrs, trunc = build_select(
                store, cfg, plan0, jnp.where(live, qf, NULL),
                jnp.where(live, gf, NULL), live, ts_q[:, None], Q, K)
            out.update(rows_gid=rows_gid, attrs=attrs, truncated=trunc)
        return out

    if has_nearest:
        run = jax.jit(_body)
    else:
        # nearest-free batches keep the historical 5-operand signature
        @jax.jit
        def run(store, keys, valid_in, ts_q, cur_q):
            return _body(store, keys, None, valid_in, ts_q, cur_q)

    _cache_put(key, run)
    return run


# ---------------------------------------------------------------------------
# the SPMD shared-frontier program
# ---------------------------------------------------------------------------

def _route_flat(seg, gid, m, S: int, SB: int, R: int, axes):
    """Shared-bucket routing: flat pairs -> all_to_all -> (S*SB,) arrivals.

    Buckets are per destination shard and *shared* by every unit (SB slots,
    the shared analogue of per-query mode's per-(unit, owner) buckets);
    dropped pairs flag their owner segment.  Returns (seg', gid',
    failed_seg)."""
    N = seg.shape[0]
    ow = jnp.where(m, gid % S, S)
    segk = jnp.where(m, seg, R)
    gidk = jnp.where(m, gid, PAD)
    ow_s, s_s, g_s = jax.lax.sort((ow, segk, gidk), num_keys=3)
    starts = jnp.searchsorted(ow_s, jnp.arange(S, dtype=ow_s.dtype),
                              side="left").astype(jnp.int32)
    idx = jnp.arange(N, dtype=jnp.int32)
    col = idx - starts[jnp.minimum(ow_s, S - 1)]
    ok = ow_s < S
    failed = jnp.zeros((R,), bool)
    failed = _flag_segs(failed, ok & (col >= SB), jnp.minimum(s_s, R), R)
    keep = ok & (col < SB)
    row = jnp.where(keep, ow_s, S)
    colc = jnp.where(keep, col, SB)
    bs = jnp.full((S, SB), R, jnp.int32).at[row, colc].set(s_s, mode="drop")
    bg = jnp.full((S, SB), PAD, jnp.int32).at[row, colc].set(g_s, mode="drop")
    rs = jax.lax.all_to_all(bs, axes, split_axis=0, concat_axis=0, tiled=True)
    rg = jax.lax.all_to_all(bg, axes, split_axis=0, concat_axis=0, tiled=True)
    return rs.reshape(-1), rg.reshape(-1), failed


def compile_batch_shared_spmd(cfg: StoreConfig, plans: tuple,
                              caps: QueryCaps, mesh,
                              storage_axes=("data", "model"),
                              backend: backend_mod.Backend = backend_mod.REF,
                              dwin: Optional[int] = None,
                              xwin: Optional[int] = None,
                              vwin: Optional[int] = None):
    """Shared-frontier waves on a mesh: the §3.4 coordinator/worker
    protocol with one shared (seg, gid) pool per shard."""
    from jax.sharding import PartitionSpec as P
    from repro.core.query.executor_spmd import _lookup_local
    from repro.dist import compat

    dwin = cfg.cap_delta if dwin is None else min(dwin, cfg.cap_delta)
    key = (cfg, plans, caps, len(plans), id(mesh), storage_axes, backend,
           dwin, xwin, vwin, "shared-spmd")
    fn = _cache_get(key)
    if fn is not None:
        return fn

    Q = len(plans)
    F, E, B, K = caps.frontier, caps.expand, caps.bucket, caps.results
    S = cfg.n_shards
    axes = storage_axes
    chains, row2q, n_br, _rows_of_q = _unit_tables(plans)
    R = len(chains)
    FS = shared_budget(R, F, caps.shared_frontier)
    ES = shared_budget(R, E, caps.shared_expand)
    SB = shared_budget(R, B, caps.shared_bucket)
    if FS < R:
        raise ValueError(f"shared frontier budget {FS} below unit count {R}")
    has_star = any(p.is_intersect for p in plans)
    waves = _wave_tables(chains)
    final_preds = _final_pred_groups(plans)
    start_vt_np = np.array([c.start_vtype for c in chains], np.int32)
    row2q_x = jnp.asarray(np.concatenate([row2q, [Q]]), jnp.int32)
    terminal = plans[0].terminal
    select = tuple(zip(plans[0].select_kind, plans[0].select_cols))
    # pending owner-side checks, exactly as in planner.compile_batch_spmd
    pend_tvt, pend_preds = [], []
    for w in range(len(waves)):
        if w == 0:
            pend_tvt.append(start_vt_np)
            pend_preds.append([])
        else:
            pend_tvt.append(np.array(
                [c.hops[w - 1].target_vtype if len(c.hops) > w else -1
                 for c in chains], np.int32))
            pend_preds.append(_pred_groups(
                [(ri, c.hops[w - 1].pred, R) for ri, c in enumerate(chains)
                 if len(c.hops) > w and c.hops[w - 1].pred]))
    # zero-hop units (Nearest-rooted with no chain) owe only the start-type
    # check, which their seeds satisfy by construction — an idempotent no-op
    fin_tvt = np.array([c.hops[-1].target_vtype if c.hops else c.start_vtype
                        for c in chains], np.int32)
    fin_preds = _pred_groups([(ri, c.hops[-1].pred, R)
                              for ri, c in enumerate(chains)
                              if c.hops and c.hops[-1].pred])
    kvec_np, has_nearest, KMAX = _nearest_tables(chains, F)
    vw = (min(cfg.cap_vec if vwin is None else vwin, cfg.cap_vec)
          if has_nearest else 0)

    def body(st, keys, vecs, valid_in, ts_q, cur_q):
        me = jax.lax.axis_index(axes).astype(jnp.int32)
        ts_r = jnp.take(ts_q, jnp.asarray(row2q))
        ts_x = jnp.concatenate([ts_r, jnp.zeros((1,), ts_r.dtype)])
        failed_r = jnp.zeros((R,), bool)
        shared_r = jnp.zeros((R,), bool)     # subset caused by shared pools
        nmask = jnp.asarray(kvec_np > 0)
        look_ok = valid_in & ~nmask if has_nearest else valid_in
        g0 = _lookup_local(st, cfg, me, jnp.asarray(start_vt_np), keys,
                           look_ok, ts_r, backend, xd_win=xwin)
        seg0 = jnp.where(g0 >= 0, jnp.arange(R, dtype=jnp.int32), R)
        gid0 = jnp.where(g0 >= 0, g0, PAD)
        if has_nearest:
            # distributed k-NN probe (same merge as planner.compile_batch_
            # spmd): local scores -> all_gather -> global top-KMAX, each
            # shard keeps the seeds it owns, seeds join the flat pool
            dd, gg = backend_mod.knn_topk(
                vecs, st.vx_emb[:vw], st.vx_gid[:vw], st.vx_vtype[:vw],
                st.vx_create[:vw], st.vx_delete[:vw],
                jnp.asarray(start_vt_np), ts_r, KMAX, backend=backend)
            ad = jax.lax.all_gather(dd, axes)             # (S, R, KMAX)
            ag0 = jax.lax.all_gather(gg, axes)
            ad = ad.transpose(1, 0, 2).reshape(R, -1)
            ag0 = ag0.transpose(1, 0, 2).reshape(R, -1)
            _, gs = jax.lax.sort((ad, ag0), dimension=1, num_keys=2)
            gsel = gs[:, :KMAX]
            colk = jnp.arange(KMAX, dtype=jnp.int32)[None, :]
            kvec = jnp.asarray(kvec_np)
            seeds_ok = (nmask[:, None] & (colk < kvec[:, None])
                        & (gsel != I32MAX) & valid_in[:, None]
                        & ((gsel % S) == me))
            seg_n = jnp.where(seeds_ok,
                              jnp.arange(R, dtype=jnp.int32)[:, None], R)
            seg0 = jnp.concatenate([seg0, seg_n.reshape(-1)])
            gid0 = jnp.concatenate(
                [gid0, jnp.where(seeds_ok, gsel, PAD).reshape(-1)])
        seg, gid, fu, fs = _dedup_pairs(seg0, gid0, seg0 < R, R, F, FS,
                                        backend)
        failed_r = failed_r | fu | fs
        shared_r = shared_r | fs
        live = seg < R

        for w, wave in enumerate(waves):
            segc = jnp.minimum(seg, R)
            act_x = jnp.asarray(_ext(wave.act, False))
            out_x = jnp.asarray(_ext(wave.is_out, False))
            et_x = jnp.asarray(_ext(wave.etype, -1))
            # parked pairs stay put until the final routing
            parked = live & ~act_x[segc]
            parts_s = [jnp.where(parked, seg, R)]
            parts_g = [jnp.where(parked, gid, PAD)]
            # 1) batched RPCs: ship active pairs to their owners (bucket
            # drops are a shared-capacity casualty, like pool eviction)
            a_s, a_g, fr = _route_flat(seg, gid, live & act_x[segc], S, SB,
                                       R, axes)
            failed_r = failed_r | fr
            shared_r = shared_r | fr
            seg_a, gid_a, fu, fs = _dedup_pairs(a_s, a_g, a_s < R, R, F, FS,
                                                backend)
            failed_r = failed_r | fu | fs
            shared_r = shared_r | fs
            live_a = seg_a < R
            segc_a = jnp.minimum(seg_a, R)
            # 2) owner-side pending checks (previous hop's vertex checks)
            rows_l = jnp.where(live_a, gid_a // S, 0)
            alive = live_a & _check_flat(
                st, rows_l, live_a, ts_x[segc_a],
                jnp.asarray(_ext(pend_tvt[w], -1))[segc_a],
                pend_preds[w], segc_a)
            lo_r, hi_r = _seg_windows(seg_a, R)
            # 3) worker step: my CSR block + delta log
            for direction, dmask, present in (
                    ("out", out_x, wave.any_out),
                    ("in", ~out_x, wave.any_in)):
                if not present:
                    continue
                m = alive & act_x[segc_a] & dmask[segc_a]
                if direction == "out":
                    indptr, nbr, typ, ecre, edel = (
                        st.oe_indptr, st.oe_dst, st.oe_type, st.oe_create,
                        st.oe_delete)
                    dslot, dnbr, dtyp, dcre, ddel = (
                        st.dl_slot, st.dl_nbr, st.dl_type, st.dl_create,
                        st.dl_delete)
                else:
                    indptr, nbr, typ, ecre, edel = (
                        st.ie_indptr, st.ie_src, st.ie_type, st.ie_create,
                        st.ie_delete)
                    dslot, dnbr, dtyp, dcre, ddel = (
                        st.il_slot, st.il_nbr, st.il_type, st.il_create,
                        st.il_delete)
                slot = jnp.where(m, gid_a // S, 0)
                start = indptr[slot]
                deg = (indptr[slot + 1] - indptr[slot]) * m
                segdeg = jax.ops.segment_sum(deg, segc_a,
                                             num_segments=R + 1)[:R]
                failed_r = failed_r | (segdeg > E)
                es_f = _flag_segs(jnp.zeros((R,), bool),
                                  m & (jnp.cumsum(deg) > ES), segc_a, R)
                failed_r = failed_r | es_f
                shared_r = shared_r | es_f
                out_n, item = _expand_flat(start, deg,
                                           (nbr, typ, ecre, edel),
                                           et_x[segc_a], ts_x[segc_a], ES,
                                           backend)
                out_s = jnp.where(out_n >= 0, segc_a[item], R)
                # inside shard_map the delta block is one shard: [:dwin]
                dslot, dnbr, dtyp, dcre, ddel = (
                    a[:dwin] for a in (dslot, dnbr, dtyp, dcre, ddel))
                # my pairs all live on my shard: gid // S is the local
                # slot and stays ascending within each segment's run
                gl = jnp.where(live_a, gid_a // S, PAD)
                ds, dn = _delta_flat(gl, m, lo_r, hi_r, dslot, dnbr, dtyp,
                                     dcre, ddel, jnp.asarray(wave.etype),
                                     ts_r, R, backend)
                parts_s += [out_s, ds]
                parts_g += [out_n, dn]
            cand_s = jnp.concatenate(parts_s)
            cand_g = jnp.concatenate(parts_g)
            seg, gid, fu, fs = _dedup_pairs(cand_s, cand_g, cand_s < R,
                                            R, F, FS, backend)
            failed_r = failed_r | fu | fs
            shared_r = shared_r | fs
            live = seg < R

        # ---- finalize: route all, owed checks, merge, aggregate -----------
        a_s, a_g, fr = _route_flat(seg, gid, live, S, SB, R, axes)
        failed_r = failed_r | fr
        shared_r = shared_r | fr
        seg, gid, fu, fs = _dedup_pairs(a_s, a_g, a_s < R, R, F, FS, backend)
        failed_r = failed_r | fu | fs
        shared_r = shared_r | fs
        live = seg < R
        segc = jnp.minimum(seg, R)
        rows_l = jnp.where(live, gid // S, 0)
        live = live & _check_flat(st, rows_l, live, ts_x[segc],
                                  jnp.asarray(_ext(fin_tvt, -1))[segc],
                                  fin_preds, segc)
        # intersect-merge is shard-local (each gid has one owner shard)
        if has_star:
            qf, gf, live = _merge_flat(seg, gid, live, row2q_x, n_br, Q, FS,
                                       backend)
        else:
            qf, gf = jnp.minimum(seg, Q), gid
        failed_q = jax.ops.segment_sum(
            failed_r.astype(jnp.int32), jnp.asarray(row2q),
            num_segments=Q) > 0
        shared_q = jax.ops.segment_sum(
            shared_r.astype(jnp.int32), jnp.asarray(row2q),
            num_segments=Q) > 0
        qc = jnp.minimum(qf, Q)
        ts_qx = jnp.concatenate([ts_q, jnp.zeros((1,), ts_q.dtype)])
        if final_preds:
            rows_l = jnp.where(live, gf // S, 0)
            live = live & _check_flat(st, rows_l, live, ts_qx[qc],
                                      jnp.full(rows_l.shape, -1, jnp.int32),
                                      final_preds, qc)
        cur_x = jnp.concatenate([cur_q, jnp.full((1,), -1, jnp.int32)])
        live = live & (gf > cur_x[qc])          # gid-cursor continuations
        out = {"failed_q":
               jax.lax.psum(failed_q.astype(jnp.int32), axes) > 0,
               "shared_q":
               jax.lax.psum(shared_q.astype(jnp.int32), axes) > 0}
        if terminal == "count":
            out["counts"] = jax.lax.psum(jax.ops.segment_sum(
                live.astype(jnp.int32), jnp.where(live, qf, Q),
                num_segments=Q + 1)[:Q], axes)
            return out

        # select: globally consistent row positions (shard-rank offsets)
        q_s, g_s, v_s, _first = sort_pairs(jnp.where(live, qf, NULL),
                                           jnp.where(live, gf, NULL), live)
        local_counts = jax.ops.segment_sum(
            v_s.astype(jnp.int32), jnp.where(v_s, q_s, Q),
            num_segments=Q + 1)[:Q]
        all_counts = jax.lax.all_gather(local_counts, axes)     # (S, Q)
        before = (jnp.arange(all_counts.shape[0]) < me)[:, None]
        base = jnp.sum(all_counts * before, axis=0)             # (Q,)
        q_srch = jnp.where(v_s, q_s, I32MAX)
        run_start = jnp.searchsorted(q_srch, q_srch,
                                     side="left").astype(jnp.int32)
        excl = jnp.cumsum(v_s.astype(jnp.int32)) - v_s.astype(jnp.int32)
        pos_local = excl - excl[run_start]
        qsafe = jnp.where(v_s, q_s, 0)
        pos = base[qsafe] + pos_local
        over = v_s & (pos >= K)
        row = jnp.where(v_s & ~over, q_s, I32MAX)
        col = jnp.where(v_s & ~over, pos, I32MAX)
        rows_gid = jnp.zeros((Q, K), jnp.int32).at[row, col].set(
            g_s + 1, mode="drop")
        rows_gid = jax.lax.psum(rows_gid, axes) - 1             # 0 -> NULL
        trunc = jax.lax.psum(jnp.zeros((Q,), jnp.int32).at[
            jnp.where(over, q_s, I32MAX)].set(1, mode="drop"), axes) > 0
        rows_local = jnp.where(v_s, g_s // S, 0)
        use_cur = st.vdata_ts[rows_local] <= ts_qx[jnp.minimum(qsafe, Q)]
        attrs = {}
        for kind, colid in select:
            if kind == "key":
                vals = st.vkey[rows_local]
                acc = jnp.zeros((Q, K), jnp.int32)
            elif kind == "f32":
                vals = jnp.where(use_cur, st.vdata_f[rows_local][..., colid],
                                 st.vprev_f[rows_local][..., colid])
                acc = jnp.zeros((Q, K), jnp.float32)
            else:
                vals = jnp.where(use_cur, st.vdata_i[rows_local][..., colid],
                                 st.vprev_i[rows_local][..., colid])
                acc = jnp.zeros((Q, K), jnp.int32)
            summed = jax.lax.psum(acc.at[row, col].set(vals, mode="drop"),
                                  axes)
            if kind == "key":     # empty cells read NULL like the local path
                summed = jnp.where(rows_gid >= 0, summed, NULL)
            attrs[(kind, colid)] = summed
        out.update(rows_gid=rows_gid, attrs=attrs, truncated=trunc)
        return out

    store_specs = jax.tree.map(lambda _: P(axes), GraphStore(
        **{f.name: 0 for f in dataclasses.fields(GraphStore)}))
    out_specs = {"failed_q": P(), "shared_q": P()}
    if terminal == "count":
        out_specs["counts"] = P()
    else:
        out_specs.update(rows_gid=P(), truncated=P(),
                         attrs={k: P() for k in select})
    if has_nearest:
        fn = jax.jit(compat.shard_map(
            body, mesh=mesh,
            in_specs=(store_specs, P(), P(), P(), P(), P()),
            out_specs=out_specs, check_vma=False))
    else:
        def body5(st, keys, valid_in, ts_q, cur_q):
            return body(st, keys, None, valid_in, ts_q, cur_q)
        fn = jax.jit(compat.shard_map(
            body5, mesh=mesh, in_specs=(store_specs, P(), P(), P(), P()),
            out_specs=out_specs, check_vma=False))
    _cache_put(key, fn)
    return fn
