"""Recovery from ObjectStore (§4) + fast restart (§5.3).

Two recovery modes, exactly the paper's semantics:

* **consistent**: rebuild from the versioned tables at the durable watermark
  t_R — the most recent *transactionally consistent* snapshot.  A partially
  replicated transaction (some entries above t_R unshipped) is excluded
  wholesale.
* **best-effort**: rebuild from the LWW tables — every vertex/edge that made
  it to durable storage, regardless of transaction boundaries, then repair
  internal consistency: an edge whose endpoint is missing is dropped (no
  dangling edges).  Always at-least-as-fresh as consistent recovery.

Fast restart: the region memory lives in a *process-external* holder (PyCo
kernel driver in the paper; a host-RAM cache object here).  A restarted
serving process re-attaches the arrays instead of re-loading from durable
storage — an order of magnitude less downtime (§5.3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.replication import TOMBSTONE, ObjectStore


# ---------------------------------------------------------------------------
# rebuild helpers
# ---------------------------------------------------------------------------

def _rebuild(db: GraphDB, vrows: dict, erows: dict, *,
             drop_dangling: bool) -> GraphDB:
    """Load logical rows through the transactional write path."""
    from repro.core.writes import CreateEdge, CreateVertex
    id2name = {vt.type_id: name
               for name, vt in db.catalog.tenants[db.tenant][db.graph]
               .vtypes.items()}
    e2name = {et.type_id: name
              for name, et in db.catalog.tenants[db.tenant][db.graph]
              .etypes.items()}

    def load(ops, chunk):
        gids = []
        for off in range(0, len(ops), chunk):
            res = db.write(ops[off:off + chunk])
            assert not res.failed
            gids += res.gids
        return gids

    v_ops, v_keys = [], []
    for (vtid, key), (val, ts) in sorted(vrows.items()):
        if val == TOMBSTONE:
            continue
        f, i = val
        name = id2name[vtid]
        vt = db.vt(name)
        attrs = {}
        for a in vt.attrs:
            attrs[a.name] = (f[a.col] if a.kind == "f32" else i[a.col])
        v_ops.append(CreateVertex(name, key, attrs))
        v_keys.append((vtid, key))
    gid_of = dict(zip(v_keys, load(v_ops, 200)))

    e_ops = []
    for ekey, (val, ts) in sorted(erows.items()):
        if val == TOMBSTONE:
            continue
        svt, sk, et, dvt, dk = ekey
        s = gid_of.get((svt, sk))
        d = gid_of.get((dvt, dk))
        if s is None or d is None:
            if drop_dangling:
                continue                  # internal consistency repair
            raise ValueError(f"dangling edge {ekey} in consistent recovery")
        # endpoints were just validated against the recovered row set —
        # the bulk-load fast path applies, like the original apply stream
        e_ops.append(CreateEdge(s, d, e2name[int(et)], check=False))
    load(e_ops, 400)
    db.run_compaction()
    db.run_index_compaction()
    return db


def _clone_schema(src_db: GraphDB, cfg: StoreConfig) -> GraphDB:
    db = GraphDB(cfg)
    meta = src_db.catalog.tenants[src_db.tenant][src_db.graph]
    for name, vt in meta.vtypes.items():
        f = [a.name for a in vt.attrs if a.kind == "f32"]
        i = [a.name for a in vt.attrs if a.kind == "i32"]
        db.vertex_type(name, f, i)
    for name in meta.etypes:
        db.edge_type(name)
    return db


def best_effort_recover(store: ObjectStore, schema_db: GraphDB,
                        cfg: StoreConfig, *, graph: str = "g") -> GraphDB:
    """LWW tables -> fresh GraphDB; dangling edges dropped (§4)."""
    db = _clone_schema(schema_db, cfg)
    vrows = {k: v for k, v in store.scan(f"{graph}.vertices").items()}
    erows = {k: v for k, v in store.scan(f"{graph}.edges").items()}
    return _rebuild(db, vrows, erows, drop_dangling=True)


def consistent_recover(store: ObjectStore, schema_db: GraphDB,
                       cfg: StoreConfig, *, graph: str = "g") -> GraphDB:
    """Versioned tables filtered at t_R -> transactionally consistent DB."""
    t_r = store.get_meta(f"{graph}.t_R", 0)
    vrows: dict = {}
    for (vt, key, ts), (val, _) in store.scan(
            f"{graph}.vertices.versions").items():
        if ts > t_r:
            continue
        cur = vrows.get((vt, key))
        if cur is None or ts >= cur[1]:
            vrows[(vt, key)] = (val, ts)
    erows: dict = {}
    for row, (val, _) in store.scan(f"{graph}.edges.versions").items():
        *ekey, ts = row
        if ts > t_r:
            continue
        ekey = tuple(ekey)
        cur = erows.get(ekey)
        if cur is None or ts >= cur[1]:
            erows[ekey] = (val, ts)
    db = _clone_schema(schema_db, cfg)
    return _rebuild(db, vrows, erows, drop_dangling=False)


# ---------------------------------------------------------------------------
# fast restart (§5.3)
# ---------------------------------------------------------------------------

def _wire_db(s: dict, store) -> GraphDB:
    """Wire a fresh GraphDB around an already-materialized store tree plus
    the held coordinator metadata (the common core of :meth:`restart` and
    :func:`attach_shared`)."""
    db = GraphDB.__new__(GraphDB)
    db.cfg = s["cfg"]
    db.caps = __import__("repro.core.txn", fromlist=["BatchCaps"]
                         ).BatchCaps()
    db.store = store
    db.catalog = s["catalog"]
    db.tenant, db.graph = "default", "g"
    db.clock = s["clock"]
    db.v_next = s["v_next"].copy()
    db.v_free = [list(x) for x in s["v_free"]]
    db._rr = 0
    db.dl_count = s["dl_count"].copy()
    db.il_count = s["il_count"].copy()
    db.xd_count = s["xd_count"].copy()
    # the vector-index slots live inside the held store tree; only the
    # host-side mirrors need re-attaching (pre-vindex holds lack them)
    db.vx_count = s.get("vx_count", np.zeros(db.cfg.n_shards, np.int64)).copy()
    db._vindexed = set(s.get("vindexed", ()))
    db._vx_pos = dict(s.get("vx_pos", {}))
    db.replication_log = None
    db.stats = {"commits": 0, "aborts": 0, "compactions": 0,
                "write_waves": 0, "bg_compactions": 0,
                "compaction_rebuilds": 0, "vindex_compactions": 0}
    db.active_query_ts = []
    db.epochs = {"delete_e": 0, "delete_v": 0,
                 "compact_edges": 0, "compact_index": 0}
    db.task_queue = None
    db.compaction_watermark = 0.5
    db._bg_compaction_pending = False
    db.faults = None
    db.backend = None
    # fleet replication state (`.get`: pre-membership holds lack these)
    import collections
    db.config_epoch = 0
    db.wave_seq = int(s.get("wave_seq", 0))
    db.wave_log = collections.deque(maxlen=512)
    db.wave_inbox = collections.deque()
    db.applied_rids = collections.OrderedDict(
        (k, dict(v)) for k, v in dict(s.get("applied_rids", {})).items())
    db.fleet_pins = []
    return db


def attach_shared(manifest: dict) -> GraphDB:
    """Re-attach a serving process to an :meth:`export_shared` segment.

    The worker maps the exporter's shared-memory pages (zero host copies —
    every coordinator reads the *same* CSR/index bytes) and materializes
    device arrays from the views: one ``device_put`` per field, the §5.3
    re-attach cost.  On the CPU backend the device arrays are themselves
    copies, so mutation by one worker can never corrupt a sibling — the
    shared segment is the one *host* copy of record, exactly the
    process-external PyCo region of the paper.

    The returned db's ``_shm_handle`` keeps the mapping alive for the
    db's lifetime; the exporter owns unlinking (via ``drop``)."""
    from multiprocessing import shared_memory
    # attaching does not register with the resource tracker (only the
    # creator does), so worker exit never unlinks the exporter's segment
    shm = shared_memory.SharedMemory(name=manifest["segment"])
    kw = {}
    for fname, (off, shape, dtype) in manifest["fields"].items():
        view = np.ndarray(shape, dtype=np.dtype(dtype),
                          buffer=shm.buf, offset=off)
        kw[fname] = jax.numpy.asarray(view)
    from repro.core.store import GraphStore
    db = _wire_db(manifest["meta"], GraphStore(**kw))
    db._shm_handle = shm
    return db


class FastRestartCache:
    """Process-external region holder (the PyCo analogue).

    Keeps the store arrays (as host numpy) + coordinator metadata.  A
    restarted process re-attaches in O(device_put) instead of replaying
    durable storage.  Does not survive a host power cycle — that's the
    disaster-recovery path's job, exactly as in the paper.
    """

    def __init__(self):
        self._slots: dict = {}
        self._shm: dict = {}             # name -> exported SharedMemory

    def hold(self, name: str, db: GraphDB) -> None:
        store_np = jax.tree.map(np.asarray, db.store)
        self._slots[name] = dict(
            store=store_np,
            clock=db.clock,
            v_next=db.v_next.copy(),
            v_free=[list(x) for x in db.v_free],
            dl_count=db.dl_count.copy(),
            il_count=db.il_count.copy(),
            xd_count=db.xd_count.copy(),
            vx_count=db.vx_count.copy(),
            vindexed=set(db._vindexed),
            vx_pos=dict(db._vx_pos),
            catalog=db.catalog,
            cfg=db.cfg,
            wave_seq=int(getattr(db, "wave_seq", 0)),
            applied_rids={k: dict(v) for k, v in
                          dict(getattr(db, "applied_rids", {})).items()},
        )

    def restart(self, name: str) -> Optional[GraphDB]:
        """Re-attach: returns a fresh GraphDB wired to the held regions."""
        s = self._slots.get(name)
        if s is None:
            return None                  # regions lost -> disaster recovery
        return _wire_db(s, jax.tree.map(jax.numpy.asarray, s["store"]))

    def export_shared(self, name: str) -> dict:
        """Publish a held slot as ONE POSIX shared-memory segment.

        This is the cluster front's store seam: the exporting frontend
        keeps the single host copy of the CSR/index arrays; every
        coordinator worker :func:`attach_shared`-maps the same pages and
        pays only its own device transfer — N workers never hold N host
        copies of the graph.  Returns a picklable manifest (segment name +
        per-field offset/shape/dtype + the coordinator metadata) that
        travels to spawned workers as a plain argument.  The segment lives
        until :meth:`drop` (or exporter exit) unlinks it."""
        from multiprocessing import shared_memory
        s = self._slots[name]
        if name in self._shm:
            raise ValueError(f"slot {name!r} already exported")
        store = s["store"]
        arrs = {f.name: np.ascontiguousarray(getattr(store, f.name))
                for f in dataclasses.fields(store)}
        fields, off = {}, 0
        for fname, a in arrs.items():
            off = (off + 63) & ~63                   # 64B-align each field
            fields[fname] = (off, a.shape, a.dtype.str)
            off += a.nbytes
        shm = shared_memory.SharedMemory(create=True, size=max(off, 1))
        for fname, a in arrs.items():
            o = fields[fname][0]
            np.ndarray(a.shape, dtype=a.dtype, buffer=shm.buf,
                       offset=o)[...] = a
        self._shm[name] = shm
        meta = {k: v for k, v in s.items() if k != "store"}
        return {"segment": shm.name, "fields": fields, "meta": meta}

    def drop(self, name: str) -> None:
        self._slots.pop(name, None)
        shm = self._shm.pop(name, None)
        if shm is not None:
            shm.close()
            shm.unlink()
