"""Replication to durable storage (§4): replication log + ObjectStore.

Faithful reproduction of the paper's pipeline:

  * every committed update transactionally appends a *logical* entry to the
    replication log (vertices as (vtype, key) -> columns, edges as endpoint
    keys — physical gids don't survive recovery, logical identities do);
  * the log is shipped to ObjectStore synchronously with the request; on
    failure an asynchronous *sweeper* flushes FIFO (§4 "replication sweeper");
  * ObjectStore holds two tables per graph (vertices, edges) in both
    encodings at once:
      - best-effort: last-writer-wins rows keyed by identity, with
        timestamped tombstones (GC'd after a retention window);
      - consistent: versioned rows keyed (identity, ts), plus the t_R
        watermark — "all writes below t_R are durable";
  * shipping is idempotent (both encodings tolerate replay, §4).

ObjectStore persistence is an append-only msgpack WAL per table; load()
replays.  Failure injection (``fail_next``) lets tests cut the pipeline
mid-transaction to reproduce the paper's partial-replication scenarios.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Optional

import msgpack
import numpy as np

from repro.core import faults as faults_mod

TOMBSTONE = "__tombstone__"


class Fenced(IOError):
    """A deposed primary's log tried to advance durable state: the
    ObjectStore's configuration-epoch meta is newer than the log's.  The
    §4 epoch fence — nothing ships, the sweep raises, and the (already
    locally committed but never acknowledged) writes die with the old
    primary instead of split-braining the durable copy."""


class ObjectStore:
    """Durable KV tables with timestamp-conditional upsert (Bing ObjectStore

    analogue).  Keys/values are msgpack-serializable."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.tables: dict[str, dict] = {}
        self.meta: dict = {}
        self._fail = 0
        self._lock = threading.Lock()
        if path:
            os.makedirs(path, exist_ok=True)
            self._load()

    # -- failure injection (tests / chaos) -----------------------------------
    def fail_next(self, n: int = 1) -> None:
        self._fail = n

    def _maybe_fail(self) -> None:
        if self._fail > 0:
            self._fail -= 1
            raise IOError("objectstore write failed (injected)")

    # -- persistence -----------------------------------------------------------
    def _wal(self, table: str):
        return os.path.join(self.path, f"{table}.wal") if self.path else None

    def _append_wal(self, table: str, record) -> None:
        wal = self._wal(table)
        if wal:
            with open(wal, "ab") as f:
                f.write(msgpack.packb(record, use_bin_type=True))

    def _load(self) -> None:
        for fn in os.listdir(self.path):
            if not fn.endswith(".wal") or fn == "meta.wal":
                continue
            table = fn[:-4]
            t = self.tables.setdefault(table, {})
            with open(os.path.join(self.path, fn), "rb") as f:
                unp = msgpack.Unpacker(f, raw=False, strict_map_key=False)
                for key, value, ts in unp:
                    self._apply(t, tuple(key), value, ts)
        metaf = os.path.join(self.path, "meta.wal")
        if os.path.exists(metaf):
            with open(metaf, "rb") as f:
                unp = msgpack.Unpacker(f, raw=False)
                for k, v in unp:
                    self.meta[k] = v

    # -- the single-roundtrip conditional upsert (§4) ------------------------
    @staticmethod
    def _apply(table: dict, key: tuple, value, ts: int) -> None:
        cur = table.get(key)
        if cur is None or ts >= cur[1]:
            table[key] = (value, ts)

    def upsert(self, table: str, key: tuple, value, ts: int) -> None:
        """LWW upsert: newer timestamp wins; idempotent on replay."""
        with self._lock:
            self._maybe_fail()
            t = self.tables.setdefault(table, {})
            self._apply(t, key, value, ts)
            self._append_wal(table, [list(key), value, ts])

    def put_meta(self, key: str, value) -> None:
        with self._lock:
            self.meta[key] = value
            if self.path:
                with open(os.path.join(self.path, "meta.wal"), "ab") as f:
                    f.write(msgpack.packb([key, value], use_bin_type=True))

    def get_meta(self, key: str, default=None):
        return self.meta.get(key, default)

    def scan(self, table: str):
        return dict(self.tables.get(table, {}))

    def gc_tombstones(self, table: str, older_than_ts: int) -> int:
        """Offline tombstone GC (the paper's week-old cleanup)."""
        t = self.tables.get(table, {})
        dead = [k for k, (v, ts) in t.items()
                if v == TOMBSTONE and ts < older_than_ts]
        for k in dead:
            del t[k]
        return len(dead)


@dataclasses.dataclass
class LogEntry:
    ts: int
    kind: str     # 'v_upsert' | 'v_delete' | 'e_insert' | 'e_delete' | 'wave'
    key: tuple    # logical identity ('wave': the (seq,) singleton)
    value: Any = None


class ReplicationLog:
    """The FaRM-resident replication log + sweeper (§4).

    ``ship_waves=True`` (the cluster frontend's durable log) additionally
    ships every committed *wave record* into a ``{graph}.waves`` table
    with a ``{graph}.wave_frontier`` meta — the WAL tail a failover reads
    back to bring a promoted replica to the commit frontier.  ``epoch``
    arms the durable fence: a sweep whose epoch is older than the
    ObjectStore's ``{graph}.epoch`` meta raises :class:`Fenced`."""

    def __init__(self, objectstore: ObjectStore, *, graph: str = "g",
                 ship_waves: bool = False):
        self.os = objectstore
        self.graph = graph
        self.entries: list[LogEntry] = []    # FIFO, unshipped
        self.db = None                       # backref set by GraphDB owner
        self.shipped_ts = 0                  # durable t_R (never ahead)
        self.ship_waves = bool(ship_waves)
        self.epoch: Optional[int] = None     # config epoch (None = unfenced)
        self.faults = None                   # injector for db-less logs
        self._max_ts = 0                     # highest ts ever appended
        self._max_seq = 0                    # highest wave seq ever appended

    # -- called transactionally with each commit wave (writes.commit_wave) ---
    def append_wave(self, rec: dict) -> None:
        """Enqueue one committed wave record's logical entries (+ the wave
        record itself when this log ships waves), then attempt the §4
        synchronous ship; failures leave entries for the sweeper.

        The record already carries the logical identities (resolved at
        commit time by ``writes.wave_record``), so this path needs no
        ``db`` backref — the cluster frontend runs one of these logs with
        nothing but an ObjectStore behind it."""
        ts = int(rec["ts"])
        for tr in rec["txns"]:
            for _g, vt, key, f, i in tr["create_v"]:
                self.entries.append(LogEntry(
                    ts, "v_upsert", (int(vt), int(key)),
                    [list(f), list(i)]))
            for _g, vt, key, f, i in tr["update_v"]:
                self.entries.append(LogEntry(
                    ts, "v_upsert", (int(vt), int(key)),
                    [list(f), list(i)]))
            for _g, vt, key in tr["delete_v"]:
                self.entries.append(LogEntry(
                    ts, "v_delete", (int(vt), int(key))))
            for _s, _d, et, svt, sk, dvt, dk in tr["create_e"]:
                self.entries.append(LogEntry(
                    ts, "e_insert",
                    (int(svt), int(sk), int(et), int(dvt), int(dk))))
            for _s, _d, et, svt, sk, dvt, dk in tr["delete_e"]:
                self.entries.append(LogEntry(
                    ts, "e_delete",
                    (int(svt), int(sk), int(et), int(dvt), int(dk))))
        if self.ship_waves:
            self.entries.append(LogEntry(ts, "wave", (int(rec["seq"]),),
                                         rec))
            self._max_seq = max(self._max_seq, int(rec["seq"]))
        self._max_ts = max(self._max_ts, ts)
        try:
            self.sweep()
        except IOError:
            pass

    def append(self, ts: int, winners) -> None:
        """Back-compat txn-list entry point (pre-wave-record callers)."""
        assert self.db is not None, "attach with log.db = db"
        from repro.core import writes as writes_mod
        self.append_wave(writes_mod.wave_record(self.db, winners, ts, 0))

    # -- shipping --------------------------------------------------------------
    def _ship_one(self, e: LogEntry) -> None:
        g = self.graph
        if e.kind == "v_upsert":
            self.os.upsert(f"{g}.vertices", e.key, e.value, e.ts)
            self.os.upsert(f"{g}.vertices.versions", (*e.key, e.ts),
                           e.value, e.ts)
        elif e.kind == "v_delete":
            self.os.upsert(f"{g}.vertices", e.key, TOMBSTONE, e.ts)
            self.os.upsert(f"{g}.vertices.versions", (*e.key, e.ts),
                           TOMBSTONE, e.ts)
        elif e.kind == "e_insert":
            self.os.upsert(f"{g}.edges", e.key, True, e.ts)
            self.os.upsert(f"{g}.edges.versions", (*e.key, e.ts), True, e.ts)
        elif e.kind == "e_delete":
            self.os.upsert(f"{g}.edges", e.key, TOMBSTONE, e.ts)
            self.os.upsert(f"{g}.edges.versions", (*e.key, e.ts), TOMBSTONE,
                           e.ts)
        elif e.kind == "wave":
            self.os.upsert(f"{g}.waves", e.key, e.value, e.ts)

    def sweep(self, budget: Optional[int] = None) -> int:
        """Flush unshipped entries FIFO (the async sweeper).  Returns the
        number shipped.

        Watermark discipline (the crash-between contract): ``shipped_ts``
        and the durable ``t_R`` / ``wave_frontier`` metas advance only to
        the frontier that is *actually durable* — computed from what
        remains unshipped after this batch, inside a ``finally`` so a
        mid-batch failure (``ObjectStore.fail_next``, an injected
        ``replication.ship.drop``) can never leave a watermark ahead of
        the rows the store holds.  Advancement is monotonic: a fresh log
        over a store with history (the failover case) never regresses the
        durable watermark either."""
        if self.epoch is not None:
            cur = self.os.get_meta(f"{self.graph}.epoch")
            if cur is not None and int(cur) > int(self.epoch):
                raise Fenced(
                    f"epoch {self.epoch} fenced by durable epoch {cur}")
        owner = self.db if self.db is not None else self
        shipped = 0
        try:
            if faults_mod.check(owner, "replication.ship.drop"):
                raise IOError("replication ship dropped (injected)")
            while self.entries and (budget is None or shipped < budget):
                e = self.entries[0]
                self._ship_one(e)      # raises on (injected) failure
                self.entries.pop(0)
                shipped += 1
        finally:
            self._advance_watermarks()
        return shipped

    def _advance_watermarks(self) -> None:
        # t_R: all writes <= t_R are durable.  Any unshipped entry at ts
        # caps it at ts-1 (FIFO: everything older already shipped whole).
        oldest = self.entries[0].ts if self.entries else None
        t_r = (oldest - 1) if oldest is not None else self._max_ts
        t_r = max(t_r, self.shipped_ts,
                  int(self.os.get_meta(f"{self.graph}.t_R", 0)))
        self.shipped_ts = t_r
        self.os.put_meta(f"{self.graph}.t_R", int(t_r))
        if self.ship_waves:
            pend = [e.key[0] for e in self.entries if e.kind == "wave"]
            frontier = (min(pend) - 1) if pend else self._max_seq
            frontier = max(frontier, int(self.os.get_meta(
                f"{self.graph}.wave_frontier", 0)))
            self.os.put_meta(f"{self.graph}.wave_frontier", int(frontier))

    def lag(self) -> int:
        return len(self.entries)


def sweeper_task(log: ReplicationLog, *, budget: int = 128):
    """Task-framework wrapper: reschedules itself while the log is nonempty

    (the paper's low-priority background sweeper)."""
    from repro.core.tasks import Task

    def run(db, task):
        try:
            log.sweep(budget)
        except IOError:
            pass
        return [task] if log.lag() else []

    return Task("replication-sweeper", run, priority=20)
