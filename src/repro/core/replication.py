"""Replication to durable storage (§4): replication log + ObjectStore.

Faithful reproduction of the paper's pipeline:

  * every committed update transactionally appends a *logical* entry to the
    replication log (vertices as (vtype, key) -> columns, edges as endpoint
    keys — physical gids don't survive recovery, logical identities do);
  * the log is shipped to ObjectStore synchronously with the request; on
    failure an asynchronous *sweeper* flushes FIFO (§4 "replication sweeper");
  * ObjectStore holds two tables per graph (vertices, edges) in both
    encodings at once:
      - best-effort: last-writer-wins rows keyed by identity, with
        timestamped tombstones (GC'd after a retention window);
      - consistent: versioned rows keyed (identity, ts), plus the t_R
        watermark — "all writes below t_R are durable";
  * shipping is idempotent (both encodings tolerate replay, §4).

ObjectStore persistence is an append-only msgpack WAL per table; load()
replays.  Failure injection (``fail_next``) lets tests cut the pipeline
mid-transaction to reproduce the paper's partial-replication scenarios.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Optional

import msgpack
import numpy as np

TOMBSTONE = "__tombstone__"


class ObjectStore:
    """Durable KV tables with timestamp-conditional upsert (Bing ObjectStore

    analogue).  Keys/values are msgpack-serializable."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.tables: dict[str, dict] = {}
        self.meta: dict = {}
        self._fail = 0
        self._lock = threading.Lock()
        if path:
            os.makedirs(path, exist_ok=True)
            self._load()

    # -- failure injection (tests / chaos) -----------------------------------
    def fail_next(self, n: int = 1) -> None:
        self._fail = n

    def _maybe_fail(self) -> None:
        if self._fail > 0:
            self._fail -= 1
            raise IOError("objectstore write failed (injected)")

    # -- persistence -----------------------------------------------------------
    def _wal(self, table: str):
        return os.path.join(self.path, f"{table}.wal") if self.path else None

    def _append_wal(self, table: str, record) -> None:
        wal = self._wal(table)
        if wal:
            with open(wal, "ab") as f:
                f.write(msgpack.packb(record, use_bin_type=True))

    def _load(self) -> None:
        for fn in os.listdir(self.path):
            if not fn.endswith(".wal") or fn == "meta.wal":
                continue
            table = fn[:-4]
            t = self.tables.setdefault(table, {})
            with open(os.path.join(self.path, fn), "rb") as f:
                unp = msgpack.Unpacker(f, raw=False, strict_map_key=False)
                for key, value, ts in unp:
                    self._apply(t, tuple(key), value, ts)
        metaf = os.path.join(self.path, "meta.wal")
        if os.path.exists(metaf):
            with open(metaf, "rb") as f:
                unp = msgpack.Unpacker(f, raw=False)
                for k, v in unp:
                    self.meta[k] = v

    # -- the single-roundtrip conditional upsert (§4) ------------------------
    @staticmethod
    def _apply(table: dict, key: tuple, value, ts: int) -> None:
        cur = table.get(key)
        if cur is None or ts >= cur[1]:
            table[key] = (value, ts)

    def upsert(self, table: str, key: tuple, value, ts: int) -> None:
        """LWW upsert: newer timestamp wins; idempotent on replay."""
        with self._lock:
            self._maybe_fail()
            t = self.tables.setdefault(table, {})
            self._apply(t, key, value, ts)
            self._append_wal(table, [list(key), value, ts])

    def put_meta(self, key: str, value) -> None:
        with self._lock:
            self.meta[key] = value
            if self.path:
                with open(os.path.join(self.path, "meta.wal"), "ab") as f:
                    f.write(msgpack.packb([key, value], use_bin_type=True))

    def get_meta(self, key: str, default=None):
        return self.meta.get(key, default)

    def scan(self, table: str):
        return dict(self.tables.get(table, {}))

    def gc_tombstones(self, table: str, older_than_ts: int) -> int:
        """Offline tombstone GC (the paper's week-old cleanup)."""
        t = self.tables.get(table, {})
        dead = [k for k, (v, ts) in t.items()
                if v == TOMBSTONE and ts < older_than_ts]
        for k in dead:
            del t[k]
        return len(dead)


@dataclasses.dataclass
class LogEntry:
    ts: int
    kind: str          # 'v_upsert' | 'v_delete' | 'e_insert' | 'e_delete'
    key: tuple         # logical identity
    value: Any = None


class ReplicationLog:
    """The FaRM-resident replication log + sweeper (§4)."""

    def __init__(self, objectstore: ObjectStore, *, graph: str = "g"):
        self.os = objectstore
        self.graph = graph
        self.entries: list[LogEntry] = []    # FIFO, unshipped
        self.db = None                       # backref set by GraphDB owner
        self.shipped_ts = 0                  # t_R candidate

    # -- called transactionally with each commit wave (writes.commit_wave) ---
    def append(self, ts: int, winners) -> None:
        assert self.db is not None, "attach with log.db = db"
        db = self.db
        for t in winners:
            for gid, vtype, key, f, i in t.create_v:
                self.entries.append(LogEntry(
                    ts, "v_upsert", (int(vtype), int(key)),
                    [np.asarray(f).tolist(), np.asarray(i).tolist()]))
            for gid, f, i in t.update_v:
                vt, key, _ = db._read_header_host(gid, ts)
                self.entries.append(LogEntry(
                    ts, "v_upsert", (int(vt), int(key)),
                    [np.asarray(f).tolist(), np.asarray(i).tolist()]))
            for gid, vtype, key in t.delete_v:
                self.entries.append(LogEntry(
                    ts, "v_delete", (int(vtype), int(key))))
            for src, dst, et in t.create_e:
                sk = self._ident(src, ts)
                dk = self._ident(dst, ts)
                self.entries.append(LogEntry(
                    ts, "e_insert", (*sk, int(et), *dk)))
            for src, dst, et in t.delete_e:
                sk = self._ident(src, ts)
                dk = self._ident(dst, ts)
                self.entries.append(LogEntry(
                    ts, "e_delete", (*sk, int(et), *dk)))
        # synchronous ship attempt (§4: "synchronously with the customer
        # request"); failures leave entries for the sweeper
        try:
            self.sweep()
        except IOError:
            pass

    def _ident(self, gid: int, ts: int) -> tuple:
        vt, key, alive = self.db._read_header_host(gid, ts)
        if not alive:     # deleted in the same batch: read pre-delete state
            vt, key, _ = self.db._read_header_host(gid, ts - 1)
        return (int(vt), int(key))

    # -- shipping --------------------------------------------------------------
    def _ship_one(self, e: LogEntry) -> None:
        g = self.graph
        if e.kind == "v_upsert":
            self.os.upsert(f"{g}.vertices", e.key, e.value, e.ts)
            self.os.upsert(f"{g}.vertices.versions", (*e.key, e.ts),
                           e.value, e.ts)
        elif e.kind == "v_delete":
            self.os.upsert(f"{g}.vertices", e.key, TOMBSTONE, e.ts)
            self.os.upsert(f"{g}.vertices.versions", (*e.key, e.ts),
                           TOMBSTONE, e.ts)
        elif e.kind == "e_insert":
            self.os.upsert(f"{g}.edges", e.key, True, e.ts)
            self.os.upsert(f"{g}.edges.versions", (*e.key, e.ts), True, e.ts)
        elif e.kind == "e_delete":
            self.os.upsert(f"{g}.edges", e.key, TOMBSTONE, e.ts)
            self.os.upsert(f"{g}.edges.versions", (*e.key, e.ts), TOMBSTONE,
                           e.ts)

    def sweep(self, budget: Optional[int] = None) -> int:
        """Flush unshipped entries FIFO (the async sweeper).  Returns the

        number shipped.  Updates the durable t_R watermark."""
        shipped = 0
        while self.entries and (budget is None or shipped < budget):
            e = self.entries[0]
            self._ship_one(e)          # raises on (injected) failure
            self.entries.pop(0)
            shipped += 1
            self.shipped_ts = max(self.shipped_ts, e.ts)
        # t_R: all writes <= t_R are durable iff the log has no older entry
        oldest_unshipped = self.entries[0].ts if self.entries else None
        t_r = (oldest_unshipped - 1 if oldest_unshipped is not None
               else self.shipped_ts)
        self.os.put_meta(f"{self.graph}.t_R", int(t_r))
        return shipped

    def lag(self) -> int:
        return len(self.entries)


def sweeper_task(log: ReplicationLog, *, budget: int = 128):
    """Task-framework wrapper: reschedules itself while the log is nonempty

    (the paper's low-priority background sweeper)."""
    from repro.core.tasks import Task

    def run(db, task):
        try:
            log.sweep(budget)
        except IOError:
            pass
        return [task] if log.lag() else []

    return Task("replication-sweeper", run, priority=20)
