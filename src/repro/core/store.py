"""GraphStore: the sharded in-memory graph storage (FaRM + A1 layout, §2-3).

Layout decisions mirror the paper:

* A vertex is a *header* (type, key, MVCC timestamps, degree bookkeeping) plus
  schematized *data* columns.  Header and data live in the same shard — the
  paper's locality between header/data/edge-list within one region is
  structural here: everything keyed by the vertex's local slot.
* Edges are *half-edges* stored on both endpoints (outgoing CSR on the source
  shard, incoming CSR on the destination shard), so vertex deletion can always
  find and retire the opposite half (no dangling edges, §3.2).
* The two-tier edge list (inline array -> global BTree) becomes a two-tier
  TPU structure: a compacted CSR pool (tier 1, bulk of the data, sorted by
  (slot, etype, dst)) plus an append-only *delta log* (tier 2) absorbing
  recent mutations.  An asynchronous compaction task merges delta -> CSR,
  mirroring A1's asynchronous workflows and geometric edge-list growth.
* Every record carries (create_ts, delete_ts] MVCC interval timestamps from
  the FaRMv2 global clock; snapshot reads at ``read_ts`` see a record iff
  ``create_ts <= read_ts < delete_ts``.  Data updates keep a cur/prev version
  pair (FaRMv2 keeps old versions until readers drain; two versions bound the
  in-flight snapshot window, see DESIGN.md §2).

All arrays are flat and shard-major: row ``shard * cap + slot`` so that a
``PartitionSpec(('data','model'))`` on axis 0 puts each shard's block on one
device, and inside ``shard_map`` each device sees exactly its local block.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.addressing import NULL, TS_INF, StoreConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphStore:
    """Device-resident graph storage.  A pure pytree of arrays."""

    # -- vertex headers -----------------------------------------------------
    vtype: jax.Array      # (S*cap_v,)  i32, NULL = empty slot
    vkey: jax.Array       # (S*cap_v,)  i32 primary key
    v_create: jax.Array   # (S*cap_v,)  i32 MVCC create ts
    v_delete: jax.Array   # (S*cap_v,)  i32 MVCC delete ts (TS_INF = live)
    v_edgever: jax.Array  # (S*cap_v,)  i32 edge-list object version (FaRM
                          #             versions the edge list separately)
    # -- vertex data (schematized columns, Bond analogue) --------------------
    vdata_f: jax.Array    # (S*cap_v, d_f32) f32  current version
    vdata_i: jax.Array    # (S*cap_v, d_i32) i32  current version
    vdata_ts: jax.Array   # (S*cap_v,)  i32 ts of current data version
    vprev_f: jax.Array    # (S*cap_v, d_f32) f32  previous version
    vprev_i: jax.Array    # (S*cap_v, d_i32) i32  previous version
    vprev_ts: jax.Array   # (S*cap_v,)  i32 ts of previous data version
    # -- outgoing half-edges: compacted CSR (tier 1) -------------------------
    oe_indptr: jax.Array  # (S*(cap_v+1),) i32 per-shard CSR offsets into pool
    oe_dst: jax.Array     # (S*cap_e,) i32 destination gid
    oe_type: jax.Array    # (S*cap_e,) i32 edge type
    oe_create: jax.Array  # (S*cap_e,) i32
    oe_delete: jax.Array  # (S*cap_e,) i32
    oe_data: jax.Array    # (S*cap_e, d_ef32) f32 edge attributes
    # -- incoming half-edges: compacted CSR (tier 1) -------------------------
    ie_indptr: jax.Array  # (S*(cap_v+1),) i32
    ie_src: jax.Array     # (S*cap_e,) i32 source gid
    ie_type: jax.Array    # (S*cap_e,) i32
    ie_create: jax.Array  # (S*cap_e,) i32
    ie_delete: jax.Array  # (S*cap_e,) i32
    # -- edge delta logs (tier 2, append-only until compaction) --------------
    dl_slot: jax.Array    # (S*cap_delta,) i32 local src slot (out log)
    dl_nbr: jax.Array     # (S*cap_delta,) i32 neighbor gid
    dl_type: jax.Array    # (S*cap_delta,) i32
    dl_create: jax.Array  # (S*cap_delta,) i32 MVCC create ts
    dl_delete: jax.Array  # (S*cap_delta,) i32 MVCC delete ts (TS_INF live)
    dl_count: jax.Array   # (S,) i32 entries used per shard
    il_slot: jax.Array    # (S*cap_delta,) i32 local dst slot (in log)
    il_nbr: jax.Array     # (S*cap_delta,) i32 source gid
    il_type: jax.Array    # (S*cap_delta,) i32
    il_create: jax.Array  # (S*cap_delta,) i32
    il_delete: jax.Array  # (S*cap_delta,) i32
    il_count: jax.Array   # (S,) i32
    # -- primary index: sorted (vtype, key) -> gid per shard (BTree analogue)
    ix_vtype: jax.Array   # (S*cap_idx,) i32 sorted lexicographically
    ix_key: jax.Array     # (S*cap_idx,) i32
    ix_gid: jax.Array     # (S*cap_idx,) i32
    ix_create: jax.Array  # (S*cap_idx,) i32
    ix_delete: jax.Array  # (S*cap_idx,) i32
    ix_count: jax.Array   # (S,) i32
    # -- primary index delta --------------------------------------------------
    xd_vtype: jax.Array   # (S*cap_idx_delta,) i32
    xd_key: jax.Array     # (S*cap_idx_delta,) i32
    xd_gid: jax.Array     # (S*cap_idx_delta,) i32
    xd_create: jax.Array  # (S*cap_idx_delta,) i32
    xd_delete: jax.Array  # (S*cap_idx_delta,) i32
    xd_count: jax.Array   # (S,) i32
    # -- vector index: flat per-type embedding entries (core/vindex.py) ------
    vx_gid: jax.Array     # (S*cap_vec,) i32 entry's vertex gid (NULL = empty)
    vx_vtype: jax.Array   # (S*cap_vec,) i32 entry's vertex type
    vx_create: jax.Array  # (S*cap_vec,) i32 MVCC create ts
    vx_delete: jax.Array  # (S*cap_vec,) i32 MVCC delete ts (TS_INF = live)
    vx_emb: jax.Array     # (S*cap_vec, d_f32) f32 embedding payload
    vx_count: jax.Array   # (S,) i32 entries used per shard (prefix fill)

    def nbytes(self) -> int:
        return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(self))


def _full(shape, fill, dtype=jnp.int32):
    return jnp.full(shape, fill, dtype=dtype)


def make_store(cfg: StoreConfig) -> GraphStore:
    """Allocate an empty store (all device arrays)."""
    S = cfg.n_shards
    V, E, D, X, XD = (S * cfg.cap_v, S * cfg.cap_e, S * cfg.cap_delta,
                      S * cfg.cap_idx, S * cfg.cap_idx_delta)
    VX = S * cfg.cap_vec
    P = S * (cfg.cap_v + 1)
    return GraphStore(
        vtype=_full(V, NULL), vkey=_full(V, 0),
        v_create=_full(V, TS_INF), v_delete=_full(V, TS_INF),
        v_edgever=_full(V, 0),
        vdata_f=jnp.zeros((V, cfg.d_f32), jnp.float32),
        vdata_i=jnp.zeros((V, cfg.d_i32), jnp.int32),
        vdata_ts=_full(V, 0),
        vprev_f=jnp.zeros((V, cfg.d_f32), jnp.float32),
        vprev_i=jnp.zeros((V, cfg.d_i32), jnp.int32),
        vprev_ts=_full(V, 0),
        oe_indptr=_full(P, 0), oe_dst=_full(E, NULL), oe_type=_full(E, NULL),
        oe_create=_full(E, TS_INF), oe_delete=_full(E, TS_INF),
        oe_data=jnp.zeros((E, cfg.d_ef32), jnp.float32),
        ie_indptr=_full(P, 0), ie_src=_full(E, NULL), ie_type=_full(E, NULL),
        ie_create=_full(E, TS_INF), ie_delete=_full(E, TS_INF),
        dl_slot=_full(D, NULL), dl_nbr=_full(D, NULL), dl_type=_full(D, NULL),
        dl_create=_full(D, TS_INF), dl_delete=_full(D, TS_INF), dl_count=_full(S, 0),
        il_slot=_full(D, NULL), il_nbr=_full(D, NULL), il_type=_full(D, NULL),
        il_create=_full(D, TS_INF), il_delete=_full(D, TS_INF), il_count=_full(S, 0),
        ix_vtype=_full(X, TS_INF), ix_key=_full(X, TS_INF), ix_gid=_full(X, NULL),
        ix_create=_full(X, TS_INF), ix_delete=_full(X, TS_INF), ix_count=_full(S, 0),
        xd_vtype=_full(XD, TS_INF), xd_key=_full(XD, TS_INF), xd_gid=_full(XD, NULL),
        xd_create=_full(XD, TS_INF), xd_delete=_full(XD, TS_INF), xd_count=_full(S, 0),
        vx_gid=_full(VX, NULL), vx_vtype=_full(VX, NULL),
        vx_create=_full(VX, TS_INF), vx_delete=_full(VX, TS_INF),
        vx_emb=jnp.zeros((VX, cfg.d_f32), jnp.float32), vx_count=_full(S, 0),
    )


def make_store_shapes(cfg: StoreConfig) -> GraphStore:
    """ShapeDtypeStruct mirror of :func:`make_store` (dry-run, no allocation)."""
    S = cfg.n_shards
    V, E, D, X, XD = (S * cfg.cap_v, S * cfg.cap_e, S * cfg.cap_delta,
                      S * cfg.cap_idx, S * cfg.cap_idx_delta)
    VX = S * cfg.cap_vec
    P = S * (cfg.cap_v + 1)
    sds = jax.ShapeDtypeStruct
    i32, f32 = jnp.int32, jnp.float32
    return GraphStore(
        vtype=sds((V,), i32), vkey=sds((V,), i32),
        v_create=sds((V,), i32), v_delete=sds((V,), i32),
        v_edgever=sds((V,), i32),
        vdata_f=sds((V, cfg.d_f32), f32), vdata_i=sds((V, cfg.d_i32), i32),
        vdata_ts=sds((V,), i32),
        vprev_f=sds((V, cfg.d_f32), f32), vprev_i=sds((V, cfg.d_i32), i32),
        vprev_ts=sds((V,), i32),
        oe_indptr=sds((P,), i32), oe_dst=sds((E,), i32), oe_type=sds((E,), i32),
        oe_create=sds((E,), i32), oe_delete=sds((E,), i32),
        oe_data=sds((E, cfg.d_ef32), f32),
        ie_indptr=sds((P,), i32), ie_src=sds((E,), i32), ie_type=sds((E,), i32),
        ie_create=sds((E,), i32), ie_delete=sds((E,), i32),
        dl_slot=sds((D,), i32), dl_nbr=sds((D,), i32), dl_type=sds((D,), i32),
        dl_create=sds((D,), i32), dl_delete=sds((D,), i32), dl_count=sds((S,), i32),
        il_slot=sds((D,), i32), il_nbr=sds((D,), i32), il_type=sds((D,), i32),
        il_create=sds((D,), i32), il_delete=sds((D,), i32), il_count=sds((S,), i32),
        ix_vtype=sds((X,), i32), ix_key=sds((X,), i32), ix_gid=sds((X,), i32),
        ix_create=sds((X,), i32), ix_delete=sds((X,), i32), ix_count=sds((S,), i32),
        xd_vtype=sds((XD,), i32), xd_key=sds((XD,), i32), xd_gid=sds((XD,), i32),
        xd_create=sds((XD,), i32), xd_delete=sds((XD,), i32), xd_count=sds((S,), i32),
        vx_gid=sds((VX,), i32), vx_vtype=sds((VX,), i32),
        vx_create=sds((VX,), i32), vx_delete=sds((VX,), i32),
        vx_emb=sds((VX, cfg.d_f32), f32), vx_count=sds((S,), i32),
    )


# ---------------------------------------------------------------------------
# Visibility & gathers (snapshot reads, §5.2)
# ---------------------------------------------------------------------------

def visible(create_ts, delete_ts, read_ts):
    """MVCC visibility: created at-or-before the snapshot, not yet deleted."""
    return (create_ts <= read_ts) & (read_ts < delete_ts)


def window_shard_major(arrs, S: int, cap: int, W: int):
    """Slice shard-major ``(S*cap,)`` delta arrays to their ``(S*W,)``
    fill-window prefix.

    All delta logs (edge ``dl_*``/``il_*``, index ``xd_*``) fill
    prefix-first per shard with exact host count mirrors, so scanning
    ``[:W]`` of each shard block sees every live entry — the invariant
    behind ``planner.delta_window`` / ``planner.index_window``."""
    return tuple(a.reshape(S, cap)[:, :W].reshape(-1) for a in arrs)


def gather_headers(store: GraphStore, cfg: StoreConfig, gids, read_ts):
    """Read vertex headers for an array of gids at snapshot ``read_ts``.

    Returns (vtype, key, alive) with NULL/False for invalid or invisible ids.
    Equivalent of the paper's single one-sided RDMA read of a vertex header.
    """
    ok = gids >= 0
    rows = cfg.row_of_gid(jnp.where(ok, gids, 0))
    vt = store.vtype[rows]
    alive = ok & visible(store.v_create[rows], store.v_delete[rows], read_ts)
    return jnp.where(alive, vt, NULL), jnp.where(alive, store.vkey[rows], NULL), alive


def gather_data(store: GraphStore, cfg: StoreConfig, gids, read_ts):
    """Read vertex data columns at a snapshot (second RDMA read of the pair).

    Chooses the current or previous data version by timestamp.
    """
    ok = gids >= 0
    rows = cfg.row_of_gid(jnp.where(ok, gids, 0))
    use_cur = store.vdata_ts[rows] <= read_ts
    f = jnp.where(use_cur[:, None], store.vdata_f[rows], store.vprev_f[rows])
    i = jnp.where(use_cur[:, None], store.vdata_i[rows], store.vprev_i[rows])
    alive = ok & visible(store.v_create[rows], store.v_delete[rows], read_ts)
    return f * alive[:, None], i * alive[:, None], alive


def local_block(arr: jax.Array, shard: int, per_shard: int):
    """Host-side helper: slice one shard's block out of a flat array."""
    return arr[shard * per_shard:(shard + 1) * per_shard]


@partial(jax.jit, static_argnames=("cap",))
def replay_log_tail(dst, src, w, n, *, cap: int):
    """Copy each shard's log tail ``[w_s, n_s)`` from ``src`` onto ``dst``'s
    prefix ``[0, n_s - w_s)``.  Flat shard-major ``(S*cap,)`` arrays.

    The compaction-handoff primitive (§2.2 concurrent GC): ``dst`` is the
    shadow store's freshly emptied delta log, ``src`` the live log, ``w``
    the per-shard fill at shadow-build time and ``n`` the fill now.
    Entries appended while the background build ran are replayed onto the
    shadow so the merged store loses nothing; positions past the tail keep
    ``dst``'s empty-log fill, preserving the prefix-fill invariant behind
    ``planner.delta_window``.
    """
    S = w.shape[0]
    OOB = jnp.int32(2**31 - 1)
    k = jnp.arange(cap, dtype=jnp.int32)[None, :]
    base = (jnp.arange(S, dtype=jnp.int32) * cap)[:, None]
    src_pos = w[:, None] + k
    valid = src_pos < n[:, None]
    vals = src[(base + jnp.where(valid, src_pos, 0)).reshape(-1)]
    dst_rows = jnp.where(valid, base + k, OOB).reshape(-1)
    return dst.at[dst_rows].set(vals, mode="drop")
