"""Task framework: asynchronous workflows (§3.3).

A1 runs long maintenance work (DeleteGraph cascades, GC) as *tasks* on a
global FaRM-resident queue, executed by low-priority workers on any backend
machine; big tasks reschedule themselves or spawn subtasks.

Host adaptation: the queue is coordinator state (checkpointed); ``pump()`` is
the cooperative low-priority worker — the serving loop calls it between query
batches, so maintenance never preempts foreground work.  Tasks return a list
of follow-up tasks (possibly themselves) to model rescheduling/spawning.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Callable, Optional


@dataclasses.dataclass
class Task:
    """A unit of deferred work.  ``fn(db, task) -> list[Task]`` spawns more."""
    name: str
    fn: Callable
    state: dict = dataclasses.field(default_factory=dict)
    priority: int = 10          # lower = sooner; foreground never waits on it
    task_id: int = -1


class TaskQueue:
    """Global task queue + stateless worker pool (cooperative)."""

    def __init__(self, db):
        self.db = db
        self._q: list[Task] = []
        self._ids = itertools.count()
        self.completed: list[str] = []
        # serving hook: runs at the top of every pump quantum, so deadline
        # work (e.g. closing a due write wave) makes progress even when the
        # query stream is empty and nothing is queued
        self.on_pump: Optional[Callable] = None
        self.fault_restarts = 0

    def enqueue(self, task: Task) -> int:
        task.task_id = next(self._ids)
        self._q.append(task)
        self._q.sort(key=lambda t: (t.priority, t.task_id))
        return task.task_id

    def pending(self) -> int:
        return len(self._q)

    def pump(self, budget: int = 1) -> int:
        """Run up to ``budget`` tasks (one worker-thread quantum each).

        A quantum killed by an injected fault models a crashed low-priority
        worker: the queue survives, the task re-enqueues (its ``state`` dict
        carries whatever progress the quantum had checkpointed), and the
        next pump retries — the paper's workers are stateless for exactly
        this reason."""
        from repro.core.faults import InjectedFault, check
        if self.on_pump is not None:
            self.on_pump()
        ran = 0
        while self._q and ran < budget:
            task = self._q.pop(0)
            try:
                check(self.db, "tasks.quantum")
                spawned = task.fn(self.db, task) or []
            except InjectedFault:
                self.fault_restarts += 1
                self.enqueue(task)              # crashed worker: retry later
                ran += 1
                continue
            for s in spawned:
                self.enqueue(s)
            self.completed.append(task.name)
            ran += 1
        return ran

    def drain(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.pump():
                return
        raise RuntimeError("task queue did not drain")


# ---------------------------------------------------------------------------
# Standard maintenance workflows
# ---------------------------------------------------------------------------

def compaction_task() -> Task:
    def run(db, task):
        db.run_compaction()
        return []
    return Task("compact-edges", run)


def index_compaction_task() -> Task:
    def run(db, task):
        db.run_index_compaction()
        return []
    return Task("compact-index", run)


def vacuum_task() -> Task:
    def run(db, task):
        db.vacuum()
        return []
    return Task("vacuum", run)


def wave_replay_task(*, per_quantum: int = 8) -> Task:
    """Replica tail-replay (§4): drain ``db.wave_inbox`` — committed wave
    records the frontend fanned out — through ``writes.replay_wave``,
    ``per_quantum`` records per quantum, rescheduling while the inbox is
    nonempty.  High priority (replication lag is user-visible staleness;
    compaction can wait) but still cooperative: a quantum killed by
    ``tasks.quantum`` chaos re-enqueues and the frontier is exactly where
    the last applied record left it (replay is idempotent by seq)."""
    from repro.core import writes as writes_mod

    def run(db, task):
        n = 0
        while db.wave_inbox and n < per_quantum:
            writes_mod.replay_wave(db, db.wave_inbox.popleft())
            n += 1
        return [task] if db.wave_inbox else []

    return Task("wave-replay", run, priority=5)


def background_compaction_task(*, kinds=None, max_rebuilds: int = 4) -> Task:
    """Two-phase threshold-triggered compaction (§2.2 concurrent GC, §3.3).

    Pump 1 (*build*): fold the delta logs into compacted shadow CSR/index at
    ``gc_ts`` — off the commit path; foreground reads and write waves keep
    running against the live store.  Pump 2 (*handoff*): merge the shadow via
    ``GraphDB.try_handoff``, which replays the delta tail appended in
    between.  A raced structural mutation (edge/vertex delete, inline
    compaction) invalidates the shadow → rebuild, up to ``max_rebuilds``;
    after that fall back to inline stop-the-world compaction so progress is
    guaranteed.  ``kinds=None`` re-reads the fill watermarks at build time.
    """
    def run(db, task):
        st = task.state
        if "kinds" not in st:
            st["kinds"] = tuple(kinds) if kinds else tuple(db._kinds_needed())
            st["rebuilds"] = 0
        if not st["kinds"]:
            db._bg_compaction_pending = False
            return []
        if "handle" not in st:
            st["handle"] = db.begin_compaction(st["kinds"])
            return [task]                     # handoff on a later quantum
        from repro.core.faults import check
        if check(db, "tasks.compaction.handoff"):
            # chaos site ("race"): a structural mutation landed between
            # build and handoff — bump the epoch so the shadow is genuinely
            # stale and the rebuild path below is the one exercised
            db.epochs["delete_e"] += 1
        res = db.try_handoff(st.pop("handle"))
        st["kinds"] = tuple(k for k, ok in res.items() if not ok)
        if not st["kinds"]:
            db._bg_compaction_pending = False
            return []
        st["rebuilds"] += 1
        db.stats["compaction_rebuilds"] += 1
        if st["rebuilds"] >= max_rebuilds:
            if "edges" in st["kinds"]:
                db.run_compaction()
            if "index" in st["kinds"]:
                db.run_index_compaction()
            db._bg_compaction_pending = False
            return []
        return [task]                         # rebuild the raced kinds
    return Task("bg-compaction", run, priority=5)


def delete_type_task(vtype: str, *, chunk: int = 64) -> Task:
    """Delete all vertices of a type, chunk by chunk, rescheduling itself

    (the paper's DeleteType: "execute for a long time ... delete all the
    vertices, edges and indexes associated with the type")."""
    def run(db, task):
        import numpy as np
        vt = db.vt(vtype)
        vtid = vt.type_id
        vtypes = np.asarray(db.store.vtype)
        v_del = np.asarray(db.store.v_delete)
        S, cap_v = db.cfg.n_shards, db.cfg.cap_v
        from repro.core.addressing import TS_INF, gid_of
        todo = []
        for row in np.where((vtypes == vtid) & (v_del == TS_INF))[0]:
            shard, slot = int(row) // cap_v, int(row) % cap_v
            todo.append(gid_of(shard, slot, S))
            if len(todo) >= chunk:
                break
        if not todo:
            return []
        # stage each cascade in its own txn, commit the chunk as one wave;
        # intra-batch losers (shared edges) stay live and retry next quantum
        from repro.core.writes import DeleteVertex
        txns = []
        for gid in todo:
            t = db.create_transaction()
            try:
                db.write([DeleteVertex(gid)], txn=t)
            except ValueError:
                continue
            txns.append(t)
        if txns:
            db.write(txns)
        return [task]       # reschedule until no vertices remain
    return Task(f"delete-type:{vtype}", run)


def delete_graph_task(graph_mgr, tenant: str, graph: str) -> Task:
    """DeleteGraph workflow: mark Deleting, spawn per-type deletes, then

    free the graph (§3.3)."""
    def run(db, task):
        phase = task.state.setdefault("phase", "mark")
        if phase == "mark":
            meta = db.catalog.mark_deleting(tenant, graph)
            task.state["phase"] = "wait"
            spawned = [delete_type_task(name) for name in list(meta.vtypes)]
            return spawned + [task]
        # wait phase: done when no vertices remain
        import numpy as np
        from repro.core.addressing import TS_INF
        live = ((np.asarray(db.store.vtype) >= 0)
                & (np.asarray(db.store.v_delete) == TS_INF)).sum()
        if live > 0:
            return [task]
        db.run_compaction()
        db.run_index_compaction()
        db.vacuum()
        db.catalog.drop_graph(tenant, graph)
        if graph_mgr is not None:
            graph_mgr.release(tenant, graph)
        return []
    return Task(f"delete-graph:{graph}", run)
