"""Transaction engine: FaRMv2-style MVCC + optimistic concurrency (§2.1, §5.2).

Semantics reproduced from the paper:

* A global clock hands out commit timestamps; all transactions are totally
  ordered by write timestamp (used by disaster recovery, §4).
* Read-only queries run at a snapshot ``read_ts`` and never conflict with
  updates (MVCC).
* Update transactions run under OCC: they record a read set and are validated
  at commit — if any object read has been overwritten since ``read_ts``,
  the transaction aborts and the client retries (Fig. 3's retry loop).
* Opacity comes for free: state is immutable; a doomed transaction can only
  ever observe a consistent snapshot, never torn pointers.

TPU adaptation ("changed assumptions" #2 in DESIGN.md): instead of per-txn
two-phase commit we gather transactions into *commit batches*.  A batch gets
one timestamp; validation is one vectorized gather; intra-batch write-write
conflicts are resolved deterministically (first transaction wins, later ones
abort and retry).  Client-visible semantics are unchanged: strict
serializability, aborts on conflict.

All op arrays are padded to static capacities so the apply step compiles once.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as ix
from repro.core.addressing import NULL, TS_INF, StoreConfig
from repro.core.store import GraphStore


@dataclasses.dataclass(frozen=True)
class BatchCaps:
    """Static op capacities of a commit batch (compiled once per value)."""
    reads: int = 256
    create_v: int = 256
    update_v: int = 128
    delete_v: int = 64
    create_e: int = 512
    delete_e: int = 256


class Aborted(Exception):
    """Raised to the caller when a transaction loses OCC validation."""


class Transaction:
    """Client-side transaction: buffered reads + staged writes (Fig. 2 API).

    ``OpenForWrite`` buffering happens implicitly: all mutations are staged
    host-side and pushed at commit, matching FaRM's local write buffering.
    """

    __slots__ = ("read_ts", "reads", "create_v", "update_v", "delete_v",
                 "create_e", "delete_e", "status", "rid")

    def __init__(self, read_ts: int):
        self.read_ts = int(read_ts)
        self.reads: list[tuple[int, str]] = []      # (gid, kind)
        self.create_v: list[tuple] = []             # (gid, vtype, key, f, i)
        self.update_v: list[tuple] = []             # (gid, f, i)
        self.delete_v: list[int] = []               # gid
        self.create_e: list[tuple] = []             # (src, dst, etype)
        self.delete_e: list[tuple] = []             # (src, dst, etype)
        self.status = "OPEN"
        self.rid: Optional[str] = None              # client request id
        # (stamped by serving admission; committed waves record it so
        # failover replay is exactly-once per client request, §4)

    def record_read(self, gid: int) -> None:
        if gid is not None and gid >= 0:
            self.reads.append((int(gid), "v"))

    # key sets for intra-batch conflict detection ----------------------------
    # vertex object -> ("v", gid); edge-list object -> ("ev", gid): an edge
    # write touches both endpoints' edge-list objects (FaRM object model).
    def write_keys(self):
        ks = set()
        for g, *_ in self.create_v:
            ks.add(("v", g))
        for g, *_ in self.update_v:
            ks.add(("v", g))
        for g, *_ in self.delete_v:
            ks.add(("v", g))
            ks.add(("ev", g))
        for s, d, t in self.create_e:
            ks.add(("ev", s))
            ks.add(("ev", d))
        for s, d, t in self.delete_e:
            ks.add(("ev", s))
            ks.add(("ev", d))
        return ks

    def read_keys(self):
        return {("ev" if kind == "e" else "v", g) for g, kind in self.reads}


# ---------------------------------------------------------------------------
# Jitted validation
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def last_write_ts(store: GraphStore, cfg: StoreConfig, gids, kinds):
    """Latest write ts of each read object (0 if never written).

    ``kinds``: 0 = vertex header/data read, 1 = edge-list read.  FaRM versions
    the vertex object and its edge-list object separately; validating per kind
    avoids false aborts when only the unrelated object changed.
    """
    ok = gids >= 0
    rows = cfg.row_of_gid(jnp.where(ok, gids, 0))
    cre = jnp.where(store.v_create[rows] == TS_INF, 0, store.v_create[rows])
    dele = jnp.where(store.v_delete[rows] == TS_INF, 0, store.v_delete[rows])
    lw_v = jnp.maximum(jnp.maximum(cre, dele), store.vdata_ts[rows])
    lw_e = jnp.maximum(jnp.maximum(cre, dele), store.v_edgever[rows])
    return jnp.where(ok, jnp.where(kinds == 1, lw_e, lw_v), 0)


# ---------------------------------------------------------------------------
# Jitted apply
# ---------------------------------------------------------------------------

def _csr_find(indptr, typ2d, nbr2d, sh, slot, etype, dst, cap_v):
    """Binary search a CSR span (sorted by (etype, nbr)) for one edge.

    ``typ2d``/``nbr2d`` are (S, cap_e) views; returns the local pool
    position (int32, < cap_e) or -1.  32 fixed halving steps.  All indices
    stay shard-local, so paper-scale stores never overflow int32.
    """
    lo = indptr[slot]
    hi = indptr[slot + 1]

    def key_less(m, t, d):
        tm, dm = typ2d[sh, m], nbr2d[sh, m]
        return (tm < t) | ((tm == t) & (dm < d))

    def body(_, lohi):
        lo, hi = lohi
        m = (lo + hi) // 2
        go_right = key_less(m, etype, dst) & (lo < hi)
        return (jnp.where(go_right, m + 1, lo), jnp.where(go_right, hi, m))

    lo, hi = jax.lax.fori_loop(0, 32, body, (lo, hi))
    found = ((lo < indptr[slot + 1])
             & (typ2d[sh, lo] == etype) & (nbr2d[sh, lo] == dst))
    return jnp.where(found, lo, -1)


def apply_batch_impl(store: GraphStore, cfg: StoreConfig, ts,
                     # create vertices
                     cv_gid, cv_vtype, cv_key, cv_f, cv_i, cv_xpos,
                     # update vertices
                     uv_gid, uv_f, uv_i,
                     # delete vertices
                     dv_gid, dv_vtype, dv_key,
                     # create edges
                     ce_src, ce_dst, ce_type, ce_opos, ce_ipos,
                     # delete edges
                     de_src, de_dst, de_type,
                     # new per-shard log counts (host-computed)
                     new_dl_count, new_il_count, new_xd_count):
    """Apply one validated commit batch.

    All vertex/edge-pool addressing is 2D (shard, local) so paper-scale
    stores (> 2^31 global slots) never overflow int32 — the FaRM address is
    (region, offset), not a flat integer, and we keep that split on device.
    Padded slots use index = INT32_MAX and drop out of every scatter
    (negative indices WRAP in jax; only out-of-range positive drop).
    """
    S, cap_v, cap_e = cfg.n_shards, cfg.cap_v, cfg.cap_e
    drop = dict(mode="drop")
    OOB = jnp.int32(2**31 - 1)

    def v2(gid):
        """(shard, slot) with OOB padding."""
        ok = gid >= 0
        g = jnp.where(ok, gid, 0)
        return jnp.where(ok, g % S, OOB), jnp.where(ok, g // S, OOB)

    def oob(pos):
        return jnp.where(pos >= 0, pos, OOB)

    def vset(arr, sh, sl, val):
        shp = arr.shape
        a2 = arr.reshape((S, cap_v) + shp[1:])
        return a2.at[sh, sl].set(val, **drop).reshape(shp)

    def vget(arr, sh, sl):
        shp = arr.shape
        a2 = arr.reshape((S, cap_v) + shp[1:])
        return a2[jnp.where(sh == OOB, 0, sh), jnp.where(sl == OOB, 0, sl)]

    # ---- create vertices ---------------------------------------------------
    sh, sl = v2(cv_gid)
    store = dataclasses.replace(
        store,
        vtype=vset(store.vtype, sh, sl, cv_vtype),
        vkey=vset(store.vkey, sh, sl, cv_key),
        v_create=vset(store.v_create, sh, sl, ts),
        v_delete=vset(store.v_delete, sh, sl, TS_INF),
        vdata_f=vset(store.vdata_f, sh, sl, cv_f),
        vdata_i=vset(store.vdata_i, sh, sl, cv_i),
        vdata_ts=vset(store.vdata_ts, sh, sl, ts),
        vprev_f=vset(store.vprev_f, sh, sl, cv_f),
        vprev_i=vset(store.vprev_i, sh, sl, cv_i),
        vprev_ts=vset(store.vprev_ts, sh, sl, ts),
        # index delta entries (flat positions host-assigned; the delta is
        # small enough that S * cap_idx_delta stays well inside int32)
        xd_vtype=store.xd_vtype.at[oob(cv_xpos)].set(cv_vtype, **drop),
        xd_key=store.xd_key.at[oob(cv_xpos)].set(cv_key, **drop),
        xd_gid=store.xd_gid.at[oob(cv_xpos)].set(cv_gid, **drop),
        xd_create=store.xd_create.at[oob(cv_xpos)].set(ts, **drop),
        xd_delete=store.xd_delete.at[oob(cv_xpos)].set(TS_INF, **drop),
    )

    # ---- update vertex data (cur -> prev, new -> cur) ----------------------
    sh, sl = v2(uv_gid)
    store = dataclasses.replace(
        store,
        vprev_f=vset(store.vprev_f, sh, sl, vget(store.vdata_f, sh, sl)),
        vprev_i=vset(store.vprev_i, sh, sl, vget(store.vdata_i, sh, sl)),
        vprev_ts=vset(store.vprev_ts, sh, sl, vget(store.vdata_ts, sh, sl)),
        vdata_f=vset(store.vdata_f, sh, sl, uv_f),
        vdata_i=vset(store.vdata_i, sh, sl, uv_i),
        vdata_ts=vset(store.vdata_ts, sh, sl, ts),
    )

    # ---- delete vertices ----------------------------------------------------
    sh, sl = v2(dv_gid)
    cap_x, cap_xd = cfg.cap_idx, cfg.cap_idx_delta
    ix_h2 = jnp.where(store.ix_gid >= 0,
                      ix.mix32(store.ix_vtype, store.ix_key),
                      jnp.int32(2**31 - 1)).reshape(S, cap_x)
    ix_gid2 = store.ix_gid.reshape(S, cap_x)
    ix_vt2 = store.ix_vtype.reshape(S, cap_x)
    ix_key2 = store.ix_key.reshape(S, cap_x)
    ix_del2 = store.ix_delete.reshape(S, cap_x)

    def find_ix_row(g, vt, k):
        """Locate the live main-index (shard, pos) of (vt, k, g), or OOB."""
        ok = g >= 0
        ish = ix.route(vt, k, S)
        blk = jax.lax.dynamic_index_in_dim(ix_h2, ish, 0, keepdims=False)
        pos = jnp.searchsorted(blk, ix.mix32(vt, k),
                               side="left").astype(jnp.int32)
        best = jnp.int32(-1)
        for w in range(16):
            pp = jnp.minimum(pos + w, cap_x - 1)
            hit = ((ix_gid2[ish, pp] == g) & (ix_vt2[ish, pp] == vt)
                   & (ix_key2[ish, pp] == k) & (ix_del2[ish, pp] == TS_INF))
            best = jnp.where(hit & (best < 0), pp, best)
        found = ok & (best >= 0)
        return (jnp.where(found, ish, OOB), jnp.where(found, best, OOB))

    def find_xd_row(g, vt, k):
        ok = g >= 0
        ish = ix.route(vt, k, S)
        XD = store.xd_gid.shape[0]
        xsh = jnp.arange(XD, dtype=jnp.int32) // cap_xd
        m = ((store.xd_gid == g) & (store.xd_vtype == vt)
             & (store.xd_key == k) & (store.xd_delete == TS_INF)
             & (xsh == ish))
        row = jnp.argmax(m).astype(jnp.int32)
        return jnp.where(ok & m.any(), row, OOB)

    xsh, xpos = jax.vmap(find_ix_row)(dv_gid, dv_vtype, dv_key)
    xrow_delta = jax.vmap(find_xd_row)(dv_gid, dv_vtype, dv_key)
    ix_del_new = ix_del2.at[xsh, xpos].set(ts, **drop).reshape(-1)
    store = dataclasses.replace(
        store,
        v_delete=vset(store.v_delete, sh, sl, ts),
        ix_delete=ix_del_new,
        xd_delete=store.xd_delete.at[xrow_delta].set(ts, **drop),
    )

    # ---- create edges (append to both half-edge delta logs) ----------------
    src_slot = jnp.where(ce_src >= 0, ce_src // S, -1)
    dst_slot = jnp.where(ce_dst >= 0, ce_dst // S, -1)
    s_sh, s_sl = v2(ce_src)
    d_sh, d_sl = v2(ce_dst)
    ds_sh, ds_sl = v2(de_src)
    dd_sh, dd_sl = v2(de_dst)
    ev2 = store.v_edgever.reshape(S, cap_v)
    ev2 = (ev2.at[s_sh, s_sl].set(ts, **drop)
              .at[d_sh, d_sl].set(ts, **drop)
              .at[ds_sh, ds_sl].set(ts, **drop)
              .at[dd_sh, dd_sl].set(ts, **drop))
    store = dataclasses.replace(
        store,
        dl_slot=store.dl_slot.at[oob(ce_opos)].set(src_slot, **drop),
        dl_nbr=store.dl_nbr.at[oob(ce_opos)].set(ce_dst, **drop),
        dl_type=store.dl_type.at[oob(ce_opos)].set(ce_type, **drop),
        dl_create=store.dl_create.at[oob(ce_opos)].set(ts, **drop),
        dl_delete=store.dl_delete.at[oob(ce_opos)].set(TS_INF, **drop),
        il_slot=store.il_slot.at[oob(ce_ipos)].set(dst_slot, **drop),
        il_nbr=store.il_nbr.at[oob(ce_ipos)].set(ce_src, **drop),
        il_type=store.il_type.at[oob(ce_ipos)].set(ce_type, **drop),
        il_create=store.il_create.at[oob(ce_ipos)].set(ts, **drop),
        il_delete=store.il_delete.at[oob(ce_ipos)].set(TS_INF, **drop),
        dl_count=new_dl_count, il_count=new_il_count, xd_count=new_xd_count,
        v_edgever=ev2.reshape(-1),
    )

    # ---- delete edges (CSR binary search + delta tombstones) ---------------
    oe_typ2 = store.oe_type.reshape(S, cap_e)
    oe_dst2 = store.oe_dst.reshape(S, cap_e)
    ie_typ2 = store.ie_type.reshape(S, cap_e)
    ie_src2 = store.ie_src.reshape(S, cap_e)
    ip_o = store.oe_indptr.reshape(S, cap_v + 1)
    ip_i = store.ie_indptr.reshape(S, cap_v + 1)

    def find_out(s_, d, t):
        ok = s_ >= 0
        ss = jnp.where(ok, s_, 0)
        fsh, fsl = ss % S, ss // S
        pos = _csr_find(
            jax.lax.dynamic_index_in_dim(ip_o, fsh, 0, keepdims=False),
            oe_typ2, oe_dst2, fsh, fsl, t, d, cap_v)
        found = ok & (pos >= 0)
        return jnp.where(found, fsh, OOB), jnp.where(found, pos, OOB)

    def find_in(s_, d, t):
        ok = d >= 0
        dd = jnp.where(ok, d, 0)
        fsh, fsl = dd % S, dd // S
        pos = _csr_find(
            jax.lax.dynamic_index_in_dim(ip_i, fsh, 0, keepdims=False),
            ie_typ2, ie_src2, fsh, fsl, t, s_, cap_v)
        found = ok & (pos >= 0)
        return jnp.where(found, fsh, OOB), jnp.where(found, pos, OOB)

    osh, opos = jax.vmap(find_out)(de_src, de_dst, de_type)
    ish_, ipos = jax.vmap(find_in)(de_src, de_dst, de_type)

    # also tombstone matching live delta-log inserts
    def delta_match(log_slot, log_nbr, log_type, log_del, ent_gid, nbr, t):
        ok = ent_gid >= 0
        eg = jnp.where(ok, ent_gid, 0)
        msh, msl = eg % S, eg // S
        D = log_slot.shape[0]
        d_shard = jnp.arange(D, dtype=jnp.int32) // cfg.cap_delta
        m = (ok[:, None] & (log_slot[None, :] == msl[:, None])
             & (d_shard[None, :] == msh[:, None])
             & (log_nbr[None, :] == nbr[:, None])
             & (log_type[None, :] == t[:, None])
             & (log_del == TS_INF)[None, :])
        return m.any(axis=0)   # (D,) mask of entries to tombstone

    m_out = delta_match(store.dl_slot, store.dl_nbr, store.dl_type,
                        store.dl_delete, de_src, de_dst, de_type)
    m_in = delta_match(store.il_slot, store.il_nbr, store.il_type,
                       store.il_delete, de_dst, de_src, de_type)

    store = dataclasses.replace(
        store,
        oe_delete=store.oe_delete.reshape(S, cap_e)
            .at[osh, opos].set(ts, **drop).reshape(-1),
        ie_delete=store.ie_delete.reshape(S, cap_e)
            .at[ish_, ipos].set(ts, **drop).reshape(-1),
        dl_delete=jnp.where(m_out, ts, store.dl_delete),
        il_delete=jnp.where(m_in, ts, store.il_delete),
    )
    return store


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0,))
def apply_batch(store: GraphStore, cfg: StoreConfig, ts, *ops):
    """Jitted :func:`apply_batch_impl` at the fixed ``BatchCaps`` shapes.

    The write planner (core/writes.py) instead jits ``apply_batch_impl``
    per canonical op-shape bucket so small commits pay small scatters.
    """
    return apply_batch_impl(store, cfg, ts, *ops)


def pad_i32(xs, cap, fill=-1):
    a = np.full((cap,), fill, np.int32)
    n = min(len(xs), cap)
    if n:
        a[:n] = np.asarray(xs[:n], np.int32)
    return jnp.asarray(a)


def pad_f32(xs, cap, d):
    a = np.zeros((cap, d), np.float32)
    n = min(len(xs), cap)
    if n:
        a[:n] = np.asarray(xs[:n], np.float32).reshape(n, d)
    return jnp.asarray(a)


def pad_i32_2d(xs, cap, d):
    a = np.zeros((cap, d), np.int32)
    n = min(len(xs), cap)
    if n:
        a[:n] = np.asarray(xs[:n], np.int32).reshape(n, d)
    return jnp.asarray(a)
