"""Vector index: flat per-type embedding entries (the `Nearest` substrate).

A1 at Bing sat next to ranking infrastructure; the hybrid "k-NN seeds ->
multi-hop expand" workload (ROADMAP item 2) needs the vector half to live
*inside* the store so it rides the same MVCC snapshots, mutation waves, and
compaction lifecycle as everything else — the GDI argument (PAPERS.md)
against bolting on a sidecar ANN service.

Layout (``store.vx_*``): a flat shard-major ``(S*cap_vec,)`` entry pool.
Each entry is ``(gid, vtype, create_ts, delete_ts, emb)`` where ``emb`` is
the vertex's full f32 payload row at write time.  Entries live on the
vertex's owning shard (``gid % S``) and fill prefix-first per shard with an
exact host count mirror (``db.vx_count``) — the same prefix-fill invariant
as the delta logs, so the planner scans only the ``vindex_window`` prefix.

Maintenance is *versioned, not in-place* (d-HNSW's immutable segments, here
as MVCC intervals): a payload update tombstones the old entry at the wave's
``ts`` and appends a fresh one at the same ``ts``, so at any snapshot at
most one entry per gid is visible and `Nearest` at an old ``read_ts`` still
sees the old vector.  Deleted vertices age out at ``gc_ts`` when the fold
(:func:`run_compaction`) prefix-compacts each shard — wired into the PR 6
background-compaction lifecycle as the ``"vindex"`` kind.

Registration is per vertex type (``GraphDB.vector_index(name)``); vertices
alive at registration are backfilled with ``create_ts = max(v_create,
vdata_ts)``, so snapshots older than a vertex's last payload write do not
see its (backfilled) vector — the documented backfill caveat.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.addressing import NULL, TS_INF, StoreConfig
from repro.core.store import GraphStore, window_shard_major

I32MAX = 2**31 - 1


def _pow2ceil(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def _bucket(n: int) -> int:
    """Pad counts to pow2 buckets so the scatter jit-caches a few shapes."""
    return _pow2ceil(n) if n else 0


# ---------------------------------------------------------------------------
# registration + backfill
# ---------------------------------------------------------------------------

def register(db, vtype_name: str):
    """Register a vertex type for vector indexing; backfill live vertices."""
    vt = db.vt(vtype_name)
    if db.cfg.cap_vec <= 0:
        raise ValueError("vector index disabled: StoreConfig.cap_vec == 0")
    if vt.type_id in db._vindexed:
        return vt
    db._vindexed.add(vt.type_id)
    _backfill(db, vt.type_id)
    return vt


def _backfill(db, vtid: int) -> None:
    cfg = db.cfg
    vtypes = np.asarray(db.store.vtype)
    cr = np.asarray(db.store.v_create)
    dl = np.asarray(db.store.v_delete)
    dts = np.asarray(db.store.vdata_ts)
    vdf = np.asarray(db.store.vdata_f)
    now = db.clock
    rows = np.where((vtypes == vtid) & (cr <= now) & (now < dl))[0]
    appends = []
    for row in rows:
        shard, slot = int(row) // cfg.cap_v, int(row) % cfg.cap_v
        gid = slot * cfg.n_shards + shard
        pos = _alloc(db, gid)
        db._vx_pos[gid] = (pos, vtid)
        appends.append((pos, gid, vtid, int(max(cr[row], dts[row])), vdf[row]))
    _device_apply(db, appends, [], 0)


def _alloc(db, gid: int) -> int:
    """Claim the next prefix position on the gid's owning shard."""
    s = int(gid) % db.cfg.n_shards
    p = int(db.vx_count[s])
    if p >= db.cfg.cap_vec:
        from repro.core.writes import CapacityError
        raise CapacityError(f"vector index full on shard {s}")
    db.vx_count[s] = p + 1
    return s * db.cfg.cap_vec + p


# ---------------------------------------------------------------------------
# write-wave maintenance (called from writes.commit_wave per applied chunk)
# ---------------------------------------------------------------------------

def wave_demand(db, txns) -> np.ndarray:
    """Exact per-shard append demand of a winner batch (capacity backstop).

    Creates of indexed types and payload updates of indexed vertices each
    append one entry (updates additionally tombstone, which frees nothing
    until the fold).  Same-batch created-then-updated gids are tracked so
    the count stays exact across chunks.
    """
    S = db.cfg.n_shards
    need = np.zeros(S, np.int64)
    fresh: set = set()
    for t in txns:
        for gid, vtid, *_ in t.create_v:
            if vtid in db._vindexed:
                need[int(gid) % S] += 1
                fresh.add(gid)
        for gid, _f, _i in t.update_v:
            if gid in db._vx_pos or gid in fresh:
                need[int(gid) % S] += 1
    return need


def apply_wave(db, chunk, ts: int) -> None:
    """Fold one applied mutation chunk into the vector index at ``ts``.

    Runs after the chunk's store-apply program: create of an indexed type
    appends an entry; update of an indexed vertex tombstones its entry at
    ``ts`` and appends the new payload at ``ts`` (disjoint MVCC intervals —
    at most one entry per gid visible at any snapshot); delete tombstones.
    """
    if not db._vindexed:
        return
    appends = []   # (pos, gid, vtid, create_ts, emb row)
    tombs = []     # positions whose delete_ts becomes `ts`
    for t in chunk:
        for gid, vtid, _key, f, _i in t.create_v:
            if vtid in db._vindexed:
                pos = _alloc(db, gid)
                db._vx_pos[gid] = (pos, vtid)
                appends.append((pos, gid, vtid, ts, f))
        for gid, f, _i in t.update_v:
            ent = db._vx_pos.get(gid)
            if ent is not None:
                tombs.append(ent[0])
                pos = _alloc(db, gid)
                db._vx_pos[gid] = (pos, ent[1])
                appends.append((pos, gid, ent[1], ts, f))
        for gid, *_ in t.delete_v:
            ent = db._vx_pos.pop(gid, None)
            if ent is not None:
                tombs.append(ent[0])
    _device_apply(db, appends, tombs, ts)


def _device_apply(db, appends, tombs, ts: int) -> None:
    if not appends and not tombs:
        return
    d = db.cfg.d_f32
    A, T = _bucket(len(appends)), _bucket(len(tombs))
    a_pos = np.full(A, I32MAX, np.int32)
    a_gid = np.zeros(A, np.int32)
    a_vt = np.zeros(A, np.int32)
    a_ts = np.zeros(A, np.int32)
    a_emb = np.zeros((A, d), np.float32)
    for j, (pos, gid, vtid, cts, f) in enumerate(appends):
        a_pos[j], a_gid[j], a_vt[j], a_ts[j] = pos, gid, vtid, cts
        a_emb[j] = np.asarray(f, np.float32)
    t_pos = np.full(T, I32MAX, np.int32)
    for j, pos in enumerate(tombs):
        t_pos[j] = pos
    g, vt, cr, dl, emb = _scatter(
        db.store.vx_gid, db.store.vx_vtype, db.store.vx_create,
        db.store.vx_delete, db.store.vx_emb,
        jnp.asarray(a_pos), jnp.asarray(a_gid), jnp.asarray(a_vt),
        jnp.asarray(a_ts), jnp.asarray(a_emb),
        jnp.asarray(t_pos), jnp.int32(ts))
    db.store = dataclasses.replace(
        db.store, vx_gid=g, vx_vtype=vt, vx_create=cr, vx_delete=dl,
        vx_emb=emb, vx_count=jnp.asarray(db.vx_count, jnp.int32))


@jax.jit
def _scatter(vx_gid, vx_vtype, vx_create, vx_delete, vx_emb,
             a_pos, a_gid, a_vt, a_ts, a_emb, t_pos, t_ts):
    # tombstones first; append positions are fresh (disjoint), pads drop
    vx_delete = vx_delete.at[t_pos].set(t_ts, mode="drop")
    vx_gid = vx_gid.at[a_pos].set(a_gid, mode="drop")
    vx_vtype = vx_vtype.at[a_pos].set(a_vt, mode="drop")
    vx_create = vx_create.at[a_pos].set(a_ts, mode="drop")
    vx_delete = vx_delete.at[a_pos].set(TS_INF, mode="drop")
    vx_emb = vx_emb.at[a_pos].set(a_emb, mode="drop")
    return vx_gid, vx_vtype, vx_create, vx_delete, vx_emb


# ---------------------------------------------------------------------------
# compaction fold (the "vindex" kind of the background lifecycle)
# ---------------------------------------------------------------------------

def run_compaction(db) -> None:
    """Fold: drop entries dead at ``gc_ts`` (or orphaned), stable
    prefix-compact each shard, rebuild the host position map.

    Host-side numpy over the small ``vx_*`` arrays — the fold is rare
    (watermark- or backstop-triggered) and synchronous at handoff, so no
    shadow/epoch machinery is needed: entry *positions* are referenced only
    by ``db._vx_pos``, which is rebuilt here.
    """
    cfg = db.cfg
    if cfg.cap_vec <= 0:
        return
    gc = db.gc_ts()
    S, cap = cfg.n_shards, cfg.cap_vec
    g = np.asarray(db.store.vx_gid).reshape(S, cap)
    vt = np.asarray(db.store.vx_vtype).reshape(S, cap)
    cr = np.asarray(db.store.vx_create).reshape(S, cap)
    dl = np.asarray(db.store.vx_delete).reshape(S, cap)
    emb = np.asarray(db.store.vx_emb).reshape(S, cap, -1)
    ng = np.full_like(g, NULL)
    nvt = np.full_like(vt, NULL)
    ncr = np.full_like(cr, TS_INF)
    ndl = np.full_like(dl, TS_INF)
    nemb = np.zeros_like(emb)
    pos = {}
    for s in range(S):
        keep = np.where((g[s] >= 0) & (dl[s] > gc))[0]
        n = len(keep)
        ng[s, :n] = g[s, keep]
        nvt[s, :n] = vt[s, keep]
        ncr[s, :n] = cr[s, keep]
        ndl[s, :n] = dl[s, keep]
        nemb[s, :n] = emb[s, keep]
        db.vx_count[s] = n
        for j, src in enumerate(keep):
            if dl[s, src] == TS_INF:
                pos[int(g[s, src])] = (s * cap + j, int(vt[s, src]))
    db._vx_pos = pos
    db.store = dataclasses.replace(
        db.store,
        vx_gid=jnp.asarray(ng.reshape(-1)),
        vx_vtype=jnp.asarray(nvt.reshape(-1)),
        vx_create=jnp.asarray(ncr.reshape(-1)),
        vx_delete=jnp.asarray(ndl.reshape(-1)),
        vx_emb=jnp.asarray(nemb.reshape(S * cap, -1)),
        vx_count=jnp.asarray(db.vx_count, jnp.int32))
    db.stats["vindex_compactions"] += 1


# ---------------------------------------------------------------------------
# read-side windowing (planner probe wave)
# ---------------------------------------------------------------------------

def vindex_window(db) -> int:
    """Pow2 prefix window covering every live entry (static cache key)."""
    if not db._vindexed:
        return 0
    fill = int(db.vx_count.max(initial=0))
    return min(_pow2ceil(max(fill, 1)), db.cfg.cap_vec)


def window_arrays(store: GraphStore, cfg: StoreConfig, W: int):
    """Slice the vx_* pool to its ``(S*W,)`` fill-window prefix."""
    S, cap = cfg.n_shards, cfg.cap_vec
    g, vt, cr, dl = window_shard_major(
        (store.vx_gid, store.vx_vtype, store.vx_create, store.vx_delete),
        S, cap, W)
    emb = store.vx_emb.reshape(S, cap, -1)[:, :W].reshape(S * W, -1)
    return g, vt, cr, dl, emb
