"""Write path: batched mutation waves behind ``GraphDB.write()`` (§3, §2.2).

The write analogue of the read planner.  Reads got wave fusion in PRs 3-5;
this module gives mutations the same treatment:

* **Typed mutation-op records** (:class:`CreateVertex` ... :class:`DeleteEdge`)
  are the write-side IR.  ``GraphDB.write(ops)`` is the single entry point —
  the historical per-op methods (``create_vertex`` et al.) are thin staging
  wrappers over these records, and ``commit``/``commit_many`` are
  DeprecationWarning shims.  Per-op results (gid / status / abort reason)
  come back positionally in a :class:`WriteResult`, mirroring ``QueryResult``.

* **One OCC validation wave** per commit batch: every transaction's read set
  is concatenated, padded to a pow2 bucket, and validated by a single jitted
  gather (``last_write_ts`` over per-read snapshot timestamps) instead of the
  historical chunked host loop.  §3's first-wins intra-batch resolution is
  unchanged.

* **One fused apply program per mutation-shape group**: the op arrays of a
  winner chunk are padded to pow2 buckets per op kind, and the jitted
  ``apply_batch`` trace is cached on that canonical shape tuple — LRU-bounded
  with observable :data:`CACHE_STATS`, exactly like the read planner's
  program cache.  A steady write mix (e.g. the serving loop's ingest waves)
  keeps hitting one program; small commits no longer pay the full
  ``BatchCaps``-padded scatter.

* **Compaction moves off the commit path**: the wave only compacts inline as
  an overflow *backstop*; crossing the fill watermark schedules the
  two-phase background task (``tasks.background_compaction_task``), which
  builds a compacted shadow store and hands it off under the MVCC pin
  contract (see ``GraphDB.begin_compaction`` / ``try_handoff``).

Semantics are exactly the historical ``commit_many``: strict serializability,
first-wins intra-batch conflicts, per-chunk commit timestamps, replication
log appends per chunk.  ``tests/test_writes.py`` pins the bit-identity.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import txn as txn_mod
from repro.core.addressing import TS_INF


class CapacityError(RuntimeError):
    """A store/log/batch static capacity would be exceeded."""


# ---------------------------------------------------------------------------
# Typed mutation-op records (the write-side IR)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CreateVertex:
    vtype: str
    key: int
    attrs: Optional[dict] = None
    hint: Optional[int] = None        # FaRM locality hint (co-locate shard)


@dataclasses.dataclass(frozen=True)
class UpdateVertex:
    gid: int
    vtype: str
    attrs: dict


@dataclasses.dataclass(frozen=True)
class DeleteVertex:
    gid: int


@dataclasses.dataclass(frozen=True)
class CreateEdge:
    src: int
    dst: int
    etype: str
    check: bool = True                # False = bulk-load fast path (§3)


@dataclasses.dataclass(frozen=True)
class DeleteEdge:
    src: int
    dst: int
    etype: str


WriteOp = Union[CreateVertex, UpdateVertex, DeleteVertex, CreateEdge,
                DeleteEdge]
_OP_TYPES = (CreateVertex, UpdateVertex, DeleteVertex, CreateEdge, DeleteEdge)


@dataclasses.dataclass
class WriteResult:
    """Per-entry outcomes of one ``GraphDB.write`` call, positionally aligned
    with the input list (the write twin of ``QueryResult``).

    ``statuses[i]`` is ``"COMMITTED"`` / ``"ABORTED"`` / ``"STAGED"`` (op
    records staged into an open transaction).  ``gids[i]`` is the allocated
    vertex gid for ``CreateVertex`` entries (−1 otherwise, and −1 when the
    batch aborted).  ``reasons[i]`` carries the abort reason, ``None`` when
    the entry succeeded.  ``ts`` is the clock after the wave (−1 for
    stage-only calls).
    """
    statuses: list
    gids: list
    reasons: list
    ts: int = -1

    @property
    def failed(self) -> bool:
        return any(s == "ABORTED" for s in self.statuses)


# ---------------------------------------------------------------------------
# Staging: op record -> Transaction (the wrappers' logic, shared)
# ---------------------------------------------------------------------------

def stage(db, op: WriteOp, t) -> int:
    """Stage one mutation-op record into an open transaction.

    Performs the record's read-validate round-trips at ``t.read_ts`` (reads
    recorded for OCC), raises ``ValueError`` on contract violations exactly
    as the historical per-op methods did, and returns the allocated gid for
    ``CreateVertex`` (−1 for every other kind).
    """
    if isinstance(op, CreateVertex):
        vt = db.vt(op.vtype)
        g, found = db.lookup_vertex(op.vtype, int(op.key), read_ts=t.read_ts)
        if found:
            raise ValueError(f"vertex ({op.vtype}, {op.key}) already exists")
        f, i = db._encode_attrs(vt, op.attrs or {})
        gid = db._alloc_vertex(op.hint)
        t.create_v.append((gid, vt.type_id, int(op.key), f, i))
        return gid
    if isinstance(op, UpdateVertex):
        vt = db.vt(op.vtype)
        cur_f, cur_i = db._read_data_host(op.gid, t.read_ts)
        t.record_read(op.gid)
        f, i = db._encode_attrs(vt, op.attrs, base_f=cur_f, base_i=cur_i)
        t.update_v.append((op.gid, f, i))
        return -1
    if isinstance(op, DeleteVertex):
        # §3.2 cascade: the incoming list names every source whose outgoing
        # half-edge must also be retired
        gid = op.gid
        vtid, key, alive = db._read_header_host(gid, t.read_ts)
        t.record_read(gid)
        if not alive:
            raise ValueError(f"vertex {gid} not found")
        outs = db.get_edges(gid, direction="out", read_ts=t.read_ts)
        ins = db.get_edges(gid, direction="in", read_ts=t.read_ts)
        for nbr, et in outs:
            t.delete_e.append((gid, int(nbr), int(et)))
        for nbr, et in ins:
            t.delete_e.append((int(nbr), gid, int(et)))
        t.delete_v.append((gid, int(vtid), int(key)))
        return -1
    if isinstance(op, CreateEdge):
        et = db.et(op.etype)
        if op.check:
            for g in (op.src, op.dst):
                _, _, alive = db._read_header_host(g, t.read_ts)
                t.record_read(g)
                if not alive:
                    raise ValueError(f"endpoint {g} not found")
            # single-edge-per-(src,type,dst) invariant (§3)
            existing = db.get_edges(op.src, direction="out",
                                    read_ts=t.read_ts, etype=et.type_id)
            t.reads.append((int(op.src), "e"))
            if any(int(n) == int(op.dst) for n, _ in existing):
                raise ValueError("edge already exists")
        t.create_e.append((int(op.src), int(op.dst), et.type_id))
        return -1
    if isinstance(op, DeleteEdge):
        et = db.et(op.etype)
        t.reads.append((int(op.src), "e"))
        t.delete_e.append((int(op.src), int(op.dst), et.type_id))
        return -1
    raise TypeError(f"not a mutation-op record: {type(op).__name__}")


# ---------------------------------------------------------------------------
# Program cache (the read planner's idiom: shape-canonical keys, LRU,
# observable hit/miss counters)
# ---------------------------------------------------------------------------

CACHE_MAX_PROGRAMS = 64
_CACHE: collections.OrderedDict = collections.OrderedDict()
CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def reset_stats() -> None:
    """Zero the module-global counters (the traced programs stay cached).

    Stats are process-global while programs are shared across ``GraphDB``
    instances, so a fresh server/bench run must reset explicitly or its
    hit-rate telemetry inherits every prior instance's traffic."""
    for k in CACHE_STATS:
        CACHE_STATS[k] = 0


def _cache_get(key):
    fn = _CACHE.get(key)
    if fn is not None:
        _CACHE.move_to_end(key)
        CACHE_STATS["hits"] += 1
    return fn


def _cache_put(key, fn):
    CACHE_STATS["misses"] += 1
    _CACHE[key] = fn
    while len(_CACHE) > CACHE_MAX_PROGRAMS:
        _CACHE.popitem(last=False)
        CACHE_STATS["evictions"] += 1


def _pow2ceil(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def _bucket(n: int) -> int:
    """Shape canonicalization: 0 stays 0, everything else pow2-rounds."""
    return 0 if n == 0 else _pow2ceil(n)


def _validate_program(cfg, P: int):
    """One jitted OCC validation wave over ``P`` padded reads.

    Returns per-read conflict flags: the read object's last write landed
    after the owning transaction's snapshot.  Padded rows (gid −1, rts 0)
    report ``last_write_ts == 0 > 0 == False`` and never conflict.
    """
    key = ("validate", cfg, P)
    fn = _cache_get(key)
    if fn is None:
        def prog(store, gids, kinds, read_ts):
            lw = txn_mod.last_write_ts(store, cfg, gids, kinds)
            return lw > read_ts
        fn = jax.jit(prog)
        _cache_put(key, fn)
    return fn


def _apply_program(cfg, shapes: tuple):
    """The fused apply program of one mutation-shape group.

    ``shapes`` is the canonical ``(create_v, update_v, delete_v, create_e,
    delete_e)`` pow2 bucket tuple; each distinct tuple traces (and donates
    through) its own jitted instance so LRU eviction actually frees the
    trace.
    """
    key = ("apply", cfg, shapes)
    fn = _cache_get(key)
    if fn is None:
        fn = jax.jit(lambda store, ts, *ops:
                     txn_mod.apply_batch_impl(store, cfg, ts, *ops),
                     donate_argnums=(0,))
        _cache_put(key, fn)
    return fn


# ---------------------------------------------------------------------------
# The commit wave
# ---------------------------------------------------------------------------

def commit_wave(db, txns: Sequence, caps=None):
    """Validate + apply a batch of transactions as fused mutation waves.

    Returns ``(statuses, reasons)`` per transaction.  Semantics are the
    historical ``commit_many`` bit-for-bit; the mechanics differ:

    1. one vectorized OCC validation wave over *all* read sets (per-read
       snapshot timestamps, so mixed-snapshot batches validate in one pass);
    2. host-side first-wins intra-batch resolution (unchanged);
    3. inline compaction only as the overflow *backstop* — and the check
       counts ``delete_e`` entries too: tombstones occupy no fresh slots,
       but a tombstone-laden log can only reclaim space at compaction, so
       delete-heavy batches trigger the fold before the log saturates;
    4. winners chunked under the static ``BatchCaps``, each chunk applied by
       the shape-canonical fused program at its own commit timestamp.

    After the wave, crossing the delta-log fill watermark schedules the
    background compaction task (never compacts inline here).
    """
    caps = caps or db.caps
    cfg = db.cfg
    txns = list(txns)

    # 1) OCC validation: one wave over every transaction's read set ---------
    gids, kinds, owner, rts = [], [], [], []
    for i, t in enumerate(txns):
        for g, kind in t.reads:
            gids.append(g)
            kinds.append(1 if kind == "e" else 0)
            owner.append(i)
            rts.append(t.read_ts)
    status = ["COMMITTED"] * len(txns)
    reason: list = [None] * len(txns)
    if gids:
        P = _pow2ceil(len(gids))
        fn = _validate_program(cfg, P)
        conflict = np.asarray(fn(
            db.store, txn_mod.pad_i32(gids, P),
            txn_mod.pad_i32(kinds, P, fill=0),
            txn_mod.pad_i32(rts, P, fill=0)))
        for i, c in zip(owner, conflict[:len(gids)]):
            if bool(c) and status[i] == "COMMITTED":
                status[i] = "ABORTED"
                reason[i] = "stale read (OCC validation)"

    # 2) intra-batch conflicts, first-wins (§3): a later txn aborts if it
    #    writes an object an earlier winner wrote, or reads an object an
    #    earlier winner wrote — every winner reads pre-batch state and the
    #    batch serializes in any order.
    taken: set = set()
    for i, t in enumerate(txns):
        if status[i] == "ABORTED":
            continue
        wk = t.write_keys()
        if wk & taken:
            status[i] = "ABORTED"
            reason[i] = "intra-batch write-write conflict (first wins)"
        elif t.read_keys() & taken:
            status[i] = "ABORTED"
            reason[i] = "intra-batch read-write conflict (first wins)"
        else:
            taken |= wk
    winners = [t for i, t in enumerate(txns) if status[i] == "COMMITTED"]
    for i, t in enumerate(txns):
        t.status = status[i]
    if not winners:
        db.stats["aborts"] += len(txns)
        return status, reason

    # 3) capacity backstop: inline-compact only if the logs would overflow --
    _ensure_capacity(db, winners)

    # 4) apply winners, chunked under the static batch caps; winners are
    #    mutually conflict-free, so chunked application at increasing
    #    timestamps preserves the batch's serializable order.  Each chunk
    #    becomes one *wave record* — physical gids plus the logical
    #    identities resolved at commit time — the unit of fleet
    #    replication (§4): ``replay_wave`` re-applies it on a replica,
    #    ``ReplicationLog.append_wave`` ships it durably.
    for chunk in _chunks(winners, caps):
        ts = db.clock + 1
        _apply_chunk(db, chunk, ts)
        seq = db.wave_seq + 1
        rec = wave_record(db, chunk, ts, seq)
        db.wave_seq = seq
        db.wave_log.append(rec)
        _remember_rids(db, chunk, ts)
        if db.replication_log is not None:
            db.replication_log.append_wave(rec)
    db.stats["commits"] += len(winners)
    db.stats["aborts"] += len(txns) - len(winners)
    db.stats["write_waves"] += 1
    db._maybe_schedule_compaction()
    return status, reason


def _ensure_capacity(db, winners) -> None:
    """Step 3 of the wave: inline-compact only as the overflow backstop."""
    cfg = db.cfg
    n_ce = sum(len(t.create_e) for t in winners)
    n_de = sum(len(t.delete_e) for t in winners)
    n_cv = sum(len(t.create_v) for t in winners)
    n_dv = sum(len(t.delete_v) for t in winners)
    if (db.dl_count.max(initial=0) + n_ce + n_de > cfg.cap_delta
            or db.il_count.max(initial=0) + n_ce + n_de > cfg.cap_delta):
        db.run_compaction()
    if db.xd_count.max(initial=0) + n_cv + n_dv > cfg.cap_idx_delta:
        db.run_index_compaction()
    if db._vindexed:
        from repro.core import vindex as vindex_mod
        need = vindex_mod.wave_demand(db, winners)
        if np.any(db.vx_count + need > cfg.cap_vec):
            db.run_vindex_compaction()
            if np.any(db.vx_count + need > cfg.cap_vec):
                raise CapacityError("vector index full; raise cap_vec")


def _apply_chunk(db, chunk, ts: int) -> None:
    """Apply one winner chunk at commit timestamp ``ts`` (the fused
    program dispatch + host bookkeeping shared by commit and replay)."""
    shapes, args = _build_wave(db, chunk)
    fn = _apply_program(db.cfg, shapes)
    db.store = fn(db.store, jnp.int32(ts), *args)
    db.clock = max(db.clock, ts)
    if db._vindexed:
        from repro.core import vindex as vindex_mod
        vindex_mod.apply_wave(db, chunk, ts)
    if any(t.delete_e for t in chunk):
        db.epochs["delete_e"] += 1
    if any(t.delete_v for t in chunk):
        db.epochs["delete_v"] += 1


def _remember_rids(db, chunk, ts: int) -> None:
    """Record each committed txn's client rid -> outcome.  A promoted
    replica answers ``write_by_rid`` lookups from this map, and a
    re-admitted request whose rid is already here returns the ORIGINAL
    result instead of committing twice (exactly-once across failover)."""
    for t in chunk:
        rid = getattr(t, "rid", None)
        if rid is None:
            continue
        db.applied_rids[rid] = {
            "ts": int(ts), "gids": [int(g) for g, *_ in t.create_v]}
    while len(db.applied_rids) > 4096:
        db.applied_rids.popitem(last=False)


# ---------------------------------------------------------------------------
# Wave records: the unit of fleet replication (§4)
# ---------------------------------------------------------------------------

def _edge_ident(db, gid: int, ts: int) -> tuple:
    vt, key, alive = db._read_header_host(gid, ts)
    if not alive:                   # deleted in the same batch: pre-state
        vt, key, _ = db._read_header_host(gid, ts - 1)
    return int(vt), int(key)


def wave_record(db, chunk, ts: int, seq: int) -> dict:
    """One committed chunk as a JSON-safe record.

    Carries the physical op arrays (gids are primary-assigned and ship
    verbatim — replicas replay them so physical ids agree fleet-wide)
    *plus* the logical identities resolved at commit time (update targets,
    edge endpoints), so a db-less consumer (the frontend's durable
    :class:`~repro.core.replication.ReplicationLog`) can derive the
    logical log entries without a store to read headers from."""
    txns = []
    for t in chunk:
        uv = []
        for gid, f, i in t.update_v:
            vt, key, _ = db._read_header_host(gid, ts)
            uv.append([int(gid), int(vt), int(key),
                       np.asarray(f).tolist(), np.asarray(i).tolist()])
        txns.append({
            "rid": getattr(t, "rid", None),
            "create_v": [[int(g), int(vt), int(k),
                          np.asarray(f).tolist(), np.asarray(i).tolist()]
                         for g, vt, k, f, i in t.create_v],
            "update_v": uv,
            "delete_v": [[int(g), int(vt), int(k)]
                         for g, vt, k in t.delete_v],
            "create_e": [[int(s), int(d), int(et),
                          *_edge_ident(db, s, ts), *_edge_ident(db, d, ts)]
                         for s, d, et in t.create_e],
            "delete_e": [[int(s), int(d), int(et),
                          *_edge_ident(db, s, ts), *_edge_ident(db, d, ts)]
                         for s, d, et in t.delete_e],
        })
    return {"seq": int(seq), "ts": int(ts),
            "epoch": int(getattr(db, "config_epoch", 0)), "txns": txns}


def replay_wave(db, rec: dict) -> int:
    """Apply one shipped wave record on a replica (the tail-replay step).

    Idempotent: a record at or below the local wave frontier is skipped
    (the rid-cache / retransmit path can deliver duplicates).  A gap means
    the replica fell off the bounded wave log and needs a full resync —
    that is an error, not a silent hole.  Replay runs at the record's
    ORIGINAL commit timestamp, so MVCC snapshots are fleet-identical:
    a read at ``read_ts`` answers the same rows on every coordinator.
    Returns 1 when applied, 0 when skipped."""
    seq = int(rec["seq"])
    if seq <= db.wave_seq:
        return 0
    if seq != db.wave_seq + 1:
        raise ValueError(
            f"replication gap: local frontier {db.wave_seq}, got {seq}; "
            "full resync required")
    ts = int(rec["ts"])
    chunk = []
    for tr in rec["txns"]:
        t = txn_mod.Transaction(read_ts=0)
        t.rid = tr.get("rid")
        t.status = "COMMITTED"
        for g, vt, k, f, i in tr["create_v"]:
            t.create_v.append((int(g), int(vt), int(k),
                               np.asarray(f, np.float32),
                               np.asarray(i, np.int32)))
        for g, vt, k, f, i in tr["update_v"]:
            t.update_v.append((int(g), np.asarray(f, np.float32),
                               np.asarray(i, np.int32)))
        t.delete_v = [(int(g), int(vt), int(k))
                      for g, vt, k in tr["delete_v"]]
        t.create_e = [(int(s), int(d), int(et))
                      for s, d, et, *_ in tr["create_e"]]
        t.delete_e = [(int(s), int(d), int(et))
                      for s, d, et, *_ in tr["delete_e"]]
        chunk.append(t)
    _ensure_capacity(db, chunk)
    # reserve primary-assigned gids: if this replica is later promoted it
    # must never re-allocate a slot the old primary already handed out
    S = db.cfg.n_shards
    for t in chunk:
        for g, *_ in t.create_v:
            sh, slot = int(g) % S, int(g) // S
            if db.v_next[sh] <= slot:
                db.v_next[sh] = slot + 1
            elif slot in db.v_free[sh]:
                db.v_free[sh].remove(slot)
    _apply_chunk(db, chunk, ts)
    db.wave_seq = seq
    db.wave_log.append(rec)
    db.config_epoch = max(db.config_epoch, int(rec.get("epoch", 0)))
    _remember_rids(db, chunk, ts)
    db.stats["replayed_waves"] = db.stats.get("replayed_waves", 0) + 1
    db._maybe_schedule_compaction()
    return 1


def _chunks(winners, caps):
    out, acc = [], []
    ncv = nuv = ndv = nce = nde = 0
    for t in winners:
        if acc and (ncv + len(t.create_v) > caps.create_v
                    or nuv + len(t.update_v) > caps.update_v
                    or ndv + len(t.delete_v) > caps.delete_v
                    or nce + len(t.create_e) > caps.create_e
                    or nde + len(t.delete_e) > caps.delete_e):
            out.append(acc)
            acc, ncv, nuv, ndv, nce, nde = [], 0, 0, 0, 0, 0
        acc.append(t)
        ncv += len(t.create_v)
        nuv += len(t.update_v)
        ndv += len(t.delete_v)
        nce += len(t.create_e)
        nde += len(t.delete_e)
        if (len(t.create_v) > caps.create_v or len(t.update_v) > caps.update_v
                or len(t.delete_v) > caps.delete_v
                or len(t.create_e) > caps.create_e
                or len(t.delete_e) > caps.delete_e):
            raise CapacityError(
                "single transaction exceeds batch caps; raise BatchCaps")
    if acc:
        out.append(acc)
    return out


def _build_wave(db, chunk):
    """Pad one winner chunk's op arrays to their canonical shape bucket and
    assign host-side log positions (delta/index fill mirrors advance here).

    Returns ``(shapes, args)`` where ``shapes`` keys the fused program and
    ``args`` is the padded argument tuple ``apply_batch`` expects.
    """
    cfg = db.cfg
    S = cfg.n_shards
    cv, uv, dv, ce, de = [], [], [], [], []
    for t in chunk:
        cv += t.create_v
        uv += t.update_v
        dv += t.delete_v
        ce += t.create_e
        de += t.delete_e
    shapes = (_bucket(len(cv)), _bucket(len(uv)), _bucket(len(dv)),
              _bucket(len(ce)), _bucket(len(de)))
    bcv, buv, bdv, bce, bde = shapes

    # index-delta positions for creates (host-assigned, per index shard)
    from repro.core import index as index_mod
    xpos = []
    for gid, vtid, key, f, i in cv:
        sh = index_mod.route_host(vtid, key, S)
        xpos.append(sh * cfg.cap_idx_delta + int(db.xd_count[sh]))
        db.xd_count[sh] += 1
    # delta-log positions for edge creates
    opos, ipos = [], []
    for s, d, et in ce:
        so, sd = s % S, d % S
        opos.append(so * cfg.cap_delta + int(db.dl_count[so]))
        db.dl_count[so] += 1
        ipos.append(sd * cfg.cap_delta + int(db.il_count[sd]))
        db.il_count[sd] += 1

    p32 = txn_mod.pad_i32
    args = (
        p32([x[0] for x in cv], bcv),
        p32([x[1] for x in cv], bcv),
        p32([x[2] for x in cv], bcv),
        txn_mod.pad_f32([x[3] for x in cv], bcv, cfg.d_f32),
        txn_mod.pad_i32_2d([x[4] for x in cv], bcv, cfg.d_i32),
        p32(xpos, bcv),
        p32([x[0] for x in uv], buv),
        txn_mod.pad_f32([x[1] for x in uv], buv, cfg.d_f32),
        txn_mod.pad_i32_2d([x[2] for x in uv], buv, cfg.d_i32),
        p32([x[0] for x in dv], bdv),
        p32([x[1] for x in dv], bdv),
        p32([x[2] for x in dv], bdv),
        p32([x[0] for x in ce], bce),
        p32([x[1] for x in ce], bce),
        p32([x[2] for x in ce], bce),
        p32(opos, bce),
        p32(ipos, bce),
        p32([x[0] for x in de], bde),
        p32([x[1] for x in de], bde),
        p32([x[2] for x in de], bde),
        jnp.asarray(db.dl_count, jnp.int32),
        jnp.asarray(db.il_count, jnp.int32),
        jnp.asarray(db.xd_count, jnp.int32),
    )
    return shapes, args


# ---------------------------------------------------------------------------
# The entry point (exported as GraphDB.write)
# ---------------------------------------------------------------------------

def write(db, ops, *, txn=None, caps=None) -> WriteResult:
    """Execute a batch of mutations (see ``GraphDB.write`` for the API doc).

    ``ops`` is either a list of mutation-op records or a list of staged
    ``Transaction`` objects (never mixed).  Op records with ``txn=`` stage
    only; without, they form one implicit atomic transaction committed
    immediately.  Transactions commit as one fused mutation wave.  Staging
    contract violations (duplicate key, missing endpoint, ...) raise
    ``ValueError`` synchronously; commit-time OCC outcomes come back as
    per-entry statuses + abort reasons.
    """
    ops = list(ops)
    if not ops:
        raise ValueError("write() needs at least one op or transaction")
    if isinstance(ops[0], txn_mod.Transaction):
        if txn is not None:
            raise ValueError("txn= only applies to mutation-op records")
        if not all(isinstance(o, txn_mod.Transaction) for o in ops):
            raise TypeError("cannot mix transactions and op records")
        statuses, reasons = commit_wave(db, ops, caps)
        return WriteResult(statuses=statuses, gids=[-1] * len(ops),
                           reasons=reasons, ts=db.clock)
    for op in ops:
        if not isinstance(op, _OP_TYPES):
            raise TypeError(f"not a mutation-op record: {type(op).__name__}")
    if txn is not None:
        t, _ = db._txn(txn)
        gids = [stage(db, op, t) for op in ops]
        return WriteResult(statuses=["STAGED"] * len(ops), gids=gids,
                           reasons=[None] * len(ops), ts=-1)
    # implicit transaction: the whole op list commits atomically (§3's
    # "a transaction is implicitly created for that operation", batched)
    t = db.create_transaction()
    gids = [stage(db, op, t) for op in ops]
    statuses, reasons = commit_wave(db, [t], caps)
    committed = statuses[0] == "COMMITTED"
    return WriteResult(
        statuses=[statuses[0]] * len(ops),
        gids=gids if committed else [-1] * len(ops),
        reasons=[reasons[0]] * len(ops), ts=db.clock)
