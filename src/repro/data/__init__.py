from repro.data.kg import build_film_kg, FilmKG
from repro.data.tokens import token_pipeline
from repro.data.graphs import (synthetic_graph_batch, cora_like, reddit_like,
                               molecule_batch)
from repro.data.recsys import bst_batch
from repro.data.sampler import fanout_sample
