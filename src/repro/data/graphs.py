"""Synthetic graph datasets shaped like the assigned GNN cells.

  full_graph_sm   cora-like:    2,708 nodes / 10,556 edges / 1,433 features
  minibatch_lg    reddit-like:  233 k nodes / 115 M edges, fanout-sampled
  ogb_products    2.45 M nodes / 61.9 M edges / 100 features
  molecule        30-atom molecular graphs, batch 128

Generators are seeded and power-law-skewed (GNN shape regime D.3).  The
full-scale geometries are only ever *lowered* (ShapeDtypeStructs in the
dry-run); tests instantiate reduced versions through the same functions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import GraphBatch


def synthetic_graph_batch(n_nodes: int, n_edges: int, d_feat: int, *,
                          n_classes: int = 16, seed: int = 0,
                          with_positions: bool = False,
                          undirected: bool = True,
                          dtype=jnp.float32) -> GraphBatch:
    rng = np.random.default_rng(seed)
    # power-law-ish degree: sample endpoints with zipf weights
    w = 1.0 / np.power(np.arange(1, n_nodes + 1), 0.8)
    w /= w.sum()
    half = n_edges // 2 if undirected else n_edges
    src = rng.choice(n_nodes, size=half, p=w).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=half).astype(np.int32)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    pad = n_edges - src.shape[0]
    if pad > 0:
        src = np.concatenate([src, np.full(pad, -1, np.int32)])
        dst = np.concatenate([dst, np.zeros(pad, np.int32)])
    feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    mask = rng.uniform(size=n_nodes) < 0.3
    return GraphBatch(
        node_feat=jnp.asarray(feat, dtype),
        edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
        labels=jnp.asarray(labels), train_mask=jnp.asarray(mask),
        positions=(jnp.asarray(rng.normal(size=(n_nodes, 3)), dtype)
                   if with_positions else None))


def cora_like(scale: float = 1.0, seed: int = 0) -> GraphBatch:
    n = max(int(2708 * scale), 32)
    e = max(int(10556 * scale), 64)
    return synthetic_graph_batch(n, e, max(int(1433 * scale), 16),
                                 n_classes=7, seed=seed)


def reddit_like(scale: float = 1.0, seed: int = 0) -> GraphBatch:
    n = max(int(232_965 * scale), 64)
    e = max(int(114_615_892 * scale), 256)
    return synthetic_graph_batch(n, e, max(int(602 * scale), 16),
                                 n_classes=41, seed=seed)


def molecule_batch(batch: int = 128, n_nodes: int = 30, n_edges: int = 64,
                   *, n_species: int = 8, seed: int = 0,
                   dtype=jnp.float32) -> GraphBatch:
    """Batched small molecules: one flat COO graph with graph_ids."""
    rng = np.random.default_rng(seed)
    N = batch * n_nodes
    srcs, dsts = [], []
    for g in range(batch):
        base = g * n_nodes
        s = rng.integers(0, n_nodes, n_edges // 2)
        d = rng.integers(0, n_nodes, n_edges // 2)
        srcs.append(np.concatenate([s, d]) + base)
        dsts.append(np.concatenate([d, s]) + base)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    species = rng.integers(0, n_species, N).astype(np.float32)[:, None]
    pos = rng.normal(size=(N, 3)).astype(np.float32) * 2.0
    gid = np.repeat(np.arange(batch, dtype=np.int32), n_nodes)
    energy = rng.normal(size=batch).astype(np.float32)
    return GraphBatch(
        node_feat=jnp.asarray(species, dtype),
        edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
        labels=jnp.asarray(energy),
        train_mask=jnp.ones((batch,), bool),
        positions=jnp.asarray(pos, dtype),
        graph_ids=jnp.asarray(gid), n_graphs=batch)


def graph_batch_shape_dtypes(n_nodes: int, n_edges: int, d_feat: int, *,
                             with_positions: bool = False,
                             graph_ids: bool = False, n_graphs: int = 1,
                             label_shape: Optional[tuple] = None,
                             dtype=jnp.float32) -> GraphBatch:
    """ShapeDtypeStruct GraphBatch for dry-run lowering (no allocation)."""
    sds = jax.ShapeDtypeStruct
    lbl = label_shape or (n_nodes,)
    return GraphBatch(
        node_feat=sds((n_nodes, d_feat), dtype),
        edge_src=sds((n_edges,), jnp.int32),
        edge_dst=sds((n_edges,), jnp.int32),
        labels=sds(lbl, jnp.int32 if len(lbl) == 1 and not graph_ids
                   else jnp.float32),
        train_mask=sds(lbl[:1], jnp.bool_),
        positions=sds((n_nodes, 3), dtype) if with_positions else None,
        graph_ids=sds((n_nodes,), jnp.int32) if graph_ids else None,
        n_graphs=n_graphs)
