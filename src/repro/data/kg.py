"""Synthetic film/entertainment knowledge graph (the paper's §6 dataset).

The evaluation graph in the paper comes from a film knowledge base
(3.7 B vertices, 6.2 B edges, ~220-byte payloads, heavy degree skew — some
vertices exceed 10 M edges).  This generator reproduces its *shape* at a
configurable scale: directors/actors/films/genres with Zipf-skewed degrees,
loaded through the real transactional write path (create_vertex/create_edge
commit batches), so benchmarks exercise the same code a production load
would.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB


@dataclasses.dataclass
class FilmKG:
    db: GraphDB
    n_directors: int
    n_actors: int
    n_films: int
    n_genres: int
    director_keys: np.ndarray
    actor_keys: np.ndarray
    film_keys: np.ndarray
    genre_keys: np.ndarray


def build_film_kg(*, n_films: int = 200, n_actors: int = 300,
                  n_directors: int = 40, n_genres: int = 8,
                  actors_per_film: tuple = (2, 8), seed: int = 0,
                  cfg: StoreConfig = None, db: GraphDB = None,
                  zipf_a: float = 1.5) -> FilmKG:
    rng = np.random.default_rng(seed)
    if db is None:
        if cfg is None:
            # size the store for the requested scale (+slack for updates)
            n_v = n_films + n_actors + n_directors + n_genres
            per_film = (actors_per_film[0] + actors_per_film[1]) // 2 + 2
            n_e = n_films * per_film * 2
            S = 8
            cfg = StoreConfig(
                n_shards=S,
                cap_v=max(256, 2 * n_v // S),
                cap_e=max(2048, 4 * n_e // S),
                cap_delta=max(512, n_e // S),
                cap_idx=max(512, 4 * n_v // S),
                cap_idx_delta=max(256, n_v // S),
                d_f32=2, d_i32=2)
        db = GraphDB(cfg)
    db.vertex_type("director", i_attrs=("dob",))
    db.vertex_type("actor", i_attrs=("dob",))
    db.vertex_type("film", f_attrs=("gross",), i_attrs=("year", "genre"))
    db.vertex_type("genre")
    db.edge_type("film.director")   # director -> film
    db.edge_type("film.actor")      # film -> actor
    db.edge_type("film.genre")      # film -> genre

    d_keys = np.arange(1_000, 1_000 + n_directors)
    a_keys = np.arange(10_000, 10_000 + n_actors)
    f_keys = np.arange(100_000, 100_000 + n_films)
    g_keys = np.arange(500, 500 + n_genres)

    dirs, acts, films, genres = [], [], [], []
    t = db.create_transaction()

    def maybe_flush(t):
        if len(t.create_v) >= 200:      # stay under the commit batch caps
            assert db.commit(t) == "COMMITTED"
            return db.create_transaction()
        return t

    for k in d_keys:
        dirs.append(db.create_vertex("director", int(k),
                                     {"dob": int(rng.integers(1940, 1995))},
                                     txn=t))
        t = maybe_flush(t)
    for k in a_keys:
        acts.append(db.create_vertex("actor", int(k),
                                     {"dob": int(rng.integers(1940, 2000))},
                                     txn=t))
        t = maybe_flush(t)
    for k in g_keys:
        genres.append(db.create_vertex("genre", int(k), txn=t))
        t = maybe_flush(t)
    assert db.commit(t) == "COMMITTED"

    # Zipf-skewed popularity: a few mega-actors, like the paper's skew
    pop = 1.0 / np.power(np.arange(1, n_actors + 1), zipf_a)
    pop /= pop.sum()
    dir_pop = 1.0 / np.power(np.arange(1, n_directors + 1), zipf_a)
    dir_pop /= dir_pop.sum()

    t = db.create_transaction()
    for i, k in enumerate(f_keys):
        films.append(db.create_vertex(
            "film", int(k),
            {"gross": float(rng.uniform(1, 500)),
             "year": int(rng.integers(1960, 2026)),
             "genre": int(rng.integers(n_genres))}, txn=t))
        if len(t.create_v) >= 200:
            assert db.commit(t) == "COMMITTED"
            t = db.create_transaction()
    assert db.commit(t) == "COMMITTED"

    t = db.create_transaction()
    for i, f in enumerate(films):
        d = int(rng.choice(n_directors, p=dir_pop))
        db.create_edge(dirs[d], f, "film.director", txn=t, check=False)
        db.create_edge(f, genres[int(rng.integers(n_genres))],
                       "film.genre", txn=t, check=False)
        n_cast = int(rng.integers(*actors_per_film))
        for a in rng.choice(n_actors, size=n_cast, replace=False, p=pop):
            db.create_edge(f, acts[int(a)], "film.actor", txn=t,
                           check=False)
        if len(t.create_e) >= 400:
            assert db.commit(t) == "COMMITTED"
            t = db.create_transaction()
    assert db.commit(t) == "COMMITTED"
    db.run_compaction()
    db.run_index_compaction()
    return FilmKG(db=db, n_directors=n_directors, n_actors=n_actors,
                  n_films=n_films, n_genres=n_genres,
                  director_keys=d_keys, actor_keys=a_keys,
                  film_keys=f_keys, genre_keys=g_keys)
