"""Synthetic film/entertainment knowledge graph (the paper's §6 dataset).

The evaluation graph in the paper comes from a film knowledge base
(3.7 B vertices, 6.2 B edges, ~220-byte payloads, heavy degree skew — some
vertices exceed 10 M edges).  This generator reproduces its *shape* at a
configurable scale: directors/actors/films/genres with Zipf-skewed degrees,
loaded through the real transactional write path (``GraphDB.write`` batches
of mutation-op records), so benchmarks exercise the same code a production
load would.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.writes import CreateEdge, CreateVertex


@dataclasses.dataclass
class FilmKG:
    db: GraphDB
    n_directors: int
    n_actors: int
    n_films: int
    n_genres: int
    director_keys: np.ndarray
    actor_keys: np.ndarray
    film_keys: np.ndarray
    genre_keys: np.ndarray


def build_film_kg(*, n_films: int = 200, n_actors: int = 300,
                  n_directors: int = 40, n_genres: int = 8,
                  actors_per_film: tuple = (2, 8), seed: int = 0,
                  cfg: StoreConfig = None, db: GraphDB = None,
                  zipf_a: float = 1.5) -> FilmKG:
    rng = np.random.default_rng(seed)
    if db is None:
        if cfg is None:
            # size the store for the requested scale (+slack for updates)
            n_v = n_films + n_actors + n_directors + n_genres
            per_film = (actors_per_film[0] + actors_per_film[1]) // 2 + 2
            n_e = n_films * per_film * 2
            S = 8
            cfg = StoreConfig(
                n_shards=S,
                cap_v=max(256, 2 * n_v // S),
                cap_e=max(2048, 4 * n_e // S),
                cap_delta=max(512, n_e // S),
                cap_idx=max(512, 4 * n_v // S),
                cap_idx_delta=max(256, n_v // S),
                d_f32=2, d_i32=2)
        db = GraphDB(cfg)
    db.vertex_type("director", i_attrs=("dob",))
    db.vertex_type("actor", i_attrs=("dob",))
    db.vertex_type("film", f_attrs=("gross",), i_attrs=("year", "genre"))
    db.vertex_type("genre")
    db.edge_type("film.director")   # director -> film
    db.edge_type("film.actor")      # film -> actor
    db.edge_type("film.genre")      # film -> genre

    d_keys = np.arange(1_000, 1_000 + n_directors)
    a_keys = np.arange(10_000, 10_000 + n_actors)
    f_keys = np.arange(100_000, 100_000 + n_films)
    g_keys = np.arange(500, 500 + n_genres)

    def load(ops, chunk):
        """Commit op-record batches as implicit atomic writes, chunked to
        stay under the commit batch caps; returns created gids in order."""
        gids = []
        for off in range(0, len(ops), chunk):
            res = db.write(ops[off:off + chunk])
            assert not res.failed
            gids += res.gids
        return gids

    dirs = load([CreateVertex("director", int(k),
                              {"dob": int(rng.integers(1940, 1995))})
                 for k in d_keys], 200)
    acts = load([CreateVertex("actor", int(k),
                              {"dob": int(rng.integers(1940, 2000))})
                 for k in a_keys], 200)
    genres = load([CreateVertex("genre", int(k)) for k in g_keys], 200)

    # Zipf-skewed popularity: a few mega-actors, like the paper's skew
    pop = 1.0 / np.power(np.arange(1, n_actors + 1), zipf_a)
    pop /= pop.sum()
    dir_pop = 1.0 / np.power(np.arange(1, n_directors + 1), zipf_a)
    dir_pop /= dir_pop.sum()

    films = load([CreateVertex(
        "film", int(k),
        {"gross": float(rng.uniform(1, 500)),
         "year": int(rng.integers(1960, 2026)),
         "genre": int(rng.integers(n_genres))}) for k in f_keys], 200)

    # bulk-load fast path (check=False): uniqueness is the loader's contract
    e_ops = []
    for i, f in enumerate(films):
        d = int(rng.choice(n_directors, p=dir_pop))
        e_ops.append(CreateEdge(dirs[d], f, "film.director", check=False))
        e_ops.append(CreateEdge(f, genres[int(rng.integers(n_genres))],
                                "film.genre", check=False))
        n_cast = int(rng.integers(*actors_per_film))
        for a in rng.choice(n_actors, size=n_cast, replace=False, p=pop):
            e_ops.append(CreateEdge(f, acts[int(a)], "film.actor",
                                    check=False))
    load(e_ops, 400)
    db.run_compaction()
    db.run_index_compaction()
    return FilmKG(db=db, n_directors=n_directors, n_actors=n_actors,
                  n_films=n_films, n_genres=n_genres,
                  director_keys=d_keys, actor_keys=a_keys,
                  film_keys=f_keys, genre_keys=g_keys)
