"""Synthetic BST batches (user behavior sequences + CTR labels)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def bst_batch(*, batch: int, seq_len: int = 20, n_items: int = 1_000_000,
              n_dense: int = 8, seed: int = 0):
    rng = np.random.default_rng(seed)
    # zipf item popularity (huge_embedding regime)
    hist = (rng.zipf(1.3, size=(batch, seq_len)) % n_items).astype(np.int32)
    target = (rng.zipf(1.3, size=(batch,)) % n_items).astype(np.int32)
    dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
    labels = (rng.uniform(size=batch) < 0.2).astype(np.float32)
    return (jnp.asarray(hist), jnp.asarray(target), jnp.asarray(dense),
            jnp.asarray(labels))


def bst_batch_shape_dtypes(*, batch: int, seq_len: int = 20,
                           n_dense: int = 8):
    sds = jax.ShapeDtypeStruct
    return (sds((batch, seq_len), jnp.int32), sds((batch,), jnp.int32),
            sds((batch, n_dense), jnp.float32), sds((batch,), jnp.float32))
