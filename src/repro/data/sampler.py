"""Fanout neighbor sampler (GraphSAGE-style) — a bounded A1 traversal.

Sampling a 2-hop neighborhood with fanouts (25, 10) *is* an A1 multi-hop
query with per-hop capacity (§3.4's bounded frontier, sampled instead of
fast-failed).  Two implementations:

  * :func:`fanout_sample` — jit-able, static-shape, from a CSR held in
    device arrays: the minibatch_lg training path (a *real* sampler, per
    the assignment).
  * :func:`fanout_sample_db` — host path against a live GraphDB, using the
    same edge-enumeration machinery as the query engine (A1 integration).

Layered layout (static shapes): node slots = [seeds | hop-1 | hop-2 ...],
hop-k edges connect slot ranges; padding edges carry src = -1.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import GraphBatch


def csr_from_coo(n_nodes: int, src, dst):
    """Host-side CSR build (sorted by src)."""
    order = np.argsort(src, kind="stable")
    src_s, dst_s = np.asarray(src)[order], np.asarray(dst)[order]
    counts = np.bincount(src_s, minlength=n_nodes)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return jnp.asarray(indptr), jnp.asarray(dst_s.astype(np.int32))


@partial(jax.jit, static_argnames=("fanouts",))
def fanout_sample(indptr, indices, seeds, key, *, fanouts: tuple):
    """Sample a layered neighborhood.  Returns (node_gids, edge_src,

    edge_dst) where edge indices refer to *slot positions*:
      slots [0, B)                      = seeds
      slots [B, B + B*f1)               = hop-1 samples
      slots [.., + B*f1*f2)             = hop-2 samples ...
    Edges are (hop-k slot) -> (hop-(k-1) slot), src = -1 where the parent
    had no neighbors (sampled with replacement, GraphSAGE semantics).
    """
    B = seeds.shape[0]
    node_gids = [seeds]
    e_src, e_dst = [], []
    frontier = seeds
    base_prev = 0
    base_next = B
    for f in fanouts:
        n = frontier.shape[0]
        key, sub = jax.random.split(key)
        deg = indptr[frontier + 1] - indptr[frontier]
        r = jax.random.randint(sub, (n, f), 0, 2**31 - 1)
        r = r % jnp.maximum(deg, 1)[:, None]
        pos = indptr[frontier][:, None] + r
        nbr = indices[pos]                               # (n, f)
        ok = (deg > 0)[:, None] & (frontier >= 0)[:, None]
        nbr = jnp.where(ok, nbr, -1)
        okf = jnp.broadcast_to(ok, (n, f)).reshape(-1)
        src_slots = base_next + jnp.arange(n * f, dtype=jnp.int32)
        dst_slots = base_prev + jnp.repeat(jnp.arange(n, dtype=jnp.int32), f)
        e_src.append(jnp.where(okf, src_slots, -1))
        e_dst.append(dst_slots)
        node_gids.append(nbr.reshape(-1))
        frontier = nbr.reshape(-1)
        base_prev = base_next
        base_next = base_next + n * f
    return (jnp.concatenate(node_gids), jnp.concatenate(e_src),
            jnp.concatenate(e_dst))


def build_sampled_batch(features, labels, indptr, indices, seeds, key, *,
                        fanouts: tuple, n_classes: Optional[int] = None
                        ) -> GraphBatch:
    """Assemble a GraphBatch from a fanout sample (features gathered by

    global id; loss is computed on seed slots only)."""
    gids, es, ed = fanout_sample(indptr, indices, seeds, key,
                                 fanouts=fanouts)
    ok = gids >= 0
    rows = jnp.where(ok, gids, 0)
    feat = features[rows] * ok[:, None].astype(features.dtype)
    B = seeds.shape[0]
    N = gids.shape[0]
    lbl = jnp.full((N,), -1, jnp.int32).at[:B].set(labels[seeds])
    mask = jnp.zeros((N,), bool).at[:B].set(True)
    return GraphBatch(node_feat=feat, edge_src=es, edge_dst=ed,
                      labels=lbl, train_mask=mask)


def fanout_sample_db(db, seed_gids, *, fanouts: tuple, etype: int = -1,
                     seed: int = 0, cap: int = 4096):
    """Host-path sampler against a live GraphDB (A1 traversal per hop)."""
    rng = np.random.default_rng(seed)
    nodes = [np.asarray(seed_gids, np.int64)]
    e_src, e_dst = [], []
    frontier = np.asarray(seed_gids, np.int64)
    base_prev, base_next = 0, len(frontier)
    for f in fanouts:
        layer = []
        for i, g in enumerate(frontier):
            nbrs = ([n for n, _ in db.get_edges(int(g), etype=etype)]
                    if g >= 0 else [])
            for j in range(f):
                if nbrs:
                    layer.append(int(rng.choice(nbrs)))
                    e_src.append(base_next + i * f + j)
                else:
                    layer.append(-1)
                    e_src.append(-1)
                e_dst.append(base_prev + i)
        nodes.append(np.asarray(layer, np.int64))
        frontier = np.asarray(layer, np.int64)
        base_prev = base_next
        base_next += len(layer)
    return (np.concatenate(nodes), np.asarray(e_src, np.int32),
            np.asarray(e_dst, np.int32))
