"""LM token pipeline: deterministic synthetic corpus with prefetch.

Real deployments stream tokenized shards; this generator produces the same
interface (an iterator of (tokens, targets) device batches) from a seeded
PRNG, with double-buffered host->device prefetch so input never serializes
the step (straggler mitigation at the input layer: a slow host batch is
overlapped with compute).
"""
from __future__ import annotations

import threading
from queue import Queue
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _synth_batch(rng, batch: int, seq: int, vocab: int):
    # markov-ish stream: cheap but non-uniform (exercises the softmax)
    base = rng.integers(0, vocab, size=(batch, 1), dtype=np.int32)
    steps = rng.integers(-32, 33, size=(batch, seq), dtype=np.int32)
    toks = (base + np.cumsum(steps, axis=1)) % vocab
    return toks.astype(np.int32)


def token_pipeline(*, batch: int, seq: int, vocab: int, seed: int = 0,
                   sharding=None, prefetch: int = 2) -> Iterator:
    """Yields (tokens, targets) forever; targets are next-token shifted."""
    rng = np.random.default_rng(seed)
    q: Queue = Queue(maxsize=prefetch)

    def producer():
        while True:
            toks = _synth_batch(rng, batch, seq + 1, vocab)
            q.put(toks)

    th = threading.Thread(target=producer, daemon=True)
    th.start()

    while True:
        toks = q.get()
        x = jnp.asarray(toks[:, :-1])
        y = jnp.asarray(toks[:, 1:])
        if sharding is not None:
            x = jax.device_put(x, sharding)
            y = jax.device_put(y, sharding)
        yield x, y
