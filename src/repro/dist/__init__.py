"""repro.dist — the SPMD substrate: sharding rules, comm overlap, pipeline.

Three layers, mirroring A1's §3.4 split between *placement* (which machine
owns which data), *shipping* (moving operators/rows between owners), and
*scheduling* (keeping the wires busy while the cores compute):

  sharding.py   logical-axis rule tables -> PartitionSpecs (placement)
  overlap.py    collective matmul: ppermute ring all-gather fused with
                the consuming contraction (shipping overlapped w/ compute)
  pipeline.py   GPipe-style microbatch pipeline over a mesh axis
  compat.py     jax version shims (shard_map / make_mesh API drift)

See README.md in this directory for the rule-system contract.
"""
from repro.dist import compat  # noqa: F401
from repro.dist.sharding import (DEFAULT_RULES, constrain, current_mesh,  # noqa: F401
                                 resolve, rules_context, tree_specs)
