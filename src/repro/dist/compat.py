"""jax API drift shims.

The repo targets the current jax surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); the pinned
toolchain ships 0.4.37 where those live under ``jax.experimental`` with
older spellings.  Every call site goes through this module so the drift is
handled exactly once.
"""
from __future__ import annotations

import jax

try:                                    # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:                     # 0.4.x
    _AxisType = None


if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        # pre-0.5 spelling: check_vma was called check_rep
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)


if hasattr(jax.lax, "axis_size"):
    def axis_size(axis_name):
        return jax.lax.axis_size(axis_name)
else:
    def axis_size(axis_name):
        # psum of a static 1 constant-folds to the (static) axis size
        return jax.lax.psum(1, axis_name)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _AxisType is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(_AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
