"""Communication/computation overlap: collective matmul.

A1 §3.4 overlaps shipping with work: while a machine serves one request it
already has the next on the wire.  The tensor-parallel analogue is the
*collective matmul* (Wang et al., ASPLOS'23): instead of all-gathering the
row-sharded activations and then running one big matmul — serializing wire
and FLOPs — walk the gather as a ``ppermute`` ring and consume each chunk
the moment it lands.  XLA overlaps step k's ppermute with step k's matmul,
hiding the wire behind the math whenever FLOPs/chunk >= bytes/bandwidth.

Runs inside ``shard_map``; callers hold per-device shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import compat


def ring_perm(n: int):
    """Send-"up" ppermute ring: device i -> i+1 (mod n)."""
    return [(i, (i + 1) % n) for i in range(n)]


def collective_matmul_ag(x_shard, w_local, axis_name: str):
    """All-gather(x) @ w, overlapped on a ppermute ring.

    Args (per-device views inside shard_map, ring of size N over
    ``axis_name``):
      x_shard: (S/N, K)  — activation rows, sharded over ``axis_name``
      w_local: (K, O/N)  — weight columns, sharded over ``axis_name``

    Returns (S, O/N): this device's output columns for *all* rows — the
    result the unfused ``all_gather(x) @ w_local`` would produce, computed
    as N chunk matmuls with the gather in flight behind them.
    """
    n = compat.axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    s = x_shard.shape[0]
    out_dtype = jnp.result_type(x_shard.dtype, w_local.dtype)
    out = jnp.zeros((n * s, w_local.shape[1]), out_dtype)
    perm = ring_perm(n)

    chunk = x_shard
    for step in range(n):
        # launch the next hop first so XLA can run it under this chunk's
        # matmul; the ring sends "up" so after k hops we hold chunk me-k
        nxt = (jax.lax.ppermute(chunk, axis_name, perm)
               if step != n - 1 else None)
        src = (me - step) % n
        out = jax.lax.dynamic_update_slice(
            out, (chunk @ w_local).astype(out_dtype), (src * s, 0))
        chunk = nxt
    return out


# ---------------------------------------------------------------------------
# opt-in wiring into the transformer TP matmuls
# ---------------------------------------------------------------------------

def tp_matmul_ag(x, w, *, axis: str = "model", batch_axes=("pod", "data")):
    """Gather-overlapped tensor-parallel matmul for 3D activations.

    The sequence-parallel TP pattern: ``x (B, S, K)`` arrives sequence-
    sharded over ``axis``; ``w (K, O)`` is column-sharded over ``axis``.
    GSPMD lowers ``x @ w`` to all-gather(x over seq) -> matmul, serializing
    wire and FLOPs; this wraps the same contraction in a shard_map running
    :func:`collective_matmul_ag`'s ppermute ring instead, so each gather hop
    hides behind the previous chunk's matmul.

    Falls back to a plain matmul when no mesh is in scope, ``axis`` is
    absent/size-1, or S doesn't divide — CPU unit tests and decode (S=1)
    run the identical reference contraction.  Opt in per model via
    ``LMConfig.use_collective_matmul`` (default off; see ROADMAP wire-model
    numbers before enabling on a real topology).
    """
    from repro.dist import sharding as _sharding
    mesh = _sharding.current_mesh()
    if (mesh is None or axis not in mesh.axis_names
            or mesh.shape[axis] == 1 or x.ndim != 3
            or x.shape[1] % mesh.shape[axis] != 0):
        return x @ w
    n = mesh.shape[axis]
    from jax.sharding import PartitionSpec as P
    baxes = tuple(a for a in batch_axes if a in mesh.axis_names)
    b_shards = 1
    for a in baxes:
        b_shards *= mesh.shape[a]
    B, S, K = x.shape
    if B % b_shards != 0 or w.shape[1] % n != 0:
        # shapes GSPMD handles but the explicit in_specs cannot split evenly
        return x @ w
    bspec = (baxes[0] if len(baxes) == 1 else (baxes or None))

    def body(x_l, w_l):
        b_loc = x_l.shape[0]
        out = collective_matmul_ag(x_l.reshape(b_loc * (S // n), K), w_l,
                                   axis)
        # ring output is chunk-major (src, b, s_loc); restore (b, S)
        return (out.reshape(n, b_loc, S // n, w_l.shape[1])
                .transpose(1, 0, 2, 3).reshape(b_loc, S, w_l.shape[1]))

    fn = compat.shard_map(body, mesh=mesh,
                          in_specs=(P(bspec, axis, None), P(None, axis)),
                          out_specs=P(bspec, None, axis), check_vma=False)
    return fn(x, w)
