"""Communication/computation overlap: collective matmul.

A1 §3.4 overlaps shipping with work: while a machine serves one request it
already has the next on the wire.  The tensor-parallel analogue is the
*collective matmul* (Wang et al., ASPLOS'23): instead of all-gathering the
row-sharded activations and then running one big matmul — serializing wire
and FLOPs — walk the gather as a ``ppermute`` ring and consume each chunk
the moment it lands.  XLA overlaps step k's ppermute with step k's matmul,
hiding the wire behind the math whenever FLOPs/chunk >= bytes/bandwidth.

Runs inside ``shard_map``; callers hold per-device shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import compat


def ring_perm(n: int):
    """Send-"up" ppermute ring: device i -> i+1 (mod n)."""
    return [(i, (i + 1) % n) for i in range(n)]


def collective_matmul_ag(x_shard, w_local, axis_name: str):
    """All-gather(x) @ w, overlapped on a ppermute ring.

    Args (per-device views inside shard_map, ring of size N over
    ``axis_name``):
      x_shard: (S/N, K)  — activation rows, sharded over ``axis_name``
      w_local: (K, O/N)  — weight columns, sharded over ``axis_name``

    Returns (S, O/N): this device's output columns for *all* rows — the
    result the unfused ``all_gather(x) @ w_local`` would produce, computed
    as N chunk matmuls with the gather in flight behind them.
    """
    n = compat.axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    s = x_shard.shape[0]
    out_dtype = jnp.result_type(x_shard.dtype, w_local.dtype)
    out = jnp.zeros((n * s, w_local.shape[1]), out_dtype)
    perm = ring_perm(n)

    chunk = x_shard
    for step in range(n):
        # launch the next hop first so XLA can run it under this chunk's
        # matmul; the ring sends "up" so after k hops we hold chunk me-k
        nxt = (jax.lax.ppermute(chunk, axis_name, perm)
               if step != n - 1 else None)
        src = (me - step) % n
        out = jax.lax.dynamic_update_slice(
            out, (chunk @ w_local).astype(out_dtype), (src * s, 0))
        chunk = nxt
    return out
