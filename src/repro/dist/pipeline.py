"""GPipe-style microbatch pipeline over a named mesh axis.

Each device along ``axis`` owns one stage's weights (the A1 analogue:
each machine owns one region of the graph and work flows through owners).
Microbatches stream through the ring: at tick t, stage s computes
microbatch t-s and hands its activation to stage s+1 via ``ppermute``.
A schedule of M microbatches over S stages takes M+S-1 ticks; the bubble
fraction (S-1)/(M+S-1) shrinks as M grows.

Runs inside ``shard_map``.  All stages share one activation shape/dtype
(each stage's output feeds the next stage's input).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import compat
from repro.dist.overlap import ring_perm


def pipeline_apply(stage_fn, stage_params, x, *, axis: str, n_stages: int,
                   n_microbatches: int):
    """Run ``x`` through ``n_stages`` pipeline stages along ``axis``.

    Args (per-device views inside shard_map):
      stage_fn:      (stage_params, h) -> h', shape/dtype preserving
      stage_params:  this device's stage weights
      x:             (n_microbatches, *mb_shape) — the full input stream,
                     replicated (only stage 0 reads it)
      axis:          mesh axis carrying the stages
      n_stages:      pipeline depth; must equal the axis size
      n_microbatches: M, the leading dim of ``x``

    Returns (n_microbatches, *mb_shape): on the *last* stage, the outputs;
    on earlier stages, zeros (callers typically select the last stage's
    copy, e.g. with a masked psum over ``axis``).
    """
    size = compat.axis_size(axis)
    if size != n_stages:
        raise ValueError(f"n_stages={n_stages} != |{axis}|={size}")
    M = n_microbatches
    if x.shape[0] != M:
        raise ValueError(f"x leading dim {x.shape[0]} != M={M}")
    stage = jax.lax.axis_index(axis)
    out_sds = jax.eval_shape(stage_fn, stage_params,
                             jax.ShapeDtypeStruct(x.shape[1:], x.dtype))
    if out_sds.shape != x.shape[1:]:
        raise ValueError(
            f"stage_fn must preserve shape: {out_sds.shape} != {x.shape[1:]}")
    perm = ring_perm(n_stages)
    h0 = jnp.zeros(x.shape[1:], out_sds.dtype)
    out0 = jnp.zeros((M,) + x.shape[1:], out_sds.dtype)

    def tick(carry, t):
        h, out = carry
        # stage 0 injects microbatch t (clamped: past M it runs garbage
        # that is never written); later stages consume the handed-off h
        x_t = jax.lax.dynamic_index_in_dim(x, jnp.clip(t, 0, M - 1), 0,
                                           keepdims=False)
        y = stage_fn(stage_params, jnp.where(stage == 0,
                                             x_t.astype(out_sds.dtype), h))
        h_next = jax.lax.ppermute(y, axis, perm)
        # the last stage emits microbatch t-(S-1) once the fill drains
        o_t = t - (n_stages - 1)
        idx = jnp.clip(o_t, 0, M - 1)
        write = (o_t >= 0) & (stage == n_stages - 1)
        prev = jax.lax.dynamic_index_in_dim(out, idx, 0, keepdims=False)
        out = jax.lax.dynamic_update_index_in_dim(
            out, jnp.where(write, y, prev), idx, 0)
        return (h_next, out), None

    (_, out), _ = jax.lax.scan(tick, (h0, out0),
                               jnp.arange(M + n_stages - 1))
    return out
