"""Logical-axis sharding rules: names -> mesh axes -> PartitionSpecs.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"fsdp", ...); a rule table maps each name to zero or more *mesh* axes.
This indirection is what lets one model implementation serve every
parallelism plan in configs/ — a plan is just a rule override dict, scoped
with :func:`rules_context` or passed explicitly to :func:`tree_specs`.

Resolution contract (everything launch/steps.py relies on):

  * a logical name maps to ``None`` (replicate), one mesh axis name, or a
    tuple of mesh axis names (the dim shards over their product);
  * mesh axes absent from the target mesh are silently dropped — the same
    plan resolves on a ("data","model") pod slice and on the full
    ("pod","data","model") mesh;
  * a mesh axis is consumed at most once per spec (first dim wins), so an
    override like ``{"fsdp": ("data","model")}`` composes with defaults
    that also use "model" without tripping GSPMD's duplicate-axis check;
  * unknown logical names resolve to the mesh axis of the same name when
    one exists (so specs can name mesh axes directly), else replicate.

:func:`constrain` applies a rule-resolved ``with_sharding_constraint`` and
is a **no-op when no mesh is in scope** — pure-CPU unit tests run the
exact model code the 256-chip mesh runs, constraints and all.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# The repo-wide default plan (Megatron-style TP on 'model', DP/FSDP on
# 'data', outer DP or pipeline on 'pod').  Logical names are the union of
# what models/{transformer,attention,moe,recsys,gnn}.py annotate.
DEFAULT_RULES: dict = {
    "batch":    ("pod", "data"),   # activations: data-parallel dims
    "seq":      None,              # sequence-parallel plans override -> model
    "kv_seq":   "model",           # flash-decoding: KV cache sharded on seq
    "layers":   None,              # scanned stack dim: never sharded
    "embed":    None,              # d_model vectors (ln scales): replicated
    "fsdp":     "data",            # ZeRO-style param/optimizer shard dim
    "heads":    "model",           # q-head tensor parallelism
    "kv_heads": None,              # kv heads < TP degree on assigned archs
    "ff":       "model",           # MLP hidden
    "vocab":    "model",           # embedding rows / logits
    "expert":   "model",           # MoE expert parallelism
    "tensor":   "model",           # generic TP dim (GNN node shards)
}


class _Rules(threading.local):
    def __init__(self):
        self.stack: list[dict] = []


_SCOPED = _Rules()


def _table(rules: Optional[dict] = None) -> dict:
    t = dict(DEFAULT_RULES)
    for d in _SCOPED.stack:
        t.update(d)
    if rules:
        t.update(rules)
    return t


@contextmanager
def rules_context(rules: dict):
    """Scope a rule-override dict: inner contexts win, exits restore."""
    _SCOPED.stack.append(dict(rules))
    try:
        yield
    finally:
        _SCOPED.stack.pop()


def is_axes_leaf(x: Any) -> bool:
    """A logical-axes tuple: all entries are names or None.

    The single definition of the tuple-leaf convention (launch/steps.py
    imports this); a pair of axes-tuples, e.g. Adafactor's factored second
    moment, is *not* a leaf and recurses into two specs."""
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def resolve(axes, *, rules: Optional[dict] = None, mesh=None) -> P:
    """Logical axes tuple -> PartitionSpec against ``mesh``."""
    table = _table(rules)
    mesh_axes = tuple(mesh.axis_names) if mesh is not None else ()
    used: set = set()
    parts = []
    for ax in axes:
        if ax is None:
            parts.append(None)
            continue
        if isinstance(ax, str):
            if ax in table:
                val = table[ax]
            else:
                val = ax if ax in mesh_axes else None
        else:           # already a mesh-axis tuple (explicit spec entry)
            val = ax
        if val is None:
            parts.append(None)
            continue
        if isinstance(val, str):
            val = (val,)
        keep = tuple(m for m in val if m in mesh_axes and m not in used)
        used.update(keep)
        parts.append(keep[0] if len(keep) == 1 else (keep or None))
    return P(*parts)


def tree_specs(tree_axes, *, rules: Optional[dict] = None, mesh=None):
    """Pytree of logical-axes tuples -> pytree of PartitionSpecs.

    Leaves are axes-tuples per :func:`_is_axes_leaf`; ``()`` (a scalar)
    resolves to ``P()``.  ``None`` leaves pass through untouched (jax
    treats them as empty subtrees on both sides)."""
    return jax.tree.map(lambda a: resolve(a, rules=rules, mesh=mesh),
                        tree_axes, is_leaf=is_axes_leaf)


# ---------------------------------------------------------------------------
# mesh discovery + constrain
# ---------------------------------------------------------------------------

def current_mesh():
    """The mesh in scope (``with mesh:`` / ``jax.sharding.use_mesh``), else
    None.  Probes the modern abstract-mesh API first, then the classic
    thread-resources context."""
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        try:
            am = get_am()
            if am is not None and not am.empty:
                return am
        except Exception:
            pass
    try:
        from jax._src import mesh as _mesh_lib
        pm = _mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    return None


def constrain(x, axes, *, rules: Optional[dict] = None):
    """Rule-aware ``with_sharding_constraint``.

    Resolves ``axes`` against the mesh currently in scope.  With no mesh —
    eager CPU tests, un-meshed jit — this is the identity, so model code
    carries its layout contract unconditionally."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve(axes, rules=rules, mesh=mesh)
    if isinstance(mesh, jax.sharding.Mesh):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)
