"""Dedup/compact Pallas TPU kernel (the per-hop frontier compaction, §3.4).

Hardware adaptation: the reference path compacts every hop with a full-width
``jax.lax.sort`` over the candidate matrix — an XLA sort that materializes
the whole (R, W) buffer in HBM per comparison pass.  Here each row block is
sorted *inside VMEM* with a bitonic network: W is padded to a power of two,
every compare-exchange stage is one vectorized min/max over the resident
block, and the dedup ("mark duplicates PAD, sort again, slice the cap") is
fused into the same kernel so the full-width sorted intermediate never
leaves VMEM.

Three entry points mirroring the ref oracle (bit-identical by construction —
integer sorting has one answer):

  * :func:`sort_rows`            — row-wise ascending sort;
  * :func:`dedup_compact_rows`   — sorted-unique first-``cap`` compaction +
                                   per-row unique counts;
  * :func:`sort_pairs`           — lexicographic flat (seg, gid) pair sort
                                   (the shared-frontier compaction), a
                                   two-key compare-exchange on both arrays.

Grid: (row_blocks,); the whole (padded) width lives in VMEM per program —
at serving caps (W ~ 16K i32) a row block is well under VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32MAX = 2**31 - 1
PAD = I32MAX


def _pow2ceil(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def _stages(W: int):
    """The bitonic network: (k, j) compare-exchange stages for width W."""
    out = []
    k = 2
    while k <= W:
        j = k // 2
        while j >= 1:
            out.append((k, j))
            j //= 2
        k *= 2
    return out


def _partner(x, j):
    """Exchange partner view: element i sees element i ^ j (axis -1)."""
    R, W = x.shape
    xr = x.reshape(R, W // (2 * j), 2, j)
    return xr[:, :, ::-1, :].reshape(R, W)


def _bitonic_rows(x, idx):
    """In-register bitonic ascending sort along axis 1 (W = pow2)."""
    W = x.shape[1]
    for k, j in _stages(W):
        px = _partner(x, j)
        is_lower = (idx & j) == 0
        up = (idx & k) == 0
        want_min = is_lower == up
        x = jnp.where(want_min, jnp.minimum(x, px), jnp.maximum(x, px))
    return x


def _bitonic_pairs(s, g, idx):
    """Two-key (lexicographic) bitonic ascending sort along axis 1."""
    W = s.shape[1]
    for k, j in _stages(W):
        ps, pg = _partner(s, j), _partner(g, j)
        le = (s < ps) | ((s == ps) & (g <= pg))     # self <= partner
        is_lower = (idx & j) == 0
        up = (idx & k) == 0
        keep_self = le == (is_lower == up)
        s = jnp.where(keep_self, s, ps)
        g = jnp.where(keep_self, g, pg)
    return s, g


def _row_idx(shape):
    return jax.lax.broadcasted_iota(jnp.int32, shape, 1)


def _sort_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = _bitonic_rows(x, _row_idx(x.shape))


def _dedup_kernel(x_ref, o_ref, n_ref, *, cap: int):
    x = x_ref[...]
    R = x.shape[0]
    idx = _row_idx(x.shape)
    x = _bitonic_rows(x, idx)
    prev = jnp.concatenate(
        [jnp.full((R, 1), -1, x.dtype), x[:, :-1]], axis=1)
    first = (x != PAD) & (x != prev)
    n_ref[...] = jnp.sum(first.astype(jnp.int32), axis=1)
    y = jnp.where(first, x, PAD)                 # non-first -> PAD, resort
    y = _bitonic_rows(y, idx)
    o_ref[...] = y[:, :cap]


def _pairs_kernel(s_ref, g_ref, os_ref, og_ref):
    s, g = s_ref[...], g_ref[...]
    s, g = _bitonic_pairs(s, g, _row_idx(s.shape))
    os_ref[...] = s
    og_ref[...] = g


def _pad_rows(x, W2: int, R2: int, fill):
    R, W = x.shape
    return jnp.pad(x, ((0, R2 - R), (0, W2 - W)), constant_values=fill)


def sort_rows(x, *, block_r: int = 8, interpret: bool = False):
    """Row-wise ascending sort of (R, W) i32; == jax.lax.sort(x, dim=1).

    Values must be <= INT32_MAX (the pad fill), which every frontier gid
    and the PAD sentinel satisfy.
    """
    R, W = x.shape
    W2 = max(128, _pow2ceil(W))
    br = min(block_r, max(1, R))
    R2 = pl.cdiv(R, br) * br
    out = pl.pallas_call(
        _sort_kernel,
        grid=(pl.cdiv(R2, br),),
        in_specs=[pl.BlockSpec((br, W2), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((br, W2), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R2, W2), jnp.int32),
        interpret=interpret,
    )(_pad_rows(x, W2, R2, I32MAX))
    # pad values are I32MAX: they sort behind every real value, so the
    # leading W columns of each padded row are exactly the sorted row
    return out[:R, :W]


def dedup_compact_rows(x, cap: int, *, block_r: int = 8,
                       interpret: bool = False):
    """(R, W) candidates -> ((R, cap), (R,) unique counts); see ref oracle."""
    R, W = x.shape
    W2 = max(128, _pow2ceil(W))
    # a row of width W holds <= W <= W2 uniques, so when cap exceeds the
    # padded width the kernel emits W2 columns and the tail is pure PAD
    kcap = min(cap, W2)
    br = min(block_r, max(1, R))
    R2 = pl.cdiv(R, br) * br
    out, n = pl.pallas_call(
        functools.partial(_dedup_kernel, cap=kcap),
        grid=(pl.cdiv(R2, br),),
        in_specs=[pl.BlockSpec((br, W2), lambda r: (r, 0))],
        out_specs=[pl.BlockSpec((br, kcap), lambda r: (r, 0)),
                   pl.BlockSpec((br,), lambda r: (r,))],
        out_shape=[jax.ShapeDtypeStruct((R2, kcap), jnp.int32),
                   jax.ShapeDtypeStruct((R2,), jnp.int32)],
        interpret=interpret,
    )(_pad_rows(x, W2, R2, I32MAX))
    out = out[:R]
    if kcap < cap:
        out = jnp.pad(out, ((0, 0), (0, cap - kcap)), constant_values=I32MAX)
    return out, n[:R]


def sort_pairs(k1, k2, *, interpret: bool = False):
    """Lexicographic ascending sort of flat (k1, k2) i32 pairs.

    == jax.lax.sort((k1, k2), num_keys=2).  Pads with (I32MAX, I32MAX),
    which sorts behind every real pair.
    """
    (W,) = k1.shape
    W2 = max(128, _pow2ceil(W))
    s = jnp.pad(k1, (0, W2 - W), constant_values=I32MAX)[None, :]
    g = jnp.pad(k2, (0, W2 - W), constant_values=I32MAX)[None, :]
    os_, og = pl.pallas_call(
        _pairs_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, W2), lambda r: (0, 0)),
                  pl.BlockSpec((1, W2), lambda r: (0, 0))],
        out_specs=[pl.BlockSpec((1, W2), lambda r: (0, 0)),
                   pl.BlockSpec((1, W2), lambda r: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, W2), jnp.int32),
                   jax.ShapeDtypeStruct((1, W2), jnp.int32)],
        interpret=interpret,
    )(s, g)
    return os_[0, :W], og[0, :W]
