"""Jitted wrappers for the dedup/compact wave primitives."""
from __future__ import annotations

import functools

import jax

from repro.kernels.dedup_compact import ref as _ref
from repro.kernels.dedup_compact.kernel import (
    dedup_compact_rows as _dedup_kernel, sort_rows as _sort_kernel)

_USE_KERNEL = jax.default_backend() == "tpu"


@jax.jit
def sort_rows(x):
    if _USE_KERNEL:
        return _sort_kernel(x)
    return _ref.sort_rows(x)


@functools.partial(jax.jit, static_argnames=("cap",))
def dedup_compact_rows(x, cap: int):
    if _USE_KERNEL:
        return _dedup_kernel(x, cap)
    return _ref.dedup_compact_rows(x, cap)
