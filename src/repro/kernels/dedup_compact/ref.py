"""Pure-jnp oracle for the per-hop dedup/compact wave (§3.4 "aggregated,
duplicates removed").

The fused multi-query planner compacts every hop's candidate neighbors into
sorted-unique frontier regions.  Three shapes of the same operator:

  * :func:`sort_rows` — row-wise ascending sort of an ``(R, W)`` i32 matrix
    (the intersect-merge wave needs the *sorted* rows, duplicates included,
    because a gid's run length is its branch coverage);
  * :func:`dedup_compact_rows` — ``(R, W)`` candidates (``PAD`` = invalid)
    to ``(R, cap)`` regions: row r keeps its first ``cap`` unique gids in
    ascending order, PAD beyond, plus the per-row unique count (count >
    cap is the §3.4 fast-fail condition);
  * :func:`sort_pairs` — lexicographic sort of flat ``(seg, gid)`` pairs,
    the shared-frontier mode's one compaction per hop.

``PAD`` is INT32_MAX: it sorts last, so compacted rows stay ascending and
row-wise binary search keeps working downstream.
"""
import jax
import jax.numpy as jnp

PAD = 2**31 - 1                  # plain int: safe to create under a trace


def sort_rows(x):
    """Row-wise ascending sort of an (R, W) i32 matrix."""
    return jax.lax.sort(x, dimension=1)


def sort_pairs(k1, k2):
    """Lexicographic ascending sort of flat (k1, k2) i32 pairs."""
    return jax.lax.sort((k1, k2), num_keys=2)


def dedup_compact_rows(x, cap: int):
    """(R, W) candidates -> ((R, cap) sorted-unique regions, (R,) counts).

    Invalid slots carry ``PAD``; row r's output is its first ``cap`` unique
    non-PAD values ascending, PAD beyond.  ``counts`` is the number of
    uniques *before* capping (``counts > cap`` == §3.4 overflow).
    """
    R = x.shape[0]
    x_s = jax.lax.sort(x, dimension=1)
    valid = x_s != PAD
    prev = jnp.concatenate(
        [jnp.full((R, 1), -1, x_s.dtype), x_s[:, :-1]], axis=1)
    first = valid & (x_s != prev)
    fi = first.astype(jnp.int32)
    n = jnp.sum(fi, axis=1)
    rank = jnp.cumsum(fi, axis=1) - 1
    col = jnp.where(first & (rank < cap), rank, cap)     # cap = dropped
    rows = jnp.broadcast_to(jnp.arange(R, dtype=jnp.int32)[:, None],
                            col.shape)
    out = jnp.full((R, cap), PAD, jnp.int32).at[rows, col].set(
        x_s, mode="drop")
    return out, n
