"""Ragged CSR expansion Pallas TPU kernel (A1 edge enumeration, §3.4).

The paper's edge enumeration walks a vertex's edge list — an (address, size)
span in FaRM.  The TPU adaptation streams those spans tile-by-tile:

* a host/jnp *plan* (ref.plan) flattens the ragged spans into a dense grid of
  128-lane tiles: tile i serves frontier item ``item_of_tile[i]``, its
  ``tw``-th tile;
* scalar-prefetched span starts feed the BlockSpec index_map, so the Pallas
  pipeline DMA-streams the right edge-pool tiles (two adjacent tiles per
  step, because spans are not tile-aligned);
* the kernel rotates the 2-tile window to the span offset and masks the tail.

Output is tile-padded ragged: lane j of tile i is edge ``tw*T + j`` of item
``item_of_tile[i]``, or -1.  Downstream (dedup/routing) consumes the mask.

Why not one DMA per edge?  Degree skew (the paper sees degrees > 10M) makes
per-edge gathers pathological; per-tile streaming keeps the DMA engine at
line rate for any degree distribution.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _expand_kernel(item_ref, tw_ref, starts_ref, degs_ref,   # scalar prefetch
                   *refs, tile: int, n_pools: int, F: int):
    t = pl.program_id(0)
    in_refs = refs[:2 * n_pools]
    out_refs = refs[2 * n_pools:]
    item = item_ref[t]
    tw = tw_ref[t]
    item_c = jnp.minimum(item, F - 1)
    start = starts_ref[item_c] + tw * tile
    off = start % tile
    lane = jax.lax.iota(jnp.int32, tile)
    valid = (item < F) & (lane < degs_ref[item_c] - tw * tile)
    for p in range(n_pools):
        lo = in_refs[2 * p][...]
        hi = in_refs[2 * p + 1][...]
        window = jnp.roll(jnp.concatenate([lo, hi]), -off)[:tile]
        out_refs[p][...] = jnp.where(valid, window, -1)[None, :]


def expand(starts, degs, pools, item_of_tile, tw_of_tile, *, tile: int = 128,
           cap_tiles: int, interpret: bool = False):
    """See ref.expand; plan arrays are produced by ref.plan (jnp, cheap)."""
    F = degs.shape[0]
    E = pools[0].shape[0]
    n_pools = len(pools)
    # pad pools by two tiles so the +1 block fetch never leaves the array
    pools_p = tuple(jnp.pad(p, (0, 2 * tile), constant_values=-1)
                    for p in pools)
    n_blocks = (E + 2 * tile) // tile

    def mk_in_spec(plus_one):
        def index_map(t, item_ref, tw_ref, starts_ref, degs_ref):
            item = jnp.minimum(item_ref[t], F - 1)
            blk = (starts_ref[item] + tw_ref[t] * tile) // tile
            return (jnp.minimum(blk + plus_one, n_blocks - 1),)
        return pl.BlockSpec((tile,), index_map)

    in_specs = []
    for _ in range(n_pools):
        in_specs.append(mk_in_spec(0))
        in_specs.append(mk_in_spec(1))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(cap_tiles,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, tile), lambda t, *_: (t, 0))
                   for _ in range(n_pools)],
    )
    # inputs interleaved: each pool appears twice (tile t and t+1)
    args = []
    for p in pools_p:
        args += [p, p]
    outs = pl.pallas_call(
        functools.partial(_expand_kernel, tile=tile, n_pools=n_pools, F=F),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((cap_tiles, tile), jnp.int32)
                   for _ in range(n_pools)],
        interpret=interpret,
    )(item_of_tile, tw_of_tile, starts, degs, *args)
    return tuple(o.reshape(-1) for o in outs)
