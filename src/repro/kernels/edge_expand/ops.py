"""Jitted ragged CSR expansion: plan (jnp) + gather (Pallas)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.edge_expand import ref as _ref
from repro.kernels.edge_expand.kernel import expand as _kernel

_USE_KERNEL = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("tile", "cap_tiles"))
def edge_expand(starts, degs, pools, *, tile: int = 128, cap_tiles: int):
    """Expand ragged CSR spans to tile-padded output.

    Returns (outs, item_of_tile, overflow): outs[i] (cap_tiles*tile,) i32
    with -1 in invalid lanes; item_of_tile (cap_tiles,) maps output tiles
    back to frontier items (item == F means padding tile).
    """
    if _USE_KERNEL:
        item, tw, n_tiles, overflow = _ref.plan(degs, tile, cap_tiles)
        outs = _kernel(starts, degs, tuple(pools), item, tw, tile=tile,
                       cap_tiles=cap_tiles)
        return outs, item, overflow
    outs, item, overflow = _ref.expand(starts, degs, tuple(pools), tile,
                                       cap_tiles)
    return outs, item, overflow
