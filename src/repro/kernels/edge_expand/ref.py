"""Pure-jnp oracle for tile-padded ragged CSR expansion."""
import jax.numpy as jnp


def plan(degs, tile: int, cap_tiles: int):
    """Tile plan for a ragged expansion.

    Returns (item_of_tile, tw_of_tile, n_tiles, overflow): which frontier item
    and which tile-within-item each output tile serves.  Items with deg 0 get
    no tiles.  Padding tiles map to item = F (sentinel).
    """
    F = degs.shape[0]
    tiles_per = (degs + tile - 1) // tile
    cum = jnp.cumsum(tiles_per)
    n_tiles = cum[-1] if F else jnp.int32(0)
    k = jnp.arange(cap_tiles, dtype=jnp.int32)
    item = jnp.searchsorted(cum, k, side="right").astype(jnp.int32)
    item_c = jnp.minimum(item, F - 1)
    tw = k - (cum[item_c] - tiles_per[item_c])
    valid = k < n_tiles
    return (jnp.where(valid, item_c, F), jnp.where(valid, tw, 0),
            n_tiles, n_tiles > cap_tiles)


def expand(starts, degs, pools, tile: int, cap_tiles: int):
    """Gather ragged CSR spans into tile-padded output.

    starts/degs: (F,) absolute span offsets/lengths into each pool array.
    pools: tuple of (E,) i32 arrays gathered with identical indexing.
    Returns (outs, item_of_tile, overflow); outs[i] has shape
    (cap_tiles*tile,) with -1 in invalid lanes.
    """
    F = degs.shape[0]
    item, tw, n_tiles, overflow = plan(degs, tile, cap_tiles)
    lane = jnp.arange(tile, dtype=jnp.int32)
    item_c = jnp.minimum(item, F - 1)
    base = starts[item_c] + tw * tile                      # (cap_tiles,)
    pos = base[:, None] + lane[None, :]                    # (cap_tiles, tile)
    ok = ((item < F)[:, None]
          & (lane[None, :] < (degs[item_c] - tw * tile)[:, None]))
    pos_c = jnp.where(ok, pos, 0)
    outs = tuple(jnp.where(ok, p[pos_c], -1).reshape(-1) for p in pools)
    return outs, item, overflow
