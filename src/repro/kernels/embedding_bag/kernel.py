"""Embedding-bag Pallas TPU kernel (batched vertex-data read / recsys tables).

This is the A1 "two consecutive RDMA reads" hot path in kernel form: given a
bag of row ids, fetch rows from a (huge, HBM-resident) table and pool them.

TPU design: the table block index is *data-dependent* — scalar-prefetched ids
feed the BlockSpec ``index_map``, so the Pallas pipeline's double-buffered DMA
engine streams exactly the rows we need (the idiom paged-attention kernels
use for block tables).  Grid = (bags, slots); the slot axis is innermost and
accumulates into the output row; padding ids point at a zeroed sentinel row.

The table dtype rides through unchanged; accumulation is f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(ids_ref, counts_ref, row_ref, o_ref, acc_ref, *,
                mode: str, L: int):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += row_ref[...].astype(jnp.float32)

    @pl.when(l == L - 1)
    def _fin():
        acc = acc_ref[...]
        if mode == "mean":
            n = jnp.maximum(counts_ref[b], 1).astype(jnp.float32)
            acc = acc / n
        o_ref[...] = acc.astype(o_ref.dtype)


def embedding_bag(table, ids, *, mode: str = "sum",
                  interpret: bool = False):
    """table: (V, D); ids: (B, L) i32 with -1 padding.  Returns (B, D)."""
    V, D = table.shape
    B, L = ids.shape
    # sentinel zero row for padding ids
    table_x = jnp.concatenate(
        [table, jnp.zeros((1, D), table.dtype)], axis=0)
    safe_ids = jnp.where(ids >= 0, ids, V).astype(jnp.int32)
    counts = jnp.sum((ids >= 0).astype(jnp.int32), axis=1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, L),
        in_specs=[pl.BlockSpec((1, D), lambda b, l, ids_ref, cnt_ref:
                               (ids_ref[b, l], 0))],
        out_specs=pl.BlockSpec((1, D), lambda b, l, *_: (b, 0)),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bag_kernel, mode=mode, L=L),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(safe_ids, counts, table_x)
