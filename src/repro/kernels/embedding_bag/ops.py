"""Jitted embedding-bag with custom VJP (Pallas fwd, scatter-add bwd)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag as _kernel
from repro.kernels.embedding_bag.ref import embedding_bag as _ref

_USE_KERNEL = jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def embedding_bag(table, ids, mode: str = "sum"):
    if _USE_KERNEL:
        return _kernel(table, ids, mode=mode)
    return _ref(table, ids, mode=mode)


def _fwd(table, ids, mode):
    return embedding_bag(table, ids, mode), (table, ids)


def _bwd(mode, res, g):
    table, ids = res
    (V, D), dtype = table.shape, table.dtype
    mask = ids >= 0                                   # (B, L)
    if mode == "mean":
        n = jnp.maximum(mask.sum(axis=1, keepdims=True), 1)
        g = g / n.astype(g.dtype)
    safe = jnp.where(mask, ids, V)                    # OOB -> dropped
    gl = jnp.broadcast_to(g[:, None, :], ids.shape + (D,))
    dtable = jnp.zeros((V, D), g.dtype).at[safe.reshape(-1)].add(
        gl.reshape(-1, D) * mask.reshape(-1, 1), mode="drop")
    return dtable.astype(dtype), None


embedding_bag.defvjp(_fwd, _bwd)
