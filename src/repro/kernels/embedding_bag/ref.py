"""Pure-jnp oracle for embedding-bag (gather + segment pooling).

JAX has no native nn.EmbeddingBag; the reference composes ``jnp.take`` with a
masked reduction — exactly the composition the taxonomy (B.6) prescribes.
"""
import jax.numpy as jnp


def embedding_bag(table, ids, *, mode: str = "sum"):
    """table: (V, D); ids: (B, L) i32, -1 = padding.  Returns (B, D).

    mode: 'sum' | 'mean' (mean over non-padding entries; empty bag -> 0).
    """
    mask = (ids >= 0)
    safe = jnp.where(mask, ids, 0)
    rows = jnp.take(table, safe, axis=0)              # (B, L, D)
    rows = rows * mask[..., None].astype(table.dtype)
    out = rows.sum(axis=1)
    if mode == "mean":
        n = jnp.maximum(mask.sum(axis=1, keepdims=True), 1)
        out = out / n.astype(table.dtype)
    return out
