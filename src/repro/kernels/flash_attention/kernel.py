"""FlashAttention-2 Pallas TPU kernels: forward + backward.

MXU-aligned streaming attention for the assigned LM architectures:
  * causal and sliding-window (h2o-danube SWA) masking,
  * GQA: the kv head for a q head is resolved in the BlockSpec index_map —
    kv blocks are fetched once per q-head group position, never materialized
    repeated,
  * f32 running-softmax state (m, l) and accumulator in VMEM scratch,
  * backward = two kernels: dkv (grid over k blocks, streaming q) and dq
    (grid over q blocks, streaming k), with the standard
    ds = p * (dp - delta) recomputation from the saved LSE.

Block sizes default to (128, 128): MXU-native for head_dim 128.
Sequence lengths must be multiples of the block sizes (callers pad).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK = 128


def _dot(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _dot_t(a, b):
    """a @ b.T in f32."""
    return jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _mask(bq, bk, qi, ki, *, causal, window, q_offset):
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        m &= kpos <= qpos
    if window and window > 0:
        m &= kpos > qpos - window
    return m


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                scale, causal, window, q_offset, bq, bk, n_kb):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = _dot_t(q, k) * scale                             # (bq, bk)
    msk = _mask(bq, bk, qi, ki, causal=causal, window=window,
                q_offset=q_offset)
    s = jnp.where(msk, s, NEG_INF)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(msk, p, 0.0)
    alpha = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + _dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == n_kb - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l))[:, 0]


def flash_fwd(q, k, v, *, causal: bool, window: int, scale: float,
              q_offset: int = 0, block_q: int = DEFAULT_BLOCK,
              block_k: int = DEFAULT_BLOCK, interpret: bool = False):
    """q: (BHq, Sq, D) flattened batch*q-heads; k, v: (BHkv, Sk, D).

    Returns (out (BHq, Sq, D), lse (BHq, Sq)).  Requires Hq % Hkv == 0 in the
    flattened layout: caller passes group = Hq // Hkv via matching shapes.
    """
    BHq, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    assert BHq % BHkv == 0
    G = BHq // BHkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "pad sequence to block multiple"
    n_kb = Sk // bk
    grid = (BHq, Sq // bq, n_kb)

    kv_map = lambda h, qi, ki: (h // G, ki, 0)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, causal=causal,
                          window=window, q_offset=q_offset, bq=bq, bk=bk,
                          n_kb=n_kb),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
                  pl.BlockSpec((1, bk, D), kv_map),
                  pl.BlockSpec((1, bk, D), kv_map)],
        out_specs=[pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
                   pl.BlockSpec((1, bq), lambda h, qi, ki: (h, qi))],
        out_shape=[jax.ShapeDtypeStruct((BHq, Sq, D), q.dtype),
                   jax.ShapeDtypeStruct((BHq, Sq), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# backward: dkv kernel (grid over kv blocks, streaming q) and dq kernel
# ---------------------------------------------------------------------------

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, causal, window, q_offset, bq, bk, G, n_qb):
    # grid: (BHkv, Tk, G, Tq)
    ki, g, qi = pl.program_id(1), pl.program_id(2), pl.program_id(3)

    @pl.when((g == 0) & (qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)                   # (bq, d)
    lse = lse_ref[0]                                     # (bq,)
    delta = delta_ref[0]                                 # (bq,)

    s = _dot_t(q, k) * scale                             # (bq, bk)
    msk = _mask(bq, bk, qi, ki, causal=causal, window=window,
                q_offset=q_offset)
    p = jnp.where(msk, jnp.exp(s - lse[:, None]), 0.0)   # (bq, bk)
    dv_acc[...] += _dot(p.T, do)                         # (bk, d)
    dp = _dot_t(do, v)                                   # (bq, bk) = do @ v.T
    ds = p * (dp - delta[:, None]) * scale
    dk_acc[...] += _dot(ds.T, q)                         # (bk, d)

    @pl.when((g == G - 1) & (qi == n_qb - 1))
    def _fin():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_acc, *, scale, causal, window, q_offset, bq, bk, n_kb):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]

    s = _dot_t(q, k) * scale
    msk = _mask(bq, bk, qi, ki, causal=causal, window=window,
                q_offset=q_offset)
    p = jnp.where(msk, jnp.exp(s - lse[:, None]), 0.0)
    dp = _dot_t(do, v)
    ds = p * (dp - delta[:, None]) * scale
    dq_acc[...] += _dot(ds, k)

    @pl.when(ki == n_kb - 1)
    def _fin():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def flash_bwd(q, k, v, out, lse, do, *, causal: bool, window: int,
              scale: float, q_offset: int = 0,
              block_q: int = DEFAULT_BLOCK, block_k: int = DEFAULT_BLOCK,
              interpret: bool = False):
    """Returns (dq, dk, dv) with q/k/v's flattened-head layout."""
    BHq, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    G = BHq // BHkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    n_qb, n_kb = Sq // bq, Sk // bk
    delta = jnp.sum(out.astype(jnp.float32) * do.astype(jnp.float32),
                    axis=-1)                              # (BHq, Sq)

    # ---- dkv: grid (BHkv, Tk, G, Tq); q-head = kvh*G + g -------------------
    def qmap(kvh, ki, g, qi):
        return (kvh * G + g, qi, 0)

    def qmap2(kvh, ki, g, qi):
        return (kvh * G + g, qi)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, q_offset=q_offset, bq=bq, bk=bk,
                          G=G, n_qb=n_qb),
        grid=(BHkv, n_kb, G, n_qb),
        in_specs=[pl.BlockSpec((1, bq, D), qmap),
                  pl.BlockSpec((1, bk, D), lambda kvh, ki, g, qi: (kvh, ki, 0)),
                  pl.BlockSpec((1, bk, D), lambda kvh, ki, g, qi: (kvh, ki, 0)),
                  pl.BlockSpec((1, bq, D), qmap),
                  pl.BlockSpec((1, bq), qmap2),
                  pl.BlockSpec((1, bq), qmap2)],
        out_specs=[pl.BlockSpec((1, bk, D), lambda kvh, ki, g, qi: (kvh, ki, 0)),
                   pl.BlockSpec((1, bk, D), lambda kvh, ki, g, qi: (kvh, ki, 0))],
        out_shape=[jax.ShapeDtypeStruct((BHkv, Sk, D), k.dtype),
                   jax.ShapeDtypeStruct((BHkv, Sk, D), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # ---- dq: grid (BHq, Tq, Tk) --------------------------------------------
    kv_map = lambda h, qi, ki: (h // G, ki, 0)
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, q_offset=q_offset, bq=bq, bk=bk,
                          n_kb=n_kb),
        grid=(BHq, n_qb, n_kb),
        in_specs=[pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
                  pl.BlockSpec((1, bk, D), kv_map),
                  pl.BlockSpec((1, bk, D), kv_map),
                  pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
                  pl.BlockSpec((1, bq), lambda h, qi, ki: (h, qi)),
                  pl.BlockSpec((1, bq), lambda h, qi, ki: (h, qi))],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BHq, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
