"""Differentiable attention op: Pallas flash kernels on TPU, ref on CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.kernel import flash_bwd, flash_fwd

_USE_KERNEL = jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def mha(q, k, v, causal: bool = True, window: int = 0, q_offset: int = 0):
    """q: (B, Hq, S, D); k, v: (B, Hkv, Sk, D).  Flash attention."""
    if not _USE_KERNEL:
        return _ref.mha(q, k, v, causal=causal, window=window,
                        q_offset=q_offset)
    out, _ = _fwd_flat(q, k, v, causal, window, q_offset)
    return out


def _flatten(q, k, v):
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    return (q.reshape(B * Hq, Sq, D), k.reshape(B * Hkv, Sk, D),
            v.reshape(B * Hkv, Sk, D))


def _fwd_flat(q, k, v, causal, window, q_offset):
    B, Hq, Sq, D = q.shape
    qf, kf, vf = _flatten(q, k, v)
    out, lse = flash_fwd(qf, kf, vf, causal=causal, window=window,
                         scale=D ** -0.5, q_offset=q_offset)
    return out.reshape(q.shape), lse.reshape(B, Hq, Sq)


def _vjp_fwd(q, k, v, causal, window, q_offset):
    if not _USE_KERNEL:
        out = _ref.mha(q, k, v, causal=causal, window=window,
                       q_offset=q_offset)
        return out, (q, k, v, out, None)
    out, lse = _fwd_flat(q, k, v, causal, window, q_offset)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, window, q_offset, res, g):
    q, k, v, out, lse = res
    if not _USE_KERNEL:
        f = lambda q, k, v: _ref.mha(q, k, v, causal=causal, window=window,
                                     q_offset=q_offset)
        _, vjp = jax.vjp(f, q, k, v)
        return vjp(g)
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    qf, kf, vf = _flatten(q, k, v)
    dq, dk, dv = flash_bwd(
        qf, kf, vf, out.reshape(B * Hq, Sq, D),
        lse.reshape(B * Hq, Sq), g.reshape(B * Hq, Sq, D),
        causal=causal, window=window, scale=D ** -0.5, q_offset=q_offset)
    return (dq.reshape(q.shape), dk.reshape(k.shape), dv.reshape(v.shape))


mha.defvjp(_vjp_fwd, _vjp_bwd)
