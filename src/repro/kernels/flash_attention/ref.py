"""Pure-jnp oracle for (causal / sliding-window / GQA) attention."""
import jax.numpy as jnp

NEG_INF = -1e30


def attention_mask(sq: int, sk: int, *, causal: bool, window: int,
                   q_offset: int = 0):
    """(sq, sk) bool mask.  ``window > 0`` keeps keys within ``window`` of the

    query (sliding-window attention); ``q_offset`` shifts query positions
    (used for decode, where the single query sits at position sk-1)."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kpos <= qpos
    if window and window > 0:
        m &= kpos > qpos - window
    return m


def _softmax(s):
    mx = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - mx)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    return p / jnp.maximum(denom, 1e-30)


def mha(q, k, v, *, causal: bool = True, window: int = 0, scale=None,
        q_offset: int = 0):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); GQA via head repetition.

    Computes softmax(q k^T * scale + mask) v in f32; returns q's dtype.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    g = Hq // Hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    scale = (D ** -0.5) if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    m = attention_mask(Sq, Sk, causal=causal, window=window,
                       q_offset=q_offset)
    s = jnp.where(m[None, None], s, NEG_INF)
    p = _softmax(s)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
