"""Batched distance+top-k Pallas TPU kernel (the `Nearest` probe wave).

Hardware adaptation: the reference path materializes the full (R, N)
distance matrix in HBM and runs an XLA two-key sort over its whole width.
Here the embedding block stays resident in VMEM and each query row block
streams over it in tiles of 128 entries: one MXU matmul produces the
(br, 128) distance tile, MVCC + type visibility is masked in-register, and
the tile is merged into a running per-query top-KP buffer with a two-key
(dist, gid) bitonic network — the same compare-exchange idiom as
``dedup_compact``, with a float primary key.  The full-width distance
matrix never exists.

Bit-parity with the ref oracle: every distance is an independent
``||e||^2 - 2<v, e>`` dot over the (zero-padded) feature axis, so tiling N
cannot change any value; selection then orders identical (dist, gid) pairs
lexicographically, which has exactly one answer.  ``+ 0.0`` canonicalizes
-0.0 on both paths so the sort sees identical bit patterns.

Grid: (row_blocks,); the padded embedding block (N2, D2) plus per-entry
metadata lives in VMEM per program — at index caps (N ~ 8K, D <= 128 this
repro) that is ~4MB, well under budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32MAX = 2**31 - 1
BN = 128  # entry-tile width (MXU lane width)


def _pow2ceil(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def _stages(W: int):
    out = []
    k = 2
    while k <= W:
        j = k // 2
        while j >= 1:
            out.append((k, j))
            j //= 2
        k *= 2
    return out


def _partner(x, j):
    R, W = x.shape
    xr = x.reshape(R, W // (2 * j), 2, j)
    return xr[:, :, ::-1, :].reshape(R, W)


def _bitonic_fpairs(d, g, idx):
    """Two-key (f32 dist, i32 gid) bitonic ascending sort along axis 1."""
    W = d.shape[1]
    for k, j in _stages(W):
        pd, pg = _partner(d, j), _partner(g, j)
        le = (d < pd) | ((d == pd) & (g <= pg))     # self <= partner
        is_lower = (idx & j) == 0
        up = (idx & k) == 0
        keep_self = le == (is_lower == up)
        d = jnp.where(keep_self, d, pd)
        g = jnp.where(keep_self, g, pg)
    return d, g


def _knn_kernel(v_ref, e_ref, ee_ref, g_ref, vt_ref, cr_ref, dl_ref,
                qvt_ref, qts_ref, od_ref, og_ref, *,
                kp: int, bn: int, nt: int, d2: int):
    v = v_ref[...]                       # (br, D2) query block
    emb = e_ref[...]                     # (N2, D2) resident embedding block
    ee = ee_ref[...]                     # (1, N2)
    gid = g_ref[...]                     # (1, N2)
    vt = vt_ref[...]
    cr = cr_ref[...]
    dl = dl_ref[...]
    qvt = qvt_ref[...]                   # (br, 1)
    qts = qts_ref[...]                   # (br, 1)
    br = v.shape[0]

    W2 = _pow2ceil(kp + bn)
    idx = jax.lax.broadcasted_iota(jnp.int32, (br, W2), 1)
    INF = jnp.float32(jnp.inf)

    def tile(t, carry):
        d_buf, g_buf = carry
        e_t = jax.lax.dynamic_slice(emb, (t * bn, 0), (bn, d2))
        ee_t = jax.lax.dynamic_slice(ee, (0, t * bn), (1, bn))
        g_t = jax.lax.dynamic_slice(gid, (0, t * bn), (1, bn))
        vt_t = jax.lax.dynamic_slice(vt, (0, t * bn), (1, bn))
        cr_t = jax.lax.dynamic_slice(cr, (0, t * bn), (1, bn))
        dl_t = jax.lax.dynamic_slice(dl, (0, t * bn), (1, bn))
        ip = jnp.dot(v, e_t.T, preferred_element_type=jnp.float32)  # (br, bn)
        ok = (g_t >= 0) & (vt_t == qvt) & (cr_t <= qts) & (qts < dl_t)
        d = jnp.where(ok, (ee_t - 2.0 * ip) + 0.0, INF)
        g = jnp.where(ok, jnp.broadcast_to(g_t, ok.shape), I32MAX)
        cd = jnp.concatenate([d_buf, d], axis=1)                    # (br, kp+bn)
        cg = jnp.concatenate([g_buf, g], axis=1)
        if W2 > kp + bn:
            cd = jnp.pad(cd, ((0, 0), (0, W2 - kp - bn)),
                         constant_values=jnp.inf)
            cg = jnp.pad(cg, ((0, 0), (0, W2 - kp - bn)),
                         constant_values=I32MAX)
        cd, cg = _bitonic_fpairs(cd, cg, idx)
        return cd[:, :kp], cg[:, :kp]

    d_buf = jnp.full((br, kp), INF, jnp.float32)
    g_buf = jnp.full((br, kp), I32MAX, jnp.int32)
    d_buf, g_buf = jax.lax.fori_loop(0, nt, tile, (d_buf, g_buf))
    od_ref[...] = d_buf
    og_ref[...] = g_buf


def knn_topk(vecs, emb, gid, vtype, create, delete, q_vt, q_ts, k: int, *,
             block_r: int = 8, interpret: bool = False):
    """Pallas top-k nearest visible entries; see the ref oracle for the
    argument contract.  Returns ``(dist (R, k) f32, gids (R, k) i32)``."""
    R, D = vecs.shape
    N = emb.shape[0]
    kp = _pow2ceil(max(1, k))
    n2 = max(BN, pl.cdiv(max(1, N), BN) * BN)
    d2 = max(128, _pow2ceil(max(1, D)))
    br = min(block_r, max(1, R))
    r2 = pl.cdiv(R, br) * br

    v2 = jnp.pad(vecs.astype(jnp.float32), ((0, r2 - R), (0, d2 - D)))
    e2 = jnp.pad(emb.astype(jnp.float32), ((0, n2 - N), (0, d2 - D)))
    # ||e||^2 over the zero-padded feature axis: extra terms are exact +0.0,
    # so this matches the ref's unpadded sum bit-for-bit
    ee = jnp.sum(e2 * e2, axis=1)[None, :]
    g2 = jnp.pad(gid, (0, n2 - N), constant_values=-1)[None, :]
    vt2 = jnp.pad(vtype, (0, n2 - N), constant_values=-1)[None, :]
    cr2 = jnp.pad(create, (0, n2 - N), constant_values=I32MAX)[None, :]
    dl2 = jnp.pad(delete, (0, n2 - N), constant_values=0)[None, :]
    qvt2 = jnp.pad(q_vt, (0, r2 - R), constant_values=-2)[:, None]
    qts2 = jnp.pad(q_ts, (0, r2 - R), constant_values=0)[:, None]

    row = lambda r: (r, 0)
    full = lambda r: (0, 0)
    od, og = pl.pallas_call(
        functools.partial(_knn_kernel, kp=kp, bn=BN, nt=n2 // BN, d2=d2),
        grid=(pl.cdiv(r2, br),),
        in_specs=[pl.BlockSpec((br, d2), row),      # queries
                  pl.BlockSpec((n2, d2), full),     # embeddings
                  pl.BlockSpec((1, n2), full),      # ||e||^2
                  pl.BlockSpec((1, n2), full),      # gid
                  pl.BlockSpec((1, n2), full),      # vtype
                  pl.BlockSpec((1, n2), full),      # create ts
                  pl.BlockSpec((1, n2), full),      # delete ts
                  pl.BlockSpec((br, 1), row),       # query vtype
                  pl.BlockSpec((br, 1), row)],      # query snapshot ts
        out_specs=[pl.BlockSpec((br, kp), row),
                   pl.BlockSpec((br, kp), row)],
        out_shape=[jax.ShapeDtypeStruct((r2, kp), jnp.float32),
                   jax.ShapeDtypeStruct((r2, kp), jnp.int32)],
        interpret=interpret,
    )(v2, e2, ee, g2, vt2, cr2, dl2, qvt2, qts2)
    if kp < k:  # unreachable (kp = pow2ceil(k) >= k); keep the slice honest
        raise AssertionError("kp < k")
    return od[:R, :k], og[:R, :k]
