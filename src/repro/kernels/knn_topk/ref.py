"""Reference batched k-NN: squared-L2 distance + per-query top-k.

The oracle for the ``knn_topk`` pallas kernel.  Given a batch of query
vectors and the flat vector-index arrays (``core/vindex.py``), returns for
each query the ``k`` nearest *visible* entries of the requested vertex type.

Distance is the gid-monotone surrogate ``||e||^2 - 2 <v, e>`` (the query's
own ``||v||^2`` term is constant per row and dropped), so values can be
negative.  Ties are broken by ascending gid via a two-key sort, which makes
the selection deterministic and backend-independent.  Invalid slots come
back as ``(+inf, I32MAX)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# plain int, NOT jnp.int32(...): this module is imported lazily from inside
# jitted programs, and a module-level device constant created mid-trace
# leaks a tracer
I32MAX = 2**31 - 1


def knn_topk(vecs, emb, gid, vtype, create, delete, q_vt, q_ts, k: int):
    """Top-k nearest visible entries per query row.

    vecs:   (R, D) f32 query vectors
    emb:    (N, D) f32 index embeddings
    gid:    (N,)   i32 entry vertex gid (NULL = empty slot)
    vtype:  (N,)   i32 entry vertex type
    create: (N,)   i32 MVCC create ts
    delete: (N,)   i32 MVCC delete ts (TS_INF = live)
    q_vt:   (R,)   i32 per-query type filter
    q_ts:   (R,)   i32 per-query snapshot ts
    k:      static int

    Returns ``(dist (R, k) f32, gids (R, k) i32)`` sorted ascending by
    ``(dist, gid)``; slots past the number of matches are ``(+inf, I32MAX)``.
    """
    R = vecs.shape[0]
    vecs = vecs.astype(jnp.float32)
    emb = emb.astype(jnp.float32)
    ee = jnp.sum(emb * emb, axis=1)  # (N,)
    ip = jnp.dot(vecs, emb.T, preferred_element_type=jnp.float32)  # (R, N)
    ok = (
        (gid >= 0)[None, :]
        & (vtype[None, :] == q_vt[:, None])
        & (create[None, :] <= q_ts[:, None])
        & (q_ts[:, None] < delete[None, :])
    )
    # `+ 0.0` canonicalizes -0.0 so both backends sort identical bit patterns.
    d = jnp.where(ok, (ee[None, :] - 2.0 * ip) + 0.0, jnp.inf)
    g = jnp.where(ok, jnp.broadcast_to(gid[None, :], ok.shape), I32MAX)
    ds, gs = jax.lax.sort((d, g), dimension=1, num_keys=2)
    N = emb.shape[0]
    if N < k:  # fewer index slots than requested neighbours: pad out
        ds = jnp.pad(ds, ((0, 0), (0, k - N)), constant_values=jnp.inf)
        gs = jnp.pad(gs, ((0, 0), (0, k - N)), constant_values=2**31 - 1)
    return ds[:, :k], gs[:, :k]
