"""Fused RMSNorm Pallas TPU kernel.

One pass over rows resident in VMEM: mean-of-squares reduction + rsqrt +
scale, f32 accumulation regardless of input dtype.  Grid tiles the row
dimension; the feature dimension stays whole (d_model <= a few K fits VMEM
lanes; callers pad d to a multiple of 128 for lane alignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_fwd(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                interpret: bool = False):
    """x: (..., d); scale: (d,).  Returns same shape/dtype as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    n = x.size // d
    x2 = x.reshape(n, d)
    br = min(block_rows, n)
    grid = (pl.cdiv(n, br),)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
