"""Jitted RMSNorm op with custom VJP (Pallas forward, analytic backward)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_fwd
from repro.kernels.rmsnorm.ref import rmsnorm as rmsnorm_ref

_USE_KERNEL = jax.default_backend() == "tpu"   # ref on CPU (incl. dry-run
                                               # lowering); kernel on TPU.
                                               # Interpret-mode kernel parity
                                               # is covered by tests/.


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, scale, eps: float = 1e-6):
    if _USE_KERNEL:
        return rmsnorm_fwd(x, scale, eps=eps)
    return rmsnorm_ref(x, scale, eps=eps)


def _fwd(x, scale, eps):
    return rmsnorm(x, scale, eps), (x, scale)


def _bwd(eps, res, g):
    x, scale = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    d = x.shape[-1]
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    xhat = xf * r
    gs = gf * sf
    dx = r * (gs - xhat * jnp.mean(gs * xhat, axis=-1, keepdims=True))
    dscale = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rmsnorm.defvjp(_fwd, _bwd)
