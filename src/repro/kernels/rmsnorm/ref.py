"""Pure-jnp oracle for fused RMSNorm."""
import jax.numpy as jnp


def rmsnorm(x, scale, *, eps: float = 1e-6):
    """y = x / rms(x) * scale, reduced over the last axis in f32."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
