"""ELL fused gather-GEMM-scale Pallas TPU kernel (GNN message passing).

FusedMM-style (taxonomy B.3): aggregate K scalar-prefetch-gathered neighbor
rows in a VMEM accumulator, then apply the (resident) weight matrix on the
MXU at the last slot — the gather never round-trips through HBM.  Padding
neighbor ids point at a zeroed sentinel row, so no mask math in the loop.

Grid = (rows, K) with K innermost; the row's output block is revisited only
within its own K-run, so the accumulator scratch carries across steps safely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmm_kernel(ids_ref, norm_ref, x_ref, w_ref, o_ref, acc_ref, *,
                 K: int, use_norm: bool, use_w: bool):
    r = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[...].astype(jnp.float32)

    @pl.when(k == K - 1)
    def _fin():
        acc = acc_ref[...]
        if use_norm:
            acc = acc * norm_ref[r]
        if use_w:
            acc = jax.lax.dot_general(
                acc, w_ref[...].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        o_ref[...] = acc.astype(o_ref.dtype)


def segment_spmm(x, ids, w=None, norm=None, *, interpret: bool = False):
    """x: (N, D); ids: (R, K) i32 (-1 pad); w: (D, Dout)?; norm: (R,)?"""
    N, D = x.shape
    R, K = ids.shape
    use_w, use_norm = w is not None, norm is not None
    d_out = w.shape[1] if use_w else D
    x_pad = jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], axis=0)
    safe = jnp.where(ids >= 0, ids, N).astype(jnp.int32)
    norm_a = (norm.astype(jnp.float32) if use_norm
              else jnp.ones((R,), jnp.float32))
    w_a = w if use_w else jnp.zeros((D, 1), x.dtype)

    in_specs = [pl.BlockSpec((1, D), lambda r, k, ids_ref, n_ref:
                             (ids_ref[r, k], 0))]
    if use_w:
        in_specs.append(pl.BlockSpec((D, d_out), lambda r, k, *_: (0, 0)))
    else:
        in_specs.append(pl.BlockSpec((D, 1), lambda r, k, *_: (0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R, K),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, d_out), lambda r, k, *_: (r, 0)),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_spmm_kernel, K=K, use_norm=use_norm, use_w=use_w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, d_out), x.dtype),
        interpret=interpret,
    )(safe, norm_a, x_pad, w_a)
