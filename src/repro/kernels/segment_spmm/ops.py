"""Jitted fused gather-GEMM with custom VJP."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_spmm.kernel import segment_spmm as _kernel
from repro.kernels.segment_spmm.ref import segment_spmm as _ref

_USE_KERNEL = jax.default_backend() == "tpu"


@jax.custom_vjp
def segment_spmm(x, ids, w, norm):
    """Differentiable wrt x and w (ids/norm are structure)."""
    if _USE_KERNEL:
        return _kernel(x, ids, w, norm)
    return _ref(x, ids, w, norm)


def _fwd(x, ids, w, norm):
    return segment_spmm(x, ids, w, norm), (x, ids, w, norm)


def _bwd(res, g):
    x, ids, w, norm = res
    mask = ids >= 0
    safe = jnp.where(mask, ids, x.shape[0])
    # recompute the aggregation for dw (cheap relative to the gather)
    rows = x[jnp.where(mask, ids, 0)] * mask[..., None].astype(x.dtype)
    aggregated = rows.sum(axis=1)
    if norm is not None:
        aggregated = aggregated * norm[:, None].astype(x.dtype)
    dw = aggregated.T @ g
    gx_rows = g @ w.T                                  # (R, D)
    if norm is not None:
        gx_rows = gx_rows * norm[:, None].astype(g.dtype)
    gl = jnp.broadcast_to(gx_rows[:, None, :], ids.shape + (x.shape[1],))
    dx = jnp.zeros_like(x, shape=(x.shape[0] + 1, x.shape[1])).at[
        safe.reshape(-1)].add(gl.reshape(-1, x.shape[1])
                              * mask.reshape(-1, 1))[:x.shape[0]]
    return dx.astype(x.dtype), None, dw.astype(w.dtype), None


segment_spmm.defvjp(_fwd, _bwd)
