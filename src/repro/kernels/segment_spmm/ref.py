"""Pure-jnp oracle for ELL-format fused gather-GEMM (GNN message passing)."""
import jax.numpy as jnp


def segment_spmm(x, ids, w=None, norm=None):
    """y[r] = (sum_k x[ids[r, k]]) * norm[r] @ w.

    x: (N, D) node features; ids: (R, K) i32 neighbor lists, -1 = padding;
    w: optional (D, Dout); norm: optional (R,) scale (e.g. 1/deg for GCN).
    Returns (R, Dout or D).
    """
    mask = ids >= 0
    safe = jnp.where(mask, ids, 0)
    rows = x[safe] * mask[..., None].astype(x.dtype)       # (R, K, D)
    agg = rows.sum(axis=1)
    if norm is not None:
        agg = agg * norm[:, None].astype(x.dtype)
    if w is not None:
        agg = agg @ w
    return agg
