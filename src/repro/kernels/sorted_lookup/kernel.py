"""Sorted-index probe Pallas TPU kernel (the primary-index BTree of §3.1).

Hardware adaptation (DESIGN.md §7): a cached high-fanout BTree probe is a
pointer-chasing log(N) walk — hostile to a vector unit.  On TPU the index is
a *sorted array* and the left-insertion position is ``count(keys < q)``,
computed by streaming the key array block-by-block through VMEM and summing
vectorized compares.  For per-shard index sizes (<= a few hundred K entries)
this linear-scan-with-128-lanes beats the serialized binary search by a wide
margin, and the access pattern is a perfect sequential prefetch.

Grid: (query_blocks, key_blocks); the key dimension is the innermost
(sequential) axis, accumulating partial counts into the output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32MAX = 2**31 - 1


def _probe_kernel(k_ref, q_ref, o_ref):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    keys = k_ref[...]          # (bk,)
    qs = q_ref[...]            # (bq,)
    # count(keys < q) for each query lane
    lt = (keys[None, :] < qs[:, None]).astype(jnp.int32)    # (bq, bk)
    o_ref[...] += jnp.sum(lt, axis=1)


def searchsorted_left(keys, queries, *, block_q: int = 512,
                      block_k: int = 2048, interpret: bool = False):
    """keys: (N,) sorted i32 (pad with INT32_MAX); queries: (Q,) i32.

    Returns (Q,) i32 left insertion positions.
    """
    n, q = keys.shape[0], queries.shape[0]
    bq, bk = min(block_q, q), min(block_k, n)
    padq = pl.cdiv(q, bq) * bq - q
    padn = pl.cdiv(n, bk) * bk - n
    keys_p = jnp.pad(keys, (0, padn), constant_values=I32MAX)
    queries_p = jnp.pad(queries, (0, padq), constant_values=I32MAX)
    grid = (pl.cdiv(q + padq, bq), pl.cdiv(n + padn, bk))
    out = pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bk,), lambda i, j: (j,)),
                  pl.BlockSpec((bq,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((q + padq,), jnp.int32),
        interpret=interpret,
    )(keys_p, queries_p)
    # padded keys are INT32_MAX: counted as >= any query, so no correction
    return out[:q]


def _probe_ranged_kernel(k_ref, q_ref, lo_ref, hi_ref, o_ref, *, bk: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    keys = k_ref[...]          # (bk,)
    qs = q_ref[...]            # (bq,)
    pos = kb * bk + jax.lax.iota(jnp.int32, bk)            # global key index
    lt = ((keys[None, :] < qs[:, None])
          & (pos[None, :] >= lo_ref[...][:, None])
          & (pos[None, :] < hi_ref[...][:, None]))
    o_ref[...] += jnp.sum(lt.astype(jnp.int32), axis=1)


def searchsorted_left_ranged(keys, queries, lo, hi, *, block_q: int = 512,
                             block_k: int = 2048, interpret: bool = False):
    """Per-query windowed probe over a block-major array of sorted runs.

    The primary index is shard-major: ``keys`` holds S independently sorted
    blocks back to back.  Each query carries its own window ``[lo, hi)`` (its
    shard's block); the result is the left insertion position *within* the
    window, i.e. ``count(keys[lo:hi] < q)`` — one streamed pass over the key
    array serves every shard at once (the batched analogue of A1 probing S
    BTrees with one wave of RDMA reads).

    keys: (N,) i32, sorted within each window; queries/lo/hi: (Q,) i32.
    Returns (Q,) i32 window-relative positions.
    """
    n, q = keys.shape[0], queries.shape[0]
    bq, bk = min(block_q, q), min(block_k, n)
    padq = pl.cdiv(q, bq) * bq - q
    padn = pl.cdiv(n, bk) * bk - n
    keys_p = jnp.pad(keys, (0, padn), constant_values=I32MAX)
    queries_p = jnp.pad(queries, (0, padq), constant_values=I32MAX)
    # padded queries get an empty window: count stays 0
    lo_p = jnp.pad(lo.astype(jnp.int32), (0, padq), constant_values=0)
    hi_p = jnp.pad(hi.astype(jnp.int32), (0, padq), constant_values=0)
    grid = (pl.cdiv(q + padq, bq), pl.cdiv(n + padn, bk))
    out = pl.pallas_call(
        functools.partial(_probe_ranged_kernel, bk=bk),
        grid=grid,
        in_specs=[pl.BlockSpec((bk,), lambda i, j: (j,)),
                  pl.BlockSpec((bq,), lambda i, j: (i,)),
                  pl.BlockSpec((bq,), lambda i, j: (i,)),
                  pl.BlockSpec((bq,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((q + padq,), jnp.int32),
        interpret=interpret,
    )(keys_p, queries_p, lo_p, hi_p)
    return out[:q]
