"""Jitted wrapper for the sorted-index probe."""
from __future__ import annotations

import functools

import jax

from repro.kernels.sorted_lookup.kernel import searchsorted_left as _kernel
from repro.kernels.sorted_lookup.ref import searchsorted_left as _ref

_USE_KERNEL = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def searchsorted_left(keys, queries, *, block_q: int = 512,
                      block_k: int = 2048):
    if _USE_KERNEL:
        return _kernel(keys, queries, block_q=block_q, block_k=block_k)
    return _ref(keys, queries)
