"""Pure-jnp oracle for the sorted-index probe (BTree analogue)."""
import jax.numpy as jnp


def searchsorted_left(keys, queries):
    """Left insertion point of each query in sorted ``keys``.

    Identical semantics to ``jnp.searchsorted(keys, queries, side='left')``:
    the number of keys strictly less than the query.
    """
    return jnp.searchsorted(keys, queries, side="left").astype(jnp.int32)
