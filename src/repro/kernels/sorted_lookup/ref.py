"""Pure-jnp oracle for the sorted-index probe (BTree analogue)."""
import jax.numpy as jnp


def searchsorted_left(keys, queries):
    """Left insertion point of each query in sorted ``keys``.

    Identical semantics to ``jnp.searchsorted(keys, queries, side='left')``:
    the number of keys strictly less than the query.
    """
    return jnp.searchsorted(keys, queries, side="left").astype(jnp.int32)


def searchsorted_left_ranged(keys, queries, lo, hi):
    """Window-relative left insertion point: ``count(keys[lo:hi] < q)``.

    ``keys`` need only be sorted within each query's ``[lo, hi)`` window
    (the shard-major primary index).  O(Q*N) reference; the kernel streams
    the same compare-and-count.
    """
    pos = jnp.arange(keys.shape[0], dtype=jnp.int32)
    lt = ((keys[None, :] < queries[:, None])
          & (pos[None, :] >= lo[:, None]) & (pos[None, :] < hi[:, None]))
    return jnp.sum(lt.astype(jnp.int32), axis=1)
