"""Cluster front: N coordinators over one shared store (Fig. 4).

This is the paper's SLB -> coordinator-fleet shape on one host.  An
:class:`A1Frontend` owns the store seam and the routing table; N
:class:`Coordinator` workers each wrap today's :class:`~repro.launch.serve.
A1Server` admission machinery (read/write waves, SLO budgets, breakers,
continuations) and answer frame-encoded requests.

**The shared-store seam** (workers must not duplicate the CSR/index
arrays — the contract ``core/README.md`` documents):

  * ``mode="inproc"`` — the fleet shares ONE ``GraphDB`` object rehydrated
    via ``FastRestartCache.restart``: every coordinator literally maps the
    same host/device buffers, writes are fleet-visible immediately, and
    chaos schedules are deterministic.  This is the default and the mode
    the acceptance contract (mixed read/write/nearest traffic) runs in.
  * ``mode="process"`` — the frontend ``export_shared``-publishes the held
    slot as one POSIX shared-memory segment and spawns real worker
    processes that ``attach_shared``-map the same pages (one host copy of
    the graph; each worker pays only its own §5.3 device re-attach) and
    serve JSON frames over TCP.  Writes are **fleet-visible** here too:
    the elected primary commits mutation waves against its own device
    arrays and the frontend ships the committed wave records (§4) to
    every replica, which tail-replays them at the ORIGINAL commit
    timestamps — MVCC snapshots and physical gids agree fleet-wide, and a
    read routed to any alive coordinator sees an acked write within the
    advertised replication lag (``/stats``).

**Membership, epochs, failover** (:mod:`repro.core.membership`).  The
frontend is the configuration manager: every worker holds a heartbeat
lease; a worker that misses renewals goes suspect, then evicted, and
every configuration change bumps a monotonic **epoch**.  All frames are
stamped with the sender's epoch — a coordinator that sees a stale epoch
bounces the frame (``STALE_EPOCH``, the fencing token), and a deposed
primary's wave close is refused by its ``write_fence`` before the store
is touched.  When the primary's lease expires (or its crash is detected)
the most caught-up replica is elected, promoted with the WAL tail it has
not yet applied, and write waves resume; an acked commit is never lost,
and an unacked in-flight write either resolves to its ORIGINAL result
via rid-idempotent replay (exactly once) or answers
``ABORTED_FAILOVER`` with a retry hint — never a silent drop.

**SLB routing.**  Fresh queries go to the least-loaded coordinator — the
load signal is each worker's wave-wall EWMA (``_wave_ms``) times its
queue depth, piggybacked on every response (``_load``).  Continuation and
gid-cursor state is *owned*: public tokens/ids are stamped
``"<cid>:<id>"`` and routed back to the stamped coordinator.  Ownership is
verified at the receiver (a stale SLB view — the ``cluster.route.stale``
site — bounces with ``WRONG_OWNER`` and the frontend re-routes; the wrong
worker never answers from the wrong state).

**Takeover.**  When a token's owner is gone, the frontend — which is
pin-of-record for every routed token's snapshot timestamp — picks a new
coordinator and sends ``adopt``: re-plan the select at the token's pinned
``read_ts``, fast-forward past the rows the client already consumed, and
assert the replayed prefix is bit-identical (MVCC at a pinned snapshot
makes the replay deterministic; divergence is a bug, not a condition to
handle).  The client's token keeps working across the crash.

**SLO budgets.**  Each request carries a budget (default 100 ms).  The
frontend spends from it at the route stage (an already-exhausted budget
answers sub-millisecond at the front door, never touching a worker), the
coordinator's admission spends it through queueing/wave/hedge
(:mod:`repro.launch.serve`), and ``/stats`` aggregates the per-stage
spend histograms fleet-wide.
"""
from __future__ import annotations

import collections
import time
import uuid
from typing import Optional

import numpy as np

from repro.core import faults as faults_mod
from repro.core import tasks as tasks_mod
from repro.core import writes as writes_mod
from repro.core.membership import Membership
from repro.core.recovery import FastRestartCache
from repro.core.replication import ObjectStore, ReplicationLog
from repro.launch.serve import A1Server
from repro.launch.transport import (MemoryChannel, WorkerClient,
                                    decode_write_op, encode_write_op,
                                    serve_worker)

_RID_CACHE = 4096


class _PinBoard:
    """Process-mode frontend store handle: the pin-of-record list and the
    fault-injector mount, without duplicating any store arrays (the
    workers map the shared segment; the frontend keeps only metadata)."""

    def __init__(self):
        self.active_query_ts: list[int] = []
        self.faults = None


class Coordinator:
    """One serving worker: an :class:`A1Server` behind a frame handler.

    Every mutating request carries a client-chosen ``rid``; responses are
    cached so a retransmit (duplicate frame after a lost response) returns
    the *original* answer instead of re-executing — at-least-once delivery
    with exactly-once effects, which is what makes result polling
    idempotent under ``transport.drop`` chaos.

    Each coordinator also tracks the configuration ``epoch`` and its
    ``role`` ("primary" commits write waves; "replica" refuses them).  A
    frame stamped with an older epoch bounces ``STALE_EPOCH`` — the
    fencing token of §2/FaRM — and a frame that proves a NEWER config in
    which someone else is primary demotes this coordinator on the spot
    (staged writes answer ``ABORTED_FAILOVER``; the store is untouched).
    Promotion is only ever explicit (the ``promote`` op, which carries
    the WAL tail this replica has not yet applied)."""

    def __init__(self, cid: int, db, *, role: str = "primary",
                 fence=None, **server_kw):
        self.cid = int(cid)
        self.role = role
        self.epoch = 1
        self.fence = fence            # extra membership fence (inproc CM)
        self.server = A1Server(db, write_fence=self._write_fence,
                               **server_kw)
        self._rids: "collections.OrderedDict[str, dict]" = \
            collections.OrderedDict()
        import threading
        self._lock = threading.Lock()

    def _write_fence(self) -> bool:
        """Commit-time check: may this coordinator close a write wave?"""
        if self.role != "primary":
            return False
        if self.fence is not None and not self.fence():
            return False              # the CM's view says we were deposed
        return True

    def _demote(self) -> None:
        self.role = "replica"
        self.server.abort_staged_writes("primary deposed")

    # -- dispatch -------------------------------------------------------
    def handle(self, msg: dict) -> dict:
        with self._lock:
            e = msg.get("epoch")
            if e is not None:
                e = int(e)
                if e < self.epoch:
                    # fencing: a frame from a configuration the fleet has
                    # left.  Bounced, NOT rid-cached — the sender restamps
                    # at the current epoch and retries under a fresh rid.
                    s = self.server
                    return {"status": "STALE_EPOCH", "epoch": self.epoch,
                            "_load": {"wave_ms": s._wave_ms,
                                      "inflight": (len(s._read_q)
                                                   + len(s._write_q))}}
                if e > self.epoch:
                    self.epoch = e
                    db = self.server.db
                    db.config_epoch = max(
                        getattr(db, "config_epoch", 0), e)
                p = msg.get("primary")
                if (p is not None and int(p) != self.cid
                        and self.role == "primary"):
                    self._demote()    # the new config elected someone else
            rid = msg.get("rid")
            if rid is not None and rid in self._rids:
                return self._rids[rid]
            try:
                resp = self._dispatch(msg)
            except faults_mod.InjectedFault:
                raise                          # chaos wants these visible
            except (KeyError, ValueError, TypeError) as e:
                resp = {"status": "ERROR", "reason": str(e)}
            s = self.server
            resp["_load"] = {
                "wave_ms": s._wave_ms,
                "inflight": len(s._read_q) + len(s._write_q)}
            if rid is not None:
                self._rids[rid] = resp
                while len(self._rids) > _RID_CACHE:
                    self._rids.popitem(last=False)
            return resp

    def _dispatch(self, msg: dict) -> dict:
        op = msg["op"]
        s = self.server
        if op == "query":
            qid = s.submit_query(msg["doc"],
                                 tenant=msg.get("tenant", "default"),
                                 qclass=msg.get("qclass", "q"),
                                 budget_ms=msg.get("budget_ms"))
            return {"status": "OK", "qid": qid}
        if op == "result":
            return {"status": "OK", "result": s.query_result(msg["qid"])}
        if op == "select_paged":
            rows, token = s.select_paged(msg["doc"],
                                         read_ts=msg.get("read_ts"))
            read_ts = (s._continuations[token].read_ts
                       if token is not None else None)
            return {"status": "OK", "rows": rows.tolist(), "token": token,
                    "read_ts": read_ts}
        if op == "next_page":
            owner = msg.get("owner", self.cid)
            if int(owner) != self.cid:
                # stale SLB view: never answer for state we don't own
                return {"status": "WRONG_OWNER", "owner": owner}
            try:
                rows, token = s.next_page(msg["token"])
            except KeyError:
                return {"status": "EXPIRED"}
            return {"status": "OK", "rows": rows.tolist(), "token": token}
        if op == "adopt":
            return self._adopt(msg)
        if op == "write":
            if self.role != "primary":
                # stale SLB view of the primaryship: bounce, never stage a
                # write on a replica (it could only ever abort or fork)
                return {"status": "NOT_PRIMARY", "epoch": self.epoch}
            wid = s.submit_write([decode_write_op(d) for d in msg["ops"]],
                                 budget_ms=msg.get("budget_ms"),
                                 wid=msg.get("wid"))
            return {"status": "OK", "wid": wid}
        if op == "write_result":
            return {"status": "OK", "result": s.write_result(msg["wid"])}
        if op == "write_by_rid":
            # failover resolution: did a wave with this rid ever commit
            # here (directly or via replay)?  Exactly-once by construction.
            hit = getattr(s.db, "applied_rids", {}).get(msg["wid"])
            if hit is None:
                return {"status": "OK", "result": None}
            return {"status": "OK",
                    "result": {"status": "COMMITTED", "reason": None,
                               "gids": list(hit["gids"]),
                               "ts": int(hit["ts"])}}
        if op == "heartbeat":
            # lease renewal carrying the CM's pin-of-record (fleet pins
            # hold MVCC GC on every replica) and returning how far this
            # worker's replication frontier has advanced
            if "pins" in msg:
                s.db.fleet_pins = [int(t) for t in msg["pins"]]
            return {"status": "OK", "role": self.role, "epoch": self.epoch,
                    "applied_seq": int(getattr(s.db, "wave_seq", 0)),
                    "gc_ts": int(s.db.gc_ts())}
        if op == "ship":
            # primary-side: hand the CM every committed wave record past
            # the durable/replicated frontier (§4 replication log pull)
            after = int(msg.get("after", 0))
            return {"status": "OK",
                    "waves": [r for r in getattr(s.db, "wave_log", ())
                              if r["seq"] > after],
                    "seq": int(getattr(s.db, "wave_seq", 0))}
        if op == "replicate":
            # replica-side: queue the shipped records on the wave inbox
            # and drain them through the tail-replay task (idempotent by
            # seq, applied at the ORIGINAL commit timestamps)
            fresh = [r for r in msg.get("waves", ())
                     if int(r["seq"]) > s.db.wave_seq]
            if fresh:
                s.db.wave_inbox.extend(fresh)
                s.tasks.enqueue(tasks_mod.wave_replay_task())
                guard = 0
                while s.db.wave_inbox and guard < 10_000:
                    s.tasks.pump()
                    guard += 1
            if "pins" in msg:
                s.db.fleet_pins = [int(t) for t in msg["pins"]]
            return {"status": "OK", "applied_seq": int(s.db.wave_seq)}
        if op == "promote":
            # failover: replay the WAL tail to the commit frontier, then
            # take the primaryship at the new epoch
            for rec in msg.get("waves", ()):
                writes_mod.replay_wave(s.db, rec)
            self.role = "primary"
            self.epoch = max(self.epoch, int(msg["epoch"]))
            s.db.config_epoch = max(
                getattr(s.db, "config_epoch", 0), self.epoch)
            return {"status": "OK", "applied_seq": int(s.db.wave_seq)}
        if op == "pump":
            return {"status": "OK", "n": s.pump()}
        if op == "flush":
            return {"status": "OK",
                    "n": s.flush_queries() + s.flush_writes()}
        if op == "stats":
            st = dict(s.stats)
            st["role"] = self.role
            st["epoch"] = self.epoch
            st["wave_seq"] = int(getattr(s.db, "wave_seq", 0))
            return {"status": "OK", "stats": st,
                    "latency": s.latency_report(),
                    "breakers": s.breaker_state()}
        return {"status": "ERROR", "reason": f"unknown op {op!r}"}

    def _adopt(self, msg: dict) -> dict:
        """Takeover: replay a lost coordinator's paged select here.

        Re-plans at the token's pinned ``read_ts`` (the frontend holds
        that pin, so the snapshot is guaranteed live), fast-forwards whole
        pages past the rows the client already consumed, and proves the
        replayed prefix bit-identical — the MVCC contract that makes
        coordinator crashes invisible to paging clients."""
        served = [int(x) for x in msg["served"]]
        rows, token = self.server.select_paged(
            msg["doc"], read_ts=int(msg["read_ts"]))
        consumed = rows.tolist()
        while len(consumed) < len(served) and token is not None:
            page, token = self.server.next_page(token)
            consumed += page.tolist()
        if consumed[:len(served)] != served:
            return {"status": "DIVERGED",
                    "reason": "replayed prefix differs from served rows"}
        return {"status": "OK", "token": token,
                "read_ts": (self.server._continuations[token].read_ts
                            if token is not None else None),
                "leftover": consumed[len(served):]}


# ---------------------------------------------------------------------------
# worker handles
# ---------------------------------------------------------------------------

class _InprocWorker:
    """A coordinator in this process behind a frame-faithful channel."""

    def __init__(self, cid: int, coord: Coordinator, owner):
        self.cid = cid
        self.coord = coord
        self.chan = MemoryChannel(coord.handle, owner)
        self.alive = True

    def request(self, msg: dict) -> Optional[dict]:
        if not self.alive:
            return None
        return self.chan.request(msg)

    def kill(self) -> None:
        self.alive = False
        # a dead coordinator's own continuation pins must not block MVCC
        # GC on the SHARED store (a process-mode worker's pins die with
        # its process; the inproc analogue is explicit).  The frontend's
        # pin-of-record keeps takeover-able snapshots alive regardless.
        srv = self.coord.server
        for c in srv._continuations.values():
            try:
                srv.db.active_query_ts.remove(c.read_ts)
            except ValueError:
                pass
        srv._continuations.clear()


class _ProcWorker:
    """A spawned coordinator process behind a TCP frame client."""

    def __init__(self, cid: int, proc, client: WorkerClient):
        self.cid = cid
        self.proc = proc
        self.client = client
        self.alive = True

    @property
    def suspect(self) -> bool:
        """Hung (recv timeout), as opposed to dead: the membership layer
        stops renewing its lease instead of evicting on the spot."""
        return self.client.suspect

    def request(self, msg: dict) -> Optional[dict]:
        if not self.alive:
            return None
        resp = self.client.request(msg)
        if resp is None and not self.client.suspect:
            self.alive = False        # refused/reset: the process is gone
        return resp

    def kill(self) -> None:
        self.alive = False
        self.proc.terminate()
        self.proc.join(timeout=10)
        self.client.close()


def _worker_main(cid: int, manifest: dict, conn, server_kw: dict,
                 role: str = "replica") -> None:
    """Entry point of a spawned coordinator worker (process mode)."""
    from repro.core.query import planner
    from repro.core.recovery import attach_shared
    db = attach_shared(manifest)
    # warm the first-dispatch path (window scans, device transfers) BEFORE
    # announcing the port: a fresh process's cold jax dispatch costs
    # hundreds of ms, which must not be billed to the first wave's SLO
    # budget — restart time is §5.3's problem, not the client's
    planner.delta_window(db)
    planner.index_window(db)
    coord = Coordinator(cid, db, role=role, **server_kw)
    port, _shutdown = serve_worker(coord.handle)
    conn.send(port)
    conn.close()
    while True:                                   # serve until terminated
        coord.handle({"op": "pump"})
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# the frontend (SLB + routing table + pin-of-record)
# ---------------------------------------------------------------------------

class A1Frontend:
    """SLB-style front over N coordinators sharing one store.

    See the module docstring for the routing/ownership/takeover and
    budget contracts.  ``close()`` tears the fleet down (and unlinks the
    shared segment in process mode); the frontend is also a context
    manager."""

    def __init__(self, db, n_workers: int = 4, *, mode: str = "inproc",
                 name: str = "cluster", cache: Optional[FastRestartCache]
                 = None, budget_ms: float = 100.0, lease_s: float = 2.0,
                 membership_clock=None, recv_timeout_s: Optional[float]
                 = None, objectstore: Optional[ObjectStore] = None,
                 **server_kw):
        if mode not in ("inproc", "process"):
            raise ValueError(f"unknown mode {mode!r}")
        self.mode = mode
        self.name = name
        self.budget_ms = budget_ms
        self.cache = cache or FastRestartCache()
        self.cache.hold(name, db)
        self.workers: dict[int, object] = {}
        self.stats = {"routed_queries": 0, "routed_writes": 0,
                      "continuation_routes": 0, "stale_routes": 0,
                      "takeovers": 0, "rescued_queries": 0,
                      "retransmits": 0, "worker_kills": 0,
                      "budget_exhausted_frontend": 0,
                      "frames_sent": 0, "frames_dropped": 0,
                      "failovers": 0, "replicated_waves": 0,
                      "ship_drops": 0}
        self._load: dict[int, float] = {}
        self._rr = 0
        self._qidmeta: dict[str, dict] = {}     # pub qid -> routing meta
        self._tokmeta: dict[str, dict] = {}     # pub token -> routing meta
        self._local: dict[str, dict] = {}       # frontend-answered results
        self._widmeta: dict[str, dict] = {}     # pub write id -> {cid, wid}
        self._applied: dict[int, int] = {}      # cid -> replicated wave seq
        self._shipped_seq = 0                   # durable/replicated frontier
        self._waves: dict[int, dict] = {}       # CM-held WAL tail (process)
        if mode == "inproc":
            # ONE rehydrated GraphDB: every coordinator wraps the same
            # store object — zero array duplication, writes fleet-visible
            self.db = self.cache.restart(name)
            self.rlog: Optional[ReplicationLog] = None
            self.membership = Membership(
                range(n_workers), lease_s=lease_s,
                clock=membership_clock or time.monotonic, owner=self.db)
            for cid in range(n_workers):
                # cid 0 starts as write-primary; the commit-time fence is
                # the CM's membership view — a deposed primary's wave
                # close is refused even if it missed its demote frame
                coord = Coordinator(
                    cid, self.db,
                    role="primary" if cid == 0 else "replica",
                    fence=(lambda c=cid: self.membership.is_primary(c)),
                    **server_kw)
                self.workers[cid] = _InprocWorker(cid, coord, self.db)
        else:
            import multiprocessing as mp
            # one host copy in shared memory; workers map the same pages.
            # spawn, not fork: jax state does not survive a fork
            self._manifest = self.cache.export_shared(name)
            self.db = _PinBoard()               # pins + faults, no arrays
            self.membership = Membership(
                range(n_workers), lease_s=lease_s,
                clock=membership_clock or time.monotonic, owner=self.db)
            # the CM's durable replication log: committed wave records are
            # pulled from the primary and shipped to the ObjectStore
            # before a commit is acked (§4); the `{graph}.epoch` meta is
            # the durable fence a deposed primary cannot get past
            self.rlog = ReplicationLog(objectstore or ObjectStore(),
                                       ship_waves=True)
            self.rlog.epoch = self.membership.epoch
            ctx = mp.get_context("spawn")
            for cid in range(n_workers):
                parent, child = ctx.Pipe()
                proc = ctx.Process(
                    target=_worker_main,
                    args=(cid, self._manifest, child, dict(server_kw),
                          "primary" if cid == 0 else "replica"),
                    daemon=True)
                proc.start()
                port = parent.recv()
                parent.close()
                self.workers[cid] = _ProcWorker(
                    cid, proc, WorkerClient(
                        "127.0.0.1", port, recv_timeout=recv_timeout_s,
                        seed=cid))
        for cid in self.workers:
            self._load[cid] = 0.0
            self._applied[cid] = 0

    # -- routing --------------------------------------------------------
    def _alive(self) -> list[int]:
        """Route-able workers: process up AND lease current (a suspect or
        evicted member stops taking fresh traffic before it is dead)."""
        routable = set(self.membership.routable())
        return [cid for cid, w in self.workers.items()
                if w.alive and cid in routable]

    def _least_loaded(self) -> int:
        """Least-loaded alive coordinator: wave-wall EWMA x queue depth,
        round-robin among ties (fresh fleets are all-zero)."""
        alive = self._alive()
        if not alive:
            raise RuntimeError("no alive coordinators")
        self._rr += 1
        return min(alive, key=lambda c: (self._load[c],
                                         (c + self._rr) % len(self.workers)))

    def _raw_request(self, w, cid: int, msg: dict) -> Optional[dict]:
        try:
            return w.request(msg)
        except faults_mod.InjectedFault:
            # the worker "crashed" executing the frame (e.g. the
            # primary.crash.midwave schedule): same outcome as a dead
            # process — evict, fail over, let the caller re-route
            self.kill_worker(cid)
            return None

    def _rpc(self, cid: int, msg: dict, retries: int = 4) -> Optional[dict]:
        """One logical request: a fixed ``rid`` across retransmits, so a
        dropped frame is retried and a duplicate delivery is absorbed by
        the coordinator's rid cache.  Every frame is stamped with the
        CM's configuration epoch and primary — the receiver adopts newer
        configs, bounces stale senders, and demotes itself when the stamp
        proves it lost the primaryship."""
        w = self.workers.get(cid)
        if w is None or not w.alive:
            return None
        msg.setdefault("rid", uuid.uuid4().hex)
        msg["epoch"] = self.membership.epoch
        msg.setdefault("primary", self.membership.primary)
        resp = self._raw_request(w, cid, msg)
        while resp is None and retries > 0 and w.alive:
            if getattr(w, "suspect", False):
                break                 # hung, not dead: don't hammer it
            self.stats["retransmits"] += 1
            retries -= 1
            resp = self._raw_request(w, cid, msg)
        if resp is not None and resp.get("status") == "STALE_EPOCH":
            # the config moved while this frame was in flight: restamp at
            # the current epoch under a FRESH rid and retry once (the old
            # rid's cached answer, if any, belongs to the old config)
            msg = dict(msg)
            msg["rid"] = uuid.uuid4().hex
            msg["epoch"] = self.membership.epoch
            msg["primary"] = self.membership.primary
            resp = self._raw_request(w, cid, msg)
        if resp is not None:
            load = resp.pop("_load", None)
            if load is not None:
                self._load[cid] = (max(load["wave_ms"], 0.01)
                                   * (1 + load["inflight"]))
            return resp
        if not w.alive:
            self._on_worker_down(cid)     # idempotent (kill may have run)
        elif getattr(w, "suspect", False):
            self.membership.suspect(cid)  # lease stops renewing
        return None

    def _maybe_crash_route_target(self, cid: int) -> bool:
        """``cluster.worker.crash``: the chaos site that kills the routing
        target just before the frame leaves — the crash-at-worst-moment
        schedule.  Returns True when the target died."""
        if faults_mod.check(self.db, "cluster.worker.crash"):
            self.kill_worker(cid)
            return True
        return False

    # -- reads ----------------------------------------------------------
    def submit_query(self, doc: dict, *, tenant: str = "default",
                     qclass: str = "q",
                     budget_ms: Optional[float] = None) -> str:
        """Admit one read through the SLB; returns a stamped query id.

        The route stage spends from the request's SLO budget: routing time
        is decremented before admission, and an already-exhausted budget
        is answered *here* — a sub-millisecond truncated-with-flag
        response that never costs a worker frame."""
        t0 = time.monotonic()
        budget = self.budget_ms if budget_ms is None else budget_ms
        if budget is not None and budget <= 0:
            pub = f"fe:{uuid.uuid4().hex}"
            self.stats["budget_exhausted_frontend"] += 1
            self._local[pub] = {"status": "OK", "failed": False,
                                "rows": [], "truncated": True,
                                "budget_exhausted": True}
            return pub
        self.stats["routed_queries"] += 1
        deadline = None if budget is None else t0 + budget * 1e-3
        for _ in range(len(self.workers) + 1):
            cid = self._least_loaded()
            self._maybe_crash_route_target(cid)
            remaining = (None if budget is None
                         else (deadline - time.monotonic()) * 1e3)
            resp = self._rpc(cid, {"op": "query", "doc": doc,
                                   "tenant": tenant, "qclass": qclass,
                                   "budget_ms": remaining})
            if resp is not None and resp["status"] == "OK":
                pub = f"{cid}:{resp['qid']}"
                self._qidmeta[pub] = {
                    "cid": cid, "qid": resp["qid"], "doc": doc,
                    "tenant": tenant, "qclass": qclass,
                    "deadline": deadline}
                return pub
            if resp is not None:                    # admission error row
                pub = f"{cid}:{uuid.uuid4().hex}"
                self._local[pub] = {"status": "REJECTED",
                                    "reason": resp.get("reason", "")}
                return pub
            # target died mid-route: fail over to another coordinator
        raise RuntimeError("no alive coordinators")

    def query_result(self, pub: str) -> Optional[dict]:
        """Poll a stamped id; drives worker wave clocks on the way."""
        local = self._local.pop(pub, None)
        if local is not None:
            return local
        meta = self._qidmeta.get(pub)
        if meta is None:
            return {"status": "UNKNOWN", "reason": "no such query id"}
        w = self.workers.get(meta["cid"])
        if w is None or not w.alive:
            self._rescue(meta["cid"])
            meta = self._qidmeta.get(pub)
            if meta is None:                       # rescue answered it
                return self._local.pop(pub, None)
        resp = self._rpc(meta["cid"], {"op": "result", "qid": meta["qid"]})
        if resp is None:
            self._rescue(meta["cid"])
            return None                            # client polls again
        r = resp.get("result")
        if r is not None:
            del self._qidmeta[pub]
        return r

    def _rescue(self, dead_cid: int) -> None:
        """Re-route every in-flight query owned by a dead coordinator.

        Queries whose results are stranded on the lost worker re-submit
        (same doc, remaining budget) to an alive one; exhausted budgets
        answer truncated-with-flag locally.  Continuations are *not*
        rescued here — their takeover is lazy, at the next ``next_page``."""
        for pub, meta in list(self._qidmeta.items()):
            if meta["cid"] != dead_cid:
                continue
            remaining = None
            if meta["deadline"] is not None:
                remaining = (meta["deadline"] - time.monotonic()) * 1e3
                if remaining <= 0:
                    self._local[pub] = {
                        "status": "OK", "failed": False, "rows": [],
                        "truncated": True, "budget_exhausted": True}
                    del self._qidmeta[pub]
                    continue
            alive = self._alive()
            if not alive:
                self._local[pub] = {"status": "ABORTED",
                                    "reason": "worker-lost"}
                del self._qidmeta[pub]
                continue
            cid = self._least_loaded()
            resp = self._rpc(cid, {"op": "query", "doc": meta["doc"],
                                   "tenant": meta["tenant"],
                                   "qclass": meta["qclass"],
                                   "budget_ms": remaining})
            if resp is None or resp["status"] != "OK":
                self._local[pub] = {"status": "ABORTED",
                                    "reason": "worker-lost"}
                del self._qidmeta[pub]
                continue
            self.stats["rescued_queries"] += 1
            meta["cid"], meta["qid"] = cid, resp["qid"]

    # -- paged selects / continuations ----------------------------------
    def select_paged(self, doc: dict) -> tuple[np.ndarray, Optional[str]]:
        """First page + a coordinator-stamped public token.

        The frontend records the token's snapshot timestamp and pins it on
        its own store handle — the pin-of-record that keeps the snapshot
        alive even if the owning coordinator dies (its takeover replay
        needs the pinned versions to still exist)."""
        for _ in range(len(self.workers) + 1):
            cid = self._least_loaded()
            self._maybe_crash_route_target(cid)
            resp = self._rpc(cid, {"op": "select_paged", "doc": doc})
            if resp is None:
                continue                            # died mid-route
            if resp["status"] != "OK":
                raise ValueError(resp.get("reason", "select_paged failed"))
            rows = np.asarray(resp["rows"], np.int64)
            if resp["token"] is None:
                return rows, None
            pub = f"{cid}:{resp['token']}"
            self._tokmeta[pub] = {
                "cid": cid, "token": resp["token"], "doc": doc,
                "read_ts": int(resp["read_ts"]),
                "served": rows.tolist()}
            self.db.active_query_ts.append(int(resp["read_ts"]))
            return rows, pub
        raise RuntimeError("no alive coordinators")

    def next_page(self, pub: str) -> tuple[np.ndarray, Optional[str]]:
        """Route a continuation to its owner; take over if the owner died.

        The happy path is one owner-routed frame.  Under
        ``cluster.route.stale`` the frame goes to the wrong coordinator
        first and bounces (``WRONG_OWNER``); under ``cluster.worker.crash``
        the owner dies as the frame leaves, and the takeover path re-plans
        on a new coordinator at the token's pinned snapshot — asserting
        the replayed pages bit-identical before the client sees a row."""
        meta = self._tokmeta.get(pub)
        if meta is None:
            raise KeyError("continuation expired; restart the query")
        self.stats["continuation_routes"] += 1
        self._maybe_crash_route_target(meta["cid"])
        target = meta["cid"]
        alive = self._alive()
        if faults_mod.check(self.db, "cluster.route.stale") and alive:
            wrong = [c for c in alive if c != meta["cid"]]
            if wrong:
                target = wrong[self._rr % len(wrong)]
        resp = None
        if self.workers[meta["cid"]].alive:
            resp = self._rpc(target, {"op": "next_page",
                                      "token": meta["token"],
                                      "owner": meta["cid"]})
            if resp is not None and resp["status"] == "WRONG_OWNER":
                # stale SLB view detected at the receiver: re-route to the
                # true owner (the stamp, not the view, is authoritative)
                self.stats["stale_routes"] += 1
                resp = self._rpc(meta["cid"], {"op": "next_page",
                                               "token": meta["token"],
                                               "owner": meta["cid"]})
        if resp is None:                            # owner is gone
            resp = self._takeover(pub, meta)
        if resp["status"] == "EXPIRED":
            self._release_token(pub)
            raise KeyError("continuation expired; restart the query")
        if resp["status"] != "OK":
            self._release_token(pub)
            raise RuntimeError(resp.get("reason", resp["status"]))
        rows = np.asarray(resp["rows"], np.int64)
        meta["served"] += rows.tolist()
        if resp["token"] is None:
            self._release_token(pub)
            return rows, None
        meta["token"] = resp["token"]
        return rows, pub

    def _takeover(self, pub: str, meta: dict) -> dict:
        """Adopt a lost coordinator's token on a new one, then page."""
        self.stats["takeovers"] += 1
        cid = self._least_loaded()
        resp = self._rpc(cid, {"op": "adopt", "doc": meta["doc"],
                               "read_ts": meta["read_ts"],
                               "served": meta["served"]})
        if resp is None or resp["status"] != "OK":
            return resp or {"status": "ERROR", "reason": "takeover failed"}
        if resp["token"] is None:
            # the replay completed the select: whatever rows remain past
            # the served prefix are the final page
            return {"status": "OK", "rows": resp["leftover"],
                    "token": None}
        meta["cid"], meta["token"] = cid, resp["token"]
        return self._rpc(cid, {"op": "next_page", "token": meta["token"],
                               "owner": cid})

    def _release_token(self, pub: str) -> None:
        meta = self._tokmeta.pop(pub, None)
        if meta is not None:
            try:
                self.db.active_query_ts.remove(meta["read_ts"])
            except ValueError:
                pass

    # -- writes ---------------------------------------------------------
    def submit_write(self, ops, *, budget_ms: Optional[float] = None) -> str:
        """Admit one write through the SLB: routed to the elected
        write-primary (both modes).

        The frontend chooses the wid up front — it doubles as the
        transaction's rid, so a retransmit to a freshly promoted primary
        that already replayed the original wave resolves to the ORIGINAL
        result instead of committing twice (exactly once, §4).  In
        process mode the commit is not acked until the wave record is
        durable in the ObjectStore and replayed on every alive replica —
        read-your-write holds on any coordinator."""
        self.stats["routed_writes"] += 1
        encoded = [encode_write_op(o) for o in ops]
        wid = uuid.uuid4().hex
        pub = f"w:{wid}"
        for _ in range(len(self.workers) + 2):
            p = self.membership.primary
            if p is None:
                raise RuntimeError("no alive coordinators")
            self._maybe_crash_route_target(p)
            p = self.membership.primary   # the crash may have failed over
            if p is None:
                raise RuntimeError("no alive coordinators")
            resp = self._rpc(p, {"op": "write", "ops": encoded,
                                 "budget_ms": budget_ms, "wid": wid})
            if resp is None:
                continue       # primary died mid-route; failover ran
            if resp["status"] == "NOT_PRIMARY":
                continue       # stale role view; re-read the membership
            if resp["status"] == "OK":
                self._widmeta[pub] = {"cid": p, "wid": wid}
                return pub
            self._local[pub] = {"status": "ABORTED",
                                "reason": resp.get("reason", "")}
            return pub
        raise RuntimeError("no alive coordinators")

    def write_result(self, pub: str) -> Optional[dict]:
        """Outcome of a routed write; ``None`` while its wave is open.

        The ack barrier: a COMMITTED result is only returned after
        :meth:`_replicate` made the wave durable and fleet-visible
        (process mode; inproc shares one store, so it is a no-op).  If
        the owning primary died, the write resolves through the rid-
        idempotent failover path — the original result when the commit
        survived, ``ABORTED_FAILOVER`` with a retry hint otherwise."""
        local = self._local.pop(pub, None)
        if local is not None:
            return local
        meta = self._widmeta.get(pub)
        if meta is None:
            if ":" in pub:                  # legacy "<cid>:<wid>" stamp
                cid, wid = pub.split(":", 1)
                resp = self._rpc(int(cid), {"op": "write_result",
                                            "wid": wid})
                if resp is None:
                    return {"status": "ABORTED", "reason": "worker-lost"}
                return resp.get("result")
            return {"status": "UNKNOWN", "reason": "no such write id"}
        w = self.workers.get(meta["cid"])
        owner_lost = (w is None or not w.alive
                      or meta["cid"] not in self.membership.admitted())
        resp = None
        if not owner_lost:
            resp = self._rpc(meta["cid"], {"op": "write_result",
                                           "wid": meta["wid"]})
            owner_lost = resp is None and not self.workers[meta["cid"]].alive
        if owner_lost:
            self._on_worker_down(meta["cid"])   # idempotent
            r = self._local.pop(pub, None)      # failover may have resolved
            if r is None:
                r = self._resolve_by_rid(meta)
            self._widmeta.pop(pub, None)
            if r.get("status") == "COMMITTED":
                self._replicate()               # ack barrier still holds
            return r
        if resp is None:
            return None                         # hung owner: poll again
        r = resp.get("result")
        if r is None:
            return None                         # wave still open
        self._widmeta.pop(pub, None)
        if r.get("status") == "COMMITTED":
            self._replicate()                   # ack barrier
        return r

    def _resolve_by_rid(self, meta: dict) -> dict:
        """Failover resolution for a write stranded on a dead primary:
        ask the CURRENT primary whether that rid ever committed (directly
        or via wave replay).  Found -> the original result, exactly once;
        not found -> the txn died unacked and the client retries."""
        p = self.membership.primary
        if p is not None:
            resp = self._rpc(p, {"op": "write_by_rid", "wid": meta["wid"]})
            r = resp.get("result") if resp is not None else None
            if r is not None:
                return r
        return {"status": "ABORTED_FAILOVER",
                "reason": "primary failed before the commit replicated; "
                          "safe to retry",
                "retry_after_ms": 5.0}

    # -- replication (process mode: §4 wave shipping) --------------------
    def _pins(self) -> list[int]:
        return [int(t) for t in self.db.active_query_ts]

    def _replicate(self) -> None:
        """Pull committed waves from the primary, make them durable, fan
        them out to every alive replica.  Inproc fleets share one store
        (replication is the identity); in process mode this is the ack
        barrier and the replication-lag pump.  ``replication.ship.drop``
        loses a whole round — lag grows, nothing is acked on top of it."""
        if self.rlog is None:
            return
        p = self.membership.primary
        if p is None:
            return
        if faults_mod.check(self.db, "replication.ship.drop"):
            self.stats["ship_drops"] += 1
            return
        resp = self._rpc(p, {"op": "ship", "after": self._shipped_seq})
        if resp is None or resp.get("status") != "OK":
            return
        waves = resp.get("waves", [])
        if not waves:
            return
        for rec in waves:
            self._waves[int(rec["seq"])] = rec
            try:
                self.rlog.append_wave(rec)      # durable point
            except IOError:
                pass                            # sweeper retries the ship
        while len(self._waves) > 2048:          # ObjectStore holds the WAL
            del self._waves[min(self._waves)]
        self._shipped_seq = max(self._shipped_seq, int(waves[-1]["seq"]))
        self._applied[p] = max(self._applied.get(p, 0), self._shipped_seq)
        self.membership.heartbeat(p, applied_seq=self._shipped_seq)
        self.stats["replicated_waves"] += len(waves)
        pins = self._pins()
        for cid in self._alive():
            if cid == p:
                continue
            r = self._rpc(cid, {"op": "replicate", "waves": waves,
                                "pins": pins})
            if r is not None and r.get("status") == "OK":
                seq = int(r.get("applied_seq", 0))
                self._applied[cid] = max(self._applied.get(cid, 0), seq)
                self.membership.heartbeat(cid, applied_seq=seq)

    # -- membership / failover -------------------------------------------
    def _on_worker_down(self, cid: int) -> None:
        """A worker is gone for sure (dead process, killed inproc, grace
        expired): evict it, complete any failover, re-route its work."""
        events = self.membership.evict(cid, reason="crash")
        if not events:
            return                    # already out of the configuration
        self._handle_events(events)
        self._rescue(cid)

    def _handle_events(self, events: list) -> None:
        for ev in events:
            if ev["type"] == "elect":
                self._complete_failover(ev["epoch"], ev["primary"])

    def _complete_failover(self, epoch: int, new_primary) -> None:
        """Finish an election: durable epoch fence, WAL-tail replay on
        the elected replica, explicit promotion, config broadcast, and
        resolution of every write stranded on the dead primary."""
        if new_primary is None:
            return
        self.stats["failovers"] += 1
        tail = []
        if self.rlog is not None:
            # fence FIRST: once `{graph}.epoch` advances, a deposed
            # primary's sweep can never reach durable state (Fenced).
            # Monotonic — a nested failover may already have fenced higher
            key = f"{self.rlog.graph}.epoch"
            if int(epoch) > int(self.rlog.os.get_meta(key, 0)):
                self.rlog.os.put_meta(key, int(epoch))
            self.rlog.epoch = max(self.rlog.epoch or 0, int(epoch))
            applied = self._applied.get(new_primary, 0)
            tail = [self._waves[s]
                    for s in range(applied + 1, self._shipped_seq + 1)
                    if s in self._waves]
        resp = self._rpc(new_primary, {"op": "promote", "epoch": int(epoch),
                                       "waves": tail})
        if resp is None or resp.get("status") != "OK":
            return      # it died too: _rpc's down-path re-elected already
        seq = int(resp.get("applied_seq", 0))
        self._applied[new_primary] = max(
            self._applied.get(new_primary, 0), seq)
        self.membership.heartbeat(new_primary, applied_seq=seq)
        # propagate the new configuration now: the epoch/primary stamp on
        # the heartbeat demotes any coordinator that still thinks it is
        # primary (its staged writes answer ABORTED_FAILOVER)
        for cid in self._alive():
            if cid != new_primary:
                self._rpc(cid, {"op": "heartbeat"})
        # resolve writes stranded on evicted owners: committed waves are
        # found by rid on the new primary (exactly once); anything else
        # aborts with a retry hint — never a silent drop
        admitted = set(self.membership.admitted())
        for pub, meta in list(self._widmeta.items()):
            w = self.workers.get(meta["cid"])
            if (w is not None and w.alive and meta["cid"] in admitted):
                continue
            self._local[pub] = self._resolve_by_rid(meta)
            del self._widmeta[pub]

    # -- fleet control ---------------------------------------------------
    def kill_worker(self, cid: int) -> None:
        """Kill one coordinator (chaos/ops).  In-flight queries it owned
        re-route; its continuations take over lazily at next_page; if it
        was the write-primary, failover completes before this returns."""
        w = self.workers.get(cid)
        if w is None:
            return
        if w.alive:
            self.stats["worker_kills"] += 1
            w.kill()
        self._on_worker_down(cid)

    def _membership_quantum(self) -> None:
        """One CM tick: renew leases (frames in process mode; liveness is
        direct inproc — the worker IS this process), advance the lease
        state machine, complete any resulting failover, pump replication."""
        if self.mode == "inproc":
            seq = int(getattr(self.db, "wave_seq", 0))
            for cid in self.membership.admitted():
                w = self.workers.get(cid)
                if w is not None and w.alive:
                    self._applied[cid] = seq    # shared store: zero lag
                    self.membership.heartbeat(cid, applied_seq=seq)
        else:
            pins = self._pins()
            for cid in list(self.membership.admitted()):
                w = self.workers.get(cid)
                if w is None or not w.alive:
                    continue
                resp = self._rpc(cid, {"op": "heartbeat", "pins": pins})
                if resp is not None and resp.get("status") == "OK":
                    seq = int(resp.get("applied_seq", 0))
                    self._applied[cid] = max(
                        self._applied.get(cid, 0), seq)
                    self.membership.heartbeat(cid, applied_seq=seq)
        self._handle_events(self.membership.tick())
        self._replicate()

    def pump(self) -> int:
        """One fleet quantum: membership/replication first, then close
        due waves on every coordinator."""
        n = 0
        self._membership_quantum()
        for cid in self._alive():
            resp = self._rpc(cid, {"op": "pump"})
            if resp is not None:
                n += resp.get("n", 0)
        return n

    def flush(self) -> int:
        n = 0
        for cid in self._alive():
            resp = self._rpc(cid, {"op": "flush"})
            if resp is not None:
                n += resp.get("n", 0)
        return n

    def cluster_stats(self) -> dict:
        """Frontend counters + per-worker /stats (budget histograms
        aggregated fleet-wide) + the membership view and per-replica
        replication lag (waves shipped but not yet applied there)."""
        agg = {"frontend": dict(self.stats), "workers": {},
               "budget_spend_ms": None,
               "membership": self.membership.view()}
        if self.rlog is not None:
            frontier = self._shipped_seq
            applied = {c: self._applied.get(c, 0)
                       for c in self.membership.admitted()}
        else:           # one shared store: every alive worker is current
            frontier = int(getattr(self.db, "wave_seq", 0))
            applied = {c: frontier for c in self.membership.admitted()
                       if self.workers[c].alive}
        agg["replication"] = {
            "shipped_seq": frontier,
            "applied_seq": applied,
            "lag": {c: max(0, frontier - s) for c, s in applied.items()},
        }
        agg["replication"]["max_lag"] = max(
            agg["replication"]["lag"].values(), default=0)
        for w in self.workers.values():
            if isinstance(w, _InprocWorker):
                agg["frontend"]["frames_sent"] += w.chan.sent
                agg["frontend"]["frames_dropped"] += w.chan.dropped
        for cid in self._alive():
            resp = self._rpc(cid, {"op": "stats"})
            if resp is None or resp["status"] != "OK":
                continue
            agg["workers"][cid] = resp["stats"]
            h = resp["stats"].get("budget_spend_ms")
            if h:
                if agg["budget_spend_ms"] is None:
                    agg["budget_spend_ms"] = {
                        k: list(v) for k, v in h.items()}
                else:
                    for k, v in h.items():
                        agg["budget_spend_ms"][k] = [
                            a + b for a, b in
                            zip(agg["budget_spend_ms"][k], v)]
        return agg

    # -- wire dispatch (serve_frontend) ----------------------------------
    def handle(self, msg: dict) -> dict:
        """The front door's frame dispatch (JSON-over-TCP clients)."""
        try:
            op = msg["op"]
            if op == "query":
                return {"status": "OK", "qid": self.submit_query(
                    msg["doc"], tenant=msg.get("tenant", "default"),
                    qclass=msg.get("qclass", "q"),
                    budget_ms=msg.get("budget_ms"))}
            if op == "result":
                return {"status": "OK",
                        "result": self.query_result(msg["qid"])}
            if op == "select_paged":
                rows, token = self.select_paged(msg["doc"])
                return {"status": "OK", "rows": rows.tolist(),
                        "token": token}
            if op == "next_page":
                try:
                    rows, token = self.next_page(msg["token"])
                except KeyError as e:
                    return {"status": "EXPIRED", "reason": str(e)}
                return {"status": "OK", "rows": rows.tolist(),
                        "token": token}
            if op == "write":
                return {"status": "OK", "wid": self.submit_write(
                    [decode_write_op(d) for d in msg["ops"]],
                    budget_ms=msg.get("budget_ms"))}
            if op == "write_result":
                return {"status": "OK",
                        "result": self.write_result(msg["wid"])}
            if op == "pump":
                return {"status": "OK", "n": self.pump()}
            if op == "stats":
                return {"status": "OK", "stats": self.cluster_stats()}
            return {"status": "ERROR", "reason": f"unknown op {op!r}"}
        except (KeyError, ValueError, TypeError, RuntimeError) as e:
            return {"status": "ERROR", "reason": str(e)}

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        for pub in list(self._tokmeta):
            self._release_token(pub)
        for w in self.workers.values():
            if w.alive:
                w.kill()
        self.cache.drop(self.name)

    def __enter__(self) -> "A1Frontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
