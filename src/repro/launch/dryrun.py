import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture x input-shape) cell on the production
meshes — 16x16 (single pod, 256 chips) and 2x16x16 (two pods, 512 chips) —
and records memory_analysis / cost_analysis / collective-schedule roofline
terms.  This is the proof that the distribution config is coherent without
real hardware: sharding mismatches, compile-time OOM, or unsupported
collectives fail HERE.

The device-count override above MUST precede any other import (jax locks
the device count at first init) and is deliberately NOT set globally —
tests and benchmarks see the real single CPU device.

Usage:
    python -m repro.launch.dryrun --arch qwen3-moe-235b-a22b --shape train_4k
    python -m repro.launch.dryrun --arch a1-kg --shape serve_q1 --multipod
    python -m repro.launch.dryrun --all [--jobs 4] [--multipod]
    python -m repro.launch.dryrun --list
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str) -> dict:
    import jax

    from repro.configs import registry
    from repro.launch import roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    spec = registry.get(arch)
    cell_meta = spec.cell(shape)
    mesh_tag = "multipod" if multi_pod else "pod"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
           "family": spec.family}
    if cell_meta.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell_meta.skip
        _write(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 1
    for ax in mesh.axis_names:
        n_dev *= mesh.shape[ax]

    t0 = time.time()
    cell = build_cell(arch, shape, mesh)
    if cell.in_shardings is not None:
        fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    else:
        fn = cell.fn        # already a jit(shard_map(...))
    with mesh:
        lowered = fn.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis() or {}
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})

    rl = roofline.analyze(compiled, n_devices=n_dev,
                          model_flops=cell.model_flops)
    rec.update(status="ok", lower_s=round(t_lower, 2),
               compile_s=round(t_compile, 2), n_devices=n_dev,
               roofline=rl.to_json(), note=cell.note)
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    fn = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    from repro.configs import registry

    if args.list:
        for a, s in registry.all_cells():
            skip = registry.get(a).cell(s).skip
            print(f"{a:28s} {s:16s}" + (f"  [SKIP: {skip[:40]}...]"
                                        if skip else ""))
        return

    if args.all:
        cells = registry.all_cells()
        meshes = [False, True] if args.both_meshes else [args.multipod]
        jobs = []
        for mp in meshes:
            for a, s in cells:
                jobs.append((a, s, mp))
        procs: list = []
        results = []
        while jobs or procs:
            while jobs and len(procs) < args.jobs:
                a, s, mp = jobs.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--out", args.out]
                if mp:
                    cmd.append("--multipod")
                print("launch:", a, s, "multipod" if mp else "pod",
                      flush=True)
                procs.append(((a, s, mp), subprocess.Popen(
                    cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT)))
            done = []
            for item in procs:
                (a, s, mp), p = item
                if p.poll() is not None:
                    out = p.stdout.read().decode()
                    ok = p.returncode == 0
                    results.append((a, s, mp, ok))
                    print(("PASS" if ok else "FAIL"), a, s,
                          "multipod" if mp else "pod", flush=True)
                    if not ok:
                        print(out[-3000:], flush=True)
                    done.append(item)
            for d in done:
                procs.remove(d)
            time.sleep(0.5)
        n_ok = sum(1 for *_, ok in results if ok)
        print(f"\n{n_ok}/{len(results)} cells passed")
        sys.exit(0 if n_ok == len(results) else 1)

    rec = run_cell(args.arch, args.shape, args.multipod, args.out)
    print(json.dumps({k: v for k, v in rec.items() if k != "roofline"},
                     indent=1))
    if "roofline" in rec:
        r = rec["roofline"]
        print(f"compute_s={r['compute_s']:.4g} memory_s={r['memory_s']:.4g}"
              f" collective_s={r['collective_s']:.4g}"
              f" bottleneck={r['bottleneck']}"
              f" useful_ratio={r['useful_ratio']:.3f}")


if __name__ == "__main__":
    main()
