"""Loop-aware HLO analysis: flops / wire bytes / memory traffic.

``compiled.cost_analysis()`` counts every computation ONCE — a scanned layer
stack or gradient-accumulation loop under-reports by its trip count (probed:
scan(8 matmuls) reports 1 matmul of flops).  The roofline needs true totals,
so this module re-derives them from the optimized HLO text:

  * computations are parsed into blocks; a call graph (fusion ``calls=``,
    while ``body=``/``condition=``, ``to_apply=``) assigns each computation a
    *multiplier* = product of enclosing while trip counts (trip count =
    the largest integer constant in the loop's condition computation —
    exact for jax.lax.scan/fori lowerings);
  * FLOPs: 2 x prod(result dims) x prod(contracted dims) per ``dot``,
    times multiplier (dots are >99% of flops in every cell here);
  * collective wire bytes: ring-model per-device traffic per collective
    (see launch/roofline.py), times multiplier;
  * memory traffic: for every non-control instruction at computation top
    level: result bytes + operand bytes (fusion internals excluded — the
    fusion boundary is exactly XLA's materialization boundary), times
    multiplier.

Validated against unrolled-vs-scanned parity tests (tests/test_dryrun.py).
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{")
_CALL_ATTRS = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPNAME = re.compile(r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(?:\([^=]*?\)|\S+)\s+"
                     r"([\w\-]+)\(")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_ID = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([\d,]*)\}")

_CONTROL_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done", "bitcast-convert", "opt-barrier", "custom-call",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_INDEXED_OPS = {"gather", "dynamic-slice", "scatter", "dynamic-update-slice",
                "select-and-scatter"}


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _first_shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(text):
        if dt in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0                       # per-device, loop-aware
    wire_bytes: float = 0.0                  # per-device collective traffic
    mem_bytes: float = 0.0                   # per-device HBM traffic model
    coll_detail: dict = dataclasses.field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1


def _split_computations(text: str):
    comps: dict[str, list[str]] = {}
    headers: dict[str, str] = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if (line.endswith("{") and "->" in line
                and (stripped.startswith("%") or stripped.startswith("ENTRY"))
                and " = " not in line.split("->")[0]):
            is_entry = stripped.startswith("ENTRY")
            name_part = stripped[6:] if is_entry else stripped
            name = name_part.strip().lstrip("%").split(" ")[0].split("(")[0]
            cur = name
            comps[cur] = []
            headers[cur] = line
            if is_entry:
                entry = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(raw)
    return comps, headers, entry


_PARAM_RE = re.compile(r"([\w\.\-]+)\s*:\s*(\(?[\w\[\],\s\{\}]*)")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def _symbols(header: str, lines: list[str]) -> dict:
    """name -> shape-text for every instruction/parameter."""
    syms: dict[str, str] = {}
    # header params: "(x.1: f32[4,512], w: (f32[2], s32[]))"
    if "(" in header:
        inner = header[header.index("(") + 1:header.rindex("->")]
        for m in _PARAM_RE.finditer(inner):
            syms[m.group(1)] = m.group(2)
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            rhs = m.group(2)
            # shape text = everything before the op name token
            syms[m.group(1)] = rhs.split(" ")[0] if rhs else ""
            # tuples: capture the parenthesized group
            if rhs.startswith("("):
                depth = 0
                for i, ch in enumerate(rhs):
                    depth += ch == "("
                    depth -= ch == ")"
                    if depth == 0:
                        syms[m.group(1)] = rhs[:i + 1]
                        break
    return syms


def _operands(line: str, op: str) -> list[str]:
    """names of the operands of `op(...)` in the line.

    Operands may carry their type (``dot(f32[4,256]{1,0} %x, ...)`` —
    older HLO printers) or not (``dot(%x, ...)``); split only on commas at
    bracket depth zero so shape commas don't shred the list."""
    try:
        inner = line.split(op + "(", 1)[1]
    except IndexError:
        return []
    depth = 1
    buf = ""
    parts = []
    for ch in inner:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                break
        if ch == "," and depth == 1:
            parts.append(buf)
            buf = ""
        else:
            buf += ch
    parts.append(buf)
    out = []
    for tok in parts:
        tok = tok.strip()
        if tok:
            out.append(tok.split(" ")[-1].lstrip("%"))
    return out


def _line_called(line: str) -> list[str]:
    out = [m.group(1) for m in _CALL_ATTRS.finditer(line)]
    for m in _BRANCHES.finditer(line):
        out += [n.strip().lstrip("%") for n in m.group(1).split(",")]
    return out


def _trip_count(cond_lines: list[str]) -> int:
    best = 1
    for line in cond_lines:
        for c in _CONST_INT.findall(line):
            best = max(best, int(c))
    return best


def analyze_hlo(text: str, n_devices: int = 1) -> Analysis:
    comps, headers, entry = _split_computations(text)
    if entry is None:
        entry = next(iter(comps)) if comps else None
    # 1) multipliers via DFS from entry
    mult: dict[str, float] = defaultdict(float)
    fused: set = set()

    def visit(name: str, m: float) -> None:
        if name not in comps:
            return
        mult[name] += m
        for line in comps[name]:
            callees = _line_called(line)
            if not callees:
                continue
            if " while(" in line:
                body = cond = None
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    visit(body, m * trips)
                if cond:
                    visit(cond, m * trips)
            elif " fusion(" in line:
                for c in callees:
                    fused.add(c)
                    visit(c, m)
            else:
                for c in callees:
                    visit(c, m)

    if entry:
        visit(entry, 1.0)

    res = Analysis()
    coll = defaultdict(float)
    counts: Counter = Counter()

    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        in_fused = name in fused
        syms = _symbols(headers.get(name, ""), lines)
        for line in lines:
            if " = " not in line:
                continue
            rhs = line.split(" = ", 1)[1]
            op_m = re.match(r"^(?:\([^=]*\)|\S+)?\s*([\w\-]+)\(", rhs)
            op = None
            for cand in ("dot", "while", "fusion") + _COLLECTIVES + tuple(
                    c + "-start" for c in _COLLECTIVES):
                if " " + cand + "(" in line:
                    op = cand
                    break
            if op is None:
                op = op_m.group(1) if op_m else ""
            if op == "while":
                res.n_while += 1
            # ---- flops: dot ----------------------------------------------
            if op == "dot":
                lhs = line.split("dot(", 1)[0]
                res_shape = _SHAPE_TOK.findall(lhs)
                ops = _operands(line, "dot")
                lhs_shape_txt = syms.get(ops[0], "") if ops else ""
                lhs_tok = _SHAPE_TOK.findall(lhs_shape_txt)
                if res_shape and lhs_tok:
                    out_elems = _shape_elems(res_shape[0][1])
                    cm = _CONTRACT.search(line)
                    contracted = 1
                    lhs_dims = (lhs_tok[0][1].split(",")
                                if lhs_tok[0][1] else [])
                    for ci in (cm.group(1).split(",")
                               if cm and cm.group(1) else []):
                        idx = int(ci)
                        if idx < len(lhs_dims):
                            contracted *= int(lhs_dims[idx])
                    res.flops += 2.0 * out_elems * contracted * m
            # ---- collectives ----------------------------------------------
            elif any(op == c or op == c + "-start" for c in _COLLECTIVES):
                base = op.replace("-start", "")
                lhs = line.split("=", 1)[1]
                lhs = lhs.split(base + "(", 1)[0] if base + "(" in lhs \
                    else lhs
                b = _first_shape_bytes(lhs)
                gm = _GROUPS_ID.search(line)
                if gm:
                    s = int(gm.group(2))
                else:
                    gm = _GROUPS_EXPL.search(line)
                    s = (len(gm.group(1).split(",")) if gm and gm.group(1)
                         else n_devices)
                s = max(s, 1)
                if s > 1:
                    if base == "all-reduce":
                        wire = 2.0 * b * (s - 1) / s
                    elif base == "all-gather":
                        wire = b * (s - 1) / s
                    elif base == "reduce-scatter":
                        wire = b * (s - 1)
                    elif base == "all-to-all":
                        wire = b * (s - 1) / s
                    else:
                        wire = float(b)
                    coll[base] += wire * m
                    counts[base] += int(m)
            # ---- memory traffic -------------------------------------------
            if not in_fused and op not in _CONTROL_OPS:
                if op in _INDEXED_OPS:
                    # a gather/dynamic-slice reads ~the result's bytes from
                    # the table, not the whole operand; counting operands
                    # overstated A1 traversal memory ~100x
                    lhs = line.split(" = ", 1)[0] + " = " + \
                        line.split(" = ", 1)[1].split(op + "(")[0]
                    res.mem_bytes += 2.0 * _first_shape_bytes(lhs) * m
                else:
                    res.mem_bytes += _first_shape_bytes(line) * m

    res.wire_bytes = sum(coll.values())
    res.coll_detail = dict(coll)
    res.coll_detail["counts"] = dict(counts)
    if mult:
        res.max_trip = int(max(mult.values()))
    return res
