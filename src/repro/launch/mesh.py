"""Production mesh definitions.

Single-pod: 16 x 16 = 256 chips (v5e pod), axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is outer data-parallel / pipeline stages for training and the
cross-datacenter replica for the A1 graph store (disaster recovery, §4).

A function, not a module constant: importing this module never touches jax
device state (the dry-run pins the device count *before* first jax init).
"""
from __future__ import annotations

from repro.dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    return compat.make_mesh(shape, axes)
