"""Assemble the EXPERIMENTS.md roofline table from dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.report [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.3g}us"
    if x < 1:
        return f"{x*1e3:.3g}ms"
    return f"{x:.3g}s"


def load(dir_: str) -> list[dict]:
    out = []
    for fn in sorted(os.listdir(dir_)):
        if fn.endswith(".json"):
            with open(os.path.join(dir_, fn)) as f:
                out.append(json.load(f))
    return out


def table(recs: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck |"
        " useful (6ND/HLO) | peak HBM/dev | compile |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"a1": 0, "lm": 1, "gnn": 2, "recsys": 3}
    recs = [r for r in recs if r["mesh"] == mesh]
    recs.sort(key=lambda r: (order.get(r.get("family", ""), 9), r["arch"],
                             r["shape"]))
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"SKIP ({r['skip_reason'][:48]}…) | — | — |")
            continue
        rl = r["roofline"]
        hbm = rl["mem_stats"].get("peak_hbm_gb", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['compute_s'])} "
            f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
            f"| {rl['bottleneck']} | {rl['useful_ratio']:.2f} "
            f"| {hbm:.1f} GB | {r['compile_s']:.0f}s |")
    return "\n".join(lines)


def summary(recs: list[dict]) -> str:
    n_ok = sum(1 for r in recs if r["status"] == "ok")
    n_skip = sum(1 for r in recs if r["status"] == "skipped")
    meshes = sorted({r["mesh"] for r in recs})
    return (f"{len(recs)} artifacts ({n_ok} compiled, {n_skip} recorded "
            f"skips) across meshes {meshes}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    recs = load(args.dir)
    print(summary(recs))
    print()
    print(table(recs, args.mesh))


if __name__ == "__main__":
    main()
