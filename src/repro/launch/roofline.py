"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), from the compiled SPMD program:

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory_s     = HLO_bytes_per_device / HBM_bandwidth
  collective_s = wire_bytes_per_device / ICI_bandwidth

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (the per-device SPMD
module).  Collective bytes are NOT in cost_analysis: we parse the optimized
HLO text and sum result-shape bytes of every collective op, converted to
per-device ring wire traffic:

  all-reduce      2 * B * (s-1)/s        (ring reduce-scatter + all-gather)
  all-gather      B_out * (s-1)/s
  reduce-scatter  B_out * (s-1)           (B_full = B_out * s)
  all-to-all      B * (s-1)/s
  collective-permute  B

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (we charge one link — conservative; multi-link meshes only improve it).
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (1 link charged)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?P<shape>[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_ID_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_ID_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def collective_wire_bytes(hlo_text: str, n_devices: int) -> dict:
    """Per-device wire bytes by collective type + totals."""
    out = defaultdict(float)
    counts: Counter = Counter()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if "-done(" in line:
            continue
        b = _shape_bytes(m.group("shape"))
        s = max(_group_size(line, n_devices), 1)
        if s == 1:
            continue
        if op == "all-reduce":
            wire = 2.0 * b * (s - 1) / s
        elif op == "all-gather":
            wire = b * (s - 1) / s
        elif op == "reduce-scatter":
            wire = b * (s - 1)
        elif op == "all-to-all":
            wire = b * (s - 1) / s
        else:                                  # collective-permute
            wire = float(b)
        out[op] += wire
        counts[op] += 1
    out_d = dict(out)
    out_d["total"] = sum(out.values())
    out_d["counts"] = dict(counts)
    return out_d


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    wire_bytes: float            # per-device collective wire bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # global useful flops (6ND-style)
    useful_ratio: float          # model_flops / (flops * n_devices)
    coll_detail: dict
    mem_stats: dict

    def to_json(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, n_devices: int, model_flops: float = 0.0,
            hlo_text: str = None) -> Roofline:
    from repro.launch.hloanalysis import analyze_hlo
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # jax 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    # loop-aware totals (cost_analysis counts while bodies once — probed)
    h = analyze_hlo(txt, n_devices)
    flops = h.flops
    hbm = max(h.mem_bytes, float(ca.get("bytes accessed", 0.0)))
    coll = dict(h.coll_detail)
    coll["total"] = h.wire_bytes
    wire = h.wire_bytes
    cs, ms, ls = flops / PEAK_FLOPS, hbm / HBM_BW, wire / ICI_BW
    bn = max((("compute", cs), ("memory", ms), ("collective", ls)),
             key=lambda t: t[1])[0]
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = dict(
            argument_bytes=getattr(ma, "argument_size_in_bytes", 0),
            output_bytes=getattr(ma, "output_size_in_bytes", 0),
            temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
            alias_bytes=getattr(ma, "alias_size_in_bytes", 0),
        )
        mem["peak_hbm_gb"] = (mem["argument_bytes"] + mem["output_bytes"]
                              + mem["temp_bytes"]
                              - mem["alias_bytes"]) / 1e9
    useful = (model_flops / (flops * n_devices)
              if flops > 0 and n_devices else 0.0)
    mem["ca_flops_flat"] = float(ca.get("flops", 0.0))
    mem["ca_bytes_flat"] = float(ca.get("bytes accessed", 0.0))
    return Roofline(flops=flops, hbm_bytes=hbm, wire_bytes=wire,
                    compute_s=cs, memory_s=ms, collective_s=ls,
                    bottleneck=bn, model_flops=model_flops,
                    useful_ratio=useful, coll_detail=coll, mem_stats=mem)
