"""A1 serving driver: the production loop of §2.2/§3.4.

Reproduces the paper's serving architecture end to end on one host:

  * a frontend loop that batches incoming A1QL queries (the SLB -> frontend
    -> backend routing of Fig. 4) through the unified ``GraphDB.query``
    entry point — mixed plan shapes, chains *and* star patterns, execute as
    fused multi-query waves (core/query/planner.py) instead of one dispatch
    per query — the paper's "many concurrent queries share each operator
    wave";
  * snapshot-timestamped execution with fast-fail + **continuation
    tokens** (§3.4: big result sets return a token; the frontend routes the
    follow-up to the owning coordinator).  Tokens are continuation-aware
    batch citizens: each token pins its snapshot and caches a result
    window; when a client pages past the window, the follow-up fetch is
    *enqueued* and joins the next wave batch — at its own pinned snapshot
    and with a per-plan ``results`` cap hint — instead of being dispatched
    alone (and pages inside the window never re-run the traversal at all);
  * interleaved writes through the transactional path + replication log;
  * the Task framework pumped between batches (compaction, sweeper,
    vacuum — "low priority workers", §3.3);
  * **read admission** mirroring the PR-6 write wave: clients
    ``submit_query`` into an async queue that closes into one fused wave at
    ``read_batch`` requests or ``read_deadline_ms`` — whichever first —
    with per-tenant in-flight caps and load shedding: past the queue
    watermark a request gets an immediate ``SHED`` response with a
    retry-after hint instead of growing the queue (the backpressure
    contract; every admitted id terminates in a result or an attributed
    shed/abort);
  * **circuit-breaker hedging**: a fast-failed batch is retried once at
    quadrupled capacities (straggler/outlier mitigation — the latency-tail
    policy the paper enforces with its 100 ms budget), but each query
    class's failure-rate window can open a breaker that skips the hedge
    (truncated-with-flag) under sustained overflow; with per-query flags
    (the fused path) only the failed slice re-dispatches — and it always
    re-dispatches **per-query-budget**, so ``budget="shared"`` overflow
    never re-enters the saturated pool (``shared_ovf_q`` attribution) and
    ``budget="auto"`` can pick shared mode safely at batch >= the knee;
  * latency accounting per query class (avg + P99, the paper's metrics);
  * named fault-injection sites (``core/faults.py``) so chaos tests can
    drive the admission→execute→hedge→respond loop under wave crashes,
    stalls, and stale-continuation storms.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import uuid
from typing import Optional

import numpy as np

from repro.core import faults as faults_mod
from repro.core.query.executor import QueryCaps, QueryResult
from repro.core.query.planner import _pow2ceil
from repro.core.tasks import (TaskQueue, compaction_task,
                              index_compaction_task, vacuum_task)

# per-stage budget-spend histogram edges (ms).  Each admitted request's SLO
# budget is spent across queueing -> wave -> hedge; /stats buckets the spend
# so operators can see *where* the 100 ms goes (the paper's budget accounting)
BUDGET_BUCKETS_MS = (1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, float("inf"))


@dataclasses.dataclass
class Continuation:
    """One paged select: a pinned snapshot + the materialized row window."""
    token: str
    query: dict           # the original A1QL select document
    read_ts: int          # pinned for the token's lifetime (GC barrier)
    rows: np.ndarray      # valid result gids materialized so far
    cursor: int
    want: int             # results cap the window was materialized at
    truncated: bool       # the server had more rows than ``want``
    expires: float
    hints: dict           # the document's effective cap hints (parse-time)
    max_rows: int         # refill-window ceiling (constant per token)
    cursor_mode: bool = False   # last refill used a gid-cursor predicate


@dataclasses.dataclass
class _ReadReq:
    """One admitted read waiting for its wave."""
    qid: str
    query: dict
    tenant: str
    qclass: str
    arrived: float
    budget_ms: Optional[float] = None   # SLO budget; None = no deadline
    deadline: Optional[float] = None    # abs monotonic: arrived + budget


class _Breaker:
    """Per-query-class circuit breaker over a post-hedge failure window.

    Closed: hedges run normally.  A full window at >= ``threshold`` failure
    rate opens the breaker — sustained overflow means the 4x retry is just
    burning capacity, so waves return truncated-with-flag instead.  While
    open, one probe hedge is admitted every ``cooldown`` skipped waves
    (half-open); any wave that ends unfailed closes it again."""

    def __init__(self, window: int = 8, threshold: float = 0.5,
                 cooldown: int = 4):
        self.window, self.threshold, self.cooldown = window, threshold, \
            cooldown
        self.events = collections.deque(maxlen=window)
        self.open = False
        self._skips = 0
        self.opens = 0

    def allow(self) -> bool:
        if not self.open:
            return True
        if self._skips >= self.cooldown:
            self._skips = 0                       # half-open: probe hedge
            return True
        self._skips += 1
        return False

    def record(self, failed: bool) -> None:
        self.events.append(bool(failed))
        if self.open:
            if not failed:
                self.open = False
                self.events.clear()
                self._skips = 0
        elif (len(self.events) >= self.window
              and sum(self.events) / len(self.events) >= self.threshold):
            self.open = True
            self.opens += 1
            self._skips = 0


class A1Server:
    def __init__(self, db, *, caps: Optional[QueryCaps] = None,
                 page_size: int = 16, continuation_ttl: float = 60.0,
                 use_spmd: bool = False, mesh=None,
                 budget: Optional[str] = "auto",
                 budget_ms: float = 100.0, queue_frac: float = 0.1,
                 write_batch: int = 16,
                 write_deadline_ms: Optional[float] = None,
                 read_batch: int = 16,
                 read_deadline_ms: Optional[float] = None,
                 shed_watermark: int = 64, tenant_inflight: int = 32,
                 result_ttl: Optional[float] = None,
                 shared_knee: int = 64,
                 breaker_window: int = 8, breaker_threshold: float = 0.5,
                 breaker_cooldown: int = 4,
                 write_fence: Optional[callable] = None):
        self.db = db
        # commit-time fence: when set, every wave close consults it and a
        # False answer aborts the whole wave ABORTED_FAILOVER — the last
        # line against a deposed primary committing after its epoch moved
        # on (the cluster front wires this to membership, §2/FaRM §3)
        self.write_fence = write_fence
        self.caps = caps or QueryCaps()
        self.page = page_size
        self.ttl = continuation_ttl
        self.tasks = TaskQueue(db)
        # attach the queue so write waves can threshold-trigger background
        # compaction (§2.2) instead of compacting on the commit path
        db.task_queue = self.tasks
        # deadline work must progress with an *empty* query stream too: the
        # low-priority pump doubles as the wave-deadline clock (§3.3)
        self.tasks.on_pump = self._maybe_close_write_wave
        self._continuations: dict[str, Continuation] = {}
        self._pending: list[str] = []       # tokens awaiting a refill fetch
        self.use_spmd = use_spmd
        self.mesh = mesh
        # fused frontier discipline: "auto" picks "shared" (the serving-cap
        # memory shape, owner-attributed fast-fail) for waves of >=
        # ``shared_knee`` queries — the measured amortization knee — and
        # per-query budgets below it; None/"per-query"/"shared" pin a mode.
        # Safe because shared-pool overflow re-dispatches per-query (see
        # ``_dispatch``), never re-entering the saturated pool.
        self.budget = budget
        self.shared_knee = shared_knee
        # SLO-budget scheduling (the paper's ~100 ms end-to-end budget):
        # every request carries a budget; admission decrements it through
        # the queueing / wave / hedge stages.  ``read_deadline_ms`` /
        # ``write_deadline_ms`` are now *optional* legacy overrides: when
        # ``None`` (the default) wave-close deadlines derive from the
        # queued requests' remaining budgets (a wave closes once its oldest
        # member has spent ``queue_frac`` of its budget queueing), the wave
        # execution deadline is the earliest member's budget edge (threaded
        # to the engine, which skips not-yet-run fusion groups past it),
        # and hedges are denied once the budget is gone.  An explicitly
        # passed value pins the historical fixed-deadline behavior — and
        # turns *off* per-request deadlines unless a request opts in with
        # its own ``budget_ms``.
        self.budget_ms = budget_ms
        self.queue_frac = queue_frac
        self._default_budget_ms = (None if read_deadline_ms is not None
                                   else budget_ms)
        self._read_floor_ms = (read_deadline_ms if read_deadline_ms
                               is not None else queue_frac * budget_ms)
        self._write_floor_ms = (write_deadline_ms if write_deadline_ms
                                is not None else queue_frac * budget_ms)
        # write admission: staged txns accumulate here and close into one
        # fused mutation wave at max-batch-or-deadline
        self.write_batch = write_batch
        self.write_deadline_ms = write_deadline_ms
        self._write_q: list[tuple] = []     # (wid, txn, staged gids, arrived)
        self._write_results: dict[str, dict] = {}
        self._write_exp: dict[str, float] = {}
        self._wave_opened = 0.0
        # read admission: the same max-batch-or-deadline wave, plus
        # backpressure — queue watermark shedding and per-tenant caps
        self.read_batch = read_batch
        self.read_deadline_ms = read_deadline_ms
        self.shed_watermark = shed_watermark
        self.tenant_cap = tenant_inflight
        self.result_ttl = continuation_ttl if result_ttl is None \
            else result_ttl
        self._read_q: list[_ReadReq] = []
        self._read_opened = 0.0
        self._read_results: dict[str, dict] = {}
        self._read_exp: dict[str, float] = {}
        self._tenant_inflight: collections.Counter = collections.Counter()
        self._closing = False               # read-wave reentrancy guard
        self._wave_ms = self._read_floor_ms  # EWMA of recent wave wall time
        self._wave_seeded = False           # EWMA holds a measured wall yet?
        self._wwave_ms = self._write_floor_ms  # write-wave wall EWMA
        self._wwave_seeded = False
        self.breakers: dict[str, _Breaker] = {}
        self._breaker_cfg = (breaker_window, breaker_threshold,
                             breaker_cooldown)
        self.latencies: dict[str, list[float]] = {}
        self.stats = {"queries": 0, "fastfails": 0, "hedged": 0,
                      "continuations": 0, "continuation_joins": 0,
                      "continuation_flushes": 0, "cursor_refills": 0,
                      "write_waves": 0, "write_txns": 0,
                      "write_aborts": 0, "write_rejects": 0,
                      "write_fenced": 0,
                      "admitted": 0, "served": 0, "sheds": 0,
                      "tenant_sheds": 0, "read_rejects": 0,
                      "read_waves": 0, "wave_faults": 0,
                      "aborted_faults": 0,
                      "breaker_skips": 0, "breaker_opens": 0,
                      "dropped_write_results": 0, "dropped_read_results": 0,
                      "shared_ovf_queries": 0,
                      "budget_exhausted": 0, "budget_denied_hedges": 0,
                      "deadline_truncated_queries": 0,
                      "budget_spend_ms": {
                          s: [0] * len(BUDGET_BUCKETS_MS)
                          for s in ("queue", "wave", "hedge")},
                      "planner_cache_hit_rate": 0.0,
                      "peak_frontier_bytes_per_query": 0,
                      "peak_frontier_bytes_shared": 0}
        # the planner/write counters are process-global (programs are
        # shared); a fresh server must not report the previous instance's
        # hit rates, peaks, or overflow tallies
        from repro.core import writes as writes_mod
        from repro.core.query import planner as planner_mod
        planner_mod.reset_stats()
        writes_mod.reset_stats()

    # ------------------------------------------------------------------
    def execute(self, queries: list[dict], *, qclass: str = "q",
                read_ts: Optional[int] = None,
                deadline: Optional[float] = None) -> QueryResult:
        """One batched execution with hedged retry on fast-fail.

        The whole attempt — base run *and* hedged retry — reads one pinned
        snapshot, so a patched batch never mixes two timestamps.  Pending
        continuation refills join the batch (at their own pinned
        snapshots, per-query ``read_ts`` vector) before it dispatches.
        ``deadline`` is the wave's SLO-budget edge (absolute monotonic):
        fusion groups past it come back ``deadline_q``-truncated and the
        hedge is denied once it has passed."""
        t0 = time.perf_counter()
        # close a due mutation wave BEFORE pinning the read snapshot: readers
        # then see the freshest committed state, and the pinned snapshot is
        # never moved by writes admitted mid-flight (hedged retries included)
        self._maybe_close_write_wave()
        ts0 = self.db.snapshot_ts() if read_ts is None else int(read_ts)
        self.db.active_query_ts.append(ts0)      # pin across run + hedge
        try:
            self._sweep()
            pend = self._drain_pending()
            n = len(queries)
            batch = queries + [q for _, q, _ in pend]
            ts_vec = [ts0] * n + [t for _, _, t in pend]
            self.stats["continuation_joins"] += len(pend)
            res = self._dispatch(batch, ts_vec, qclass=qclass,
                                 deadline=deadline)
            for j, (token, _, _) in enumerate(pend):
                self._refill(token, res, n + j)
            if pend:
                res = self._slice_result(res, n)
        finally:
            self.db.active_query_ts.remove(ts0)
        dt = time.perf_counter() - t0
        self.latencies.setdefault(qclass, []).append(dt)
        self.stats["queries"] += len(queries)
        self._update_planner_stats()
        # cooperative maintenance between batches (§3.3 low-priority pump)
        self.tasks.pump(1)
        return res

    def _update_planner_stats(self) -> None:
        """Surface the planner's cache hit-rate and peak frontier footprint
        (per budget mode) in the server's /stats counters."""
        from repro.core.query import planner
        cs = planner.CACHE_STATS
        total = cs["hits"] + cs["misses"]
        self.stats["planner_cache_hit_rate"] = (
            round(cs["hits"] / total, 4) if total else 0.0)
        self.stats["peak_frontier_bytes_per_query"] = (
            planner.FRONTIER_STATS["per_query_peak_bytes"])
        self.stats["peak_frontier_bytes_shared"] = (
            planner.FRONTIER_STATS["shared_peak_bytes"])
        self.stats["shared_ovf_queries"] = (
            planner.OVERFLOW_STATS["shared_ovf_queries"])

    def _budget_for(self, n: int) -> Optional[str]:
        """Resolve the per-dispatch frontier discipline: ``"auto"`` takes
        shared budgets at/above the amortization knee, else per-query."""
        if self.budget == "auto":
            return "shared" if n >= self.shared_knee else "per-query"
        return self.budget

    def _run(self, queries, caps, read_ts, fused: Optional[bool] = None,
             budget: str = "auto", deadline: Optional[float] = None):
        """The unified entry point; ``fused=True`` forces per-query
        ``failed_q`` flags (what hedged retries want).  ``budget="auto"``
        resolves the server policy; hedged retries pass ``"per-query"``
        explicitly so they never re-enter a saturated shared pool."""
        if budget == "auto":
            budget = self._budget_for(len(queries))
        mesh = self.mesh if self.use_spmd else None
        return self.db.query(queries, caps=caps, read_ts=read_ts, mesh=mesh,
                             fused=fused, budget=budget, deadline=deadline)

    def _doc_hints(self, q: dict) -> dict:
        """Effective cap hints of a document, exactly as the parser merges
        them (terminal + root, root wins) — the parse result is the single
        source of that precedence."""
        from repro.core.query.a1ql import parse
        return {k: v
                for k, v in dataclasses.asdict(parse(self.db, q).hints
                                               ).items() if v is not None}

    def _hedged_doc(self, q: dict) -> dict:
        """Quadruple a document's own frontier/expand hints for the hedged
        retry (hints override the retry caps, so they must scale too)."""
        h = self._doc_hints(q)
        scaled = {k: (4 * v if k in ("frontier", "expand") else v)
                  for k, v in h.items()}
        return {**q, "hints": scaled} if scaled else q

    def _breaker(self, qclass: str) -> _Breaker:
        br = self.breakers.get(qclass)
        if br is None:
            br = self.breakers[qclass] = _Breaker(*self._breaker_cfg)
        return br

    def breaker_state(self) -> dict:
        return {k: ("open" if b.open else "closed")
                for k, b in self.breakers.items()}

    def _dispatch(self, batch, ts_vec, fused: Optional[bool] = None,
                  qclass: str = "q",
                  deadline: Optional[float] = None) -> QueryResult:
        """Base run + circuit-breaker-hedged retry.

        A fast-failed batch is retried once at 4x capacity (tail control,
        then give up — the paper discards queries that blow the time
        budget), unless ``qclass``'s breaker is open: under sustained
        overflow the hedge is pure waste, so the wave returns
        truncated-with-flag immediately (a half-open probe hedge every few
        waves closes the breaker once retries succeed again).  With
        per-query flags (fused path) only the failed slice retries, and the
        retry always runs **per-query budgets**: a shared-pool eviction
        (``shared_ovf_q``) must not re-enter the pool that evicted it, and
        per-query-mode flags are a subset of shared-mode flags, so anything
        the pool would have answered the retry answers identically.
        Queries whose own cap hints pin frontier/expand get those hints
        quadrupled too — otherwise the hint would override ``big`` and the
        retry would re-run at exactly the failed budget.

        The hedge decision derives from the remaining SLO budget: a wave
        whose ``deadline`` has already passed gets no hedge at all
        (``budget_denied_hedges``) — re-running a failed query past the
        budget edge is exactly the waste the paper's 100 ms discipline
        forbids — and a hedge that does run inherits the deadline, so its
        not-yet-run groups truncate instead of overshooting."""
        faults_mod.check(self.db, "serve.wave.stall")
        res = self._run(batch, self.caps, ts_vec, fused=fused,
                        deadline=deadline)
        if res.failed:
            t_hedge = time.monotonic()
            if deadline is not None and t_hedge >= deadline:
                self.stats["budget_denied_hedges"] += 1
                self.stats["fastfails"] += 1
            elif self._breaker(qclass).allow():
                self.stats["hedged"] += 1
                big = dataclasses.replace(
                    self.caps, frontier=self.caps.frontier * 4,
                    expand=self.caps.expand * 4)
                if res.failed_q is not None and not all(res.failed_q):
                    idx = [i for i, f in enumerate(res.failed_q) if f]
                    retry = self._run(
                        [self._hedged_doc(batch[i]) for i in idx], big,
                        [ts_vec[i] for i in idx], fused=True,
                        budget="per-query", deadline=deadline)
                    self._patch(res, retry, idx)
                else:
                    res = self._run([self._hedged_doc(q) for q in batch],
                                    big, ts_vec, fused=fused,
                                    budget="per-query", deadline=deadline)
                self._spend("hedge", (time.monotonic() - t_hedge) * 1e3)
                if res.failed:
                    self.stats["fastfails"] += 1
            else:
                self.stats["breaker_skips"] += 1
                self.stats["fastfails"] += 1
        self._breaker(qclass).record(bool(res.failed))
        self.stats["breaker_opens"] = sum(b.opens
                                          for b in self.breakers.values())
        return res

    @staticmethod
    def _patch(res: QueryResult, retry: QueryResult, idx: list[int]) -> None:
        """Overwrite the failed queries' slices with their hedged retry."""
        for j, i in enumerate(idx):
            if retry.counts is not None and res.counts is not None:
                res.counts[i] = retry.counts[j]
            if retry.rows_gid is not None and res.rows_gid is not None:
                k = min(retry.rows_gid.shape[1], res.rows_gid.shape[1])
                res.rows_gid[i, :k] = retry.rows_gid[j, :k]
                res.truncated[i] = retry.truncated[j]
                for key in (res.rows or {}):
                    if retry.rows and key in retry.rows:
                        res.rows[key][i, :k] = retry.rows[key][j, :k]
            res.failed_q[i] = retry.failed_q[j]
            if retry.deadline_q is not None and res.deadline_q is not None:
                # the hedge itself ran out of budget: the query is now
                # budget-truncated, not failed
                res.deadline_q[i] = retry.deadline_q[j]
            if res.shared_ovf_q is not None:
                # the retry ran per-query: any surviving failure is now
                # self-inflicted, not a shared-pool eviction
                res.shared_ovf_q[i] = (False if retry.shared_ovf_q is None
                                       else retry.shared_ovf_q[j])
        res.failed = bool(np.any(res.failed_q))

    @staticmethod
    def _slice_result(res: QueryResult, n: int) -> QueryResult:
        sl = lambda a: None if a is None else a[:n]
        return QueryResult(
            counts=sl(res.counts), rows_gid=sl(res.rows_gid),
            rows=None if res.rows is None else
            {k: v[:n] for k, v in res.rows.items()},
            truncated=sl(res.truncated),
            failed_q=sl(res.failed_q),
            shared_ovf_q=sl(res.shared_ovf_q),
            deadline_q=sl(res.deadline_q),
            failed=res.failed if res.failed_q is None
            else bool(np.any(res.failed_q[:n])))

    def _spend(self, stage: str, ms: float) -> None:
        """Bucket one stage's budget spend into the /stats histogram."""
        h = self.stats["budget_spend_ms"][stage]
        for i, edge in enumerate(BUDGET_BUCKETS_MS):
            if ms <= edge:
                h[i] += 1
                return

    # ------------------------------------------------------------------
    # continuation tokens (§3.4)
    # ------------------------------------------------------------------
    def select_paged(self, query: dict, *, read_ts: Optional[int] = None
                     ) -> tuple[np.ndarray, Optional[str]]:
        """Run a select query; return (first page, continuation token).

        ``read_ts`` pins the page walk at a caller-chosen snapshot — the
        cluster takeover path replays a lost coordinator's token at the
        *original* token's timestamp so the remaining pages come back
        bit-identical (the caller owns that pin; this method adds its own
        for the token's lifetime either way)."""
        ts0 = self.db.snapshot_ts() if read_ts is None else int(read_ts)
        self.db.active_query_ts.append(ts0)      # the token's pin
        token = None
        try:
            res = self.execute([query], qclass="select", read_ts=ts0)
            if res.rows_gid is None:
                raise ValueError("select_paged needs a select query")
            rows = res.rows_gid[0]
            rows = rows[rows >= 0]
            truncated = bool(res.truncated[0])
            if len(rows) <= self.page and not truncated:
                return rows, None
            first = rows[: self.page]
            token = uuid.uuid4().hex
            hints = self._doc_hints(query)
            self._continuations[token] = Continuation(
                token=token, query=query, read_ts=ts0, rows=rows,
                cursor=len(first), want=self.caps.results,
                truncated=truncated, expires=time.monotonic() + self.ttl,
                hints=hints, max_rows=self._max_rows(hints))
            self.stats["continuations"] += 1
            return first, token
        finally:
            if token is None:                    # no token owns the pin
                self.db.active_query_ts.remove(ts0)

    def next_page(self, token: str) -> tuple[np.ndarray, Optional[str]]:
        """Follow a continuation token (expired/crashed -> client restarts,

        exactly the paper's contract).  Pages inside the cached window are
        free; paging past it enqueues a refill that joins the next wave
        batch (``execute``), or flushes synchronously when the client gets
        there first."""
        c = self._continuations.get(token)
        if c is None or time.monotonic() > c.expires:
            self._drop(token)
            raise KeyError("continuation expired; restart the query")
        if c.truncated and c.cursor + self.page > len(c.rows):
            # client outran the prefetch (or there was no traffic for the
            # refill to join): flush the pending batch now.  A no-op when a
            # prior ``execute`` already carried the refill.
            self._request_refill(token)
            self._flush_pending()
        page = c.rows[c.cursor:c.cursor + self.page]
        c.cursor += len(page)
        if c.cursor >= len(c.rows) and not c.truncated:
            self._drop(token)
            return page, None
        if c.truncated and c.cursor + self.page > len(c.rows):
            # prefetch: the follow-up fetch joins the next wave batch
            self._request_refill(token)
        return page, token

    # -- continuation internals ----------------------------------------
    def _max_rows(self, hints: dict) -> int:
        """Ceiling on the rows a refill can materialize: the final frontier
        region is per-shard under SPMD (global rows span all shards), the
        document's own ``frontier`` hint may raise it, and the hedged retry
        runs at 4x — so the window keeps growing as long as refills can
        still deliver (a progress guard in ``_refill`` terminates deep
        pagination once they stop)."""
        shards = self.db.cfg.n_shards if self.use_spmd else 1
        frontier = max(self.caps.frontier, hints.get("frontier", 0))
        return 4 * frontier * shards

    def _request_refill(self, token: str) -> None:
        if token not in self._pending:
            self._pending.append(token)

    def _drain_pending(self):
        """Pending refills -> (token, hinted query, read_ts) triples.

        Two refill plans:

        * **gid-cursor** (preferred): the document gains a root-level
          ``gid_cursor`` — a runtime ``gid > cursor`` final predicate — and
          a *constant* O(page) ``results`` window, so every deep-page
          refill costs one page instead of re-materializing a pow2-growing
          window.  Requires the local executors (rows are globally
          gid-ascending there; under SPMD positions are shard-major, so a
          max-gid cursor could skip rows) and no pinned document hints.
        * **pow2 fallback**: the historical growing-window refill (kept for
          SPMD and hint-pinned documents)."""
        out = []
        for token in self._pending:
            c = self._continuations.get(token)
            if c is None:
                continue
            c.cursor_mode = (not self.use_spmd and not c.hints
                             and len(c.rows) > 0)
            if c.cursor_mode:
                self.stats["cursor_refills"] += 1
                want = _pow2ceil(2 * self.page)          # O(page), constant
                doc = {**c.query, "gid_cursor": int(c.rows[-1]),
                       "hints": {"results": want}}
                out.append((token, doc, c.read_ts))
                continue
            want = min(_pow2ceil(max(c.want * 2, c.cursor + 2 * self.page)),
                       c.max_rows)
            c.want = want
            # keep the document's own hints (frontier/expand budgets it may
            # need) — only the results window is overridden, root wins
            out.append((token,
                        {**c.query, "hints": {**c.hints, "results": want}},
                        c.read_ts))
        self._pending = []
        return out

    def _refill(self, token: str, res: QueryResult, idx: int) -> None:
        c = self._continuations.get(token)
        if c is None:
            return
        if res.failed_q is not None and bool(res.failed_q[idx]):
            # the refill fast-failed (even after the hedge): keep the old
            # window rather than committing a failed run's partial rows —
            # the client retries via the still-truncated token (or it
            # expires)
            return
        if res.deadline_q is not None and bool(res.deadline_q[idx]):
            # the wave it joined ran out of SLO budget before the refill's
            # group dispatched: same keep-the-window contract as a failure
            return
        rows = res.rows_gid[idx]
        new_rows = rows[rows >= 0]
        if c.cursor_mode:
            # cursor refill: every row is past the window's last gid, so
            # the fetch *appends* — the window stays ascending and each
            # refill did O(page) work.  A truncated cursor fetch always
            # returned >= 1 row, so pagination is guaranteed to progress.
            if len(new_rows):
                c.rows = np.concatenate([c.rows, new_rows])
            c.truncated = bool(res.truncated[idx])
            c.expires = time.monotonic() + self.ttl
            return
        # once the window can no longer grow (want at ceiling) AND a refill
        # stopped delivering new rows, the token must complete — otherwise
        # every next_page would re-dispatch the same doomed fetch
        progressed = len(new_rows) > len(c.rows)
        c.rows = new_rows
        c.truncated = bool(res.truncated[idx]) and (
            c.want < c.max_rows or progressed)
        c.expires = time.monotonic() + self.ttl

    def _flush_pending(self) -> None:
        """Run the pending refills as their own wave batch (no primary
        traffic to join).  Same hedged-retry policy as primary batches."""
        pend = self._drain_pending()
        if not pend:
            return
        self.stats["continuation_flushes"] += 1
        res = self._dispatch([q for _, q, _ in pend],
                             [t for _, _, t in pend], fused=True,
                             qclass="continuation")
        for j, (token, _, _) in enumerate(pend):
            self._refill(token, res, j)

    def _drop(self, token: str) -> None:
        c = self._continuations.pop(token, None)
        if c is not None:
            self.db.active_query_ts.remove(c.read_ts)

    def _sweep(self) -> None:
        """Expiry sweep: continuations, write results, read results.

        Results for ids the client never polls would otherwise accumulate
        forever (the PR-6 ``_write_results`` leak); they age out on the
        same ``result_ttl`` clock and the drops are counted — a dropped
        result is an *attributed* loss, visible in /stats, never a silent
        one.  The ``serve.continuation.stale`` chaos site force-expires
        every token here (stale-token storm): clients get the §3.4
        "restart the query" contract, pins are released, nothing leaks."""
        now = time.monotonic()
        if faults_mod.check(self.db, "serve.continuation.stale"):
            for c in self._continuations.values():
                c.expires = now - 1.0
        for token in [t for t, c in self._continuations.items()
                      if now > c.expires]:
            self._drop(token)
        for results, exp, key in (
                (self._write_results, self._write_exp,
                 "dropped_write_results"),
                (self._read_results, self._read_exp,
                 "dropped_read_results")):
            for k in [k for k, e in exp.items() if now > e]:
                del exp[k]
                results.pop(k, None)
                self.stats[key] += 1

    # ------------------------------------------------------------------
    # read admission (the §3.4 serving queue: SLB -> frontend backpressure)
    # ------------------------------------------------------------------
    def submit_query(self, query: dict, *, tenant: str = "default",
                     qclass: str = "q",
                     budget_ms: Optional[float] = None) -> str:
        """Admit one client read; returns a query id to poll.

        Admission control runs *before* the queue grows: past the
        ``shed_watermark`` (or the tenant's in-flight cap) the request is
        shed immediately — a ``SHED`` result with a ``retry_after_ms``
        drain estimate, costing dict ops, not a wave slot.  Malformed
        documents reject at admission (``REJECTED``) so a bad query can
        never poison a wave.  Admitted requests close into a fused wave at
        ``read_batch`` or the wave-close deadline — fixed
        ``read_deadline_ms`` if pinned, else the oldest member's
        ``queue_frac`` budget spend (serviced by :meth:`query_result`
        polls, :meth:`pump`, or :meth:`flush_queries`).  Every admitted id
        terminates in exactly one stored result.

        ``budget_ms`` is this request's SLO budget (default: the server's
        ``budget_ms`` when running budget-derived deadlines, none when a
        fixed ``read_deadline_ms`` was pinned).  An already-exhausted
        budget (``<= 0``) short-circuits at admission: the truncated
        ``budget_exhausted`` row is stored immediately — never queued, no
        wave slot, the sub-millisecond fast-reject the paper's budget
        discipline implies."""
        qid = uuid.uuid4().hex
        now = time.monotonic()
        if budget_ms is None:
            budget_ms = self._default_budget_ms
        if budget_ms is not None and budget_ms <= 0:
            self.stats["budget_exhausted"] += 1
            self._store_read_result(qid, {
                "status": "OK", "failed": False, "rows": [],
                "truncated": True, "budget_exhausted": True})
            return qid
        if len(self._read_q) >= self.shed_watermark:
            self.stats["sheds"] += 1
            self._store_read_result(qid, {
                "status": "SHED", "reason": "overload",
                "retry_after_ms": self._retry_after_ms()})
            return qid
        if self._tenant_inflight[tenant] >= self.tenant_cap:
            self.stats["sheds"] += 1
            self.stats["tenant_sheds"] += 1
            self._store_read_result(qid, {
                "status": "SHED", "reason": f"tenant-cap:{tenant}",
                "retry_after_ms": self._retry_after_ms()})
            return qid
        try:
            from repro.core.query.a1ql import parse
            parse(self.db, query)
        except (ValueError, KeyError, TypeError) as e:
            self.stats["read_rejects"] += 1
            self._store_read_result(qid, {"status": "REJECTED",
                                          "reason": str(e)})
            return qid
        self._read_q.append(_ReadReq(
            qid, query, tenant, qclass, now, budget_ms=budget_ms,
            deadline=None if budget_ms is None
            else now + budget_ms * 1e-3))
        self._tenant_inflight[tenant] += 1
        self.stats["admitted"] += 1
        if len(self._read_q) == 1:
            self._read_opened = now
        if len(self._read_q) >= self.read_batch:
            self._close_read_wave()
        return qid

    def query_result(self, qid: str) -> Optional[dict]:
        """Poll a submitted read: the result dict, or ``None`` while its
        wave is still open.  Polling drives the deadline clock."""
        self._maybe_close_read_wave()
        r = self._read_results.pop(qid, None)
        if r is not None:
            self._read_exp.pop(qid, None)
        return r

    def flush_queries(self) -> int:
        """Close every pending read wave now (shutdown, test barriers)."""
        n = 0
        while self._read_q:
            n += self._close_read_wave()
        return n

    def pump(self) -> int:
        """One serving quantum with no client traffic: close due admission
        waves (writes and reads), sweep expired state, and run one
        maintenance task."""
        n = self._maybe_close_write_wave()
        nr = self._maybe_close_read_wave()
        if nr == 0:
            # idle tick: decay the EWMA toward the deadline floor so a burst
            # of slow waves long past doesn't inflate shed retry-after hints
            # forever (_retry_after_ms trusts _wave_ms; stale is a lie)
            self._wave_ms += 0.2 * (self._read_floor_ms - self._wave_ms)
        n += nr
        self._sweep()
        self.tasks.pump(1)
        return n

    def _retry_after_ms(self) -> float:
        """Drain estimate for a shed client: backlog waves x recent wave
        wall time (EWMA), floored at one wave deadline — *both* sides of
        the house.  Reads and writes drain through the same serving loop
        (a read wave closes the due mutation wave first), so a queued
        write backlog delays the shed client's retry exactly like queued
        reads do; quoting from the read EWMA alone under-estimates under
        mixed overload."""
        waves = max(1, -(-len(self._read_q) // self.read_batch))
        est = waves * max(self._wave_ms, self._read_floor_ms)
        if self._write_q:
            wwaves = -(-len(self._write_q) // self.write_batch)
            est += wwaves * max(self._wwave_ms, self._write_floor_ms)
        return round(est, 3)

    def _store_read_result(self, qid: str, row: dict) -> None:
        self._read_results[qid] = row
        self._read_exp[qid] = time.monotonic() + self.result_ttl

    def _maybe_close_read_wave(self) -> int:
        if self._closing or not self._read_q:
            return 0
        now = time.monotonic()
        if self.read_deadline_ms is not None:      # pinned legacy deadline
            due = (now - self._read_opened) * 1e3 >= self.read_deadline_ms
        else:
            # SLO-budget scheduling: the wave is due once any queued
            # request has spent its queueing allowance (queue_frac of its
            # budget) — the deadline knob derives from the budgets, not a
            # constant
            due = any(
                r.budget_ms is not None
                and (now - r.arrived) * 1e3
                >= self.queue_frac * r.budget_ms
                for r in self._read_q)
        if due or len(self._read_q) >= self.read_batch:
            return self._close_read_wave()
        return 0

    def _close_read_wave(self) -> int:
        """Execute one admitted wave and store every member's result.

        An injected wave crash (``engine.wave``) gets one retry — the
        crashed-worker re-dispatch — then the whole wave aborts *with
        attribution* (``fault:<site>``): the invariant is that no admitted
        request ever terminates silently, not that every wave succeeds."""
        if self._closing or not self._read_q:
            return 0
        self._closing = True
        try:
            wave = self._read_q[:self.read_batch]
            self._read_q = self._read_q[self.read_batch:]
            if self._read_q:
                self._read_opened = time.monotonic()
            t0 = time.monotonic()
            # requests whose whole budget went to queueing answer here:
            # truncated-with-flag, never a wave slot (§3.4 discards queries
            # past the budget; we answer them with the exhaustion marker)
            live = []
            for r in wave:
                if r.deadline is not None and t0 >= r.deadline:
                    self._tenant_inflight[r.tenant] -= 1
                    self.stats["budget_exhausted"] += 1
                    self._spend("queue", (t0 - r.arrived) * 1e3)
                    self._store_read_result(r.qid, {
                        "status": "OK", "failed": False, "rows": [],
                        "truncated": True, "budget_exhausted": True})
                    self.latencies.setdefault(r.qclass, []).append(
                        t0 - r.arrived)
                else:
                    live.append(r)
            if not live:
                self.stats["read_waves"] += 1
                return len(wave)
            # the wave's execution deadline: the earliest member's budget
            # edge — one fused dispatch serves the whole wave, so the
            # tightest budget bounds it (groups past the edge come back
            # ``deadline_q`` for *every* member; the paper's budget is a
            # shared discipline, not per-query slack)
            edges = [r.deadline for r in live if r.deadline is not None]
            wave_deadline = min(edges) if edges else None
            res, err = None, None
            for _ in range(2):
                try:
                    res = self.execute([r.query for r in live],
                                       qclass="wave",
                                       deadline=wave_deadline)
                    break
                except faults_mod.InjectedFault as e:
                    err = e
                    self.stats["wave_faults"] += 1
            wall = (time.monotonic() - t0) * 1e3
            if self._wave_seeded:
                self._wave_ms = 0.7 * self._wave_ms + 0.3 * wall
            else:
                # first completed wave: seed with the measurement instead of
                # blending into the deadline-derived initial guess
                self._wave_ms = wall
                self._wave_seeded = True
            done = time.monotonic()
            for i, r in enumerate(live):
                self._tenant_inflight[r.tenant] -= 1
                self._spend("queue", (t0 - r.arrived) * 1e3)
                self._spend("wave", wall)
                if res is None:
                    self.stats["aborted_faults"] += 1
                    self._store_read_result(r.qid, {
                        "status": "ABORTED", "reason": f"fault:{err.site}"})
                else:
                    self._store_read_result(r.qid, self._result_row(res, i))
                    self.stats["served"] += 1
                self.latencies.setdefault(r.qclass, []).append(
                    done - r.arrived)
            if res is not None and res.deadline_q is not None:
                self.stats["deadline_truncated_queries"] += int(
                    np.asarray(res.deadline_q)[:len(live)].sum())
            self.stats["read_waves"] += 1
            return len(wave)
        finally:
            self._closing = False

    @staticmethod
    def _result_row(res: QueryResult, i: int) -> dict:
        row = {"status": "OK",
               "failed": bool(res.failed_q[i]) if res.failed_q is not None
               else bool(res.failed)}
        if res.counts is not None and int(res.counts[i]) >= 0:
            row["count"] = int(res.counts[i])
        if res.rows_gid is not None:
            r = res.rows_gid[i]
            row["rows"] = r[r >= 0].tolist()
            row["truncated"] = bool(res.truncated[i])
        if res.deadline_q is not None and bool(res.deadline_q[i]):
            # SLO-budget truncation: the group never dispatched.  Not a
            # failure (failed stays False) — the client sees a partial
            # result with the exhaustion marker and decides to retry
            row["budget_exhausted"] = True
            row["truncated"] = True
        return row

    # ------------------------------------------------------------------
    # write admission (§3.4 grows its first write-side machinery)
    # ------------------------------------------------------------------
    def submit_write(self, ops, *, budget_ms: Optional[float] = None,
                     wid: Optional[str] = None) -> str:
        """Admit one client write: a list of mutation-op records.

        The ops stage into their own transaction at the admission snapshot
        and queue for the next mutation wave, which closes at
        ``write_batch`` transactions or the wave-close deadline — fixed
        ``write_deadline_ms`` when pinned, else once the oldest staged
        write has spent ``queue_frac`` of its SLO budget queueing (the
        deadline is serviced by query traffic via :meth:`execute`, or by
        :meth:`flush_writes`).  Returns a write id; poll
        :meth:`write_result` for the outcome.  Staging contract violations
        (duplicate key, missing endpoint, ...) reject immediately — the
        wave never sees them.  Write budgets drive *scheduling* only: an
        admitted write always commits or aborts through its wave —
        truncating a half-applied transaction is not a thing.

        ``wid=`` lets the cluster frontend pin the id (its rid): if that
        rid already committed here — a retransmit to a freshly promoted
        primary that replayed the original wave — the ORIGINAL result is
        restored instead of committing twice (exactly-once, §4).
        """
        wid = wid or uuid.uuid4().hex
        hit = getattr(self.db, "applied_rids", {}).get(wid)
        if hit is not None:
            self._write_results[wid] = {
                "status": "COMMITTED", "reason": None,
                "gids": list(hit["gids"]), "ts": hit["ts"]}
            self._write_exp[wid] = time.monotonic() + self.result_ttl
            return wid
        if budget_ms is None:
            budget_ms = (None if self.write_deadline_ms is not None
                         else self.budget_ms)
        t = self.db.create_transaction()
        t.rid = wid
        try:
            staged = self.db.write(list(ops), txn=t)
        except ValueError as e:
            self.stats["write_rejects"] += 1
            self._write_results[wid] = {"status": "ABORTED",
                                        "reason": str(e), "gids": [], "ts": -1}
            self._write_exp[wid] = time.monotonic() + self.result_ttl
            return wid
        self._write_q.append((wid, t, staged.gids,
                              time.monotonic(), budget_ms))
        if len(self._write_q) == 1:
            self._wave_opened = time.monotonic()
        if len(self._write_q) >= self.write_batch:
            self._close_write_wave()
        return wid

    def write_result(self, wid: str) -> Optional[dict]:
        """Outcome of a submitted write: ``{status, reason, gids, ts}``, or
        ``None`` while it is still queued for a wave."""
        r = self._write_results.pop(wid, None)
        if r is not None:
            self._write_exp.pop(wid, None)
        return r

    def flush_writes(self) -> int:
        """Close the open mutation wave now (deadline expiry, shutdown)."""
        return self._maybe_close_write_wave(force=True)

    def abort_staged_writes(self, reason: str = "primary deposed") -> int:
        """Demotion path: answer every staged (not yet waved) write
        ABORTED_FAILOVER with a retry hint.  A replica must never commit,
        and an admitted write must never vanish silently."""
        wave, self._write_q = self._write_q, []
        exp = time.monotonic() + self.result_ttl
        for wid, _, gids, *_ in wave:
            self._write_results[wid] = {
                "status": "ABORTED_FAILOVER", "reason": reason,
                "gids": [-1] * len(gids), "ts": -1,
                "retry_after_ms": self._wwave_ms}
            self._write_exp[wid] = exp
        self.stats["write_fenced"] = (
            self.stats.get("write_fenced", 0) + len(wave))
        return len(wave)

    def _maybe_close_write_wave(self, force: bool = False) -> int:
        if not self._write_q:
            return 0
        now = time.monotonic()
        if self.write_deadline_ms is not None:     # pinned legacy deadline
            due = (now - self._wave_opened) * 1e3 >= self.write_deadline_ms
        else:
            due = any(
                b is not None
                and (now - arr) * 1e3 >= self.queue_frac * b
                for _, _, _, arr, b in self._write_q)
        if force or due or len(self._write_q) >= self.write_batch:
            return self._close_write_wave()
        return 0

    def _close_write_wave(self) -> int:
        wave, self._write_q = self._write_q, []
        if self.write_fence is not None and not self.write_fence():
            # deposed between admission and commit: the store is untouched
            # and every queued write answers ABORTED_FAILOVER (retryable
            # through the new primary) — never a silent drop, never a
            # split-brain commit
            exp = time.monotonic() + self.result_ttl
            for wid, _, gids, *_ in wave:
                self._write_results[wid] = {
                    "status": "ABORTED_FAILOVER",
                    "reason": "primary deposed before wave close",
                    "gids": [-1] * len(gids), "ts": -1,
                    "retry_after_ms": self._wwave_ms}
                self._write_exp[wid] = exp
            self.stats["write_fenced"] = (
                self.stats.get("write_fenced", 0) + len(wave))
            return len(wave)
        t0 = time.monotonic()
        res = self.db.write([t for _, t, *_ in wave])
        # the worst-moment crash: the wave COMMITTED (it is in the store
        # and the wave log) but this primary dies before a single result
        # is stored or acked — failover must surface those commits via
        # rid-idempotent replay, exactly once
        faults_mod.check(self.db, "primary.crash.midwave")
        wall = (time.monotonic() - t0) * 1e3
        if self._wwave_seeded:
            self._wwave_ms = 0.7 * self._wwave_ms + 0.3 * wall
        else:
            self._wwave_ms = wall
            self._wwave_seeded = True
        exp = time.monotonic() + self.result_ttl
        for i, (wid, _, gids, *_) in enumerate(wave):
            ok = res.statuses[i] == "COMMITTED"
            self._write_results[wid] = {
                "status": res.statuses[i], "reason": res.reasons[i],
                "gids": gids if ok else [-1] * len(gids),
                "ts": res.ts if ok else -1}
            self._write_exp[wid] = exp
            if not ok:
                self.stats["write_aborts"] += 1
        self.stats["write_waves"] += 1
        self.stats["write_txns"] += len(wave)
        return len(wave)

    # ------------------------------------------------------------------
    def enqueue_maintenance(self) -> None:
        self.tasks.enqueue(compaction_task())
        self.tasks.enqueue(index_compaction_task())
        self.tasks.enqueue(vacuum_task())
        if self.db.replication_log is not None:
            from repro.core.replication import sweeper_task
            self.tasks.enqueue(sweeper_task(self.db.replication_log))

    def latency_report(self) -> dict:
        out = {}
        for k, xs in self.latencies.items():
            a = np.asarray(xs) * 1e3
            out[k] = {"avg_ms": float(a.mean()),
                      "p99_ms": float(np.percentile(a, 99)),
                      "n": len(a)}
        return out
