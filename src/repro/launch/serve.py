"""A1 serving driver: the production loop of §2.2/§3.4.

Reproduces the paper's serving architecture end to end on one host:

  * a frontend loop that batches incoming A1QL queries by plan shape
    (the SLB -> frontend -> backend routing of Fig. 4);
  * snapshot-timestamped execution with fast-fail + **continuation
    tokens** (§3.4: big result sets return a token; the frontend routes the
    follow-up to the owning coordinator — here, the token indexes a TTL'd
    host cache);
  * mixed plan shapes in one batch: heterogeneous batches execute as fused
    multi-query waves (core/query/planner.py) instead of one dispatch per
    query — the paper's "many concurrent queries share each operator wave";
  * interleaved writes through the transactional path + replication log;
  * the Task framework pumped between batches (compaction, sweeper,
    vacuum — "low priority workers", §3.3);
  * hedged dispatch: a query batch that fast-fails is retried once with
    quadrupled capacities (straggler/outlier mitigation — the latency-tail
    policy the paper enforces with its 100 ms budget).  When per-query
    fast-fail flags are available (the planner path), only the failed
    queries are re-dispatched and their rows patched into the batch result;
  * latency accounting per query class (avg + P99, the paper's metrics).
"""
from __future__ import annotations

import dataclasses
import time
import uuid
from typing import Optional

import numpy as np

from repro.core.query.executor import QueryCaps, QueryResult, run_queries
from repro.core.tasks import (TaskQueue, compaction_task,
                              index_compaction_task, vacuum_task)


@dataclasses.dataclass
class Continuation:
    token: str
    rows: np.ndarray
    cursor: int
    expires: float


class A1Server:
    def __init__(self, db, *, caps: Optional[QueryCaps] = None,
                 page_size: int = 16, continuation_ttl: float = 60.0,
                 use_spmd: bool = False, mesh=None):
        self.db = db
        self.caps = caps or QueryCaps()
        self.page = page_size
        self.ttl = continuation_ttl
        self.tasks = TaskQueue(db)
        self._continuations: dict[str, Continuation] = {}
        self.use_spmd = use_spmd
        self.mesh = mesh
        self.latencies: dict[str, list[float]] = {}
        self.stats = {"queries": 0, "fastfails": 0, "hedged": 0,
                      "continuations": 0}

    # ------------------------------------------------------------------
    def execute(self, queries: list[dict], *, qclass: str = "q"
                ) -> QueryResult:
        """One batched execution with hedged retry on fast-fail.

        The whole attempt — base run *and* hedged retry — reads one pinned
        snapshot, so a patched batch never mixes two timestamps."""
        t0 = time.perf_counter()
        ts0 = self.db.snapshot_ts()
        self.db.active_query_ts.append(ts0)      # pin across run + hedge
        try:
            res = self._run(queries, self.caps, ts0)
            if res.failed:
                # hedge: one retry at 4x capacity (tail control, then give
                # up — the paper discards queries that blow the time
                # budget).  With per-query flags (planner path) only the
                # failed slice retries.
                self.stats["hedged"] += 1
                big = dataclasses.replace(
                    self.caps, frontier=self.caps.frontier * 4,
                    expand=self.caps.expand * 4)
                if res.failed_q is not None and not all(res.failed_q):
                    idx = [i for i, f in enumerate(res.failed_q) if f]
                    retry = self._run_batched([queries[i] for i in idx],
                                              big, ts0)
                    self._patch(res, retry, idx)
                else:
                    res = self._run(queries, big, ts0)
                if res.failed:
                    self.stats["fastfails"] += 1
        finally:
            self.db.active_query_ts.remove(ts0)
        dt = time.perf_counter() - t0
        self.latencies.setdefault(qclass, []).append(dt)
        self.stats["queries"] += len(queries)
        # cooperative maintenance between batches (§3.3 low-priority pump)
        self.tasks.pump(1)
        return res

    def _run(self, queries, caps, read_ts):
        # both entry points route mixed-shape batches through the planner
        if self.use_spmd:
            from repro.core.query.executor_spmd import run_queries_spmd
            return run_queries_spmd(self.db, queries, self.mesh, caps,
                                    read_ts=read_ts)
        return run_queries(self.db, queries, caps, read_ts=read_ts)

    def _run_batched(self, queries, caps, read_ts):
        """Planner path unconditionally: per-query budgets + failed_q, so
        hedged retries report each retried query's own outcome."""
        if self.use_spmd:
            from repro.core.query.planner import run_queries_batched_spmd
            return run_queries_batched_spmd(self.db, queries, self.mesh,
                                            caps, read_ts=read_ts)
        from repro.core.query.planner import run_queries_batched
        return run_queries_batched(self.db, queries, caps, read_ts=read_ts)

    @staticmethod
    def _patch(res: QueryResult, retry: QueryResult, idx: list[int]) -> None:
        """Overwrite the failed queries' slices with their hedged retry."""
        for j, i in enumerate(idx):
            if retry.counts is not None and res.counts is not None:
                res.counts[i] = retry.counts[j]
            if retry.rows_gid is not None and res.rows_gid is not None:
                res.rows_gid[i] = retry.rows_gid[j]
                res.truncated[i] = retry.truncated[j]
                for k in (res.rows or {}):
                    if retry.rows and k in retry.rows:
                        res.rows[k][i] = retry.rows[k][j]
            res.failed_q[i] = retry.failed_q[j]
        res.failed = bool(np.any(res.failed_q))

    # ------------------------------------------------------------------
    # continuation tokens (§3.4)
    # ------------------------------------------------------------------
    def select_paged(self, query: dict) -> tuple[np.ndarray, Optional[str]]:
        """Run a select query; return (first page, continuation token)."""
        res = self.execute([query], qclass="select")
        rows = res.rows_gid[0]
        rows = rows[rows >= 0]
        if len(rows) <= self.page:
            return rows, None
        token = uuid.uuid4().hex
        self._continuations[token] = Continuation(
            token=token, rows=rows, cursor=self.page,
            expires=time.monotonic() + self.ttl)
        self.stats["continuations"] += 1
        return rows[:self.page], token

    def next_page(self, token: str) -> tuple[np.ndarray, Optional[str]]:
        """Follow a continuation token (expired/crashed -> client restarts,

        exactly the paper's contract)."""
        c = self._continuations.get(token)
        if c is None or time.monotonic() > c.expires:
            self._continuations.pop(token, None)
            raise KeyError("continuation expired; restart the query")
        page = c.rows[c.cursor:c.cursor + self.page]
        c.cursor += self.page
        if c.cursor >= len(c.rows):
            self._continuations.pop(token, None)
            return page, None
        return page, token

    # ------------------------------------------------------------------
    def enqueue_maintenance(self) -> None:
        self.tasks.enqueue(compaction_task())
        self.tasks.enqueue(index_compaction_task())
        self.tasks.enqueue(vacuum_task())
        if self.db.replication_log is not None:
            from repro.core.replication import sweeper_task
            self.tasks.enqueue(sweeper_task(self.db.replication_log))

    def latency_report(self) -> dict:
        out = {}
        for k, xs in self.latencies.items():
            a = np.asarray(xs) * 1e3
            out[k] = {"avg_ms": float(a.mean()),
                      "p99_ms": float(np.percentile(a, 99)),
                      "n": len(a)}
        return out
