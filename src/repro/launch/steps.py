"""Cell builders: (arch x shape x mesh) -> lowerable step functions.

For every cell this module produces:
    fn            the step function (train/prefill/decode/serve/query)
    args          ShapeDtypeStruct inputs (no allocation — dry-run safe)
    in_shardings  NamedShardings consistent with the parallelism plan
    out_shardings (or None to let GSPMD choose)
    donate        argnums donated (params/opt-state/store buffers)

The same builders back the dry-run driver, the real train/serve loops, and
the smoke tests (with reduced configs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.dist.sharding import (is_axes_leaf, resolve, rules_context,
                                 tree_specs)
from repro.optim.optimizers import (AdafactorConfig, AdamWConfig, OptState,
                                    init_opt_state, opt_update)

_AXES_LEAF = is_axes_leaf       # the shared tuple-leaf convention


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_id: str
    fn: Any
    args: tuple
    in_shardings: Any
    out_shardings: Any = None
    donate_argnums: tuple = ()
    model_flops: float = 0.0        # 6ND-style useful flops (global, /step)
    note: str = ""
    model_cfg: Any = None           # the exact config this cell lowers


def _shardings(tree_axes, mesh, rules):
    specs = tree_specs(tree_axes, rules=rules, mesh=mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _n_devices(mesh):
    n = 1
    for ax in mesh.axis_names:
        n *= mesh.shape[ax]
    return n


def _pad_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _batch_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _batch_shards(mesh):
    n = 1
    for ax in _batch_axes(mesh):
        n *= mesh.shape[ax]
    return n


def pick_opt(n_params: int):
    """Optimizer selection by memory budget (DESIGN.md §4): factored second

    moments above 100B params, bf16 moments above 10B, fp32 below."""
    if n_params > 100e9:
        return AdafactorConfig(lr=1e-3)
    if n_params > 10e9:
        return AdamWConfig(state_dtype=jnp.bfloat16)
    return AdamWConfig()


def _opt_axes(params_sds, params_axes, ocfg):
    """Optimizer-state logical axes matching init_opt_state's structure."""
    if isinstance(ocfg, AdamWConfig):
        return OptState(step=(), m=params_axes, v=params_axes)
    flat_p, tdef = jax.tree.flatten(params_sds)
    flat_a = tdef.flatten_up_to(params_axes)
    m_ax = jax.tree.unflatten(tdef, [()] * len(flat_p))

    def vax(p, a):
        if (p.ndim >= 2 and p.shape[-1] >= ocfg.min_dim_factored
                and p.shape[-2] >= ocfg.min_dim_factored):
            return (tuple(a[:-1]), tuple(a[:-2]) + (a[-1],))
        return tuple(a)

    v_ax = jax.tree.unflatten(tdef, [vax(p, a)
                                     for p, a in zip(flat_p, flat_a)])
    return OptState(step=(), m=m_ax, v=v_ax)


def _opt_state_sds(params_sds, ocfg):
    """ShapeDtypeStruct mirror of init_opt_state (no allocation)."""
    sds = jax.ShapeDtypeStruct
    if isinstance(ocfg, AdamWConfig):
        z = lambda p: sds(p.shape, ocfg.state_dtype)
        return OptState(step=sds((), jnp.int32),
                        m=jax.tree.map(z, params_sds),
                        v=jax.tree.map(z, params_sds))

    def vstate(p):
        if (p.ndim >= 2 and p.shape[-1] >= ocfg.min_dim_factored
                and p.shape[-2] >= ocfg.min_dim_factored):
            return (sds(p.shape[:-1], jnp.float32),
                    sds(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return sds(p.shape, jnp.float32)

    return OptState(step=sds((), jnp.int32),
                    m=jax.tree.map(lambda p: sds((), jnp.float32),
                                   params_sds),
                    v=jax.tree.map(vstate, params_sds))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(spec, cell, mesh, *, reduced=False) -> Cell:
    from repro.models import transformer as T
    cfg = spec.reduced if reduced else spec.model
    # sharding-rule overrides are tuned on (and scoped to) the train cells;
    # serve-path cells run the default parallelism plan
    rules = dict(spec.rules_override) if cell.kind == "train" else {}
    g = cell.geometry
    sds = jax.ShapeDtypeStruct
    params_sds = T.param_shape_dtypes(cfg)
    paxes = T.logical_axes(cfg)
    pshard = _shardings(paxes, mesh, rules)
    raw_b = rules.get("batch", _batch_axes(mesh))
    if raw_b is None:
        raw_b = ()
    elif not isinstance(raw_b, tuple):
        raw_b = (raw_b,)
    batch_ax = tuple(a for a in raw_b if a in mesh.axis_names) or None
    bs = 1
    for a in (batch_ax or ()):
        bs *= mesh.shape[a]

    if cell.kind == "train":
        gb, S = g["global_batch"], g["seq_len"]
        if reduced:
            gb, S = 4, 64
        accum = max(1, min(g.get("accum", 8), gb))
        mb = max(bs if not reduced else 1, gb // accum)
        mb = min(mb, gb)
        accum = max(1, gb // mb)
        ocfg = pick_opt(cfg.n_params())
        orules = {**rules, **spec.opt_rules_override}
        oaxes = _opt_axes(params_sds, paxes, ocfg)
        oshard = _shardings(oaxes, mesh, orules)
        o_sds = _opt_state_sds(params_sds, ocfg)
        gspecs = tree_specs(paxes, rules=orules, mesh=mesh)

        def _gconstrain(g):
            return jax.tree.map(
                lambda x, sp: jax.lax.with_sharding_constraint(x, sp),
                g, gspecs, is_leaf=lambda x: not isinstance(x, (dict, list)))

        def train_step(params, opt_state, tokens, targets):
            def micro(carry, xs):
                gacc, lacc = carry
                tk, tg = xs
                (loss, metrics), grads = jax.value_and_grad(
                    T.loss_fn, has_aux=True)(params, cfg, tk, tg)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, grads)
                # grad accumulation lives under the *optimizer* sharding
                # (ZeRO: the f32 accumulator never replicates)
                gacc = _gconstrain(gacc)
                return (gacc, lacc + loss), None

            g0 = _gconstrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0),
                                            (tokens, targets))
            grads = jax.tree.map(lambda x: x / accum, grads)
            params, opt_state, gnorm = opt_update(params, grads, opt_state,
                                                  ocfg)
            return params, opt_state, {"loss": loss / accum, "gnorm": gnorm}

        tok = sds((accum, mb, S), jnp.int32)
        bspec_t = batch_ax if mb % bs == 0 else None
        tshard = NamedSharding(mesh, P(None, bspec_t, None))
        toks_total = gb * S
        return Cell(spec.arch_id, cell.shape_id, train_step,
                    (params_sds, o_sds, tok, tok),
                    (pshard, oshard, tshard, tshard),
                    donate_argnums=(0, 1),
                    model_flops=6.0 * cfg.n_active_params() * toks_total)

    if cell.kind == "prefill":
        B, S = g["global_batch"], g["seq_len"]
        if reduced:
            B, S = 2, 64

        def prefill_step(params, tokens):
            return T.prefill(params, cfg, tokens)

        tok = sds((B, S), jnp.int32)
        bspec_p = batch_ax if B % bs == 0 else None
        tshard = NamedSharding(mesh, P(bspec_p, None))
        return Cell(spec.arch_id, cell.shape_id, prefill_step,
                    (params_sds, tok), (pshard, tshard),
                    model_flops=2.0 * cfg.n_active_params() * B * S)

    # decode (decode_32k / long_500k)
    B, S = g["global_batch"], g["seq_len"]
    if reduced:
        B, S = 2, 64
    cache_sds = T.kv_cache_shape_dtypes(cfg, B, S)
    cache_axes = [(("layers", "batch", None, "kv_seq", None),) * 2
                  for _ in cfg.block_pattern]
    bspec = batch_ax if B % bs == 0 else None
    crules = dict(rules)
    crules["batch"] = bspec
    cshard = _shardings(cache_axes, mesh, crules)
    prules = dict(rules)

    def decode_step(params, tokens, cache, pos):
        return T.decode_step(params, cfg, tokens, cache, pos)

    tok = sds((B, 1), jnp.int32)
    tshard = NamedSharding(mesh, P(bspec, None))
    pos_sds = sds((), jnp.int32)
    return Cell(spec.arch_id, cell.shape_id, decode_step,
                (params_sds, tok, cache_sds, pos_sds),
                (_shardings(paxes, mesh, prules), tshard, cshard,
                 NamedSharding(mesh, P())),
                donate_argnums=(2,),
                model_flops=2.0 * cfg.n_active_params() * B)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_geometry(cell, reduced: bool):
    g = cell.geometry
    if g.get("sampled"):
        b, (f1, f2) = g["batch_nodes"], g["fanout"]
        if reduced:
            b, f1, f2 = 8, 3, 2
        n = b * (1 + f1 + f1 * f2)
        e = b * f1 + b * f1 * f2
        return n, e, (g["d_feat"] if not reduced else 16), 1
    if g.get("molecule"):
        bt = g["batch"] if not reduced else 4
        return (bt * g["n_nodes"], bt * g["n_edges"],
                g["d_feat"] if not reduced else 8, bt)
    if reduced:
        return 64, 256, 16, 1
    return g["n_nodes"], g["n_edges"], g["d_feat"], 1


def _gnn_cell(spec, cell, mesh, *, reduced=False) -> Cell:
    from repro.models.gnn import gcn, meshgraphnet as mgn, nequip, sage
    from repro.models.gnn.common import GraphBatch
    N, E, dF, n_graphs = _gnn_geometry(cell, reduced)
    nd = _n_devices(mesh)
    E = _pad_to(E, nd)            # edges shard over the whole mesh
    if N > 1_000_000:
        N = _pad_to(N, mesh.shape["model"])
    base = spec.reduced if reduced else spec.model
    fam = type(base).__name__
    sds = jax.ShapeDtypeStruct
    edge_spec = P(tuple(mesh.axis_names))
    # huge graphs: shard node arrays on 'model' (A1-style routed gathers);
    # small graphs replicate nodes
    huge = N > 1_000_000
    node_spec = P("model") if huge else P()

    if fam == "GCNConfig":
        cfg = dataclasses.replace(base, d_in=dF)
        mod, label_sds, mask_n = gcn, sds((N,), jnp.int32), N
    elif fam == "SageConfig":
        cfg = dataclasses.replace(base, d_in=dF)
        mod, label_sds, mask_n = sage, sds((N,), jnp.int32), N
    elif fam == "MGNConfig":
        cfg = dataclasses.replace(base, d_in=dF)
        mod, label_sds, mask_n = mgn, sds((N, 3), jnp.float32), N
    else:
        cfg = base
        mod, label_sds, mask_n = nequip, sds((n_graphs,), jnp.float32), \
            n_graphs
    needs_pos = fam in ("MGNConfig", "NequIPConfig")
    needs_gid = fam == "NequIPConfig"

    batch_sds = GraphBatch(
        node_feat=sds((N, dF), jnp.float32),
        edge_src=sds((E,), jnp.int32), edge_dst=sds((E,), jnp.int32),
        labels=label_sds, train_mask=sds((mask_n,), jnp.bool_),
        positions=sds((N, 3), jnp.float32) if needs_pos else None,
        graph_ids=sds((N,), jnp.int32) if needs_gid else None,
        n_graphs=n_graphs)
    batch_spec = GraphBatch(
        node_feat=node_spec, edge_src=edge_spec, edge_dst=edge_spec,
        labels=P() if not huge else (node_spec if label_sds.shape[0] == N
                                     else P()),
        train_mask=P() if mask_n != N or not huge else node_spec,
        positions=(node_spec if needs_pos else None),
        graph_ids=(node_spec if needs_gid else None),
        n_graphs=n_graphs)
    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_spec,
                          is_leaf=lambda x: isinstance(x, P))

    params_sds = mod.param_shape_dtypes(cfg)
    pshard = jax.tree.map(lambda _: NamedSharding(mesh, P()), params_sds)
    ocfg = AdamWConfig()
    o_sds = _opt_state_sds(params_sds, ocfg)
    oshard = jax.tree.map(lambda _: NamedSharding(mesh, P()), o_sds)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            mod.loss_fn, has_aux=True)(params, cfg, batch)
        params, opt_state, gnorm = opt_update(params, grads, opt_state,
                                              ocfg)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    # useful flops: gather+scatter ~ 4*E*d + dense transforms per model
    d_h = getattr(cfg, "d_hidden", getattr(cfg, "mul", 32))
    layers = getattr(cfg, "n_layers", 2)
    mf = 3 * (2.0 * E * d_h + 2.0 * N * dF * d_h) * layers
    return Cell(spec.arch_id, cell.shape_id, train_step,
                (params_sds, o_sds, batch_sds), (pshard, oshard, bshard),
                donate_argnums=(0, 1), model_flops=mf, model_cfg=cfg)


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _recsys_cell(spec, cell, mesh, *, reduced=False) -> Cell:
    from repro.models import recsys as R
    cfg = spec.reduced if reduced else spec.model
    g = cell.geometry
    sds = jax.ShapeDtypeStruct
    params_sds = R.param_shape_dtypes(cfg)
    paxes = R.logical_axes(cfg)
    pshard = _shardings(paxes, mesh, spec.rules_override)
    batch_ax = _batch_axes(mesh)
    B = g["batch"] if not reduced else 8
    hist = sds((B, cfg.seq_len), jnp.int32)
    tgt = sds((B,), jnp.int32)
    dense = sds((B, cfg.n_dense), jnp.float32)
    labels = sds((B,), jnp.float32)
    bspec = batch_ax if B >= _batch_shards(mesh) else None
    bshard = NamedSharding(mesh, P(bspec))
    bshard2 = NamedSharding(mesh, P(bspec, None))
    # ~flops: emb gather + 1 attn block over L+1 + MLP
    L1 = cfg.seq_len + 1
    mlp_f = 0
    dims = ((cfg.seq_len + 2) * cfg.embed_dim,) + cfg.mlp_dims + (1,)
    for a, b in zip(dims[:-1], dims[1:]):
        mlp_f += 2 * a * b
    flops_fwd = B * (4 * L1 * cfg.embed_dim ** 2
                     + 2 * L1 * L1 * cfg.embed_dim + mlp_f)

    if cell.kind == "train":
        ocfg = AdamWConfig()
        oaxes = _opt_axes(params_sds, paxes, ocfg)
        oshard = _shardings(oaxes, mesh, spec.rules_override)
        o_sds = _opt_state_sds(params_sds, ocfg)

        def train_step(params, opt_state, hist, tgt, dense, labels):
            (loss, m), grads = jax.value_and_grad(
                R.loss_fn, has_aux=True)(params, cfg, hist, tgt, dense,
                                         labels)
            params, opt_state, gnorm = opt_update(params, grads, opt_state,
                                                  ocfg)
            return params, opt_state, {"loss": loss, "gnorm": gnorm}

        return Cell(spec.arch_id, cell.shape_id, train_step,
                    (params_sds, o_sds, hist, tgt, dense, labels),
                    (pshard, oshard, bshard2, bshard, bshard2, bshard),
                    donate_argnums=(0, 1), model_flops=3 * flops_fwd)

    if cell.kind == "serve":
        def serve_step(params, hist, tgt, dense):
            return R.forward(params, cfg, hist, tgt, dense)

        return Cell(spec.arch_id, cell.shape_id, serve_step,
                    (params_sds, hist, tgt, dense),
                    (pshard, bshard2, bshard, bshard2),
                    model_flops=flops_fwd)

    # retrieval: 1 user x 1M candidates
    C = g["n_candidates"] if not reduced else 256
    cand = sds((C,), jnp.int32)
    cshard = NamedSharding(mesh, P(batch_ax))

    def retrieval_step(params, hist, dense, cand_ids):
        return R.retrieval_scores(params, cfg, hist, dense, cand_ids)

    return Cell(spec.arch_id, cell.shape_id, retrieval_step,
                (params_sds, sds((B, cfg.seq_len), jnp.int32),
                 dense, cand),
                (pshard, NamedSharding(mesh, P(None, None)),
                 NamedSharding(mesh, P(None, None)), cshard),
                model_flops=flops_fwd + 2.0 * C * cfg.embed_dim)


# ---------------------------------------------------------------------------
# a1 cells (the paper's own workload)
# ---------------------------------------------------------------------------

def _a1_cell(spec, cell, mesh, *, reduced=False) -> Cell:
    from repro.core.query.a1ql import Hop, Plan
    from repro.core.query.executor import QueryCaps
    from repro.core.query.executor_spmd import compile_query_spmd
    from repro.core.store import make_store_shapes
    from repro.core import txn as txn_mod

    cfg = spec.reduced if reduced else spec.model
    n_dev = 1
    for ax in mesh.axis_names:
        n_dev *= mesh.shape[ax]
    storage_axes = ("data", "model")
    store_dev = mesh.shape["data"] * mesh.shape["model"]
    cfg = dataclasses.replace(cfg, n_shards=store_dev)
    store_sds = make_store_shapes(cfg)
    g = cell.geometry
    sds = jax.ShapeDtypeStruct
    store_spec = jax.tree.map(lambda _: NamedSharding(mesh, P(storage_axes)),
                              store_sds)

    if cell.kind == "a1_update":
        caps = txn_mod.BatchCaps()
        d = cfg

        def upd(store, ts, *ops):
            return txn_mod.apply_batch(store, d, ts, *ops)

        p32 = lambda n: sds((n,), jnp.int32)
        ops = (p32(caps.create_v), p32(caps.create_v), p32(caps.create_v),
               sds((caps.create_v, d.d_f32), jnp.float32),
               sds((caps.create_v, d.d_i32), jnp.int32), p32(caps.create_v),
               p32(caps.update_v),
               sds((caps.update_v, d.d_f32), jnp.float32),
               sds((caps.update_v, d.d_i32), jnp.int32),
               p32(caps.delete_v), p32(caps.delete_v), p32(caps.delete_v),
               p32(caps.create_e), p32(caps.create_e), p32(caps.create_e),
               p32(caps.create_e), p32(caps.create_e),
               p32(caps.delete_e), p32(caps.delete_e), p32(caps.delete_e),
               p32(cfg.n_shards), p32(cfg.n_shards), p32(cfg.n_shards))
        rep = NamedSharding(mesh, P())
        opsh = tuple(jax.tree.map(lambda _: rep, o) for o in ops)
        return Cell(spec.arch_id, cell.shape_id, upd,
                    (store_sds, sds((), jnp.int32)) + ops,
                    (store_spec, rep) + opsh,
                    donate_argnums=(0,),
                    model_flops=0.0)

    Q = g["n_queries"] if not reduced else 4
    caps = (QueryCaps(**g["caps"]) if not reduced
            else QueryCaps(frontier=64, expand=256, bucket=32, results=8))
    if g.get("star"):
        branches = tuple(
            Plan(start_vtype=i, hops=(Hop("out", i, 2, None),),
                 terminal="count") for i in range(g["star"]))
        plan = Plan(start_vtype=-1, hops=(), terminal="count",
                    branches=branches)
        keys = sds((g["star"], Q), jnp.int32)
    else:
        hops = tuple(Hop("out", h % 3, -1, None) for h in range(g["hops"]))
        plan = Plan(start_vtype=0, hops=hops, terminal="count")
        keys = sds((Q,), jnp.int32)

    query_axis = "pod" if "pod" in mesh.axis_names else None
    fn = compile_query_spmd(cfg, plan, caps, Q, mesh, storage_axes,
                            query_axis=query_axis)
    valid = sds((Q,), jnp.bool_)
    rep = NamedSharding(mesh, P())
    # traversal 'useful work': ~1 gather per expanded edge per hop
    mf = float(Q * caps.expand * 8)
    return Cell(spec.arch_id, cell.shape_id, fn,
                (store_sds, keys, valid, sds((), jnp.int32)),
                None,   # shard_map-под jit: shardings baked into in_specs
                model_flops=mf,
                note="jit(shard_map): shardings baked into in_specs")


# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_id: str, mesh, *,
               reduced: bool = False) -> Cell:
    spec = registry.get(arch_id)
    cell = spec.cell(shape_id)
    if cell.skip and not reduced:
        raise ValueError(
            f"cell {arch_id}/{shape_id} is skipped: {cell.skip}")
    if spec.family == "lm":
        c = _lm_cell(spec, cell, mesh, reduced=reduced)
        c.model_cfg = spec.reduced if reduced else spec.model
        if spec.rules_override and cell.kind == "train":
            inner = c.fn
            rules = dict(spec.rules_override)

            def fn_with_rules(*a, __inner=inner, __rules=rules, **k):
                with rules_context(__rules):
                    return __inner(*a, **k)

            c.fn = fn_with_rules
    elif spec.family == "gnn":
        c = _gnn_cell(spec, cell, mesh, reduced=reduced)
    elif spec.family == "recsys":
        c = _recsys_cell(spec, cell, mesh, reduced=reduced)
        c.model_cfg = spec.reduced if reduced else spec.model
    elif spec.family == "a1":
        c = _a1_cell(spec, cell, mesh, reduced=reduced)
        c.model_cfg = spec.reduced if reduced else spec.model
    else:
        raise ValueError(spec.family)
    return c
