"""Training driver: fault-tolerant loop with checkpoint/restart.

Runs any registered arch at reduced (CPU) or full (TPU) scale:

    PYTHONPATH=src python -m repro.launch.train --arch gcn-cora --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-3-4b \
        --reduced --steps 20 --ckpt-dir /tmp/ckpt

Fault-tolerance features exercised here (not just claimed):
  * periodic async checkpoints (params + opt state + data cursor);
  * automatic resume from the latest checkpoint, including onto a
    *different* mesh shape (elastic resume — re-shard at load);
  * input pipeline prefetch (a straggling host batch overlaps compute);
  * NaN-loss circuit breaker (skip-and-log, a production must-have).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()
    run_training(arch=args.arch, steps=args.steps, reduced=args.reduced,
                 ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)


def run_training(arch: str, *, steps: int = 50, reduced: bool = True,
                 ckpt_dir: str = None, ckpt_every: int = 20, seed: int = 0,
                 log_every: int = 10, mesh=None) -> dict:
    """Programmatic entry point; returns final metrics."""
    from repro.ckpt.manager import CheckpointManager
    from repro.configs import registry
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import AdamWConfig, build_cell, pick_opt
    from repro.optim.optimizers import init_opt_state

    spec = registry.get(arch)
    mesh = mesh or make_test_mesh((1, 1), ("data", "model"))
    shape0 = spec.shapes[0].shape_id
    cell = build_cell(arch, shape0, mesh, reduced=reduced)
    cfg = cell.model_cfg

    key = jax.random.key(seed)
    if spec.family == "lm":
        from repro.models.transformer import init_params
        params = init_params(cfg, key)
        ocfg = pick_opt(cfg.n_params())
    elif spec.family == "recsys":
        from repro.models.recsys import init_params
        params = init_params(cfg, key)
        ocfg = AdamWConfig()
    else:
        from repro.models.gnn import gcn, meshgraphnet as mgn, nequip, sage
        mod = {"GCNConfig": gcn, "SageConfig": sage, "MGNConfig": mgn,
               "NequIPConfig": nequip}[type(cfg).__name__]
        params = mod.init_params(cfg, key)
        ocfg = AdamWConfig()
    opt_state = init_opt_state(params, ocfg)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        (params, opt_state), manifest = mgr.restore((params, opt_state))
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(cell.fn, donate_argnums=cell.donate_argnums)
    batches = _batch_source(spec, cell, cfg, seed)
    metrics = {}
    t0 = time.time()
    with mesh:
        for step in range(start_step, steps):
            batch = next(batches)
            params_new, opt_new, metrics = step_fn(params, opt_state,
                                                   *batch)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                print(f"step {step}: non-finite loss, skipping update")
                continue            # circuit breaker: keep old state
            params, opt_state = params_new, opt_new
            if step % log_every == 0:
                dt = (time.time() - t0) / max(step - start_step + 1, 1)
                print(f"step {step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['gnorm']):.3f} "
                      f"({dt*1e3:.0f} ms/step)")
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state),
                         meta={"arch": arch, "loss": loss})
    if mgr is not None:
        mgr.wait()
    return {k: float(v) for k, v in metrics.items()}


def _batch_source(spec, cell, cfg, seed):
    """Infinite iterator of real input batches matching the cell's args."""
    rng = np.random.default_rng(seed)
    if spec.family == "lm":
        accum, mb, S = cell.args[2].shape

        def gen():
            while True:
                toks = rng.integers(0, cfg.vocab, (accum, mb, S + 1))
                yield (jnp.asarray(toks[..., :-1], jnp.int32),
                       jnp.asarray(toks[..., 1:], jnp.int32))
        return gen()
    if spec.family == "recsys":
        from repro.data.recsys import bst_batch
        B = cell.args[2].shape[0]

        def gen():
            i = 0
            while True:
                yield bst_batch(batch=B, seq_len=cfg.seq_len,
                                n_items=cfg.n_items, n_dense=cfg.n_dense,
                                seed=seed + i)
                i += 1
        return gen()
    # gnn: synthetic graphs matching the cell geometry (full-batch
    # semantics; the minibatch shapes use data/sampler.py in production)
    from repro.models.gnn.common import GraphBatch
    tmpl = cell.args[2]
    N = tmpl.node_feat.shape[0]
    E = tmpl.edge_src.shape[0]

    def gen():
        i = 0
        while True:
            r = np.random.default_rng(seed + i)
            lbl_int = tmpl.labels.dtype == jnp.int32
            yield (GraphBatch(
                node_feat=jnp.asarray(
                    np.abs(r.normal(size=tmpl.node_feat.shape)) % 4,
                    tmpl.node_feat.dtype),
                edge_src=jnp.asarray(r.integers(0, N, E), jnp.int32),
                edge_dst=jnp.asarray(r.integers(0, N, E), jnp.int32),
                labels=(jnp.asarray(r.integers(0, 4, tmpl.labels.shape),
                                    jnp.int32) if lbl_int else
                        jnp.asarray(r.normal(size=tmpl.labels.shape),
                                    jnp.float32)),
                train_mask=jnp.ones(tmpl.train_mask.shape, bool),
                positions=(jnp.asarray(r.normal(size=tmpl.positions.shape),
                                       tmpl.positions.dtype)
                           if tmpl.positions is not None else None),
                graph_ids=(jnp.asarray(
                    np.minimum(np.arange(N) // max(N // tmpl.n_graphs, 1),
                               tmpl.n_graphs - 1), jnp.int32)
                    if tmpl.graph_ids is not None else None),
                n_graphs=tmpl.n_graphs),)
            i += 1
    return gen()


if __name__ == "__main__":
    main()
