"""Cluster transport: length-prefixed JSON frames + channels (Fig. 4).

The A1 fleet talks through an SLB in front of coordinator processes; this
module is the wire layer under :mod:`repro.launch.cluster`:

  * **frames** — every message is one length-prefixed JSON frame
    (4-byte big-endian length + UTF-8 JSON body).  JSON keeps the protocol
    debuggable (``nc``-able) and forces the routing layer to stay
    data-only; a numpy-safe encoder folds result arrays to plain lists at
    the boundary.
  * **write-op codec** — the typed mutation-op records
    (:mod:`repro.core.writes`) serialize to tagged dicts so clients can
    submit writes over the wire.
  * :class:`MemoryChannel` — the in-process channel used by inproc
    coordinator fleets and the chaos suite: every request/response pair
    still round-trips through *real encoded frames*, and each frame
    consults the ``transport.drop`` fault site, so drop/duplicate
    schedules are deterministic and the idempotency contract (resend the
    same ``rid``, get the same answer) is testable without sockets.
  * :class:`WorkerClient` / :func:`serve_worker` — a blocking JSON-frame
    TCP client and a threaded socket server: the frontend's link to
    spawned coordinator worker processes.
  * :func:`serve_frontend` — the asyncio front door: clients connect over
    TCP, send frames, get frames back (the SLB's public face).

Frame-level loss is the *client's* problem by design: a dropped request or
response returns ``None`` from :meth:`MemoryChannel.request` and the caller
retransmits with the same ``rid`` — the coordinator's rid cache makes the
retry idempotent even when the first attempt executed (response lost after
the work was done, the classic at-least-once duplicate).
"""
from __future__ import annotations

import dataclasses
import json
import socket
import struct
import threading
from typing import Callable, Optional

import numpy as np

from repro.core import faults as faults_mod
from repro.core import writes as writes_mod

_LEN = struct.Struct(">I")
MAX_FRAME = 64 * 1024 * 1024


class _NumpyEncoder(json.JSONEncoder):
    """Results carry numpy scalars/arrays; the wire carries plain JSON."""

    def default(self, o):
        if isinstance(o, np.integer):
            return int(o)
        if isinstance(o, np.floating):
            return float(o)
        if isinstance(o, np.bool_):
            return bool(o)
        if isinstance(o, np.ndarray):
            return o.tolist()
        return super().default(o)


def encode_frame(obj: dict) -> bytes:
    body = json.dumps(obj, cls=_NumpyEncoder,
                      separators=(",", ":")).encode()
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame too large ({len(body)} bytes)")
    return _LEN.pack(len(body)) + body


def decode_frame(frame: bytes) -> dict:
    (n,) = _LEN.unpack_from(frame)
    return json.loads(frame[_LEN.size:_LEN.size + n].decode())


class FrameBuffer:
    """Incremental frame decoder for a byte stream (TCP reassembly)."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> list[dict]:
        self._buf += data
        out = []
        while len(self._buf) >= _LEN.size:
            (n,) = _LEN.unpack_from(self._buf)
            if n > MAX_FRAME:
                raise ValueError(f"frame too large ({n} bytes)")
            if len(self._buf) < _LEN.size + n:
                break
            out.append(json.loads(bytes(
                self._buf[_LEN.size:_LEN.size + n]).decode()))
            del self._buf[:_LEN.size + n]
        return out


# ---------------------------------------------------------------------------
# write-op wire codec
# ---------------------------------------------------------------------------

_WRITE_OPS = {cls.__name__: cls for cls in writes_mod._OP_TYPES}


def encode_write_op(op) -> dict:
    if type(op).__name__ not in _WRITE_OPS:
        raise TypeError(f"not a write op: {type(op).__name__}")
    return {"op": type(op).__name__, **dataclasses.asdict(op)}


def decode_write_op(d: dict):
    d = dict(d)
    cls = _WRITE_OPS[d.pop("op")]
    return cls(**d)


# ---------------------------------------------------------------------------
# in-process channel (deterministic chaos)
# ---------------------------------------------------------------------------

class MemoryChannel:
    """Frame-encoded request/response against an in-process handler.

    Both directions are real frames: the request is encoded, the
    ``transport.drop`` site is consulted (``race`` = this frame is lost),
    the handler sees the *decoded* frame, and the response frame gets its
    own drop check.  A response-side drop is the nasty one — the handler
    already executed — which is exactly the duplicate-delivery case the
    coordinator rid cache must absorb.  ``owner`` carries the fault
    injector (the shared db in the cluster, so one schedule drives every
    channel deterministically)."""

    def __init__(self, handler: Callable[[dict], dict], owner=None):
        self._handler = handler
        self._owner = owner
        self.sent = 0
        self.dropped = 0

    def request(self, msg: dict) -> Optional[dict]:
        """One round trip; ``None`` = a frame was lost, caller retransmits."""
        frame = encode_frame(msg)
        self.sent += 1
        if faults_mod.check(self._owner, "transport.drop"):
            self.dropped += 1
            return None                       # request frame lost
        resp = self._handler(decode_frame(frame))
        frame = encode_frame(resp)
        self.sent += 1
        if faults_mod.check(self._owner, "transport.drop"):
            self.dropped += 1
            return None                       # response frame lost
        return decode_frame(frame)


# ---------------------------------------------------------------------------
# TCP worker link (process mode)
# ---------------------------------------------------------------------------

def _recv_frame(sock: socket.socket) -> Optional[dict]:
    hdr = b""
    while len(hdr) < _LEN.size:
        chunk = sock.recv(_LEN.size - len(hdr))
        if not chunk:
            return None
        hdr += chunk
    (n,) = _LEN.unpack(hdr)
    if n > MAX_FRAME:
        raise ValueError(f"frame too large ({n} bytes)")
    body = b""
    while len(body) < n:
        chunk = sock.recv(min(65536, n - len(body)))
        if not chunk:
            return None
        body += chunk
    return json.loads(body.decode())


class WorkerClient:
    """JSON-frame request/response client to one worker socket.

    One in-flight request at a time per client (the frontend serializes
    per-worker traffic; cross-worker requests are concurrent because each
    worker has its own client/socket).

    A worker that *hangs* (accepts the frame, never answers) must not
    wedge the frontend: every recv is bounded by ``recv_timeout``.  A
    timeout desynchronizes the frame stream — the late response would
    misalign against the next request — so the socket is dropped and
    rebuilt with a bounded, jitter-backed reconnect.  The outcome is
    surfaced as ``suspect=True`` (hung, lease should stop renewing —
    membership's problem) rather than ``dead`` (connection refused/reset:
    the process is gone).  A clean round trip clears suspicion."""

    def __init__(self, host: str, port: int, timeout: float = 30.0, *,
                 recv_timeout: Optional[float] = None,
                 reconnect_attempts: int = 3,
                 backoff_s: float = 0.05, seed: int = 0):
        self.addr = (host, port)
        self.connect_timeout = timeout
        self.recv_timeout = timeout if recv_timeout is None else recv_timeout
        self.reconnect_attempts = int(reconnect_attempts)
        self.backoff_s = float(backoff_s)
        self.suspect = False
        self.timeouts = 0
        self.reconnects = 0
        import random
        self._rng = random.Random((seed << 17) ^ port)
        self._lock = threading.Lock()
        self._sock: Optional[socket.socket] = None
        self._connect()

    def _connect(self) -> None:
        self._sock = socket.create_connection(
            self.addr, timeout=self.connect_timeout)
        self._sock.settimeout(self.recv_timeout)

    def _drop_sock(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _reconnect(self) -> bool:
        """Bounded reconnect with jittered exponential backoff: the fleet's
        clients must not stampede a worker that is coming back up."""
        import time as _time
        self._drop_sock()
        for attempt in range(self.reconnect_attempts):
            _time.sleep(self.backoff_s * (2 ** attempt)
                        * (0.5 + self._rng.random()))
            try:
                self._connect()
                self.reconnects += 1
                return True
            except OSError:
                continue
        return False

    def request(self, msg: dict) -> Optional[dict]:
        """One round trip; ``None`` means no answer — check ``suspect`` to
        tell a hung worker (route around, don't bury) from a dead one."""
        with self._lock:
            if self._sock is None and not self._reconnect():
                return None                   # worker gone
            try:
                self._sock.sendall(encode_frame(msg))
                resp = _recv_frame(self._sock)
                if resp is not None:
                    self.suspect = False      # clean round trip
                return resp
            except socket.timeout:
                # hung, not dead: the stream is now desynced — drop it,
                # rebuild lazily, and flag the worker suspect so the
                # frontend routes around it instead of blocking forever
                self.timeouts += 1
                self.suspect = True
                self._drop_sock()
                self._reconnect()
                return None
            except OSError:
                self._drop_sock()
                return None                   # worker gone

    def close(self) -> None:
        self._drop_sock()


def serve_worker(handler: Callable[[dict], dict], host: str = "127.0.0.1",
                 port: int = 0):
    """Threaded frame server for a coordinator worker process.

    Returns ``(bound_port, shutdown)``.  Each accepted connection gets a
    thread running a strict frame-in/frame-out loop; the handler is the
    coordinator's dispatch (which does its own locking)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(16)
    stop = threading.Event()

    def _conn_loop(conn: socket.socket) -> None:
        with conn:
            while not stop.is_set():
                try:
                    msg = _recv_frame(conn)
                except (OSError, ValueError):
                    return
                if msg is None:
                    return
                try:
                    resp = handler(msg)
                except Exception as e:          # never kill the link
                    resp = {"status": "ERROR", "reason": repr(e)}
                try:
                    conn.sendall(encode_frame(resp))
                except OSError:
                    return

    def _accept_loop() -> None:
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
            except OSError:
                return
            threading.Thread(target=_conn_loop, args=(conn,),
                             daemon=True).start()

    threading.Thread(target=_accept_loop, daemon=True).start()

    def shutdown() -> None:
        stop.set()
        try:
            srv.close()
        except OSError:
            pass

    return srv.getsockname()[1], shutdown


# ---------------------------------------------------------------------------
# asyncio front door (the SLB's public face)
# ---------------------------------------------------------------------------

async def serve_frontend(frontend, host: str = "127.0.0.1", port: int = 0):
    """Serve ``frontend.handle`` over asyncio TCP; returns the server.

    Clients send JSON frames (``{"op": ..., ...}``) and receive one frame
    per request.  The frontend's handler is synchronous (waves are
    CPU-bound device dispatches, not I/O), so it runs on the default
    executor to keep the event loop responsive to other connections."""
    import asyncio
    loop = asyncio.get_running_loop()

    async def _client(reader: "asyncio.StreamReader",
                      writer: "asyncio.StreamWriter") -> None:
        buf = FrameBuffer()
        try:
            while True:
                data = await reader.read(65536)
                if not data:
                    break
                for msg in buf.feed(data):
                    resp = await loop.run_in_executor(
                        None, frontend.handle, msg)
                    writer.write(encode_frame(resp))
                    await writer.drain()
        finally:
            writer.close()

    return await asyncio.start_server(_client, host, port)
