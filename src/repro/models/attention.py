"""Sharding-annotated attention for the model zoo.

kernels/flash_attention/ref.py is the *pure* oracle used for kernel parity
tests.  The model path needs the same math with explicit sharding
constraints on every intermediate — without them GSPMD re-shards the
(B, H, S, S) score tensors to full-batch on the 16x16 mesh (measured: 16x
redundant attention compute and terabyte-scale temps on the train cells).

Layout contract: batch on 'data' (+'pod'), q heads on 'model', kv heads
replicated (kv_heads < TP degree for every assigned GQA arch), sequence
unsharded inside attention (Megatron-SP gathers happen at the block edges).

On TPU this module routes to the flash kernel (which enforces the same
layout via its BlockSpecs); the constrained-einsum path below is what the
dry-run lowers on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.kernels.flash_attention.ops import mha as kernel_mha
from repro.kernels.flash_attention.ref import NEG_INF, attention_mask

_USE_KERNEL = jax.default_backend() == "tpu"

_BHSS = ("batch", "heads", None, None)


_CHUNK = 2048      # flash-style kv chunk for the jnp path


def mha(q, k, v, *, causal: bool = True, window: int = 0,
        q_offset: int = 0):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) — GQA-aware.

    The jnp path is *chunked*: a lax.scan over kv blocks with a running
    (max, denom, acc) softmax state — the flash recurrence in pure jnp — so
    the lowered program's working set is O(S * chunk), not O(S^2).  This is
    what the dry-run compiles; the TPU path is the Pallas kernel with the
    same recurrence in VMEM.
    """
    if _USE_KERNEL:
        return kernel_mha(q, k, v, causal, window, q_offset)
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    q = constrain(q, _BHSS)
    k = constrain(k, ("batch", None, None, None))
    v = constrain(v, ("batch", None, None, None))
    if G > 1:
        k = jnp.repeat(k, G, axis=1)
        v = jnp.repeat(v, G, axis=1)
    k = constrain(k, _BHSS)
    v = constrain(v, _BHSS)
    qf = q.astype(jnp.float32) * (D ** -0.5)

    C = min(_CHUNK, Sk)
    if Sk % C != 0:                      # fall back: one chunk
        C = Sk
    n_chunks = Sk // C
    kc = k.astype(jnp.float32).reshape(B, Hq, n_chunks, C, D)
    vc = v.astype(jnp.float32).reshape(B, Hq, n_chunks, C, D)
    kc = jnp.moveaxis(kc, 2, 0)          # (n, B, H, C, D)
    vc = jnp.moveaxis(vc, 2, 0)
    qpos = jnp.arange(Sq) + q_offset     # (Sq,)

    def body(carry, xs):
        mx, den, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb)        # (B,H,Sq,C)
        s = constrain(s, _BHSS)
        kpos = ci * C + jnp.arange(C)
        msk = jnp.ones((Sq, C), bool)
        if causal:
            msk &= kpos[None, :] <= qpos[:, None]
        if window and window > 0:
            msk &= kpos[None, :] > (qpos[:, None] - window)
        s = jnp.where(msk[None, None], s, NEG_INF)
        mx_new = jnp.maximum(mx, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - mx_new)
        p = jnp.where(msk[None, None], p, 0.0)
        alpha = jnp.exp(mx - mx_new)
        den = den * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (mx_new, den, constrain(acc, _BHSS)), None

    mx0 = jnp.full((B, Hq, Sq, 1), NEG_INF, jnp.float32)
    den0 = jnp.zeros((B, Hq, Sq, 1), jnp.float32)
    acc0 = jnp.zeros((B, Hq, Sq, D), jnp.float32)
    if n_chunks == 1:
        (mx, den, acc), _ = body((mx0, den0, acc0),
                                 (kc[0], vc[0], jnp.int32(0)))
    else:
        (mx, den, acc), _ = jax.lax.scan(
            jax.checkpoint(body), (mx0, den0, acc0),
            (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(den, 1e-30)
    out = constrain(out, _BHSS)
    return out.astype(q.dtype)
