"""Sharded embedding tables with A1-style query-shipping lookup.

The recsys hot path (and the KG vertex-data read) is: given a batch of row
ids, fetch rows from a table too large for any single device.  This module
provides both execution strategies:

  * ``gspmd``: plain ``jnp.take`` on a row-sharded table — GSPMD infers the
    gather collectives.  Used under plain jit (dry-run baseline).
  * ``a1_ship``: the paper's §3.4 protocol, explicit: bucket ids by owner
    shard (id % S), one all_to_all ships the *requests*, owners gather
    locally, a second all_to_all ships the *rows* back.  This is exactly
    the executor_spmd routing fabric re-used for ML embedding lookups —
    the paper's technique as a first-class feature of the ML stack.

The a1_ship path runs inside shard_map and is what the §Perf hillclimb
compares against the GSPMD baseline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import compat

I32MAX = jnp.int32(2**31 - 1)


def gspmd_lookup(table, ids):
    """Row gather; sharding comes from the table/ids shardings."""
    ok = ids >= 0
    safe = jnp.where(ok, ids, 0)
    return table[safe] * ok[..., None].astype(table.dtype)


def _ship_lookup_local(table_local, ids, *, axes, bucket: int):
    """Inside shard_map: ids (B,) global; table_local (V/S, D)."""
    S = compat.axis_size(axes)
    me = jax.lax.axis_index(axes)
    B = ids.shape[0]
    rows_per = table_local.shape[0]

    # every shard holds the full (replicated) id batch; it serves the rows
    # it owns.  NamedSharding blocks rows contiguously, so the placement
    # arithmetic is owner = id // rows_per (the A1 CM's region map).
    ok = ids >= 0
    owner = jnp.where(ok, ids // rows_per, S)
    mine = owner == me
    rows = jnp.where(mine, ids % rows_per, 0)
    vals = table_local[rows] * mine[:, None].astype(table_local.dtype)
    # combine: each position was served by exactly one shard
    return jax.lax.psum(vals, axes)


def a1_ship_lookup(table, ids, mesh, *, axes=("data", "model"),
                   out_sharded: bool = False):
    """Query-shipping embedding lookup over a mesh.

    table: (V, D) row-sharded over ``axes``; ids: (..., ) replicated.
    Returns (..., D) replicated rows.

    Implementation note: with a *replicated* id batch the ship degenerates
    to local-gather + psum (each row has one owner, so the psum is the
    ship-back).  That is the same wire traffic as the two all_to_alls when
    B is replicated, with one fewer collective — the §Perf log quantifies
    the difference against GSPMD's gather.
    """
    shape = ids.shape
    flat = ids.reshape(-1)

    fn = compat.shard_map(
        partial(_ship_lookup_local, axes=axes, bucket=0),
        mesh=mesh,
        in_specs=(P(axes), P()),
        out_specs=P(),
        check_vma=False)
    out = fn(table, flat)
    return out.reshape(*shape, table.shape[-1])
