"""Shared GNN machinery: the COO GraphBatch contract + message passing.

Every GNN cell — full-batch (cora, ogb_products), fanout-sampled minibatch
(reddit), and batched small molecules — is expressed as one static-shape
:class:`GraphBatch`.  The neighbor sampler (data/sampler.py, built on the A1
graph store's traversal machinery) emits the same structure, so models are
mode-agnostic.

Message passing is ``jax.ops.segment_sum`` over the edge index (JAX has no
CSR SpMM; the scatter formulation IS the system, per the assignment).  On
TPU the ELL hot path goes through the fused segment_spmm Pallas kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphBatch:
    """Static-shape COO graph (padded; src < 0 marks padding edges)."""
    node_feat: jax.Array                  # (N, F)
    edge_src: jax.Array                   # (E,) i32, -1 = padding
    edge_dst: jax.Array                   # (E,) i32
    labels: jax.Array                     # (N,) or (G,) i32 / f32
    train_mask: jax.Array                 # (N,) or (G,) bool
    positions: Optional[jax.Array] = None   # (N, 3) for equivariant models
    edge_feat: Optional[jax.Array] = None   # (E, Fe)
    graph_ids: Optional[jax.Array] = None   # (N,) for per-graph readout
    n_graphs: int = dataclasses.field(default=1, metadata=dict(static=True))


def degree(batch: GraphBatch, n_nodes: int, direction: str = "dst"):
    idx = batch.edge_dst if direction == "dst" else batch.edge_src
    ok = batch.edge_src >= 0
    return jax.ops.segment_sum(ok.astype(jnp.float32),
                               jnp.where(ok, idx, n_nodes),
                               num_segments=n_nodes + 1)[:n_nodes]


def gather_src(x, batch: GraphBatch):
    """x[src] with padding masked to zero (the A1 'read remote vertex')."""
    ok = batch.edge_src >= 0
    rows = jnp.where(ok, batch.edge_src, 0)
    return x[rows] * ok[:, None].astype(x.dtype)


def scatter_dst(msgs, batch: GraphBatch, n_nodes: int, *, mode="sum"):
    """segment-reduce messages onto destination nodes."""
    ok = batch.edge_src >= 0
    dst = jnp.where(ok, batch.edge_dst, n_nodes)
    out = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes + 1)[:n_nodes]
    if mode == "mean":
        d = degree(batch, n_nodes)[:, None]
        out = out / jnp.maximum(d, 1.0)
    return out


def spmm(x, batch: GraphBatch, n_nodes: int, *, norm: Optional[str] = None):
    """One propagation: A~ x with optional 'sym' (GCN) or 'mean' norm."""
    msgs = gather_src(x, batch)
    if norm == "sym":
        d = jnp.maximum(degree(batch, n_nodes), 1.0)
        dinv = jax.lax.rsqrt(d)
        ok = batch.edge_src >= 0
        coef = (dinv[jnp.where(ok, batch.edge_src, 0)]
                * dinv[jnp.where(ok, batch.edge_dst, 0)])
        msgs = msgs * coef[:, None]
        return scatter_dst(msgs, batch, n_nodes)
    if norm == "mean":
        return scatter_dst(msgs, batch, n_nodes, mode="mean")
    return scatter_dst(msgs, batch, n_nodes)


# ---------------------------------------------------------------------------
# plain MLP (+ LayerNorm) building block
# ---------------------------------------------------------------------------

def mlp_init(key, dims, *, dtype=jnp.float32, layer_norm=False):
    ks = jax.random.split(key, len(dims) - 1)
    params = {"w": [], "b": []}
    for k, (a, b) in zip(ks, zip(dims[:-1], dims[1:])):
        params["w"].append((jax.random.normal(k, (a, b), jnp.float32)
                            * (a ** -0.5)).astype(dtype))
        params["b"].append(jnp.zeros((b,), dtype))
    if layer_norm:
        params["ln_scale"] = jnp.ones((dims[-1],), dtype)
        params["ln_bias"] = jnp.zeros((dims[-1],), dtype)
    return params


def mlp_apply(params, x, *, act=jax.nn.relu, final_act=False):
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        x = x @ w + b
        if i < n - 1 or final_act:
            x = act(x)
    if "ln_scale" in params:
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-6)
        x = x * params["ln_scale"] + params["ln_bias"]
    return x


def mlp_shape_dtypes(dims, *, dtype=jnp.float32, layer_norm=False):
    sds = jax.ShapeDtypeStruct
    p = {"w": [sds((a, b), dtype) for a, b in zip(dims[:-1], dims[1:])],
         "b": [sds((b,), dtype) for b in dims[1:]]}
    if layer_norm:
        p["ln_scale"] = sds((dims[-1],), dtype)
        p["ln_bias"] = sds((dims[-1],), dtype)
    return p


def constrain_batch(batch: GraphBatch, replicate_nodes: bool = True):
    """Sharding: edges data-parallel over the whole mesh; nodes replicated

    (full-batch) or sharded on 'model' (huge graphs; GSPMD inserts the
    gather/scatter collectives — the query-shipping pattern)."""
    espec = ("batch", None) if not replicate_nodes else ("batch", None)
    b = batch
    es = constrain(b.edge_src, (("batch"),))
    ed = constrain(b.edge_dst, (("batch"),))
    nf = b.node_feat if replicate_nodes else constrain(
        b.node_feat, ("tensor", None))
    return dataclasses.replace(b, edge_src=es, edge_dst=ed, node_feat=nf)
