"""GCN (Kipf & Welling, arXiv:1609.02907): sym-normalized SpMM layers.

gcn-cora assigned config: 2 layers, d_hidden 16, mean/sym aggregation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphBatch, spmm


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    norm: str = "sym"
    dtype: Any = jnp.float32


def init_params(cfg: GCNConfig, key):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    ks = jax.random.split(key, cfg.n_layers)
    return {"w": [(jax.random.normal(k, (a, b), jnp.float32) * (a ** -0.5)
                   ).astype(cfg.dtype)
                  for k, a, b in zip(ks, dims[:-1], dims[1:])],
            "b": [jnp.zeros((b,), cfg.dtype) for b in dims[1:]]}


def param_shape_dtypes(cfg: GCNConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    sds = jax.ShapeDtypeStruct
    return {"w": [sds((a, b), cfg.dtype) for a, b in zip(dims[:-1], dims[1:])],
            "b": [sds((b,), cfg.dtype) for b in dims[1:]]}


def forward(params, cfg: GCNConfig, batch: GraphBatch):
    n = batch.node_feat.shape[0]
    x = batch.node_feat.astype(cfg.dtype)
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        x = spmm(x @ w, batch, n, norm=cfg.norm) + b
        if i < len(params["w"]) - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(params, cfg: GCNConfig, batch: GraphBatch):
    logits = forward(params, cfg, batch).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    labels = jnp.maximum(batch.labels, 0)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    mask = batch.train_mask & (batch.labels >= 0)
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)
    acc = jnp.sum((logits.argmax(-1) == batch.labels) * mask) \
        / jnp.maximum(mask.sum(), 1)
    return loss, {"acc": acc}
