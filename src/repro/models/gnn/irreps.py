"""Minimal real-spherical-harmonics irrep algebra for NequIP (l_max <= 2).

No e3nn available in this environment, so the O(3) machinery is built from
scratch:

* real spherical harmonics Y_l for l = 0, 1, 2 (hardcoded, component order
  m = -l..l in the standard real basis);
* Wigner-D matrices for arbitrary rotations obtained *numerically*: D_l(R)
  is the unique matrix with Y_l(R x) = D_l(R) Y_l(x), solved by least
  squares over sample points;
* real Clebsch-Gordan tensors C^{l1 l2 l3} obtained as the null space of
  stacked invariance constraints (D1 (x) D2 (x) D3 - I) vec(C) = 0 over a
  few random rotations — exact to numerical precision, no Racah formula
  plumbing.  Validity is *checked at import* (equivariance residual < 1e-8).

This is the kernel-taxonomy "irrep tensor-product" regime (B.3) in its
O(L^6)-naive form; eSCN-style O(L^3) contraction is unnecessary at l_max=2
(the paths are tiny) — noted in DESIGN.md.
"""
from __future__ import annotations

import functools

import numpy as np

L_MAX = 2
_DIMS = {0: 1, 1: 3, 2: 5}


def sh_np(x: np.ndarray, l: int) -> np.ndarray:
    """Real spherical harmonics of unit vectors x (..., 3), component-normed

    (Racah normalization scaled so ||Y_l|| is rotation invariant)."""
    xx, yy, zz = x[..., 0], x[..., 1], x[..., 2]
    if l == 0:
        return np.ones(x.shape[:-1] + (1,))
    if l == 1:
        return np.stack([yy, zz, xx], axis=-1)
    if l == 2:
        s3 = np.sqrt(3.0)
        return np.stack([
            s3 * xx * yy,
            s3 * yy * zz,
            0.5 * (2 * zz * zz - xx * xx - yy * yy),
            s3 * xx * zz,
            0.5 * s3 * (xx * xx - yy * yy),
        ], axis=-1)
    raise NotImplementedError(l)


def _rand_rotation(rng) -> np.ndarray:
    a = rng.normal(size=(3, 3))
    q, r = np.linalg.qr(a)
    q *= np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


def wigner_d_np(R: np.ndarray, l: int) -> np.ndarray:
    """D_l(R) s.t. Y_l(R x) = D_l(R) Y_l(x) — least squares over samples."""
    if l == 0:
        return np.ones((1, 1))
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(64, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    A = sh_np(pts, l)                       # (P, d)
    B = sh_np(pts @ R.T, l)                 # (P, d) = Y(R x)
    D, *_ = np.linalg.lstsq(A, B, rcond=None)
    return D.T                              # B^T = D A^T


@functools.lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real Clebsch-Gordan tensor C (d1, d2, d3): the SO(3)-invariant

    coupling, normalized to Frobenius norm 1.  Zero tensor if the triangle
    rule fails."""
    d1, d2, d3 = _DIMS[l1], _DIMS[l2], _DIMS[l3]
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((d1, d2, d3))
    rng = np.random.default_rng(42)
    rows = []
    for _ in range(4):
        R = _rand_rotation(rng)
        D1, D2, D3 = (wigner_d_np(R, l1), wigner_d_np(R, l2),
                      wigner_d_np(R, l3))
        M = np.einsum("ai,bj,ck->abcijk", D1, D2, D3).reshape(
            d1 * d2 * d3, d1 * d2 * d3)
        rows.append(M - np.eye(d1 * d2 * d3))
    A = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(A)
    null_dim = int(np.sum(s < 1e-8))
    assert null_dim >= 1, (l1, l2, l3, s[-3:])
    c = vt[-1].reshape(d1, d2, d3)
    c /= np.linalg.norm(c)
    # deterministic sign: make the first significant entry positive
    flat = c.reshape(-1)
    idx = int(np.argmax(np.abs(flat) > 1e-6))
    if flat[idx] < 0:
        c = -c
    return c


def _selfcheck() -> None:
    rng = np.random.default_rng(7)
    R = _rand_rotation(rng)
    for (l1, l2, l3) in [(1, 1, 0), (1, 1, 1), (1, 1, 2), (2, 1, 1),
                         (2, 2, 2), (2, 2, 0)]:
        C = real_cg(l1, l2, l3)
        D1, D2, D3 = (wigner_d_np(R, l1), wigner_d_np(R, l2),
                      wigner_d_np(R, l3))
        C2 = np.einsum("ai,bj,ck,ijk->abc", D1, D2, D3, C)
        assert np.abs(C2 - C).max() < 1e-8, (l1, l2, l3)


_selfcheck()


# all (l1, l2, l3) paths with l's <= L_MAX and valid triangle rule
PATHS = [(l1, l2, l3)
         for l1 in range(L_MAX + 1)
         for l2 in range(L_MAX + 1)
         for l3 in range(L_MAX + 1)
         if abs(l1 - l2) <= l3 <= l1 + l2
         # parity selection: SH of edge vectors carry parity (-1)^l, so a
         # path is O(3)-consistent iff (-1)^(l1+l2) == (-1)^l3
         and (l1 + l2 + l3) % 2 == 0]
