"""MeshGraphNet (arXiv:2010.03409): encode-process-decode with edge MLPs.

Assigned config: 15 processor blocks, d_hidden 128, sum aggregation,
2-hidden-layer MLPs with LayerNorm.  Edge features are relative positions +
norms when ``positions`` are present, else the provided edge_feat.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.gnn.common import (GraphBatch, gather_src, mlp_apply,
                                     mlp_init, mlp_shape_dtypes, scatter_dst)


@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_in: int = 8
    d_edge_in: int = 4
    d_hidden: int = 128
    d_out: int = 3
    mlp_layers: int = 2
    dtype: Any = jnp.float32


def _mlp_dims(cfg, d_in):
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers + [cfg.d_hidden]


def init_params(cfg: MGNConfig, key):
    ks = jax.random.split(key, 3 + 2 * cfg.n_layers)
    p = {
        "enc_node": mlp_init(ks[0], _mlp_dims(cfg, cfg.d_in),
                             dtype=cfg.dtype, layer_norm=True),
        "enc_edge": mlp_init(ks[1], _mlp_dims(cfg, cfg.d_edge_in),
                             dtype=cfg.dtype, layer_norm=True),
        "dec": mlp_init(ks[2], [cfg.d_hidden] * (cfg.mlp_layers + 1)
                        + [cfg.d_out], dtype=cfg.dtype),
        "proc_edge": [], "proc_node": [],
    }
    for i in range(cfg.n_layers):
        p["proc_edge"].append(mlp_init(
            ks[3 + 2 * i], _mlp_dims(cfg, 3 * cfg.d_hidden),
            dtype=cfg.dtype, layer_norm=True))
        p["proc_node"].append(mlp_init(
            ks[4 + 2 * i], _mlp_dims(cfg, 2 * cfg.d_hidden),
            dtype=cfg.dtype, layer_norm=True))
    return p


def param_shape_dtypes(cfg: MGNConfig):
    p = {
        "enc_node": mlp_shape_dtypes(_mlp_dims(cfg, cfg.d_in),
                                     dtype=cfg.dtype, layer_norm=True),
        "enc_edge": mlp_shape_dtypes(_mlp_dims(cfg, cfg.d_edge_in),
                                     dtype=cfg.dtype, layer_norm=True),
        "dec": mlp_shape_dtypes([cfg.d_hidden] * (cfg.mlp_layers + 1)
                                + [cfg.d_out], dtype=cfg.dtype),
        "proc_edge": [mlp_shape_dtypes(_mlp_dims(cfg, 3 * cfg.d_hidden),
                                       dtype=cfg.dtype, layer_norm=True)
                      for _ in range(cfg.n_layers)],
        "proc_node": [mlp_shape_dtypes(_mlp_dims(cfg, 2 * cfg.d_hidden),
                                       dtype=cfg.dtype, layer_norm=True)
                      for _ in range(cfg.n_layers)],
    }
    return p


def _edge_inputs(cfg: MGNConfig, batch: GraphBatch):
    if batch.edge_feat is not None:
        return batch.edge_feat.astype(cfg.dtype)
    assert batch.positions is not None
    ok = batch.edge_src >= 0
    src = jnp.where(ok, batch.edge_src, 0)
    dst = jnp.where(ok, batch.edge_dst, 0)
    rel = batch.positions[dst] - batch.positions[src]
    feat = jnp.concatenate(
        [rel, jnp.linalg.norm(rel, axis=-1, keepdims=True)], axis=-1)
    return (feat * ok[:, None]).astype(cfg.dtype)


def forward(params, cfg: MGNConfig, batch: GraphBatch):
    n = batch.node_feat.shape[0]
    ok = (batch.edge_src >= 0)[:, None].astype(cfg.dtype)
    v = mlp_apply(params["enc_node"], batch.node_feat.astype(cfg.dtype))
    e = mlp_apply(params["enc_edge"], _edge_inputs(cfg, batch))
    src = jnp.where(batch.edge_src >= 0, batch.edge_src, 0)
    dst = jnp.where(batch.edge_src >= 0, batch.edge_dst, 0)
    for pe, pn in zip(params["proc_edge"], params["proc_node"]):
        e_in = jnp.concatenate([e, v[src], v[dst]], axis=-1)
        e = e + mlp_apply(pe, e_in) * ok
        agg = scatter_dst(e, batch, n)
        v = v + mlp_apply(pn, jnp.concatenate([v, agg], axis=-1))
    return mlp_apply(params["dec"], v)


def loss_fn(params, cfg: MGNConfig, batch: GraphBatch):
    pred = forward(params, cfg, batch).astype(jnp.float32)
    target = batch.labels.astype(jnp.float32)
    mask = batch.train_mask[:, None].astype(jnp.float32)
    mse = jnp.sum(((pred - target) ** 2) * mask) / jnp.maximum(mask.sum(), 1)
    return mse, {"mse": mse}
