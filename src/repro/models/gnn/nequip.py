"""NequIP (arXiv:2101.03164): O(3)-equivariant interatomic potential.

Assigned config: 5 interaction layers, hidden multiplicity 32, l_max = 2,
8 Bessel radial basis functions, 5 A cutoff, E(3) tensor-product messages.

Implementation (irreps.py provides the O(3) algebra):
  * features: dict l -> (N, mul, 2l+1);
  * message on edge (i->j): sum over CG paths (l1, l2 -> l3) of
    R_path(|r|) * CG(feat_i[l1] (x) Y_l2(r_hat)), radial weights from a
    per-path MLP over the Bessel basis with polynomial cutoff;
  * aggregation: segment-sum onto destinations; self-interaction linear mix
    per l + residual; norm-gate nonlinearity (scalars: SiLU; l>0: scaled by
    SiLU of channel norms — an equivariant gate);
  * readout: per-atom scalar MLP -> site energies -> per-graph sum; forces
    available as -grad_positions (exercised in tests).

Energy is rotation/translation invariant by construction — property-tested
(tests/test_models.py) rather than assumed.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import GraphBatch
from repro.models.gnn.irreps import L_MAX, PATHS, real_cg, sh_np


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    mul: int = 32                 # hidden multiplicity per l
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    radial_hidden: int = 32
    dtype: Any = jnp.float32


_DIMS = {0: 1, 1: 3, 2: 5}


def sh_jax(vec, l: int):
    """Real SH of (E, 3) unit vectors (jnp mirror of irreps.sh_np)."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    if l == 0:
        return jnp.ones(vec.shape[:-1] + (1,), vec.dtype)
    if l == 1:
        return jnp.stack([y, z, x], axis=-1)
    s3 = np.sqrt(3.0)
    return jnp.stack([
        s3 * x * y, s3 * y * z, 0.5 * (2 * z * z - x * x - y * y),
        s3 * x * z, 0.5 * s3 * (x * x - y * y)], axis=-1)


def bessel_rbf(r, n: int, cutoff: float):
    """Bessel basis with smooth polynomial cutoff (NequIP eq. 6-7)."""
    safe = jnp.maximum(r, 1e-6)
    k = jnp.arange(1, n + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(
        k[None, :] * jnp.pi * safe[:, None] / cutoff) / safe[:, None]
    u = jnp.clip(r / cutoff, 0, 1)
    fcut = 1 - 10 * u**3 + 15 * u**4 - 6 * u**5      # C^2 polynomial cutoff
    return basis * fcut[:, None]


def _paths(cfg: NequIPConfig):
    return [(l1, l2, l3) for (l1, l2, l3) in PATHS
            if l1 <= cfg.l_max and l2 <= cfg.l_max and l3 <= cfg.l_max]


def init_params(cfg: NequIPConfig, key):
    ks = iter(jax.random.split(key, 256))
    nrm = lambda k, s: (jax.random.normal(k, s, jnp.float32)
                        * (s[-2] if len(s) > 1 else s[-1]) ** -0.5
                        ).astype(cfg.dtype)
    p = {"embed": nrm(next(ks), (cfg.n_species, cfg.mul)), "layers": []}
    for _ in range(cfg.n_layers):
        lp = {"radial_w1": {}, "radial_w2": {}, "self": {}, "skip": {}}
        for path in _paths(cfg):
            tag = f"{path[0]}{path[1]}{path[2]}"
            lp["radial_w1"][tag] = nrm(next(ks), (cfg.n_rbf,
                                                  cfg.radial_hidden))
            lp["radial_w2"][tag] = nrm(next(ks), (cfg.radial_hidden,
                                                  cfg.mul))
        for l in range(cfg.l_max + 1):
            lp["self"][str(l)] = nrm(next(ks), (cfg.mul, cfg.mul))
            lp["skip"][str(l)] = nrm(next(ks), (cfg.mul, cfg.mul))
        p["layers"].append(lp)
    p["readout_w1"] = nrm(next(ks), (cfg.mul, cfg.mul))
    p["readout_w2"] = nrm(next(ks), (cfg.mul, 1))
    return p


def param_shape_dtypes(cfg: NequIPConfig):
    sds = lambda s: jax.ShapeDtypeStruct(s, cfg.dtype)
    p = {"embed": sds((cfg.n_species, cfg.mul)), "layers": []}
    for _ in range(cfg.n_layers):
        lp = {"radial_w1": {}, "radial_w2": {}, "self": {}, "skip": {}}
        for path in _paths(cfg):
            tag = f"{path[0]}{path[1]}{path[2]}"
            lp["radial_w1"][tag] = sds((cfg.n_rbf, cfg.radial_hidden))
            lp["radial_w2"][tag] = sds((cfg.radial_hidden, cfg.mul))
        for l in range(cfg.l_max + 1):
            lp["self"][str(l)] = sds((cfg.mul, cfg.mul))
            lp["skip"][str(l)] = sds((cfg.mul, cfg.mul))
        p["layers"].append(lp)
    p["readout_w1"] = sds((cfg.mul, cfg.mul))
    p["readout_w2"] = sds((cfg.mul, 1))
    return p


def _gate(feats):
    """Equivariant nonlinearity: SiLU on scalars, norm-gate on l>0."""
    out = {0: jax.nn.silu(feats[0])}
    for l, x in feats.items():
        if l == 0:
            continue
        n = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + 1e-12)
        out[l] = x * (jax.nn.silu(n) / n)
    return out


def forward(params, cfg: NequIPConfig, batch: GraphBatch):
    """Returns per-graph energies (n_graphs,)."""
    assert batch.positions is not None
    N = batch.node_feat.shape[0]
    ok = batch.edge_src >= 0
    src = jnp.where(ok, batch.edge_src, 0)
    dst = jnp.where(ok, batch.edge_dst, 0)
    rel = batch.positions[dst] - batch.positions[src]
    r = jnp.linalg.norm(rel + 1e-12, axis=-1)
    rhat = rel / jnp.maximum(r, 1e-6)[:, None]
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff) * ok[:, None]
    Y = {l: sh_jax(rhat, l).astype(cfg.dtype) for l in range(cfg.l_max + 1)}

    species = batch.node_feat[:, 0].astype(jnp.int32)
    feats = {0: params["embed"][species][:, :, None]}     # (N, mul, 1)
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((N, cfg.mul, _DIMS[l]), cfg.dtype)

    cg = {p: jnp.asarray(real_cg(*p), cfg.dtype) for p in _paths(cfg)}
    edge_mask = ok[:, None, None].astype(cfg.dtype)

    for lp in params["layers"]:
        msgs = {l: 0.0 for l in range(cfg.l_max + 1)}
        for path in _paths(cfg):
            l1, l2, l3 = path
            tag = f"{l1}{l2}{l3}"
            w = jax.nn.silu(rbf @ lp["radial_w1"][tag]) \
                @ lp["radial_w2"][tag]                     # (E, mul)
            fsrc = feats[l1][src]                          # (E, mul, d1)
            m = jnp.einsum("emi,ej,ijk->emk", fsrc, Y[l2], cg[path])
            msgs[l3] = msgs[l3] + m * w[:, :, None] * edge_mask
        new = {}
        for l in range(cfg.l_max + 1):
            agg = jax.ops.segment_sum(msgs[l], jnp.where(ok, dst, N),
                                      num_segments=N + 1)[:N]
            mixed = jnp.einsum("nmi,mk->nki", agg, lp["self"][str(l)])
            skip = jnp.einsum("nmi,mk->nki", feats[l], lp["skip"][str(l)])
            new[l] = mixed + skip
        feats = _gate(new)

    site = jax.nn.silu(feats[0][:, :, 0] @ params["readout_w1"]) \
        @ params["readout_w2"]                             # (N, 1)
    gid = (batch.graph_ids if batch.graph_ids is not None
           else jnp.zeros((N,), jnp.int32))
    energy = jax.ops.segment_sum(site[:, 0], gid,
                                 num_segments=batch.n_graphs)
    return energy


def loss_fn(params, cfg: NequIPConfig, batch: GraphBatch):
    energy = forward(params, cfg, batch).astype(jnp.float32)
    target = batch.labels.astype(jnp.float32)
    mask = batch.train_mask.astype(jnp.float32)
    mse = jnp.sum(((energy - target) ** 2) * mask) / jnp.maximum(mask.sum(),
                                                                 1)
    return mse, {"mse": mse}


def forces(params, cfg: NequIPConfig, batch: GraphBatch):
    """F = -dE/dpositions (the equivariant observable)."""
    def e_of_pos(pos):
        b = dataclasses.replace(batch, positions=pos)
        return forward(params, cfg, b).sum()
    return -jax.grad(e_of_pos)(batch.positions)
