"""GraphSAGE (arXiv:1706.02216): mean-aggregator, fanout-sampled training.

graphsage-reddit assigned config: 2 layers, d_hidden 128, fanout 25-10.
The sampled-minibatch path consumes COO subgraphs produced by the A1
store's fanout sampler (a bounded 2-hop A1 traversal, data/sampler.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphBatch, spmm


@dataclasses.dataclass(frozen=True)
class SageConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    n_classes: int = 41
    dtype: Any = jnp.float32


def init_params(cfg: SageConfig, key):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    ks = jax.random.split(key, 2 * cfg.n_layers)
    p = {"w_self": [], "w_nbr": [], "b": []}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p["w_self"].append((jax.random.normal(ks[2 * i], (a, b), jnp.float32)
                            * (a ** -0.5)).astype(cfg.dtype))
        p["w_nbr"].append((jax.random.normal(ks[2 * i + 1], (a, b),
                                             jnp.float32)
                           * (a ** -0.5)).astype(cfg.dtype))
        p["b"].append(jnp.zeros((b,), cfg.dtype))
    return p


def param_shape_dtypes(cfg: SageConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    sds = jax.ShapeDtypeStruct
    return {"w_self": [sds((a, b), cfg.dtype)
                       for a, b in zip(dims[:-1], dims[1:])],
            "w_nbr": [sds((a, b), cfg.dtype)
                      for a, b in zip(dims[:-1], dims[1:])],
            "b": [sds((b,), cfg.dtype) for b in dims[1:]]}


def forward(params, cfg: SageConfig, batch: GraphBatch):
    n = batch.node_feat.shape[0]
    x = batch.node_feat.astype(cfg.dtype)
    L = len(params["b"])
    for i in range(L):
        nbr = spmm(x, batch, n, norm="mean")
        x = x @ params["w_self"][i] + nbr @ params["w_nbr"][i] \
            + params["b"][i]
        if i < L - 1:
            x = jax.nn.relu(x)
            # l2-normalize (SAGE's stability trick)
            x = x * jax.lax.rsqrt(jnp.sum(x * x, -1, keepdims=True) + 1e-6)
    return x


def loss_fn(params, cfg: SageConfig, batch: GraphBatch):
    logits = forward(params, cfg, batch).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    labels = jnp.maximum(batch.labels, 0)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    mask = batch.train_mask & (batch.labels >= 0)
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)
    acc = jnp.sum((logits.argmax(-1) == batch.labels) * mask) \
        / jnp.maximum(mask.sum(), 1)
    return loss, {"acc": acc}
