"""Mixture-of-Experts layer: sort-based token dispatch (GShard semantics,

MegaBlocks-style memory footprint).

The classic GSPMD MoE materializes a (tokens, experts, capacity) one-hot
dispatch tensor — at prefill scale (1M tokens x 128 experts) that is
hundreds of GB.  We instead dispatch by sorting (token, k) pairs by expert
id and scattering into a dense (E, C, D) buffer:

  route -> top-k -> sort by expert -> rank within expert -> capacity clip
        -> scatter tokens -> per-expert FFN (einsum, experts sharded on
           'model' = expert parallelism) -> gather back -> weighted combine.

Under GSPMD the scatter/gather lower to the expert all-to-alls; token
dropping at capacity bounds the skew (straggler mitigation in-graph: no
expert can run ahead of the capacity budget).  Dropped tokens pass through
the residual stream untouched (standard Switch behavior).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def expert_capacity(n_tokens: int, n_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    c = int(capacity_factor * n_tokens * top_k / n_experts)
    return max(_round_up(c, 8), 8)


def moe_ffn(x, router_w, we1, we3, we2, *, top_k: int,
            capacity_factor: float = 1.25, dtype=None, groups: int = 0):
    """x: (T, D) tokens; router_w: (D, E); we*: (E, D, F) / (E, F, D).

    Returns (T, D) output + aux dict (load-balance loss, drop fraction).

    ``groups > 0`` dispatches per token *group* (GShard's G axis): tokens
    reshape to (G, T/G) aligned with the data-parallel sharding, so the
    dispatch sort/rank runs shard-local instead of as a global sorted
    collective — the §Perf iteration that removed the all-to-all storm the
    baseline global sort compiled to (EXPERIMENTS.md §Perf/qwen3).
    Capacity is per group, which also bounds *per-shard* skew (in-graph
    straggler mitigation).
    """
    if groups and groups > 1 and x.shape[0] % groups == 0:
        return _moe_ffn_grouped(x, router_w, we1, we3, we2, top_k=top_k,
                                capacity_factor=capacity_factor,
                                groups=groups)
    T, D = x.shape
    E = router_w.shape[-1]
    F = we1.shape[-1]
    C = expert_capacity(T, E, top_k, capacity_factor)
    xf = x.astype(jnp.float32)

    logits = xf @ router_w.astype(jnp.float32)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, top_k)              # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch eq. 4) ------------------------
    me = probs.mean(axis=0)                               # (E,)
    ce = jax.nn.one_hot(eidx[:, 0], E).mean(axis=0)
    aux_loss = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----------------------------------------------
    # NB: sort only integer keys + a permutation index; differentiable values
    # ride through `take` (lax.sort's VJP is unusable in this jaxlib).
    flat_e = eidx.reshape(-1).astype(jnp.int32)           # (T*k,)
    flat_t = (jnp.arange(T * top_k, dtype=jnp.int32) // top_k)
    order = jnp.arange(T * top_k, dtype=jnp.int32)
    e_s, t_s, perm = jax.lax.sort((flat_e, flat_t, order), num_keys=2)
    g_s = gate.reshape(-1)[perm]
    # rank within expert run
    run_start = jnp.searchsorted(e_s, e_s, side="left").astype(jnp.int32)
    rank = jnp.arange(T * top_k, dtype=jnp.int32) - run_start
    keep = rank < C
    slot = jnp.where(keep, e_s * C + rank, E * C)         # OOB drops

    xe = jnp.zeros((E * C, D), x.dtype).at[slot].set(
        x[t_s], mode="drop").reshape(E, C, D)

    # ---- expert FFN (SwiGLU), experts sharded over 'model' ----------------
    h = jnp.einsum("ecd,edf->ecf", xe, we1,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe, we3,
                   preferred_element_type=jnp.float32)
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), we2,
                    preferred_element_type=jnp.float32)   # (E, C, F->D)

    # ---- combine back -------------------------------------------------------
    slot_c = jnp.minimum(slot, E * C - 1)
    y_tok = ye.reshape(E * C, D)[slot_c]                  # (T*k, D)
    w = jnp.where(keep, g_s, 0.0)[:, None]
    y = jnp.zeros((T, D), jnp.float32).at[t_s].add(
        y_tok.astype(jnp.float32) * w)
    drop_frac = 1.0 - keep.mean()
    return y.astype(x.dtype), {"aux_loss": aux_loss, "drop_frac": drop_frac}


def _moe_ffn_grouped(x, router_w, we1, we3, we2, *, top_k: int,
                     capacity_factor: float, groups: int):
    """Group-local dispatch: all sort/rank work stays inside a data shard."""
    from repro.dist.sharding import constrain
    T, D = x.shape
    E = router_w.shape[-1]
    G = groups
    Tg = T // G
    C = expert_capacity(Tg, E, top_k, capacity_factor)
    xg = constrain(x.reshape(G, Tg, D), ("batch", None, None))
    xf = xg.astype(jnp.float32)

    logits = jnp.einsum("gtd,de->gte", xf, router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, top_k)              # (G, Tg, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(eidx[..., 0], E).mean(axis=(0, 1))
    aux_loss = E * jnp.sum(me * ce)

    flat_e = eidx.reshape(G, Tg * top_k).astype(jnp.int32)
    flat_t = jnp.broadcast_to(
        (jnp.arange(Tg * top_k, dtype=jnp.int32) // top_k)[None],
        (G, Tg * top_k))
    order = jnp.broadcast_to(
        jnp.arange(Tg * top_k, dtype=jnp.int32)[None], (G, Tg * top_k))
    # per-group sort (last axis): shard-local under the G -> data sharding
    e_s, t_s, perm = jax.lax.sort((flat_e, flat_t, order), num_keys=2,
                                  dimension=1)
    g_s = jnp.take_along_axis(gate.reshape(G, Tg * top_k), perm, axis=1)

    idx = jnp.arange(Tg * top_k, dtype=jnp.int32)[None]
    run_start = jax.vmap(
        lambda es: jnp.searchsorted(es, es, side="left"))(e_s)
    rank = idx - run_start.astype(jnp.int32)
    keep = rank < C
    slot = jnp.where(keep, e_s * C + rank, E * C)

    xe = jax.vmap(
        lambda xg_, t_, sl_: jnp.zeros((E * C, D), x.dtype)
        .at[sl_].set(xg_[t_], mode="drop"))(xg, t_s, slot)
    xe = xe.reshape(G, E, C, D)
    xe = constrain(xe, ("batch", "expert", None, None))

    h = jnp.einsum("gecd,edf->gecf", xe, we1,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("gecd,edf->gecf", xe, we3,
                   preferred_element_type=jnp.float32)
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("gecf,efd->gecd", h.astype(x.dtype), we2,
                    preferred_element_type=jnp.float32)
    ye = constrain(ye.astype(jnp.float32), ("batch", "expert", None, None))

    slot_c = jnp.minimum(slot, E * C - 1)
    w = jnp.where(keep, g_s, 0.0)
    y = jax.vmap(
        lambda ye_, sl_, t_, w_: jnp.zeros((Tg, D), jnp.float32)
        .at[t_].add(ye_.reshape(E * C, D)[sl_] * w_[:, None]))(
            ye, slot_c, t_s, w)
    drop_frac = 1.0 - keep.mean()
    return (y.reshape(T, D).astype(x.dtype),
            {"aux_loss": aux_loss, "drop_frac": drop_frac})
