"""BST: Behavior Sequence Transformer (Alibaba, arXiv:1905.06874).

Assigned config: embed_dim 32, behavior seq_len 20, 1 transformer block,
8 heads, MLP 1024-512-256, transformer-seq feature interaction.

The item embedding table is the huge-sparse-table regime (10^6-10^9 rows):
row-sharded over the entire mesh and fetched with the A1 lookup path
(models/embedding.py).  Four serving shapes:

  train_batch     (B=65536)  CTR training step (BCE)
  serve_p99       (B=512)    online scoring
  serve_bulk      (B=262144) offline scoring
  retrieval_cand  (B=1, 1M candidates) one user tower output dotted
                  against a million candidate item embeddings (batched
                  matmul — never a loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.embedding import gspmd_lookup


@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    n_items: int = 1_000_000
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    d_ff: int = 128
    mlp_dims: tuple = (1024, 512, 256)
    n_dense: int = 8
    dtype: Any = jnp.float32


def param_shapes(cfg: BSTConfig):
    d = cfg.embed_dim
    L = cfg.seq_len + 1
    shapes = {
        "item_emb": ((cfg.n_items, d), ("storage", None)),
        "pos_emb": ((L, d), (None, None)),
        "dense_proj": ((cfg.n_dense, d), (None, None)),
        "blocks": [],
        "mlp_w": [], "mlp_b": [],
    }
    for _ in range(cfg.n_blocks):
        shapes["blocks"].append({
            "wq": ((d, d), (None, "tensor")),
            "wk": ((d, d), (None, "tensor")),
            "wv": ((d, d), (None, "tensor")),
            "wo": ((d, d), ("tensor", None)),
            "ln1": ((d,), (None,)),
            "ln2": ((d,), (None,)),
            "w1": ((d, cfg.d_ff), (None, "tensor")),
            "w2": ((cfg.d_ff, d), ("tensor", None)),
        })
    dims = ((cfg.seq_len + 2) * d,) + cfg.mlp_dims + (1,)
    for a, b in zip(dims[:-1], dims[1:]):
        # tiny output layers (b < TP degree) stay unsharded on that dim
        shapes["mlp_w"].append(((a, b), ("fsdp",
                                         "tensor" if b >= 128 else None)))
        shapes["mlp_b"].append(((b,), (None,)))
    shp = jax.tree.map(lambda t: t[0], shapes,
                       is_leaf=lambda x: isinstance(x, tuple)
                       and isinstance(x[0], tuple))
    axes = jax.tree.map(lambda t: t[1], shapes,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and isinstance(x[0], tuple))
    return shp, axes


def init_params(cfg: BSTConfig, key):
    shp, _ = param_shapes(cfg)
    leaves, tdef = jax.tree.flatten(shp,
                                    is_leaf=lambda x: isinstance(x, tuple))
    ks = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(ks, leaves):
        if len(s) == 1:
            out.append(jnp.ones(s, cfg.dtype) if s[0] == cfg.embed_dim
                       else jnp.zeros(s, cfg.dtype))
        else:
            out.append((jax.random.normal(k, s, jnp.float32)
                        * (s[0] ** -0.5)).astype(cfg.dtype))
    return jax.tree.unflatten(tdef, out)


def param_shape_dtypes(cfg: BSTConfig):
    shp, _ = param_shapes(cfg)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, cfg.dtype), shp,
                        is_leaf=lambda x: isinstance(x, tuple))


def logical_axes(cfg: BSTConfig):
    _, axes = param_shapes(cfg)
    return axes


def _ln(x, scale):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * scale


def _block(p, cfg: BSTConfig, x):
    """Post-norm transformer block over the (L+1) behavior sequence."""
    B, L, d = x.shape
    h = cfg.n_heads
    dh = d // h
    q = (x @ p["wq"]).reshape(B, L, h, dh).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, L, h, dh).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, L, h, dh).transpose(0, 2, 1, 3)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * dh ** -0.5
    a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bhkd->bhqd", a, v)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, d) @ p["wo"]
    x = _ln(x + o, p["ln1"])
    f = jax.nn.relu(x @ p["w1"]) @ p["w2"]          # leaky-relu in paper
    return _ln(x + f, p["ln2"])


def forward(params, cfg: BSTConfig, hist_ids, target_ids, dense):
    """hist_ids (B, L), target_ids (B,), dense (B, n_dense) -> logits (B,)."""
    B, L = hist_ids.shape
    seq = jnp.concatenate([hist_ids, target_ids[:, None]], axis=1)
    emb = gspmd_lookup(params["item_emb"], seq).astype(cfg.dtype)
    emb = emb + params["pos_emb"][None, :, :]
    emb = constrain(emb, ("batch", None, None))
    for bp in params["blocks"]:
        emb = _block(bp, cfg, emb)
    other = dense.astype(cfg.dtype) @ params["dense_proj"]
    feat = jnp.concatenate([emb.reshape(B, -1), other], axis=-1)
    x = feat
    n = len(params["mlp_w"])
    for i, (w, b) in enumerate(zip(params["mlp_w"], params["mlp_b"])):
        x = x @ w + b
        if i < n - 1:
            x = jax.nn.leaky_relu(x)
    return x[:, 0].astype(jnp.float32)


def loss_fn(params, cfg: BSTConfig, hist_ids, target_ids, dense, labels):
    logits = forward(params, cfg, hist_ids, target_ids, dense)
    bce = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return bce, {"bce": bce}


def user_tower(params, cfg: BSTConfig, hist_ids, dense):
    """Retrieval: encode the user history into one d-dim vector."""
    B, L = hist_ids.shape
    emb = gspmd_lookup(params["item_emb"], hist_ids).astype(cfg.dtype)
    emb = emb + params["pos_emb"][None, :L, :]
    for bp in params["blocks"]:
        emb = _block(bp, cfg, emb)
    u = emb.mean(axis=1) + dense.astype(cfg.dtype) @ params["dense_proj"]
    return u


def retrieval_scores(params, cfg: BSTConfig, hist_ids, dense, cand_ids):
    """Score one (or few) users against a large candidate set.

    cand_ids (C,): scores (B, C) = user_vec @ cand_emb^T — a single batched
    matmul over the gathered candidate rows.
    """
    u = user_tower(params, cfg, hist_ids, dense)           # (B, d)
    ce = gspmd_lookup(params["item_emb"], cand_ids)        # (C, d)
    return (u @ ce.T.astype(u.dtype)).astype(jnp.float32)
