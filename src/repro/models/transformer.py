"""Configurable GQA transformer LM: dense and MoE blocks, train + serve.

One implementation serves the five assigned LM architectures:

  qwen3-moe-235b  94L MoE(128e top-8)      llama4-maverick  48L dense|MoE
  llama3-405b     126L dense               h2o-danube-3     24L dense + SWA
  qwen1.5-32b     64L dense + QKV bias

Design notes:
  * layers are stacked and scanned (compile time O(1) in depth) with
    activation rematerialization per block;
  * ``block_pattern`` cycles layer kinds — ("dense",) for dense stacks,
    ("moe",) for qwen3, ("dense", "moe") for llama4's interleaved layout;
  * attention runs the flash kernel on TPU / the jnp oracle on CPU (the
    dry-run lowers the oracle so cost_analysis counts true attention math);
  * decode keeps the KV cache sharded along the *sequence* axis on the
    'model' mesh axis (flash-decoding): GSPMD partitions the softmax
    reductions, so kv_heads < TP-degree never forces head replication;
  * every parameter carries logical sharding axes (dist/sharding.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.overlap import tp_matmul_ag
from repro.dist.sharding import constrain
from repro.models.attention import mha
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.models.moe import moe_ffn


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 512
    vocab: int = 1024
    block_pattern: tuple = ("dense",)
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    window: int = 0                # sliding-window attention; 0 = full
    qkv_bias: bool = False
    rope_theta: float = 1e4
    moe_groups: int = 0            # >0: group-local MoE dispatch (§Perf)
    use_collective_matmul: bool = False   # opt-in: overlap TP all-gathers
                                   # with the consuming matmuls (qkv, w1/w3)
                                   # via dist.overlap.tp_matmul_ag
    dtype: Any = jnp.bfloat16
    remat: bool = True
    aux_loss_weight: float = 0.01

    @property
    def n_cycles(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, \
            (self.n_layers, self.block_pattern)
        return self.n_layers // len(self.block_pattern)

    def n_params(self) -> int:
        """Total parameter count (for 6ND model-FLOPs accounting)."""
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh \
            + self.n_heads * dh * d
        dense = 3 * d * self.d_ff
        moe = d * self.n_experts + 3 * d * self.expert_d_ff * self.n_experts
        per_cycle = 0
        for kind in self.block_pattern:
            per_cycle += attn + (moe if kind == "moe" else dense) + 2 * d
        return self.n_cycles * per_cycle + 2 * self.vocab * d + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh \
            + self.n_heads * dh * d
        dense = 3 * d * self.d_ff
        moe_act = d * self.n_experts + 3 * d * self.expert_d_ff * self.top_k
        per_cycle = 0
        for kind in self.block_pattern:
            per_cycle += attn + (moe_act if kind == "moe" else dense) + 2 * d
        return self.n_cycles * per_cycle + 2 * self.vocab * d + d


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def _block_param_shapes(cfg: LMConfig, kind: str):
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    C = cfg.n_cycles
    p = {
        "ln1": ((C, d), ("layers", "embed")),
        "ln2": ((C, d), ("layers", "embed")),
        "wq": ((C, d, hq * dh), ("layers", "fsdp", "heads")),
        "wk": ((C, d, hkv * dh), ("layers", "fsdp", "heads")),
        "wv": ((C, d, hkv * dh), ("layers", "fsdp", "heads")),
        "wo": ((C, hq * dh, d), ("layers", "heads", "fsdp")),
    }
    if cfg.qkv_bias:
        p["bq"] = ((C, hq * dh), ("layers", "heads"))
        p["bk"] = ((C, hkv * dh), ("layers", "heads"))
        p["bv"] = ((C, hkv * dh), ("layers", "heads"))
    if kind == "dense":
        p["w1"] = ((C, d, cfg.d_ff), ("layers", "fsdp", "ff"))
        p["w3"] = ((C, d, cfg.d_ff), ("layers", "fsdp", "ff"))
        p["w2"] = ((C, cfg.d_ff, d), ("layers", "ff", "fsdp"))
    else:
        fe, e = cfg.expert_d_ff, cfg.n_experts
        p["router"] = ((C, d, e), ("layers", "embed", None))
        p["we1"] = ((C, e, d, fe), ("layers", "expert", "fsdp", None))
        p["we3"] = ((C, e, d, fe), ("layers", "expert", "fsdp", None))
        p["we2"] = ((C, e, fe, d), ("layers", "expert", None, "fsdp"))
    return p


def param_shapes(cfg: LMConfig):
    """Returns (shapes pytree, logical-axes pytree) with identical structure."""
    d = cfg.d_model
    shapes = {
        "embed": ((cfg.vocab, d), ("vocab", "fsdp")),
        "head": ((d, cfg.vocab), ("fsdp", "vocab")),
        "ln_f": ((d,), ("embed",)),
        "blocks": [],
    }
    for kind in cfg.block_pattern:
        shapes["blocks"].append(_block_param_shapes(cfg, kind))
    shp = jax.tree.map(lambda t: t[0], shapes,
                       is_leaf=lambda x: isinstance(x, tuple)
                       and isinstance(x[0], tuple))
    axes = jax.tree.map(lambda t: t[1], shapes,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and isinstance(x[0], tuple))
    return shp, axes


def init_params(cfg: LMConfig, key):
    shp, _ = param_shapes(cfg)
    leaves, tdef = jax.tree.flatten(shp, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, shape in zip(keys, leaves):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        if len(shape) <= 2 and shape[-1] == cfg.d_model:    # ln scales
            out.append(jnp.ones(shape, cfg.dtype))
        else:
            out.append((jax.random.normal(k, shape, jnp.float32)
                        * (fan_in ** -0.5)).astype(cfg.dtype))
    return jax.tree.unflatten(tdef, out)


def param_shape_dtypes(cfg: LMConfig):
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    shp, _ = param_shapes(cfg)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, cfg.dtype), shp,
                        is_leaf=lambda x: isinstance(x, tuple))


def logical_axes(cfg: LMConfig):
    _, axes = param_shapes(cfg)
    return axes


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _rope(x, positions, theta: float):
    """x: (B, H, S, dh); positions: (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,h)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def _attn(p, cfg: LMConfig, x, positions, kv_cache=None, cache_pos=None):
    """x: (B, S, D).  If kv_cache given: decode (append + attend)."""
    B, S, D = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    mm = tp_matmul_ag if cfg.use_collective_matmul else (lambda a, b: a @ b)
    q = mm(x, p["wq"])
    k = mm(x, p["wk"])
    v = mm(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, hq, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, hkv, dh).transpose(0, 2, 1, 3)
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        out = mha(q, k, v, causal=True, window=cfg.window)
        new_cache = None
    else:
        ck, cv = kv_cache                                # (B, Hkv, Sc, dh)
        Sc = ck.shape[2]
        # ring-buffer write for SWA, plain append otherwise
        wpos = cache_pos % Sc if cfg.window else cache_pos
        ck = jax.lax.dynamic_update_slice(
            ck, k.astype(ck.dtype), (0, 0, wpos, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, v.astype(cv.dtype), (0, 0, wpos, 0))
        ck = constrain(ck, ("batch", None, "kv_seq", None))
        cv = constrain(cv, ("batch", None, "kv_seq", None))
        # decode attends over the whole (validity-masked) cache
        out = _decode_attention(q, ck, cv, cache_pos, cfg)
        new_cache = (ck, cv)

    out = out.transpose(0, 2, 1, 3).reshape(B, S, hq * dh)
    return out @ p["wo"], new_cache


def _decode_attention(q, ck, cv, cache_pos, cfg: LMConfig):
    """Single-token attention over a sequence-sharded KV cache.

    Computed with explicit (q k^T) einsums so GSPMD partitions the length
    axis across 'model' and inserts the lse-merge collectives (the in-XLA
    form of flash-decoding).
    """
    B, Hq, S1, dh = q.shape
    Hkv, Sc = ck.shape[1], ck.shape[2]
    G = Hq // Hkv
    kx = jnp.repeat(ck, G, axis=1).astype(jnp.float32)
    vx = jnp.repeat(cv, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) * dh ** -0.5
    kpos = jnp.arange(Sc)
    if cfg.window:
        # ring buffer: valid slots are the window's most recent writes
        n_written = jnp.minimum(cache_pos + 1, Sc)
        valid = kpos[None, None, None, :] < n_written
    else:
        valid = kpos[None, None, None, :] <= cache_pos
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx)
    return out.astype(q.dtype)


def _ffn_dense(p, x, cfg: LMConfig):
    mm = tp_matmul_ag if cfg.use_collective_matmul else (lambda a, b: a @ b)
    h = jax.nn.silu(mm(x, p["w1"])) * mm(x, p["w3"])
    return h @ p["w2"]


def _block(p, cfg: LMConfig, kind: str, x, positions, kv_cache=None,
           cache_pos=None):
    B, S, D = x.shape
    h = rmsnorm(x, p["ln1"])
    attn_out, new_cache = _attn(p, cfg, h, positions, kv_cache, cache_pos)
    x = x + attn_out
    h = rmsnorm(x, p["ln2"])
    if kind == "dense":
        x = x + _ffn_dense(p, h, cfg)
        aux = jnp.zeros((), jnp.float32)
    else:
        y, moe_aux = moe_ffn(h.reshape(B * S, D), p["router"], p["we1"],
                             p["we3"], p["we2"], top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor,
                             groups=cfg.moe_groups)
        x = x + y.reshape(B, S, D)
        aux = moe_aux["aux_loss"]
    x = constrain(x, ("batch", "seq", None))
    return x, aux, new_cache


def forward_hidden(params, cfg: LMConfig, tokens, positions=None):
    """Trunk only: tokens (B, S) -> hidden (B, S, D), aux."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)
    x = constrain(x, ("batch", "seq", None))

    def cycle(x, block_params):
        aux_total = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(cfg.block_pattern):
            x, aux, _ = _block(block_params[j], cfg, kind, x, positions)
            aux_total += aux
        return x, aux_total

    body = jax.checkpoint(cycle) if cfg.remat else cycle
    x, auxs = jax.lax.scan(lambda c, bp: body(c, bp), x,
                           tuple(params["blocks"]))
    return rmsnorm(x, params["ln_f"]), auxs.sum()


def forward(params, cfg: LMConfig, tokens, positions=None):
    """Training forward: tokens (B, S) -> logits (B, S, V), aux."""
    x, aux = forward_hidden(params, cfg, tokens, positions)
    logits = (x @ params["head"]).astype(jnp.float32)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux


def loss_fn(params, cfg: LMConfig, tokens, targets):
    logits, aux = forward(params, cfg, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = targets >= 0
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)
    return loss + cfg.aux_loss_weight * aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """Cache pytree: per pattern position, stacked over cycles."""
    dtype = dtype or cfg.dtype
    Sc = min(max_len, cfg.window) if cfg.window else max_len
    C = cfg.n_cycles
    mk = lambda: (jnp.zeros((C, batch, cfg.n_kv_heads, Sc, cfg.d_head),
                            dtype),
                  jnp.zeros((C, batch, cfg.n_kv_heads, Sc, cfg.d_head),
                            dtype))
    return [mk() for _ in cfg.block_pattern]


def kv_cache_shape_dtypes(cfg: LMConfig, batch: int, max_len: int,
                          dtype=None):
    dtype = dtype or cfg.dtype
    Sc = min(max_len, cfg.window) if cfg.window else max_len
    C = cfg.n_cycles
    sds = jax.ShapeDtypeStruct
    mk = lambda: (sds((C, batch, cfg.n_kv_heads, Sc, cfg.d_head), dtype),
                  sds((C, batch, cfg.n_kv_heads, Sc, cfg.d_head), dtype))
    return [mk() for _ in cfg.block_pattern]


def decode_step(params, cfg: LMConfig, tokens, kv_cache, cache_pos):
    """One decode step: tokens (B, 1), cache_pos scalar i32 (current length).

    Returns (logits (B, V), new_cache).
    """
    B = tokens.shape[0]
    positions = jnp.full((B, 1), cache_pos, jnp.int32)
    x = params["embed"][tokens].astype(cfg.dtype)

    def cycle(carry, xs):
        x = carry
        block_params, cache = xs
        new_caches = []
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(cfg.block_pattern):
            x, a, nc = _block(block_params[j], cfg, kind, x, positions,
                              kv_cache=cache[j], cache_pos=cache_pos)
            new_caches.append(nc)
            aux += a
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(
        cycle, x, (tuple(params["blocks"]), tuple(kv_cache)))
    x = rmsnorm(x, params["ln_f"])
    logits = (x[:, 0] @ params["head"]).astype(jnp.float32)
    return logits, list(new_cache)


def prefill(params, cfg: LMConfig, tokens):
    """Prefill: returns (last-token logits (B, V), aux).  Only the final

    position touches the output head — the (B, S, V) logits tensor is never
    materialized (matters at 32k x 200k vocab)."""
    x, aux = forward_hidden(params, cfg, tokens)
    logits = (x[:, -1] @ params["head"]).astype(jnp.float32)
    return logits, aux
