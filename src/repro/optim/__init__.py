from repro.optim.optimizers import (AdamWConfig, AdafactorConfig, OptState,
                                    init_opt_state, opt_update)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine
from repro.optim.compression import (compress_int8, decompress_int8,
                                     ef_compress_grads)
