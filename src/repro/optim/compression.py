"""Gradient compression: int8 quantization with error feedback.

Distributed-optimization trick for bandwidth-bound data-parallel reductions:
quantize gradients to int8 with a per-tensor scale before the cross-replica
all-reduce, and fold the quantization error back into the next step's
gradient (error feedback keeps SGD convergence unbiased in expectation).
4x fewer bytes on the DP all-reduce, which is what the collective roofline
term of the train cells is made of.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x):
    """x (f32/bf16) -> (int8 values, f32 scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_compress_grads(grads, error_state, axis_name=None):
    """Error-feedback int8 compression of a gradient pytree.

    Adds the carried error, quantizes, optionally psums the int8 payload over
    ``axis_name`` (inside shard_map), and returns (decompressed grads,
    new_error_state).  With ``axis_name=None`` the psum is the caller's job
    (GSPMD inserts it from the sharding); the compression still models the
    wire format and carries the error.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = compress_int8(gf)
        if axis_name is not None:
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
            deq = (qsum.astype(jnp.float32) * scale) / n.astype(jnp.float32)
        else:
            deq = decompress_int8(q, scale)
        err = gf - decompress_int8(q, scale)
        return deq.astype(g.dtype), err

    out = jax.tree.map(one, grads, error_state)
    newg = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    newe = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    return newg, newe


def init_error_state(grads_shape):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                        grads_shape)
