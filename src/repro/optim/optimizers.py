"""Optimizers built from scratch (no optax in this environment).

AdamW with configurable state dtype (bf16 m/v for HBM-tight configs: the
405B-class archs cannot afford fp32 moments on a 16 GB/chip pod — see
DESIGN.md memory budget), and Adafactor (factored second moment) for the
largest configs.  Optimizer states inherit the parameter sharding (ZeRO:
states live wherever the param shard lives, never replicated).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Any = jnp.float32      # bf16 halves optimizer HBM
    grad_clip: float = 1.0


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    weight_decay: float = 0.0
    min_dim_factored: int = 128         # factor only big matrices
    grad_clip: float = 1.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jax.Array
    m: Any          # AdamW first moment, or None-like empty for Adafactor
    v: Any          # AdamW second moment, or Adafactor (vr, vc) tuples


def _is_factored(p, cfg) -> bool:
    return (p.ndim >= 2 and p.shape[-1] >= cfg.min_dim_factored
            and p.shape[-2] >= cfg.min_dim_factored)


def init_opt_state(params, cfg) -> OptState:
    if isinstance(cfg, AdamWConfig):
        zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))
    assert isinstance(cfg, AdafactorConfig)

    def vstate(p):
        if _is_factored(p, cfg):
            return (jnp.zeros(p.shape[:-1], jnp.float32),
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(lambda p: jnp.zeros((), jnp.float32),
                                   params),
                    v=jax.tree.map(vstate, params))


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), grads), g


def opt_update(params, grads, state: OptState, cfg, lr_scale=1.0):
    """One optimizer step.  Returns (new_params, new_state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    if isinstance(cfg, AdamWConfig):
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = mf / bc1
            vhat = vf / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - cfg.lr * lr_scale * delta
            return (newp.astype(p.dtype), mf.astype(cfg.state_dtype),
                    vf.astype(cfg.state_dtype))

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        newp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        newv = jax.tree.map(lambda t: t[2], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        return newp, OptState(step=step, m=newm, v=newv), gnorm

    assert isinstance(cfg, AdafactorConfig)
    rho = 1.0 - step.astype(jnp.float32) ** -cfg.decay

    def upd(p, g, v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + cfg.eps
        if isinstance(v, tuple):
            vr, vc = v
            vr = rho * vr + (1 - rho) * jnp.mean(g2, axis=-1)
            vc = rho * vc + (1 - rho) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                                cfg.eps)
            vhat = vr[..., None] * vc[..., None, :] / denom
            newv = (vr, vc)
        else:
            vhat = rho * v + (1 - rho) * g2
            newv = vhat
        update = gf * jax.lax.rsqrt(vhat + cfg.eps)
        # relative step-size clipping (Adafactor's d=1.0)
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        newp = (p.astype(jnp.float32)
                - cfg.lr * lr_scale * update
                - cfg.lr * lr_scale * cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), newv

    is_v_leaf = lambda x: isinstance(x, tuple) or not isinstance(
        x, (dict, list))
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_v = tdef.flatten_up_to(state.v)
    outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    newp = jax.tree.unflatten(tdef, [o[0] for o in outs])
    newv = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return newp, OptState(step=step, m=state.m, v=newv), gnorm
