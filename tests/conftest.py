"""Shared test configuration.

Registers reproducible hypothesis profiles; CI runs the suite with
``--hypothesis-profile=ci`` so the chaos/property sweeps
(test_chaos_replication, test_property_txn, test_query,
test_backend_parity_prop, test_kernels) draw a fixed example sequence —
a red CI run replays locally with the same seed.
"""
try:
    from hypothesis import settings
except ImportError:        # hypothesis optional locally; CI installs it
    pass
else:
    settings.register_profile("ci", derandomize=True, deadline=None,
                              print_blob=True)
    settings.register_profile("dev", deadline=None)
