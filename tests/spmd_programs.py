"""Multi-device SPMD programs run by tests/test_spmd.py in subprocesses

(the forced host-device count must precede jax's first init, so these can't
run inside the main pytest process)."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np


def prog_query_parity():
    import jax
    import jax.numpy as jnp
    from repro.core.addressing import StoreConfig
    from repro.core.graphdb import GraphDB
    from repro.core.query.executor import QueryCaps
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 4), ("data", "model"))
    cfg = StoreConfig(n_shards=8, cap_v=128, cap_e=1024, cap_delta=128,
                      cap_idx=256, cap_idx_delta=64, d_f32=2, d_i32=2)
    db = GraphDB(cfg)
    db.vertex_type("director")
    db.vertex_type("actor")
    db.vertex_type("film", i_attrs=("year", "genre"))
    db.edge_type("film.director")
    db.edge_type("film.actor")
    rng = np.random.default_rng(0)
    d = [db.create_vertex("director", i) for i in range(5)]
    films = [db.create_vertex("film", 100 + i,
                              {"year": 1990 + i,
                               "genre": int(rng.integers(0, 3))})
             for i in range(20)]
    actors = [db.create_vertex("actor", 300 + i) for i in range(30)]
    t = db.create_transaction()
    for i, f in enumerate(films):
        db.create_edge(d[i % 5], f, "film.director", txn=t)
        for a in rng.choice(30, size=int(rng.integers(1, 6)), replace=False):
            db.create_edge(f, actors[a], "film.actor", txn=t)
    assert db.commit(t) == "COMMITTED"
    db.run_compaction()
    # leave fresh edges in the delta log so both tiers are exercised
    t = db.create_transaction()
    for f in films[:3]:
        try:
            db.create_edge(f, actors[29], "film.actor", txn=t)
        except ValueError:
            pass
    db.commit(t)

    caps = QueryCaps(frontier=128, expand=512, bucket=64, results=16)
    q = lambda i: {"type": "director", "id": i,
                   "_out_edge": {"type": "film.director",
                                 "_target": {"type": "film",
                                             "_out_edge": {
                                                 "type": "film.actor",
                                                 "_target": {
                                                     "type": "actor",
                                                     "select": "count"}}}}}
    queries = [q(i) for i in range(5)]
    rl = db.query(queries, caps=caps)
    rs = db.query(queries, caps=caps, mesh=mesh)
    assert np.array_equal(rl.counts, rs.counts), (rl.counts, rs.counts)

    # select parity
    qs = [{"type": "actor", "id": 300 + i,
           "_in_edge": {"type": "film.actor",
                        "_target": {"type": "film",
                                    "select": ["key", "year"]}}}
          for i in range(8)]
    rl = db.query(qs, caps=caps)
    rs = db.query(qs, caps=caps, mesh=mesh)
    for qi in range(8):
        kl = sorted(int(x) for x in rl.rows[("key", 0)][qi] if x >= 0)
        ks = sorted(int(x) for x in rs.rows[("key", 0)][qi] if x >= 0)
        assert kl == ks, (qi, kl, ks)

    # intersect parity (director 0 AND actor with guaranteed overlap)
    q3 = {"intersect": [
        {"type": "director", "id": 0,
         "_out_edge": {"type": "film.director", "_target": {"type": "film"}}},
        {"type": "actor", "id": 329,
         "_in_edge": {"type": "film.actor", "_target": {"type": "film"}}}],
        "select": "count"}
    rl = db.query([q3], caps=caps)
    rs = db.query([q3], caps=caps, mesh=mesh)
    assert np.array_equal(rl.counts, rs.counts)

    # pallas backend (interpret on CPU): same program, kernel read path
    rp = db.query(queries, caps=caps, mesh=mesh, backend="pallas")
    rl = db.query(queries, caps=caps, backend="ref")
    assert np.array_equal(rl.counts, rp.counts), (rl.counts, rp.counts)
    print("PARITY_OK")


def prog_multiquery_parity():
    """The planner's fused batched path inside shard_map: heterogeneous
    batches (mixed hop counts/directions/filters/terminals, star patterns
    fused into the waves, per-query MVCC snapshots) must match the local
    batched path — which the deterministic suite pins to per-query
    execution — on ref and pallas backends."""
    import numpy as np
    from repro.core.addressing import StoreConfig
    from repro.core.graphdb import GraphDB
    from repro.core.query.executor import QueryCaps
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 4), ("data", "model"))
    cfg = StoreConfig(n_shards=8, cap_v=128, cap_e=1024, cap_delta=128,
                      cap_idx=256, cap_idx_delta=64, d_f32=2, d_i32=2)
    db = GraphDB(cfg)
    db.vertex_type("director")
    db.vertex_type("actor")
    db.vertex_type("film", i_attrs=("year", "genre"))
    db.edge_type("film.director")
    db.edge_type("film.actor")
    rng = np.random.default_rng(1)
    d = [db.create_vertex("director", i) for i in range(5)]
    films = [db.create_vertex("film", 100 + i,
                              {"year": 1990 + i,
                               "genre": int(rng.integers(0, 3))})
             for i in range(20)]
    actors = [db.create_vertex("actor", 300 + i) for i in range(30)]
    t = db.create_transaction()
    for i, f in enumerate(films):
        db.create_edge(d[i % 5], f, "film.director", txn=t)
        for a in rng.choice(30, size=int(rng.integers(1, 6)), replace=False):
            db.create_edge(f, actors[a], "film.actor", txn=t)
    assert db.commit(t) == "COMMITTED"
    db.run_compaction()
    t1 = db.snapshot_ts()
    t = db.create_transaction()      # fresh delta-log edges after t1
    for f in films[:3]:
        try:
            db.create_edge(f, actors[29], "film.actor", txn=t)
        except ValueError:
            pass
    db.commit(t)
    t2 = db.snapshot_ts()

    caps = QueryCaps(frontier=128, expand=512, bucket=64, results=16)
    q2hop = lambda i: {"type": "director", "id": i,
                       "_out_edge": {"type": "film.director",
                                     "_target": {"type": "film",
                                                 "_out_edge": {
                                                     "type": "film.actor",
                                                     "_target": {
                                                         "type": "actor",
                                                         "select": "count"}}}}}
    qrev = lambda i: {"type": "actor", "id": 300 + i,
                      "_in_edge": {"type": "film.actor",
                                   "_target": {"type": "film",
                                               "select": "count"}}}
    qsel = lambda i: {"type": "actor", "id": 300 + i,
                      "_in_edge": {"type": "film.actor",
                                   "_target": {"type": "film",
                                               "select": ["key", "year"]}}}
    # star patterns (Q3) fuse into the same wave batch since A1QL v2
    qstar = lambda d, a: {"intersect": [
        {"type": "director", "id": d,
         "_out_edge": {"type": "film.director",
                       "_target": {"type": "film"}}},
        {"type": "actor", "id": 300 + a,
         "_in_edge": {"type": "film.actor", "_target": {"type": "film"}}}],
        "select": "count"}
    queries = [q2hop(0), qrev(3), q2hop(1), qrev(29), qsel(2), qsel(29),
               q2hop(4), qstar(0, 29), qstar(2, 5)]
    ts = [t2, t2, t1, t1, t2, t2, t2, t2, t1]

    rl = db.query(queries, caps=caps, read_ts=ts, fused=True)
    # anchor the local-batched oracle to per-query sequential runs
    for i in (0, 1, 3, 7, 8):
        solo = db.query([queries[i]], caps=caps, read_ts=ts[i])
        assert rl.counts[i] == solo.counts[0], (i, rl.counts, solo.counts)

    # the shared-frontier mode must agree too (no overflow at these caps:
    # bit-identical to per-query mode, locally and under shard_map)
    budgets = [(None, "fused"), ("shared", "shared")]
    for budget, tag in budgets:
        for be in ("ref", "pallas"):
            rs = db.query(queries, caps=caps, mesh=mesh, backend=be,
                          read_ts=ts, fused=True, budget=budget)
            assert np.array_equal(rl.counts, rs.counts), (tag, be, rl.counts,
                                                          rs.counts)
            assert np.array_equal(rl.failed_q, rs.failed_q), (tag, be)
            assert np.array_equal(rl.truncated, rs.truncated), (tag, be)
            for qi in (4, 5):   # select rows: set-equal (shard order differs)
                for col in (("key", 0), ("i32", 0)):
                    kl = sorted(int(x) for x, gg in
                                zip(rl.rows[col][qi], rl.rows_gid[qi])
                                if gg >= 0)
                    ks = sorted(int(x) for x, gg in
                                zip(rs.rows[col][qi], rs.rows_gid[qi])
                                if gg >= 0)
                    assert kl == ks, (tag, be, qi, col, kl, ks)
                assert (sorted(x for x in rl.rows_gid[qi] if x >= 0)
                        == sorted(x for x in rs.rows_gid[qi] if x >= 0)), \
                    (tag, be, qi)
        sl = db.query(queries, caps=caps, read_ts=ts, fused=True,
                      budget=budget)
        assert np.array_equal(rl.counts, sl.counts), (tag, sl.counts)
    print("MQ_OK")


def prog_knn_parity():
    """The Nearest probe wave under shard_map: each shard computes a local
    top-k over its vector-index block, all-gathers the (dist, gid) pairs,
    and re-sorts — the seed set must be bit-identical to the local path,
    for mixed Nearest+Scan batches on ref and pallas, per-query and shared
    budgets, and across MVCC snapshots."""
    import numpy as np
    from repro.core.addressing import StoreConfig
    from repro.core.graphdb import GraphDB
    from repro.core.query.executor import QueryCaps
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 4), ("data", "model"))
    D = 4
    cfg = StoreConfig(n_shards=8, cap_v=128, cap_e=1024, cap_delta=128,
                      cap_idx=256, cap_idx_delta=64, cap_vec=64,
                      d_f32=D, d_i32=2)
    db = GraphDB(cfg)
    fa = tuple(f"f{i}" for i in range(D))
    db.vertex_type("doc", f_attrs=fa, i_attrs=("x", "y"))
    db.vertex_type("tag")
    db.edge_type("doc.tag")
    rng = np.random.default_rng(5)
    emb = rng.normal(size=(40, D)).astype(np.float32)
    docs = [db.create_vertex("doc", i,
                             dict(zip(fa, map(float, emb[i])), x=i, y=0))
            for i in range(40)]
    tags = [db.create_vertex("tag", 500 + i) for i in range(6)]
    t = db.create_transaction()
    for i, g in enumerate(docs):
        db.create_edge(g, tags[i % 6], "doc.tag", txn=t)
    assert db.commit(t) == "COMMITTED"
    db.vector_index("doc")
    t1 = db.snapshot_ts()
    for i in range(0, 40, 7):          # post-snapshot churn: delete/update
        g, found = db.lookup_vertex("doc", i)
        if found and i % 14 == 0:
            db.delete_vertex(g)
        elif found:
            db.update_vertex(g, "doc",
                             dict(zip(fa, map(float,
                                              rng.normal(size=D)))))
    t2 = db.snapshot_ts()

    caps = QueryCaps(frontier=128, expand=512, bucket=64, results=16)
    qn = lambda v, k, hop: (
        {"nearest": {"type": "doc", "vector": [float(x) for x in v],
                     "k": k},
         "_out_edge": {"type": "doc.tag",
                       "_target": {"type": "tag", "select": "count"}}}
        if hop else
        {"nearest": {"type": "doc", "vector": [float(x) for x in v],
                     "k": k}, "select": ["key"]})
    qs_scan = lambda i: {"type": "doc", "id": i,
                         "_out_edge": {"type": "doc.tag",
                                       "_target": {"type": "tag",
                                                   "select": "count"}}}
    queries = [qn(rng.normal(size=D), 4, True), qs_scan(1),
               qn(rng.normal(size=D), 7, False), qs_scan(8),
               qn(rng.normal(size=D), 1, True)]
    ts = [t2, t2, t1, t1, t2]
    rl = db.query(queries, caps=caps, read_ts=ts, fused=True)
    for budget in (None, "shared"):
        for be in ("ref", "pallas"):
            rs = db.query(queries, caps=caps, mesh=mesh, backend=be,
                          read_ts=ts, fused=True, budget=budget)
            assert np.array_equal(rl.counts, rs.counts), \
                (budget, be, rl.counts, rs.counts)
            assert np.array_equal(rl.failed_q, rs.failed_q), (budget, be)
            # the k-NN seed rows of query 2: set-equal (shard-major order)
            kl = sorted(int(x) for x, g in zip(rl.rows[("key", 0)][2],
                                               rl.rows_gid[2]) if g >= 0)
            ks = sorted(int(x) for x, g in zip(rs.rows[("key", 0)][2],
                                               rs.rows_gid[2]) if g >= 0)
            assert kl == ks and len(kl) == 7, (budget, be, kl, ks)
    print("KNN_OK")


def prog_dedup_compact():
    """kernels/dedup_compact under shard_map: every shard sorts/compacts its
    own candidate block, ref and pallas-interpret bit-identical (the same
    layout the fused wave programs dispatch through core/backend.py)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import backend as backend_mod
    from repro.dist import compat
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 4), ("data", "model"))
    PAD = 2**31 - 1
    rng = np.random.default_rng(7)
    S, R, W, cap = 8, 4, 96, 16
    x = rng.integers(0, 40, (S * R, W)).astype(np.int32)
    x[rng.random(x.shape) < 0.3] = PAD
    s_flat = rng.integers(0, 6, (S * 128,)).astype(np.int32)
    g_flat = rng.integers(0, 40, (S * 128,)).astype(np.int32)

    def body(be):
        def f(xb, sb, gb):
            out, n = backend_mod.dedup_compact_rows(xb, cap, backend=be)
            srt = backend_mod.sort_rows(xb, backend=be)
            ps, pg = backend_mod.sort_pairs(sb, gb, backend=be)
            return out, n, srt, ps, pg
        return jax.jit(compat.shard_map(
            f, mesh=mesh,
            in_specs=(P(("data", "model")), P(("data", "model")),
                      P(("data", "model"))),
            out_specs=(P(("data", "model")),) * 5, check_vma=False))

    ref = backend_mod.REF
    pal = backend_mod.Backend("pallas", interpret=True)
    a = body(ref)(jnp.asarray(x), jnp.asarray(s_flat), jnp.asarray(g_flat))
    b = body(pal)(jnp.asarray(x), jnp.asarray(s_flat), jnp.asarray(g_flat))
    for i, (ai, bi) in enumerate(zip(a, b)):
        assert np.array_equal(np.asarray(ai), np.asarray(bi)), i
    # shard-local oracle: each shard block == the plain jnp compaction
    from repro.kernels.dedup_compact import ref as dc_ref
    want, n_want = dc_ref.dedup_compact_rows(jnp.asarray(x), cap)
    assert np.array_equal(np.asarray(a[0]), np.asarray(want))
    assert np.array_equal(np.asarray(a[1]), np.asarray(n_want))
    print("DEDUP_OK")


def prog_collective_matmul():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.dist import compat
    from repro.dist.overlap import collective_matmul_ag
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 4), ("data", "model"))
    S, K, O = 16, 32, 24
    x = jax.random.normal(jax.random.key(0), (S, K), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (K, O), jnp.float32)
    y = jax.jit(compat.shard_map(
        lambda xs, wl: collective_matmul_ag(xs, wl, "model"), mesh=mesh,
        in_specs=(P("model", None), P(None, "model")),
        out_specs=P(None, "model")))(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=2e-5, atol=1e-4)
    print("CM_OK")


def prog_pipeline():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.dist import compat
    from repro.dist.pipeline import pipeline_apply
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((4, 2), ("pod", "model"))
    M, mb, d = 6, 3, 8
    xin = jax.random.normal(jax.random.key(2), (M, mb, d))
    ws = jax.random.normal(jax.random.key(3), (4, d, d)) * 0.3

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    def pf(x, w):
        o = pipeline_apply(stage_fn, w[0], x, axis="pod", n_stages=4,
                           n_microbatches=M)
        return jax.lax.psum(
            jnp.where(jax.lax.axis_index("pod") == 3, o, 0.), "pod")

    out = jax.jit(compat.shard_map(pf, mesh=mesh, in_specs=(P(), P("pod")),
                                   out_specs=P(), check_vma=False))(xin, ws)
    ref = xin
    for s in range(4):
        ref = jnp.tanh(ref @ ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=1e-5)
    print("PIPE_OK")


def prog_a1_ship_lookup():
    import jax
    import jax.numpy as jnp
    from repro.models.embedding import a1_ship_lookup, gspmd_lookup
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 4), ("data", "model"))
    V, D = 64, 16
    table = jax.random.normal(jax.random.key(0), (V, D))
    ids = jax.random.randint(jax.random.key(1), (10,), 0, V)
    got = a1_ship_lookup(table, ids, mesh)
    want = gspmd_lookup(table, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    print("SHIP_OK")


def prog_cm_transformer():
    """use_collective_matmul=True matches the GSPMD baseline numerically
    under a sequence-parallel rules table (the plan whose all-gathers the
    ring overlap replaces)."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.dist.sharding import rules_context
    from repro.launch.mesh import make_test_mesh
    from repro.models.transformer import LMConfig, forward, init_params

    mesh = make_test_mesh((2, 4), ("data", "model"))
    cfg = LMConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_head=16, d_ff=128, vocab=64,
                   dtype=jnp.float32, remat=False)
    cfg_cm = dataclasses.replace(cfg, use_collective_matmul=True)
    params = init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    with mesh:
        with rules_context({"seq": "model"}):
            base = jax.jit(lambda p, t: forward(p, cfg, t)[0])(params, tokens)
            cm = jax.jit(lambda p, t: forward(p, cfg_cm, t)[0])(params,
                                                                tokens)
    np.testing.assert_allclose(np.asarray(base), np.asarray(cm),
                               rtol=2e-5, atol=2e-5)
    print("CMT_OK")


def prog_reduced_cells_lower():
    """Every (arch x shape) lowers + compiles on an 8-device mesh (reduced)."""
    import jax
    from repro.configs import registry
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import build_cell

    mesh = make_test_mesh((2, 4), ("data", "model"))
    n = 0
    for arch, shape in registry.all_cells():
        spec = registry.get(arch)
        if spec.cell(shape).skip:
            continue
        cell = build_cell(arch, shape, mesh, reduced=True)
        if cell.in_shardings is not None:
            fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate_argnums)
        else:
            fn = cell.fn
        with mesh:
            fn.lower(*cell.args).compile()
        n += 1
    print(f"LOWER_OK {n}")


if __name__ == "__main__":
    globals()[f"prog_{sys.argv[1]}"]()
