"""Per-architecture smoke tests: reduced configs, one real step on CPU.

Each assigned arch instantiates its REDUCED config through the same cell
builders the dry-run uses, materializes real inputs, executes one step, and
asserts output shapes + finiteness.  (Full configs are exercised only via
the dry-run's lower/compile, per the assignment.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_cell

ARCHS = ["qwen3-moe-235b-a22b", "llama4-maverick-400b-a17b", "llama3-405b",
         "h2o-danube-3-4b", "qwen1.5-32b", "nequip", "gcn-cora",
         "meshgraphnet", "graphsage-reddit", "bst"]


def single_mesh():
    return make_test_mesh((1, 1), ("data", "model"))


def materialize(args, spec, seed=0):
    """Real arrays for a cell's ShapeDtypeStruct inputs, with index domains

    respected (tokens < vocab, edge ids < N, item ids < table, ...)."""
    rng = np.random.default_rng(seed)
    from repro.models.gnn.common import GraphBatch
    from repro.optim.optimizers import OptState

    def mat_leaf(sds, hint=""):
        shape, dtype = sds.shape, sds.dtype
        if dtype == jnp.int32:
            hi = 8 if "small" in hint else 64
            return jnp.asarray(rng.integers(0, hi, shape), jnp.int32)
        if dtype == jnp.bool_:
            return jnp.ones(shape, bool)
        return jnp.asarray(rng.normal(size=shape) * 0.1, dtype)

    out = []
    for a in args:
        if isinstance(a, GraphBatch):
            N = a.node_feat.shape[0]
            E = a.edge_src.shape[0]
            lbl_int = a.labels.dtype == jnp.int32
            out.append(GraphBatch(
                node_feat=jnp.asarray(
                    np.abs(rng.normal(size=a.node_feat.shape)) % 4,
                    a.node_feat.dtype),
                edge_src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                edge_dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                labels=(jnp.asarray(rng.integers(0, 4, a.labels.shape),
                                    jnp.int32) if lbl_int
                        else jnp.asarray(rng.normal(size=a.labels.shape),
                                         jnp.float32)),
                train_mask=jnp.ones(a.train_mask.shape, bool),
                positions=(jnp.asarray(rng.normal(size=a.positions.shape),
                                       a.positions.dtype)
                           if a.positions is not None else None),
                graph_ids=(jnp.asarray(
                    np.minimum(np.arange(N) // max(N // a.n_graphs, 1),
                               a.n_graphs - 1), jnp.int32)
                    if a.graph_ids is not None else None),
                n_graphs=a.n_graphs))
        elif isinstance(a, OptState) or not isinstance(
                a, jax.ShapeDtypeStruct):
            out.append(jax.tree.map(mat_leaf, a))
        else:
            out.append(mat_leaf(a))
    return tuple(out)


def init_real_params(spec, cell):
    key = jax.random.key(0)
    cfg = cell.model_cfg
    if spec.family == "lm":
        from repro.models.transformer import init_params
        return init_params(cfg, key)
    if spec.family == "recsys":
        from repro.models.recsys import init_params
        return init_params(cfg, key)
    fam = type(cfg).__name__
    from repro.models.gnn import gcn, meshgraphnet as mgn, nequip, sage
    mod = {"GCNConfig": gcn, "SageConfig": sage, "MGNConfig": mgn,
           "NequIPConfig": nequip}[fam]
    return mod.init_params(cfg, key)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_primary_cell(arch):
    """One real reduced train step per arch: finite loss, shapes intact."""
    spec = registry.get(arch)
    mesh = single_mesh()
    shape0 = spec.shapes[0].shape_id
    cell = build_cell(arch, shape0, mesh, reduced=True)
    args = list(materialize(cell.args, spec))
    args[0] = init_real_params(spec, cell)  # real params
    if spec.family in ("lm", "gnn", "recsys"):
        from repro.optim.optimizers import init_opt_state
        from repro.launch.steps import pick_opt, AdamWConfig
        ocfg = (pick_opt(spec.reduced.n_params())
                if spec.family == "lm" else AdamWConfig())
        args[1] = init_opt_state(args[0], ocfg)
    with mesh:
        out = cell.fn(*args)
    params_new = out[0]
    metrics = out[-1]
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    # params keep structure + shapes, no NaNs
    for a, b in zip(jax.tree.leaves(args[0]), jax.tree.leaves(params_new)):
        assert a.shape == b.shape
    sample = jax.tree.leaves(params_new)[0]
    assert not np.any(np.isnan(np.asarray(sample, np.float32)))


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "h2o-danube-3-4b"])
def test_smoke_lm_decode(arch):
    spec = registry.get(arch)
    mesh = single_mesh()
    cell = build_cell(arch, "decode_32k", mesh, reduced=True)
    args = list(materialize(cell.args, spec))
    args[0] = init_real_params(spec, cell)
    with mesh:
        logits, cache = cell.fn(*args)
    assert logits.shape == (2, spec.reduced.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_smoke_lm_prefill():
    spec = registry.get("qwen1.5-32b")
    mesh = single_mesh()
    cell = build_cell("qwen1.5-32b", "prefill_32k", mesh, reduced=True)
    args = list(materialize(cell.args, spec))
    args[0] = init_real_params(spec, cell)
    with mesh:
        logits, aux = cell.fn(*args)
    assert logits.shape == (2, spec.reduced.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_smoke_bst_serve_and_retrieval():
    spec = registry.get("bst")
    mesh = single_mesh()
    for shape, out_shape in [("serve_p99", (8,)), ("retrieval_cand", (8, 256))]:
        cell = build_cell("bst", shape, mesh, reduced=True)
        args = list(materialize(cell.args, spec))
        args[0] = init_real_params(spec, cell)
        with mesh:
            scores = cell.fn(*args)
        assert scores.shape == out_shape, (shape, scores.shape)
        assert np.all(np.isfinite(np.asarray(scores)))


def test_smoke_gnn_all_shapes():
    """Every GNN arch x every shape geometry runs (reduced)."""
    for arch in ("gcn-cora", "graphsage-reddit", "meshgraphnet", "nequip"):
        spec = registry.get(arch)
        mesh = single_mesh()
        for cellmeta in spec.shapes:
            cell = build_cell(arch, cellmeta.shape_id, mesh, reduced=True)
            args = list(materialize(cell.args, spec))
            args[0] = init_real_params(spec, cell)
            from repro.optim.optimizers import init_opt_state
            from repro.launch.steps import AdamWConfig
            args[1] = init_opt_state(args[0], AdamWConfig())
            with mesh:
                _, _, metrics = cell.fn(*args)
            assert np.isfinite(float(metrics["loss"])), (arch,
                                                         cellmeta.shape_id)


def test_smoke_a1_update_cell():
    spec = registry.get("a1-kg")
    mesh = single_mesh()
    cell = build_cell("a1-kg", "update", mesh, reduced=True)
    from repro.core.store import make_store
    cfg = dataclasses.replace(spec.reduced, n_shards=1)
    args = list(materialize(cell.args, spec))
    args[0] = make_store(cfg)
    with mesh:
        store2 = cell.fn(*args)
    assert jax.tree.structure(store2) == jax.tree.structure(args[0])
