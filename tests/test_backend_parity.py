"""Backend parity: the Pallas read path must be bit-identical to ref.

The kernels stream the same CSR spans / index blocks the jnp reference path
gathers, and their output is scattered back into the reference layout
(core/edges.py, core/index.py) — so every observable of a query must match
exactly between ``backend='ref'`` and ``backend='pallas'`` (interpret mode
on CPU), over random graphs, plans, and MVCC timestamps.  This suite is the
contract that lets the TPU path ship without its own oracle.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.core import edges as edges_mod
from repro.core import index as index_mod
from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.query import executor
from repro.core.query.executor import QueryCaps

CAPS = QueryCaps(frontier=128, expand=512, results=16)
PALLAS = backend_mod.Backend("pallas", interpret=True)


def build_db(seed=0, n_dir=3, n_film=10, n_act=12, mutate=True):
    """Random film KG with both storage tiers and MVCC churn populated."""
    cfg = StoreConfig(n_shards=4, cap_v=128, cap_e=1024, cap_delta=256,
                      cap_idx=256, cap_idx_delta=128, d_f32=2, d_i32=2)
    db = GraphDB(cfg)
    db.vertex_type("director")
    db.vertex_type("actor")
    db.vertex_type("film", f_attrs=("gross",), i_attrs=("year", "genre"))
    db.edge_type("film.director")
    db.edge_type("film.actor")
    rng = np.random.default_rng(seed)
    dirs = [db.create_vertex("director", i) for i in range(n_dir)]
    films = [db.create_vertex("film", 100 + i,
                              {"year": 1990 + int(rng.integers(30)),
                               "genre": int(rng.integers(3))})
             for i in range(n_film)]
    acts = [db.create_vertex("actor", 300 + i) for i in range(n_act)]
    t = db.create_transaction()
    for f in films:
        db.create_edge(dirs[int(rng.integers(n_dir))], f, "film.director",
                       txn=t)
        for a in rng.choice(n_act, size=int(rng.integers(1, 6)),
                            replace=False):
            db.create_edge(f, acts[a], "film.actor", txn=t)
    assert db.commit(t) == "COMMITTED"
    if mutate:
        # push some edges into tier 1, leave fresh ones in the delta log,
        # and delete/re-create vertices so MVCC intervals matter
        db.run_compaction()
        t = db.create_transaction()
        for f in films[: max(1, n_film // 3)]:
            try:
                db.create_edge(f, acts[-1], "film.actor", txn=t)
            except ValueError:
                pass
        db.commit(t)
        victim = 300 + int(rng.integers(n_act))
        g, found = db.lookup_vertex("actor", victim)
        if found:
            db.delete_vertex(g)
        if rng.integers(2):
            db.create_vertex("actor", victim)
    return db


def q_chain(did, genre=None, select="count", direction="out"):
    tgt = {"type": "film",
           "_out_edge": {"type": "film.actor",
                         "_target": {"type": "actor", "select": select}}}
    if genre is not None:
        tgt["filter"] = {"attr": "genre", "op": "==", "value": genre}
    if direction == "out":
        return {"type": "director", "id": did,
                "_out_edge": {"type": "film.director", "_target": tgt}}
    return {"type": "actor", "id": did,
            "_in_edge": {"type": "film.actor",
                         "_target": {"type": "film", "select": select}}}


def q_star(did, aid):
    return {"intersect": [
        {"type": "director", "id": did,
         "_out_edge": {"type": "film.director", "_target": {"type": "film"}}},
        {"type": "actor", "id": aid,
         "_in_edge": {"type": "film.actor", "_target": {"type": "film"}}}],
        "select": "count"}


def assert_query_parity(res, i, solo):
    """Query i of a batched result == its solo per-plan-executor result.

    The shared fused-vs-solo parity oracle (used by test_planner and the
    randomized-IR sweep in test_ir): counts/rows/truncation/fast-fail must
    match bit-for-bit, with batch rows beyond the solo width NULL-padded."""
    assert bool(res.failed_q[i]) == bool(solo.failed), i
    if solo.counts is not None:
        assert res.counts[i] == solo.counts[0], i
    else:
        k = solo.rows_gid.shape[1]
        assert np.array_equal(res.rows_gid[i, :k], solo.rows_gid[0]), i
        assert (res.rows_gid[i, k:] < 0).all(), i
        assert res.truncated[i] == solo.truncated[0], i
        for key, v in solo.rows.items():
            assert np.array_equal(res.rows[key][i, :k], v[0]), (i, key)


def assert_identical(a, b):
    assert a.failed == b.failed
    if a.counts is not None or b.counts is not None:
        assert np.array_equal(a.counts, b.counts)
    if a.rows_gid is not None or b.rows_gid is not None:
        assert np.array_equal(a.rows_gid, b.rows_gid)
        assert np.array_equal(a.truncated, b.truncated)
        assert sorted(a.rows) == sorted(b.rows)
        for k in a.rows:
            assert np.array_equal(a.rows[k], b.rows[k]), k


def run_both(db, queries, caps=CAPS):
    r_ref = db.query(queries, caps=caps, backend="ref")
    r_pal = db.query(queries, caps=caps, backend="pallas")
    assert_identical(r_ref, r_pal)
    return r_ref


def test_chain_count_parity():
    db = build_db(seed=1)
    res = run_both(db, [q_chain(d) for d in range(3)])
    assert not res.failed


def test_chain_filter_select_parity():
    db = build_db(seed=2)
    run_both(db, [q_chain(d, genre=1, select=["key"]) for d in range(3)])


def test_reverse_and_star_parity():
    db = build_db(seed=3)
    run_both(db, [q_chain(300 + a, direction="in") for a in range(4)])
    run_both(db, [q_star(0, 301)])


def test_overflow_parity():
    """Fast-fail must trip identically: cap_tiles is sized so the tile plan
    accepts exactly the expansions the reference path accepts."""
    db = build_db(seed=4)
    tiny = QueryCaps(frontier=16, expand=2, results=4)
    r_ref = db.query([q_chain(0)], caps=tiny, backend="ref")
    r_pal = db.query([q_chain(0)], caps=tiny, backend="pallas")
    assert r_ref.failed and r_pal.failed


def test_compile_cache_no_retrace():
    """Repeated same-shape run_queries batches reuse the compiled program."""
    db = build_db(seed=5, mutate=False)
    queries = [q_chain(d) for d in range(3)]
    db.query(queries, caps=CAPS, backend="ref")         # warm the cache
    h0, m0 = executor.CACHE_STATS["hits"], executor.CACHE_STATS["misses"]
    for _ in range(3):
        db.query(queries, caps=CAPS, backend="ref")
    assert executor.CACHE_STATS["hits"] == h0 + 3
    assert executor.CACHE_STATS["misses"] == m0


def test_backend_resolution(monkeypatch):
    monkeypatch.delenv(backend_mod.ENV_VAR, raising=False)
    assert backend_mod.resolve("ref") == backend_mod.REF
    auto = backend_mod.resolve(None)
    import jax
    if jax.default_backend() == "tpu":
        assert auto == backend_mod.Backend("pallas", interpret=False)
    else:
        assert auto == backend_mod.REF
    monkeypatch.setenv(backend_mod.ENV_VAR, "pallas")
    assert backend_mod.resolve(None).is_pallas
    with pytest.raises(ValueError):
        backend_mod.resolve("cuda")


def test_snapshot_reads_parity_deterministic():
    """Primitive-level parity at historical snapshots (see the hypothesis
    sweep in test_backend_parity_prop.py for the randomized version)."""
    db = build_db(seed=6)
    cfg = db.cfg
    rng = np.random.default_rng(6)
    gids = jnp.asarray(rng.integers(0, cfg.total_v, 32).astype(np.int32))
    qids = jnp.arange(32, dtype=jnp.int32)
    vmask = jnp.asarray(rng.integers(0, 2, 32).astype(bool))
    for ts in (1, db.clock // 2, db.clock):
        read_ts = jnp.int32(ts)
        for direction in ("out", "in"):
            a = edges_mod.expand(db.store, cfg, qids, gids, vmask,
                                 etype=jnp.int32(-1), direction=direction,
                                 read_ts=read_ts, cap_out=512)
            b = edges_mod.expand(db.store, cfg, qids, gids, vmask,
                                 etype=jnp.int32(-1), direction=direction,
                                 read_ts=read_ts, cap_out=512,
                                 backend=PALLAS)
            for x, y in zip(a, b):
                assert np.array_equal(np.asarray(x), np.asarray(y))
