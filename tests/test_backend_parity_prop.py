"""Hypothesis sweep of the ref/pallas backend-parity contract.

Asserts bit-identical ``QueryResult``s (and raw primitive outputs) between
the jnp reference path and the Pallas kernels in interpret mode, over random
knowledge graphs, plan shapes, and MVCC snapshot timestamps.  The
deterministic spot checks live in test_backend_parity.py so they run even
without hypothesis.
"""
import numpy as np
import pytest

import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (CI installs it)")
from hypothesis import given, settings, strategies as st

from repro.core import edges as edges_mod
from repro.core import index as index_mod
from test_backend_parity import PALLAS, build_db, q_chain, q_star, run_both


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), genre=st.sampled_from([None, 0, 1, 2]),
       select=st.sampled_from(["count", ["key"]]))
def test_property_query_parity(seed, genre, select):
    db = build_db(seed=seed, n_dir=3, n_film=8, n_act=10)
    run_both(db, [q_chain(d, genre=genre, select=select) for d in range(3)])


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_star_and_reverse_parity(seed):
    db = build_db(seed=seed, n_dir=3, n_film=8, n_act=10)
    run_both(db, [q_star(0, 300 + (seed % 10))])
    # reverse chains terminate at 'film', which carries attribute columns
    run_both(db, [q_chain(300 + a, direction="in", select=["key", "year"])
                  for a in range(3)])


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), ts_frac=st.floats(0.0, 1.0))
def test_property_snapshot_reads_parity(seed, ts_frac):
    """Primitive-level parity at arbitrary historical snapshots: the MVCC
    visibility mask is evaluated on kernel-streamed timestamp pools."""
    db = build_db(seed=seed, n_dir=2, n_film=6, n_act=8)
    cfg = db.cfg
    read_ts = jnp.int32(max(1, int(db.clock * ts_frac)))
    rng = np.random.default_rng(seed)

    vt = jnp.asarray(rng.integers(0, 3, 16).astype(np.int32))
    keys = jnp.asarray(rng.choice(
        [0, 1, 2, 100, 101, 105, 300, 301, 305, 999], 16).astype(np.int32))
    valid = jnp.asarray(rng.integers(0, 2, 16).astype(bool))
    g_ref, f_ref = index_mod.lookup(db.store, cfg, vt, keys, valid, read_ts)
    g_pal, f_pal = index_mod.lookup(db.store, cfg, vt, keys, valid, read_ts,
                                    backend=PALLAS)
    assert np.array_equal(np.asarray(g_ref), np.asarray(g_pal))
    assert np.array_equal(np.asarray(f_ref), np.asarray(f_pal))

    gids = jnp.asarray(rng.integers(0, cfg.total_v, 32).astype(np.int32))
    qids = jnp.arange(32, dtype=jnp.int32)
    vmask = jnp.asarray(rng.integers(0, 2, 32).astype(bool))
    for direction in ("out", "in"):
        for etype in (-1, 0, 1):
            a = edges_mod.expand(db.store, cfg, qids, gids, vmask,
                                 etype=jnp.int32(etype), direction=direction,
                                 read_ts=read_ts, cap_out=512)
            b = edges_mod.expand(db.store, cfg, qids, gids, vmask,
                                 etype=jnp.int32(etype), direction=direction,
                                 read_ts=read_ts, cap_out=512,
                                 backend=PALLAS)
            for x, y in zip(a, b):
                assert np.array_equal(np.asarray(x), np.asarray(y))
