"""Cluster chaos: the fleet under crashes, dropped frames, stale routes.

The cluster-front invariants (core/README.md) under injected faults:

* **takeover is invisible**: a coordinator crash mid-pagination re-plans
  the token on a new worker at the pinned snapshot, and the remaining
  pages are **bit-identical** to the no-crash stream (MVCC replay, not
  best-effort resume);
* **delivery is at-least-once, effects exactly-once**: dropped request
  *and* dropped response frames are retransmitted under one ``rid`` and
  absorbed by the coordinator's rid cache — one admission, never two;
* **ownership is authoritative**: a stale SLB view routes a continuation
  to the wrong coordinator, which must bounce (``WRONG_OWNER``) rather
  than answer from state it does not own.

Deterministic schedules pin each path; the hypothesis sweep then asserts
the pagination stream is schedule-independent — any mix of drops, stale
routes, and one crash converges to the identical row stream.
"""
import numpy as np
import pytest

from repro.core.faults import FaultInjector
from repro.core.query.executor import QueryCaps
from repro.launch.cluster import A1Frontend

from test_backend_parity import q_chain
from test_serve import SEL, busy_db, full_rows

CAPS = QueryCaps(frontier=128, expand=512, results=8)


def mk_fleet(db, n=3, **kw):
    kw.setdefault("caps", CAPS)
    kw.setdefault("page_size", 2)
    return A1Frontend(db, n, **kw)


def paginate(fe, on_page=None):
    """Drain one paged select; returns the ordered row stream."""
    page, tok = fe.select_paged(SEL)
    got, pages = list(page), 0
    while tok is not None and pages < 60:
        pages += 1
        if on_page is not None:
            on_page(pages, tok)
        page, tok = fe.next_page(tok)
        got.extend(int(x) for x in page)
    assert tok is None
    return [int(x) for x in got]


@pytest.fixture(scope="module")
def chaos_db():
    return busy_db()


@pytest.fixture(scope="module")
def clean_stream(chaos_db):
    """The no-fault pagination stream — the bit-identity oracle."""
    with mk_fleet(chaos_db) as fe:
        return paginate(fe)


# ---------------------------------------------------------------------------
# deterministic schedules
# ---------------------------------------------------------------------------

def test_takeover_mid_pagination_is_bit_identical(chaos_db, clean_stream):
    """Kill the owning coordinator after the first page: the takeover
    replays at the pinned snapshot and the FULL stream — including every
    page served after the crash — matches the no-crash stream exactly."""
    with mk_fleet(chaos_db) as fe:
        killed = []

        def crash_once(pages, tok):
            if pages == 1:
                fe.kill_worker(fe._tokmeta[tok]["cid"])
                killed.append(fe._tokmeta[tok]["cid"])

        got = paginate(fe, on_page=crash_once)
        assert got == clean_stream                  # ordered, bit-identical
        assert fe.stats["takeovers"] == 1
        assert fe.stats["worker_kills"] == 1
        assert not fe.db.active_query_ts            # pin released at the end
        assert sorted(got) == full_rows(fe.db, SEL)


def test_crash_with_inflight_queries_rescues_them(chaos_db):
    """Queries queued on the dead coordinator re-route with their
    remaining budget; every admitted id still terminates in one result."""
    with mk_fleet(chaos_db, read_batch=64) as fe:    # stays queued
        pubs = [fe.submit_query(q_chain(i % 3), budget_ms=1e6)
                for i in range(6)]
        owners = {fe._qidmeta[p]["cid"] for p in pubs}
        victim = sorted(owners)[0]
        n_victim = sum(1 for p in pubs if fe._qidmeta[p]["cid"] == victim)
        assert n_victim >= 1
        fe.kill_worker(victim)
        fe.flush()
        for i, p in enumerate(pubs):
            row = fe.query_result(p)
            solo = fe.db.query([q_chain(i % 3)], caps=CAPS)
            assert row is not None and row["status"] == "OK"
            assert row["count"] == int(solo.counts[0])
        assert fe.stats["rescued_queries"] == n_victim


def test_crash_site_kills_route_target_and_fails_over(chaos_db):
    """``cluster.worker.crash``: the target dies as the frame leaves; the
    SLB fails over to an alive coordinator in the same submit."""
    with mk_fleet(chaos_db) as fe:
        fe.db.faults = FaultInjector(0).inject(
            "cluster.worker.crash", action="race", times=(0,))
        pub = fe.submit_query(q_chain(0), budget_ms=1e6)
        fe.flush()
        row = fe.query_result(pub)
        solo = fe.db.query([q_chain(0)], caps=CAPS)
        assert row["status"] == "OK"
        assert row["count"] == int(solo.counts[0])
        assert fe.stats["worker_kills"] == 1
        assert len(fe._alive()) == 2


@pytest.mark.parametrize("drop_visit", [0, 1])
def test_dropped_frames_retransmit_idempotently(chaos_db, drop_visit):
    """``transport.drop`` on the request frame (visit 0: handler never
    ran) and on the response frame (visit 1: handler DID run — duplicate
    delivery) both end in exactly one admission under one ``rid``."""
    with mk_fleet(chaos_db, n=1, read_batch=1) as fe:
        fe.db.faults = FaultInjector(5).inject(
            "transport.drop", action="race", times=(drop_visit,))
        pub = fe.submit_query(q_chain(0), budget_ms=1e6)
        assert fe.stats["retransmits"] == 1
        row = fe.query_result(pub)
        solo = fe.db.query([q_chain(0)], caps=CAPS)
        assert row["status"] == "OK"
        assert row["count"] == int(solo.counts[0])
        st = fe.cluster_stats()
        assert st["workers"][0]["admitted"] == 1     # exactly-once effect
        assert st["frontend"]["frames_dropped"] == 1


def test_stale_route_storm_bounces_every_frame_to_the_owner(chaos_db,
                                                            clean_stream):
    """Every continuation frame first lands on a WRONG coordinator (stale
    SLB view, prob=1).  The receiver bounces by ownership stamp and the
    re-route serves the identical stream — the wrong worker never answers
    from state it does not own."""
    with mk_fleet(chaos_db) as fe:
        fe.db.faults = FaultInjector(3).inject(
            "cluster.route.stale", action="race", prob=1.0)
        got = paginate(fe)
        assert got == clean_stream
        assert fe.stats["stale_routes"] == fe.stats["continuation_routes"]
        assert fe.stats["stale_routes"] >= 2
        assert fe.stats["takeovers"] == 0


# ---------------------------------------------------------------------------
# any-schedule sweep
# ---------------------------------------------------------------------------

try:        # the deterministic schedules above run without hypothesis
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # CI installs it; local runs skip
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    seeds = st.integers(0, 2**16)
    crashes = st.integers(0, 6)
    drops = st.floats(0.0, 0.25)
    stales = st.floats(0.0, 1.0)
    checks = [HealthCheck.too_slow]
else:                                     # keep the decorators importable
    def given(**kw):
        return lambda fn: fn

    def settings(**kw):
        return lambda fn: fn
    seeds = crashes = drops = stales = checks = None


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="any-schedule sweep needs hypothesis (CI has it)")
@settings(max_examples=10, deadline=None, suppress_health_check=checks)
@given(seed=seeds, crash_after=crashes, drop_prob=drops,
       stale_prob=stales)
def test_any_schedule_pagination_converges(chaos_db, clean_stream, seed,
                                           crash_after, drop_prob,
                                           stale_prob):
    """Any seeded mix of frame drops, stale routes, and one mid-stream
    coordinator crash yields the SAME ordered row stream as the clean
    run.  ``max_fires`` bounds the drop storm so retransmits always
    converge (an unbounded adversary could drop every frame forever —
    that is an availability loss, not a correctness one)."""
    with mk_fleet(chaos_db) as fe:
        fe.db.faults = (
            FaultInjector(seed)
            .inject("transport.drop", action="race", prob=drop_prob,
                    max_fires=6)
            .inject("cluster.route.stale", action="race", prob=stale_prob))

        def maybe_crash(pages, tok):
            if pages == crash_after:
                fe.kill_worker(fe._tokmeta[tok]["cid"])

        got = paginate(fe, on_page=maybe_crash)
        assert got == clean_stream
