"""Chaos suite: background MVCC compaction under concurrent traffic (§2.2).

The contract being attacked: a pinned snapshot read returns the same rows
before, during, and after a background compaction cycle — and always equals
an *uncompacted replay* (a second DB that executed the identical write
sequence and never compacted).  Structural mutations raced against an
in-flight shadow build must force a rebuild, never a wrong handoff.

The hypothesis sweep at the bottom drives random interleavings of write
waves, task-queue pumps (build / handoff quanta), snapshot pins, and edge
deletes — the serializability oracle for the two-phase handoff.
"""
import numpy as np
import pytest

from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.tasks import TaskQueue, background_compaction_task
from repro.core.writes import CreateEdge, CreateVertex, DeleteEdge


CFG = StoreConfig(n_shards=2, cap_v=128, cap_e=1024, cap_delta=64,
                  cap_idx=256, cap_idx_delta=128, d_f32=1, d_i32=1)


def chaos_db(*, tasks: bool):
    db = GraphDB(CFG)
    db.vertex_type("hub")
    db.vertex_type("spoke")
    db.edge_type("link")
    if tasks:
        db.task_queue = TaskQueue(db)
    return db


def twin_dbs():
    """(db under test with a task queue, uncompacted replay twin)."""
    return chaos_db(tasks=True), chaos_db(tasks=False)


def both(dbs, ops):
    outs = [db.write(list(ops)) for db in dbs]
    assert not any(o.failed for o in outs)
    assert len({db.clock for db in dbs}) == 1       # twins stay clock-locked
    return outs[0].gids


def edges_at(db, hub, ts=None):
    return sorted(db.get_edges(hub, read_ts=ts))


def test_pinned_reads_stable_across_bg_cycle():
    db, ref = twin_dbs()
    dbs = (db, ref)
    hub = both(dbs, [CreateVertex("hub", 0)])[0]
    spokes = both(dbs, [CreateVertex("spoke", 1 + i) for i in range(40)])
    # hub's out-log crosses the 0.5 watermark (40 of cap_delta=64) -> the
    # wave schedules the background task instead of compacting inline
    both(dbs, [CreateEdge(hub, s, "link", check=False) for s in spokes])
    assert db.task_queue.pending() == 1 and db._bg_compaction_pending
    assert ref.stats["compactions"] == 0

    ts0 = db.snapshot_ts()
    db.active_query_ts.append(ts0)                  # reader pins the snapshot
    read0 = edges_at(db, hub, ts0)
    assert read0 == edges_at(ref, hub, ts0)

    db.task_queue.pump(1)                           # phase 1: shadow build
    assert edges_at(db, hub, ts0) == read0          # during: live store intact
    extra = both(dbs, [CreateVertex("spoke", 100 + i) for i in range(5)])
    both(dbs, [CreateEdge(hub, s, "link", check=False) for s in extra])
    assert edges_at(db, hub, ts0) == read0          # tail doesn't leak into ts0

    db.task_queue.pump(1)                           # phase 2: handoff + replay
    assert db.stats["bg_compactions"] >= 1
    assert db.stats["compactions"] == 0             # never went inline
    assert int(db.dl_count.max()) == 5              # only the raced tail left
    assert edges_at(db, hub, ts0) == read0 == edges_at(ref, hub, ts0)
    assert edges_at(db, hub) == edges_at(ref, hub)  # current snapshot too
    db.active_query_ts.remove(ts0)
    assert edges_at(db, hub, ts0) == read0          # §2.2: ts0 <= build gc_ts


def test_raced_delete_forces_rebuild():
    db, ref = twin_dbs()
    dbs = (db, ref)
    hub = both(dbs, [CreateVertex("hub", 0)])[0]
    spokes = both(dbs, [CreateVertex("spoke", 1 + i) for i in range(40)])
    both(dbs, [CreateEdge(hub, s, "link", check=False) for s in spokes])
    db.task_queue.pump(1)                           # build shadow
    # structural race: a delete tombstones a CSR/log position the shadow
    # already folded away -> the epoch guard must reject the handoff
    both(dbs, [DeleteEdge(hub, spokes[0], "link")])
    db.task_queue.pump(1)                           # handoff attempt -> rebuild
    assert db.stats["compaction_rebuilds"] == 1
    assert db.task_queue.pending() == 1             # rescheduled itself
    db.task_queue.pump(2)                           # rebuild + clean handoff
    assert db.stats["bg_compactions"] == 1
    assert not db._bg_compaction_pending
    assert edges_at(db, hub) == edges_at(ref, hub)
    assert len(edges_at(db, hub)) == 39


def test_rebuild_cap_falls_back_inline():
    db = chaos_db(tasks=True)
    hub = db.write([CreateVertex("hub", 0)]).gids[0]
    spokes = db.write([CreateVertex("spoke", 1 + i)
                       for i in range(10)]).gids
    db.write([CreateEdge(hub, s, "link", check=False) for s in spokes])
    expect = edges_at(db, hub)
    tq = db.task_queue
    db._bg_compaction_pending = True
    tq.enqueue(background_compaction_task(kinds=("edges",), max_rebuilds=1))
    tq.pump(1)                                      # build
    db.write([DeleteEdge(hub, spokes[0], "link")])  # race it
    tq.pump(1)                                      # handoff fails -> at cap
    # progress guarantee: fell back to stop-the-world inline compaction
    assert db.stats["compactions"] == 1
    assert not db._bg_compaction_pending and tq.pending() == 0
    assert int(db.dl_count.max()) == 0
    assert edges_at(db, hub) == [e for e in expect if e[0] != spokes[0]]


def test_index_compaction_handoff_with_tail():
    db, ref = twin_dbs()
    dbs = (db, ref)
    db.compaction_watermark = 2.0                   # keep edges out of the way
    ref_gids = both(dbs, [CreateVertex("spoke", i) for i in range(30)])
    handle = db.begin_compaction(("index",))
    late = both(dbs, [CreateVertex("spoke", 100 + i) for i in range(4)])
    assert db.try_handoff(handle) == {"index": True}
    assert int(db.xd_count.sum()) == 4              # only the late tail
    for i, g in enumerate(ref_gids):
        got, found = db.lookup_vertex("spoke", i)
        assert found and got == g
    for i, g in enumerate(late):
        got, found = db.lookup_vertex("spoke", 100 + i)
        assert found and got == g
    _, found = db.lookup_vertex("spoke", 999)
    assert not found


def test_raced_vertex_delete_invalidates_index_shadow():
    db = chaos_db(tasks=True)
    gids = db.write([CreateVertex("spoke", i) for i in range(10)]).gids
    handle = db.begin_compaction(("index",))
    from repro.core.writes import DeleteVertex
    db.write([DeleteVertex(gids[0])])               # bumps the delete_v epoch
    assert db.try_handoff(handle) == {"index": False}
    _, found = db.lookup_vertex("spoke", 0)
    assert not found                                # live index untouched


# ---------------------------------------------------------------------------
# hypothesis interleaving sweep
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # CI installs it; local runs skip
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    actions = st.lists(
        st.sampled_from(["write", "delete", "pump", "pin"]),
        min_size=4, max_size=24)
else:                                     # keep the decorators importable
    def given(**kw):
        return lambda fn: fn

    def settings(**kw):
        return lambda fn: fn
    actions = None


def _run_interleaving(acts):
    db, ref = twin_dbs()
    dbs = (db, ref)
    db.compaction_watermark = 0.05                  # trigger early and often
    hub = both(dbs, [CreateVertex("hub", 0)])[0]
    nkey, alive, pins = 1, [], []
    for act in acts:
        if act == "write":
            s = both(dbs, [CreateVertex("spoke", nkey)])[0]
            both(dbs, [CreateEdge(hub, s, "link", check=False)])
            alive.append(s)
            nkey += 1
        elif act == "delete" and alive:
            both(dbs, [DeleteEdge(hub, alive.pop(0), "link")])
        elif act == "pump":
            db.task_queue.pump(1)
        elif act == "pin":
            ts = db.snapshot_ts()
            assert ts == ref.snapshot_ts()
            db.active_query_ts.append(ts)
            pins.append((ts, edges_at(ref, hub, ts)))
        # invariant after every step: current snapshots agree
        assert edges_at(db, hub) == edges_at(ref, hub)
    db.task_queue.drain()
    assert edges_at(db, hub) == edges_at(ref, hub)
    # every pinned snapshot still reads exactly the uncompacted replay
    for ts, expect in pins:
        assert edges_at(db, hub, ts) == expect
        assert edges_at(ref, hub, ts) == expect


# hand-picked adversarial interleavings: pins straddling both compaction
# phases, deletes racing an in-flight shadow, back-to-back cycles
FIXED_SCHEDULES = [
    ["write"] * 4 + ["pin", "pump", "write", "pin", "pump", "pin"],
    ["write"] * 5 + ["pump", "delete", "pump", "pump", "pin", "write"],
    ["write", "pin", "write", "pump", "delete", "pin", "pump",
     "write", "pump", "pump", "pin"],
    ["write"] * 6 + ["pin", "pump", "delete", "delete", "pump",
                     "pump", "pump", "write", "pin"],
]


@pytest.mark.parametrize("acts", FIXED_SCHEDULES)
def test_interleaving_fixed_schedules(acts):
    _run_interleaving(acts)


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="interleaving sweep needs hypothesis (CI has it)")
@settings(max_examples=10, deadline=None)
@given(acts=actions)
def test_interleaved_waves_pumps_and_pins(acts):
    _run_interleaving(acts)
