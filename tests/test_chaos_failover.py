"""Failover chaos: leases, epochs, and fleet-visible writes under crashes.

The membership/failover invariants (core/README.md) under injected faults:

* **acked commits are durable**: a write acknowledged ``COMMITTED``
  stays readable after the primary that committed it is killed — the
  election promotes a caught-up replica and reads keep answering;
* **exactly once**: a primary that crashes *after* commit but *before*
  the ack (``primary.crash.midwave``) never double-applies — the
  retransmit to the promoted primary resolves by rid to the ORIGINAL
  result, and the store holds the write exactly once;
* **no split-brain**: a deposed primary that missed its demote frame
  (partitioned zombie) is stopped at the commit-time fence — its staged
  wave answers ``ABORTED_FAILOVER`` and the store is untouched; frames
  stamped with an old configuration epoch bounce ``STALE_EPOCH``;
* **no silent drops**: every admitted write terminates with a definite
  answer — ``COMMITTED`` or a retryable abort, never a lost promise.

Deterministic schedules pin each path (kill, mid-wave crash, zombie
fence, lease expiry on a fake clock, forced primary expiry); the
hypothesis sweep then runs seeded mixes of writes, crashes, and
heartbeat loss and asserts the durability/exactly-once/no-split-brain
trio on every schedule.
"""
import numpy as np
import pytest

from repro.core.faults import FaultInjector
from repro.core.query.executor import QueryCaps
from repro.core.writes import CreateEdge
from repro.launch.cluster import A1Frontend

from test_backend_parity import q_chain
from test_serve import SEL, busy_db, full_rows

CAPS = QueryCaps(frontier=128, expand=512, results=64)
COUNT_DOC = q_chain(323, direction="in")          # films of actor 323


def mk_fleet(db, n=3, **kw):
    kw.setdefault("caps", CAPS)
    return A1Frontend(db, n, **kw)


def unlinked_films(db, actor_key=323):
    """(actor_gid, [film gids not yet linked to the actor]) — each chaos
    write links one more film, so edge creation never collides."""
    a_gid, ok = db.lookup_vertex("actor", actor_key)
    assert ok
    linked = set(full_rows(db, SEL))
    films = []
    for k in range(100, 120):
        g, found = db.lookup_vertex("film", k)
        if found and g not in linked:
            films.append(int(g))
    assert films, "busy_db should leave some films unlinked"
    return int(a_gid), films


def fleet_count(fe, doc=COUNT_DOC, tries=200):
    """Count query through the SLB (counts ignore the results cap)."""
    pub = fe.submit_query(doc, budget_ms=1e6)
    fe.flush()
    for _ in range(tries):
        r = fe.query_result(pub)
        if r is not None:
            assert not r.get("failed"), r
            return int(r["count"])
        fe.flush()
    raise AssertionError("query never completed")


def do_write(fe, ops, tries=200):
    """Submit one write and poll it to a terminal answer."""
    pub = fe.submit_write(ops)
    for _ in range(tries):
        r = fe.write_result(pub)
        if r is not None:
            return r
        fe.flush()
    raise AssertionError("write never terminated")


@pytest.fixture(scope="module")
def chaos_db():
    return busy_db()


# ---------------------------------------------------------------------------
# deterministic schedules
# ---------------------------------------------------------------------------

def test_primary_kill_preserves_acked_commit(chaos_db):
    """Durability: an acked commit survives the death of the primary that
    committed it, and the promoted replica keeps serving writes."""
    with mk_fleet(chaos_db, write_batch=1) as fe:
        a_gid, films = unlinked_films(fe.db)
        base = fleet_count(fe)
        r = do_write(fe, [CreateEdge(films[0], a_gid, "film.actor")])
        assert r["status"] == "COMMITTED"

        fe.kill_worker(0)                         # the write-primary
        view = fe.membership.view()
        assert view["leases"][0]["state"] == "evicted"
        assert view["epoch"] == 2 and view["primary"] == 1
        assert fe.stats["failovers"] == 1
        assert fleet_count(fe) == base + 1        # the ack was not a lie

        r2 = do_write(fe, [CreateEdge(films[1], a_gid, "film.actor")])
        assert r2["status"] == "COMMITTED"        # writes resumed
        assert fleet_count(fe) == base + 2
        # exactly the elected primary holds the role in the routable fleet
        roles = [c for c in fe._alive()
                 if fe.workers[c].coord.role == "primary"]
        assert roles == [fe.membership.primary]


def test_midwave_crash_commits_exactly_once(chaos_db):
    """``primary.crash.midwave``: the wave committed, the primary died
    before storing a single result.  The retransmit to the promoted
    primary must resolve by rid to the ORIGINAL commit — once."""
    with mk_fleet(chaos_db, write_batch=1) as fe:
        a_gid, films = unlinked_films(fe.db)
        base = fleet_count(fe)
        fe.db.faults = FaultInjector(7).inject(
            "primary.crash.midwave", times=(0,))

        r = do_write(fe, [CreateEdge(films[0], a_gid, "film.actor")])
        assert fe.db.faults.fired, "the crash schedule never fired"
        assert r["status"] == "COMMITTED"         # original result, via rid
        assert fe.stats["failovers"] == 1
        assert fe.membership.epoch == 2
        assert not fe.workers[0].alive            # it really crashed
        assert fleet_count(fe) == base + 1        # once — never twice


def test_deposed_zombie_is_fenced_and_client_gets_retry_hint(chaos_db):
    """A primary partitioned from the CM keeps running with stale role
    state.  Its staged wave must be refused at the commit-time fence
    (store untouched), and the stranded client write resolves to
    ``ABORTED_FAILOVER`` with a retry hint — the retry then commits on
    the new primary."""
    with mk_fleet(chaos_db) as fe:                # default batch: wave open
        a_gid, films = unlinked_films(fe.db)
        base = fleet_count(fe)
        pub = fe.submit_write([CreateEdge(films[0], a_gid, "film.actor")])
        assert fe.write_result(pub) is None       # staged, wave still open

        # the CM declares worker 0 gone; worker 0 itself never hears it
        fe._handle_events(fe.membership.evict(0, reason="partition"))
        assert fe.membership.primary == 1 and fe.membership.epoch == 2

        zombie = fe.workers[0].coord
        assert zombie.role == "primary"           # missed its demote frame
        n = zombie.server.flush_writes()          # tries to commit anyway
        assert n == 1
        assert zombie.server.stats["write_fenced"] == 1
        assert fleet_count(fe) == base            # store untouched

        r = fe.write_result(pub)                  # resolved at failover
        assert r["status"] == "ABORTED_FAILOVER"
        assert r["retry_after_ms"] > 0
        r2 = do_write(fe, [CreateEdge(films[0], a_gid, "film.actor")])
        assert r2["status"] == "COMMITTED"
        assert fleet_count(fe) == base + 1


def test_lease_expiry_suspects_then_evicts_on_fake_clock(chaos_db):
    """``membership.heartbeat.drop`` starves worker 0's renewals; the
    fake clock walks its lease through alive -> suspect -> evicted and
    the election completes without a single real-time sleep."""
    t = {"now": 0.0}
    with mk_fleet(chaos_db, write_batch=1, lease_s=2.0,
                  membership_clock=lambda: t["now"]) as fe:
        a_gid, films = unlinked_films(fe.db)
        # renewals visit admitted members in cid order: worker 0 is
        # visits 0, 3, 6 across three pumps of a 3-worker fleet
        fe.db.faults = FaultInjector(3).inject(
            "membership.heartbeat.drop", action="race", times=(0, 3, 6))

        fe.pump()                                 # renewal lost, not late
        assert fe.membership.view()["leases"][0]["state"] == "alive"
        t["now"] = 2.5
        fe.pump()                                 # lease expired -> suspect
        assert fe.membership.view()["leases"][0]["state"] == "suspect"
        assert 0 not in fe._alive()               # no fresh traffic
        assert fe.membership.primary == 0         # not yet deposed
        t["now"] = 4.6
        fe.pump()                                 # grace expired -> evict
        view = fe.membership.view()
        assert view["leases"][0]["state"] == "evicted"
        assert view["primary"] == 1 and view["epoch"] == 2
        assert fe.stats["failovers"] == 1

        r = do_write(fe, [CreateEdge(films[0], a_gid, "film.actor")])
        assert r["status"] == "COMMITTED"


def test_forced_primary_expiry_and_stale_epoch_fence(chaos_db):
    """``membership.lease.expire`` force-expires the primary straight
    through suspect: one tick completes the whole failover.  A frame
    stamped with the old epoch then bounces ``STALE_EPOCH``."""
    t = {"now": 0.0}
    with mk_fleet(chaos_db, write_batch=1,
                  membership_clock=lambda: t["now"]) as fe:
        a_gid, films = unlinked_films(fe.db)
        fe.db.faults = FaultInjector(5).inject(
            "membership.lease.expire", action="race", times=(0,))

        fe.pump()                                 # one tick: evict + elect
        view = fe.membership.view()
        assert view["leases"][0]["state"] == "evicted"
        assert view["primary"] == 1 and view["epoch"] == 2
        assert fe.stats["failovers"] == 1

        # fencing: the promoted coordinator bounces old-config frames
        resp = fe.workers[1].request(
            {"op": "stats", "rid": "stale-probe", "epoch": 1})
        assert resp["status"] == "STALE_EPOCH" and resp["epoch"] == 2
        # ... and the frontend's restamp-and-retry makes that invisible
        resp = fe._rpc(1, {"op": "stats"})
        assert resp["status"] == "OK" and resp["stats"]["role"] == "primary"

        r = do_write(fe, [CreateEdge(films[0], a_gid, "film.actor")])
        assert r["status"] == "COMMITTED"


# ---------------------------------------------------------------------------
# any-schedule sweep
# ---------------------------------------------------------------------------

try:        # the deterministic schedules above run without hypothesis
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # CI installs it; local runs skip
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    seeds = st.integers(0, 2**16)
    checks = [HealthCheck.too_slow]
else:                                     # keep the decorators importable
    def given(**kw):
        return lambda fn: fn

    def settings(**kw):
        return lambda fn: fn
    seeds = checks = None


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="any-schedule sweep needs hypothesis (CI has it)")
@settings(max_examples=8, deadline=None, suppress_health_check=checks)
@given(seed=seeds)
def test_any_schedule_failover_invariants(chaos_db, seed):
    """Any seeded mix of writes, mid-wave primary crashes, worker kills,
    and lost heartbeats upholds the trio: the store holds exactly the
    COMMITTED writes (durability + exactly once), the routable fleet has
    at most the elected primary in the primary role (no split-brain),
    and every submitted write terminated with a definite answer."""
    rng = np.random.default_rng(seed)
    # frozen membership clock: wall-clock time (slow jax dispatches on a
    # loaded CI host) must not add lease expiries the schedule didn't ask
    # for — the lease state machine itself is pinned by the fake-clock
    # deterministic tests above; this sweep owns the write invariants
    with mk_fleet(chaos_db, write_batch=1,
                  membership_clock=lambda: 0.0) as fe:
        inj = FaultInjector(int(seed))
        fe.db.faults = inj
        a_gid, films = unlinked_films(fe.db)
        base = fleet_count(fe)
        outcomes, fi = [], 0
        for _ in range(10):
            action = int(rng.integers(0, 4))
            if action == 0 and fi < len(films):
                outcomes.append(do_write(
                    fe, [CreateEdge(films[fi], a_gid, "film.actor")]))
                fi += 1
            elif (action == 1 and fi < len(films)
                    and len(fe._alive()) > 1):
                # crash the primary right after this wave commits
                inj.inject("primary.crash.midwave",
                           times=(inj.visits("primary.crash.midwave"),))
                outcomes.append(do_write(
                    fe, [CreateEdge(films[fi], a_gid, "film.actor")]))
                fi += 1
            elif action == 2 and len(fe._alive()) > 1:
                fe.kill_worker(int(rng.choice(fe._alive())))
            else:
                inj.inject("membership.heartbeat.drop", action="race",
                           times=(inj.visits("membership.heartbeat.drop"),))
                fe.pump()
        fe.flush()

        statuses = [r["status"] for r in outcomes]
        assert all(s in ("COMMITTED", "ABORTED", "ABORTED_FAILOVER")
                   for s in statuses), statuses
        committed = statuses.count("COMMITTED")
        # durability + exactly once: an under-count is a lost ack, an
        # over-count is a double-apply — both are failures
        assert fleet_count(fe) == base + committed
        # no split-brain among routable workers
        roles = [c for c in fe._alive()
                 if fe.workers[c].coord.role == "primary"]
        p = fe.membership.primary
        assert roles == ([p] if p in fe._alive() else [])
        # every configuration change is fenced by an epoch bump
        evicted = [c for c, m in fe.membership.members.items()
                   if m.state == "evicted"]
        assert fe.membership.epoch == 1 + len(evicted)
