"""Chaos sweep of the replication -> recovery pipeline (§4).

The ObjectStore write pipeline is cut at arbitrary points mid-transaction
(via a counting ``upsert`` wrapper and ``fail_next``), then both recovery
modes must uphold the paper's §4 contract under *any* cut:

  * **consistent** recovery equals replaying exactly the transactions with
    ``ts <= t_R`` against a sequential model — partially shipped
    transactions are excluded *wholesale*, never half-applied;
  * **best-effort** recovery never leaves dangling edges (an edge whose
    endpoint did not survive the cut is repaired away), whatever got cut;
  * once the sweeper catches up, both modes converge on the full model.

Deterministic sweeps run everywhere; the hypothesis suite (random op
sequences x random cut points) runs where hypothesis is installed — CI
pins it with ``--hypothesis-profile=ci`` for reproducibility.
"""
import numpy as np
import pytest

from repro.core.addressing import StoreConfig
from repro.core.graphdb import GraphDB
from repro.core.recovery import best_effort_recover, consistent_recover
from repro.core.replication import ObjectStore, ReplicationLog

KEYS = list(range(6))


def make_db():
    cfg = StoreConfig(n_shards=2, cap_v=64, cap_e=512, cap_delta=128,
                      cap_idx=128, cap_idx_delta=64, d_f32=1, d_i32=1)
    store = ObjectStore()
    log = ReplicationLog(store)
    db = GraphDB(cfg, replication_log=log)
    log.db = db
    db.vertex_type("node", f_attrs=("w",))
    db.edge_type("link")
    return db, log, store, cfg


def cut_pipeline(store: ObjectStore, after: int):
    """Fail every ObjectStore write past the ``after``-th (disaster at a
    byte offset, not a transaction boundary).  Returns a restore()."""
    orig = store.upsert
    n = {"i": 0}

    def failing(table, key, value, ts):
        n["i"] += 1
        if n["i"] > after:
            raise IOError("chaos: pipeline cut")
        orig(table, key, value, ts)

    store.upsert = failing
    return lambda: setattr(store, "upsert", orig)


# ---------------------------------------------------------------------------
# the shared chaos driver (deterministic + hypothesis entry points)
# ---------------------------------------------------------------------------

def apply_ops(db, ops):
    """Run an op sequence through the transactional path; returns the
    committed history [(ts, op)] for the sequential model."""
    history = []
    gid_of = {}
    live = set()
    edges = set()
    for op, a, b in ops:
        try:
            if op == "create" and a not in live:
                gid_of[a] = db.create_vertex("node", a, {"w": float(b)})
                live.add(a)
            elif op == "update" and a in live:
                db.update_vertex(gid_of[a], "node", {"w": float(b)})
            elif op == "delete" and a in live:
                db.delete_vertex(gid_of[a])
                live.discard(a)
                edges = {e for e in edges if a not in e}
            elif op == "edge" and a in live and int(b) in live \
                    and a != int(b) and (a, int(b)) not in edges:
                db.create_edge(gid_of[a], gid_of[int(b)], "link")
                edges.add((a, int(b)))
            else:
                continue
        except (ValueError, IOError):
            continue
        history.append((db.clock, (op, a, b)))
    return history


def model_at(history, t_r):
    """Sequential replay of transactions with ts <= t_R."""
    v, edges = {}, set()
    for ts, (op, a, b) in history:
        if ts > t_r:
            continue
        if op == "create":
            v[a] = float(b)
        elif op == "update" and a in v:
            v[a] = float(b)
        elif op == "delete" and a in v:
            del v[a]
            edges = {e for e in edges if a not in e}
        elif op == "edge":
            edges.add((a, int(b)))
    return v, edges


def recovered_state(r):
    """(vertices key->w, edges key-pair set) of a recovered GraphDB."""
    v, gid2key = {}, {}
    for k in KEYS:
        got = r.get_vertex("node", k)
        if got is not None:
            v[k] = round(float(got["w"]), 4)
            gid2key[got["gid"]] = k
    edges = set()
    for k, g in [(k, r.get_vertex("node", k)["gid"]) for k in v]:
        for nbr, _ in r.get_edges(g):
            assert nbr in gid2key, f"dangling edge {k}->{nbr}"
            edges.add((k, gid2key[nbr]))
    return v, edges


def check_invariants(ops, cut_after: int, resume: bool):
    db, log, store, cfg = make_db()
    restore = cut_pipeline(store, cut_after)
    history = apply_ops(db, ops)
    restore()
    if resume:
        log.sweep()          # the async sweeper catches up before disaster

    # --- best-effort: internally consistent, no dangling edges -------------
    be = best_effort_recover(store, db, cfg)
    recovered_state(be)      # asserts every edge endpoint exists

    # --- consistent: the t_R prefix, whole transactions only ---------------
    t_r = store.get_meta("g.t_R", 0)
    want_v, want_e = model_at(history, t_r)
    cr = consistent_recover(store, db, cfg)
    got_v, got_e = recovered_state(cr)
    assert got_v.keys() == want_v.keys(), (t_r, got_v, want_v)
    for k in want_v:
        assert abs(got_v[k] - want_v[k]) < 1e-3, (k, got_v[k], want_v[k])
    assert got_e == want_e, (t_r, got_e, want_e)

    if resume:
        # sweeper drained: both modes converge on the full history
        full_v, full_e = model_at(history, db.clock)
        assert got_v.keys() == full_v.keys()
        be_v, be_e = recovered_state(be)
        assert be_v.keys() == full_v.keys() and be_e == full_e


# ---------------------------------------------------------------------------
# deterministic sweeps (no hypothesis required)
# ---------------------------------------------------------------------------

OPS_SCRIPT = [
    ("create", 0, 1.5), ("create", 1, 2.5), ("edge", 0, 1),
    ("create", 2, 0.5), ("edge", 2, 0), ("update", 1, 9.0),
    ("create", 3, 4.0), ("edge", 3, 1), ("delete", 0, 0.0),
    ("create", 4, 7.0), ("edge", 4, 2), ("update", 4, 8.0),
]


@pytest.mark.parametrize("cut_after", [0, 1, 3, 5, 8, 13, 21, 34, 55, 99])
def test_deterministic_cut_sweep(cut_after):
    check_invariants(OPS_SCRIPT, cut_after, resume=False)


@pytest.mark.parametrize("cut_after", [2, 7, 19])
def test_sweeper_resume_converges(cut_after):
    check_invariants(OPS_SCRIPT, cut_after, resume=True)


def test_mid_transaction_cut_is_wholesale():
    """One multi-entry transaction (A, B, edge) cut at every write offset:
    consistent recovery returns all of it or none of it."""
    for cut in range(8):
        db, log, store, cfg = make_db()
        restore = cut_pipeline(store, cut)
        t = db.create_transaction()
        a = db.create_vertex("node", 0, {"w": 1.0}, txn=t)
        b = db.create_vertex("node", 1, {"w": 2.0}, txn=t)
        t.create_e.append((a, b, 0))
        assert db.commit(t) == "COMMITTED"   # commit != durable (§4)
        restore()
        cr = consistent_recover(store, db, cfg)
        va, vb = cr.get_vertex("node", 0), cr.get_vertex("node", 1)
        if cut >= 6:      # 3 entries x 2 writes each all shipped
            assert va is not None and vb is not None
            assert cr.get_edges(va["gid"]) == [(vb["gid"], 0)]
        else:             # any earlier cut excludes the whole transaction
            assert va is None and vb is None, cut
        # best-effort may keep a prefix, but never a dangling edge
        be = best_effort_recover(store, db, cfg)
        ba = be.get_vertex("node", 0)
        if ba is not None and be.get_edges(ba["gid"]):
            assert be.get_vertex("node", 1) is not None


def test_fail_next_sweeper_backlog():
    """fail_next cuts the synchronous ship; the log holds the backlog and
    t_R stays put until the sweeper drains it."""
    db, log, store, cfg = make_db()
    db.create_vertex("node", 0, {"w": 1.0})
    t_r0 = store.get_meta("g.t_R", 0)
    store.fail_next(1)
    db.create_vertex("node", 1, {"w": 2.0})
    assert log.lag() > 0
    assert store.get_meta("g.t_R", 0) == t_r0
    cr = consistent_recover(store, db, cfg)
    assert cr.get_vertex("node", 1) is None       # not durable yet
    log.sweep()
    assert log.lag() == 0
    cr = consistent_recover(store, db, cfg)
    assert cr.get_vertex("node", 1) is not None   # durable after drain


def test_ship_drop_round_never_advances_watermarks():
    """``replication.ship.drop`` loses a whole ship round: the durable
    ``t_R`` must stay exactly where the last *successful* batch left it
    (a watermark ahead of the rows would turn consistent recovery into a
    lie), and the next round drains the backlog."""
    from repro.core.faults import FaultInjector
    db, log, store, cfg = make_db()
    db.create_vertex("node", 0, {"w": 1.0})       # durable baseline
    t_r0 = store.get_meta("g.t_R", 0)
    db.faults = FaultInjector(1).inject(
        "replication.ship.drop", action="race", times=(0,))
    db.create_vertex("node", 1, {"w": 2.0})       # this ship round is lost
    assert log.lag() > 0
    assert store.get_meta("g.t_R", 0) == t_r0     # never ahead of the rows
    cr = consistent_recover(store, db, cfg)
    assert cr.get_vertex("node", 1) is None
    log.sweep()                                   # retry round ships
    assert log.lag() == 0
    assert store.get_meta("g.t_R", 0) > t_r0
    cr = consistent_recover(store, db, cfg)
    assert cr.get_vertex("node", 1) is not None


def test_wave_frontier_tracks_durable_waves_only():
    """The WAL frontier (``wave_frontier``) obeys the same discipline as
    ``t_R``: it advances only past wave records the store actually holds
    — a failover reading the WAL tail must never skip an undurable wave."""
    from repro.core.faults import FaultInjector
    store = ObjectStore()
    log = ReplicationLog(store, ship_waves=True)
    log.faults = FaultInjector(1).inject(
        "replication.ship.drop", action="race", times=(0,))
    rec = {"seq": 1, "ts": 5, "epoch": 1,
           "txns": [{"rid": "r1", "create_v": [[0, 0, 0, [1.0], [0]]],
                     "update_v": [], "delete_v": [],
                     "create_e": [], "delete_e": []}]}
    log.append_wave(rec)                          # ship round dropped
    assert store.get_meta("g.wave_frontier", 0) == 0
    assert not store.scan("g.waves")
    log.sweep()
    assert store.get_meta("g.wave_frontier", 0) == 1
    assert len(store.scan("g.waves")) == 1


def test_sweep_fenced_by_durable_epoch():
    """A deposed primary's log (epoch older than the store's durable
    ``{g}.epoch`` meta) can never reach durable state: the sweep raises
    ``Fenced`` before shipping a byte, and the watermarks stay put."""
    from repro.core.replication import Fenced
    db, log, store, cfg = make_db()
    db.create_vertex("node", 0, {"w": 1.0})
    t_r0 = store.get_meta("g.t_R", 0)
    rows0 = len(store.scan("g.vertices"))
    log.epoch = 1
    store.put_meta("g.epoch", 2)                  # failover fenced epoch 2
    db.create_vertex("node", 1, {"w": 2.0})       # fence blocks the ship
    assert log.lag() > 0
    with pytest.raises(Fenced):
        log.sweep()
    assert store.get_meta("g.t_R", 0) == t_r0
    assert len(store.scan("g.vertices")) == rows0  # nothing leaked past it


# ---------------------------------------------------------------------------
# hypothesis sweep: random interleavings x random cut points
# ---------------------------------------------------------------------------

try:        # the deterministic sweeps above run without hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:                                        # pragma: no cover
    st = None

if st is not None:
    ops_strategy = st.lists(
        st.one_of(
            st.tuples(st.just("create"), st.sampled_from(KEYS),
                      st.floats(0, 10, allow_nan=False)),
            st.tuples(st.just("update"), st.sampled_from(KEYS),
                      st.floats(0, 10, allow_nan=False)),
            st.tuples(st.just("delete"), st.sampled_from(KEYS),
                      st.just(0.0)),
            st.tuples(st.just("edge"), st.sampled_from(KEYS),
                      st.sampled_from(KEYS)),
        ),
        min_size=1, max_size=20)

    @settings(max_examples=12, deadline=None)
    @given(ops=ops_strategy, cut_after=st.integers(0, 80),
           resume=st.booleans())
    def test_chaos_recovery_property(ops, cut_after, resume):
        check_invariants(ops, cut_after, resume)
